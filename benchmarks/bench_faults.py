"""Fault-injection sweep — the fault-tolerance contract, measured.

For every collective op x fault class x rank count, replay the op's pinned
schedule in the numpy simulator under a seeded :class:`~repro.comm.FaultSpec`
and record which side of the correctness contract the replay landed on:

  * ``bit_identical`` — the faulty replay matched the fault-free oracle
    exactly (slow links, stalled rounds, and in-budget transient drops only
    stretch the clock; values are untouched). The entry records the
    baseline vs degraded simulator clock.
  * ``typed_error`` — a named FaultError subclass fired (dead rank, drop
    streak past the retry budget). Dead-rank entries additionally carry the
    degraded replan built by ``plan_cached`` under a :class:`MeshHealth`
    report: the shrunk mesh size, the re-priced prediction, and the
    survivor-mesh wire bytes that ``comm.tables.load_fault_table``
    re-derives from the closed-form accounting.

There is no third outcome — a silent wrong answer makes the sweep raise,
so it can never be committed as an artifact. Everything here is host-side
numpy (the same simulator the schedule property tests use); algorithms are
pinned per op to non-composite choices so wire-byte accounting is exact.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.comm import (
    DeadRankError,
    FaultSpec,
    MeshHealth,
    load_fault_table,
    plan_cached,
)
from repro.core.simulator import simulate_collective

SEED = 0
ROW = 1024          # bytes per ragged row
M_UNIFORM = 1 << 16
DEAD = 1            # the injected dead rank (never the root)

# non-composite algo per op: reduce_then_bcast has no single-phase
# closed-form wire accounting (expected_wire_bytes raises on it by design)
ALGOS = {
    "bcast": "pipelined_chain",
    "reduce": "pipelined_reduce_chain",
    "allreduce": "ring_allreduce",
    "allgather": "ring_allgather",
    "reduce_scatter": "ring_reduce_scatter",
    "allgatherv": "ring_allgatherv",
    "alltoallv": "pairwise_alltoallv",
}


def _sizes(op, n, rng):
    if op == "allgatherv":
        return tuple(int(rng.integers(1, 5)) for _ in range(n))
    if op == "alltoallv":
        return tuple(int(rng.integers(1, 4)) for _ in range(n * n))
    return None


def _data(plan, rng):
    """Per-rank input arrays, same conventions as tests/test_comm_plans.py:
    uniform ops get dense (num_chunks, 3) payloads; ragged ops get the
    global row frame with only their own rows valid."""
    sched = plan.schedule
    n = sched.n
    if plan.op in ("allgatherv", "alltoallv"):
        sz = np.asarray(plan.sizes, dtype=np.int64)
        full = rng.standard_normal((sched.num_chunks, 3))
        owner = (
            np.repeat(np.arange(n), sz)
            if plan.op == "allgatherv"
            else np.repeat(np.arange(n * n) // n, sz)
        )
        return [np.where((owner == r)[:, None], full, 0.0) for r in range(n)]
    return [rng.standard_normal((sched.num_chunks, 3)) for _ in range(n)]


def _bit_identical(plan, spec, rng):
    """Replay plan's schedule with and without the fault; return (matches
    oracle exactly, report). Raises the spec's typed error if it fires."""
    data = _data(plan, rng)
    oracle = simulate_collective(plan.schedule, [d.copy() for d in data])
    report = {}
    faulty = simulate_collective(
        plan.schedule, [d.copy() for d in data], faults=spec, report=report
    )
    same = all(np.array_equal(a, b) for a, b in zip(oracle, faulty))
    return same, report


def _clock_us(plan, spec=None):
    return plan.timed_rounds_s(faults=spec) * 1e6


def _replan_entry(op, M, n, algo, sizes, health):
    """Degraded replan through plan_cached — and proof it is NOT the
    pre-fault plan (the cache keys on the health fingerprint)."""
    healthy = plan_cached(op, M, n, algo=algo, sizes=sizes)
    degraded = plan_cached(op, M, n, algo=algo, sizes=sizes, health=health)
    assert degraded is not healthy, "plan_cached served a pre-fault-mesh plan"
    assert degraded.n == n - len(health.dead_ranks), degraded.n
    assert degraded.survivors == health.survivors()
    rep = {
        "n": degraded.n,
        "algo": degraded.algo,
        "num_chunks": degraded.num_chunks,
        "M": degraded.M,
        "wire_bytes": degraded.wire_bytes(),
        "predicted_us": degraded.predicted_s * 1e6,
        "survivors": list(degraded.survivors),
    }
    if degraded.sizes is not None:
        rep["sizes"] = list(degraded.sizes)
    return rep


def sweep(ns, *, dryrun: bool = False) -> dict:
    table = {}
    for n in ns:
        for oi, (op, algo) in enumerate(ALGOS.items()):
            # stable stream per (n, op) — str hash is salted per process and
            # would make the committed ragged sizes irreproducible
            rng = np.random.default_rng((SEED, n, oi))
            sizes = _sizes(op, n, rng)
            M = M_UNIFORM if sizes is None else ROW * sum(sizes)
            plan = plan_cached(op, M, n, algo=algo, sizes=sizes)
            base_us = _clock_us(plan)
            common = {"algo": plan.algo, "seed": SEED}

            # slow link / stalled round: clock-only faults
            for fault, spec in (
                ("slow_link", FaultSpec(seed=SEED, link_slowdown=(((0, 1), 4.0),))),
                ("stalled_round", FaultSpec(seed=SEED, stalled_rounds=(0,), stall_s=5e-3)),
            ):
                same, _ = _bit_identical(plan, spec, rng)
                assert same, f"{op}/{fault}/n{n}: faulty replay diverged from oracle"
                faulty_us = _clock_us(plan, spec)
                assert faulty_us >= base_us, (op, fault, n)
                table[f"{op}/{fault}/n{n}"] = {
                    **common,
                    "outcome": "bit_identical",
                    "baseline_us": base_us,
                    "faulty_us": faulty_us,
                    "fault": "0->1 at 4x" if fault == "slow_link" else "round 0 +5ms",
                }

            # transient drops: retransmits inside the round, values identical
            spec = FaultSpec(seed=SEED, drop_prob=0.25, max_drop_retries=8)
            same, report = _bit_identical(plan, spec, rng)
            assert same, f"{op}/transient_drop/n{n}: retransmit changed values"
            table[f"{op}/transient_drop/n{n}"] = {
                **common,
                "outcome": "bit_identical",
                "baseline_us": base_us,
                "faulty_us": _clock_us(plan, spec),
                "retries": int(report["retries"]),
                "fault": "drop_prob=0.25, budget 8",
            }

            # dead rank: typed error + degraded replan on the survivors
            spec = FaultSpec(seed=SEED, dead_ranks=(DEAD,))
            try:
                _bit_identical(plan, spec, rng)
            except DeadRankError:
                pass
            else:
                raise AssertionError(
                    f"{op}/dead_rank/n{n}: schedule replayed through a dead rank"
                )
            health = MeshHealth(n=n, dead_ranks=(DEAD,))
            table[f"{op}/dead_rank/n{n}"] = {
                **common,
                "outcome": "typed_error",
                "error": "DeadRankError",
                "dead_rank": DEAD,
                "replanned": _replan_entry(op, M, n, algo, sizes, health),
            }
    if dryrun:
        for entry in table.values():
            entry["dryrun"] = True
    return table


def rows(quick: bool = False, dryrun: bool = False):
    ns = [4] if (quick or dryrun) else [4, 8]
    table = sweep(ns, dryrun=dryrun)
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/fault_table.json", "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
    table = load_fault_table("experiments/fault_table.json")  # schema gate
    out = []
    for key, e in sorted(table.items()):
        derived = {"outcome": e["outcome"], "algo": e["algo"]}
        if e["outcome"] == "bit_identical":
            derived["slowdown"] = (
                e["faulty_us"] / e["baseline_us"] if e["baseline_us"] else 1.0
            )
            if "retries" in e:
                derived["retries"] = e["retries"]
        else:
            derived["error"] = e["error"]
            if "replanned" in e:
                derived["replanned_n"] = e["replanned"]["n"]
                derived["replanned_us"] = e["replanned"]["predicted_us"]
        if e.get("dryrun"):
            derived["dryrun"] = True
        out.append(
            {
                "name": f"faults/{key}",
                "us_per_call": e.get("faulty_us", 0.0),
                "derived": derived,
            }
        )
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in rows(quick=not args.full, dryrun=args.dryrun):
        print(r["name"], f"{r['us_per_call']:.1f}", json.dumps(r["derived"]))
