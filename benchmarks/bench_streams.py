"""Multi-stream link-scheduler benchmark — arbitrated vs naive serialization.

For each contention scenario this suite plans a :class:`~repro.comm.StreamGraph`
(the SAME ``plan_streams`` path the trainer's prefetch stream and the serve
engine's distribution graph resolve through), replays it in the round-accurate
contention simulator (``comm.simulate_streams``), and records the arbitrated
span against naive serialization of the same entries — plus the two scheduler
properties (fairness within the graph's bound, no idle-while-ready rounds) in
checkable form. Rows land in the schema-gated
``experiments/streams_table.json`` (``comm.tables.load_streams_table``), whose
loader RE-CHECKS multi <= naive, requires a strict win for independently
contending streams at n >= 4, and rebuilds every 1-stream entry through the
PR 4 overlap engine round-for-round (the backward-compat contract).

Scenarios:

* ``sync_prefetch`` — the trainer's steady state: gradient sync (allreduce,
  priority 1, backward dispatch order, hidden-compute gaps) contends with the
  previous step's weight prefetch (bcast, priority 0) for the same ICI link.
  The entries are independent — in the pipelined regime the prefetch of step
  t-1 overlaps the grad sync of step t — so the arbiter fills sync's
  compute-gated link gaps with prefetch buckets: the strict-win witness.
* ``distribute_drain`` — the serve engine's start-up: checkpoint drain on the
  host link concurrent with tuned weight distribution on ICI. Different
  links never contend, so arbitration runs them concurrently while naive
  serialization chains them — the cross-link strict win.
* ``overlap_<mix>`` — 1-stream parity rows at the overlap-bench bucket
  mixes: the loader rebuilds each through ``plan_overlap``/``simulate_overlap``
  and requires identical round counts (a drifted refactor fails the gate).

``dryrun=True`` brands the table (simulator numbers only); the non-dryrun
mode additionally measures interleaved vs sequential execution of the
``sync_prefetch`` graph on simulated host devices.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

import jax

from repro.comm.streams import (
    StreamGraph,
    StreamSpec,
    plan_streams,
    simulate_streams,
)
from repro.comm.tables import load_streams_table
from repro.core.tuner import Tuner

from .common import run_worker

RANKS = [4, 8]
BUCKET_BYTES = 64 << 10
# the overlap-bench bucket mixes (paper Sec. V-D spectrum) — reused so the
# 1-stream parity rows cover the same points the overlap table does
MIXES = [
    ("uniform8", [4096] * 8),
    ("mixed", [65536, 65536, 4096, 4096, 512, 512, 64, 64]),
    ("two_big", [262144, 262144]),
]
GRAD_LEAVES = MIXES[1][1]
WEIGHT_LEAVES = [32768, 32768, 8192, 8192, 1024, 1024]
SYNC_COMPUTE_S = 1e-3

MEASURE_STREAMS = """
import time, json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm.streams import StreamSpec, plan_streams, execute_streams, execute_stream_entry
from repro.core.tuner import Tuner

def measure(n, gleaves, pleaves, interleaved, reps=5):
    mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    trees = {
        "grad_sync": {f"g{i}": jnp.asarray(rng.randn(n, e).astype(np.float32))
                      for i, e in enumerate(gleaves)},
        "weight_prefetch": {f"w{i}": jnp.asarray(rng.randn(n, e).astype(np.float32))
                            for i, e in enumerate(pleaves)},
    }
    graph = plan_streams([
        StreamSpec(name="grad_sync",
                   tree=jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                                     trees["grad_sync"]),
                   axes=(("data", n),), op="allreduce", priority=1,
                   compute_s=%r, bucket_bytes=%d, reverse=True),
        StreamSpec(name="weight_prefetch",
                   tree=jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                                     trees["weight_prefetch"]),
                   axes=(("data", n),), op="bcast", priority=0,
                   bucket_bytes=%d, reverse=False),
    ], tuner=Tuner())
    specs = jax.tree.map(lambda _: P("data"), trees)
    def g(t):
        sub = jax.tree.map(lambda x: x[0], t)
        if interleaved:
            out = execute_streams(graph, sub)
        else:
            out = {name: execute_stream_entry(graph.entry(name), tree)
                   for name, tree in sub.items()}
        return jax.tree.map(lambda x: x[None], out)
    f = jax.jit(lambda t: jax.shard_map(g, mesh=mesh, in_specs=(specs,),
                                        out_specs=specs, check_vma=False)(t))
    jax.block_until_ready(f(trees))   # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter(); jax.block_until_ready(f(trees))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
""" % (SYNC_COMPUTE_S, BUCKET_BYTES, BUCKET_BYTES)


def _tree(leaves):
    return {
        f"l{i}": jax.ShapeDtypeStruct((e,), np.float32)
        for i, e in enumerate(leaves)
    }


def _graph_sync_prefetch(n: int, tuner: Tuner) -> tuple[StreamGraph, dict]:
    graph = plan_streams(
        [
            StreamSpec(
                name="grad_sync", tree=_tree(GRAD_LEAVES), axes=(("data", n),),
                op="allreduce", priority=1, compute_s=SYNC_COMPUTE_S,
                bucket_bytes=BUCKET_BYTES, reverse=True,
            ),
            StreamSpec(
                name="weight_prefetch", tree=_tree(GRAD_LEAVES),
                axes=(("data", n),), op="bcast", priority=0,
                bucket_bytes=BUCKET_BYTES, reverse=False,
            ),
        ],
        tuner=tuner,
    )
    meta = {
        "grad_sync": {"leaves": GRAD_LEAVES, "compute_s": SYNC_COMPUTE_S,
                      "reverse": True},
        "weight_prefetch": {"leaves": GRAD_LEAVES, "compute_s": 0.0,
                            "reverse": False},
    }
    return graph, meta


def _graph_distribute_drain(n: int, tuner: Tuner) -> tuple[StreamGraph, dict]:
    g = plan_streams(
        [
            StreamSpec(
                name="distribute", tree=_tree(WEIGHT_LEAVES),
                axes=(("data", n),), op="bcast", priority=1, overlap_depth=2,
                bucket_bytes=BUCKET_BYTES, reverse=False,
            ),
        ],
        tuner=tuner,
    )
    dist = g.entries[0]
    # the host-link snapshot stream the engine's drain_dir path carries:
    # same bucket mix, no collective plans, one round per bucket on 'host'
    drain = dataclasses.replace(
        dist, name="ckpt_drain", op="drain", axes=(), plans={},
        overlap_depth=1, priority=2, link="host",
    )
    graph = StreamGraph((drain, dist), key=g.key)
    meta = {
        "ckpt_drain": {"leaves": WEIGHT_LEAVES, "compute_s": 0.0,
                       "reverse": False},
        "distribute": {"leaves": WEIGHT_LEAVES, "compute_s": 0.0,
                       "reverse": False},
    }
    return graph, meta


def _graph_single(n: int, leaves, tuner: Tuner) -> tuple[StreamGraph, dict]:
    graph = plan_streams(
        [
            StreamSpec(
                name="overlap", tree=_tree(leaves), axes=(("data", n),),
                op="allreduce", priority=0, compute_s=SYNC_COMPUTE_S,
                bucket_bytes=BUCKET_BYTES, reverse=True,
            ),
        ],
        tuner=tuner,
    )
    meta = {"overlap": {"leaves": leaves, "compute_s": SYNC_COMPUTE_S,
                        "reverse": True}}
    return graph, meta


def _entry_for_table(graph: StreamGraph, sim: dict, meta: dict,
                     dryrun: bool) -> dict:
    rows = []
    for e in graph.entries:
        s = sim["streams"][e.name]
        m = meta[e.name]
        rows.append({
            "name": e.name,
            "op": e.op,
            "algo": "auto",
            "priority": e.priority,
            "depth": e.overlap_depth,
            "depth_source": e.depth_source,
            "link": e.link,
            "after": list(e.after),
            "comm_rounds": s["comm_rounds"],
            "stage_rounds": s["stage_rounds"],
            "finish_round": s["finish_round"],
            "naive_finish_round": s["naive_finish_round"],
            "wait_rounds": s["wait_rounds"],
            "idle_rounds": s["idle_rounds"],
            "wire_bytes": s["wire_bytes"],
            "leaves": list(m["leaves"]),
            "bucket_bytes": BUCKET_BYTES,
            "compute_s": m["compute_s"],
            "reverse": bool(m["reverse"]),
        })
    entry = {
        "streams": rows,
        "starvation_bound": sim["starvation_bound"],
        "fairness_bound": sim["fairness_bound"],
        "multi_span_rounds": sim["multi_span_rounds"],
        "naive_span_rounds": sim["naive_span_rounds"],
        "max_skips": sim["max_skips"],
        "idle_while_ready_rounds": sim["idle_while_ready_rounds"],
        "mean_round_us": sim["mean_round_s"] * 1e6,
        "wire_bytes": sim["wire_bytes"],
    }
    if dryrun:
        entry["dryrun"] = True
    return entry


def rows(quick: bool = False, dryrun: bool = False):
    ranks = RANKS[:1] if quick else RANKS
    mixes = MIXES[:2] if quick else MIXES
    scenarios = []
    for n in ranks:
        scenarios.append((f"sync_prefetch/n{n}", *_graph_sync_prefetch(n, Tuner())))
        scenarios.append((f"distribute_drain/n{n}",
                          *_graph_distribute_drain(n, Tuner())))
        for mix_name, leaves in mixes:
            scenarios.append((f"overlap_{mix_name}/n{n}",
                              *_graph_single(n, leaves, Tuner())))
    table = {}
    out = []
    for key, graph, meta in scenarios:
        sim = simulate_streams(graph)
        table[key] = _entry_for_table(graph, sim, meta, dryrun)
        derived = {
            "num_streams": sim["num_streams"],
            "naive_span_rounds": sim["naive_span_rounds"],
            "span_speedup": sim["naive_span_rounds"]
            / max(sim["multi_span_rounds"], 1),
            "max_skips": sim["max_skips"],
            "fairness_bound": sim["fairness_bound"],
            "idle_while_ready_rounds": sim["idle_while_ready_rounds"],
            "wire_bytes": sim["wire_bytes"],
            "links": sim["links"],
            "fingerprint": graph.fingerprint(),
        }
        if not dryrun and key.startswith("sync_prefetch/"):
            n = int(key.rsplit("/n", 1)[1])
            worker = MEASURE_STREAMS + f"""
res = {{"interleaved": measure({n}, {GRAD_LEAVES!r}, {GRAD_LEAVES!r}, True),
       "sequential": measure({n}, {GRAD_LEAVES!r}, {GRAD_LEAVES!r}, False)}}
print(json.dumps(res))
"""
            res = run_worker(worker, devices=n)
            derived["measured_interleaved_us"] = res["interleaved"] * 1e6
            derived["measured_sequential_us"] = res["sequential"] * 1e6
        out.append({
            "name": f"streams/{key}",
            "us_per_call": sim["multi_span_rounds"] * sim["mean_round_s"] * 1e6,
            "derived": derived,
        })
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/streams_table.json", "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
    load_streams_table("experiments/streams_table.json")  # schema gate at source
    return out


if __name__ == "__main__":
    for r in rows(quick=True, dryrun=True):
        print(r["name"], r["us_per_call"], json.dumps(r["derived"]))
