"""Benchmark infrastructure: subprocess workers with N simulated devices.

Benchmarks print ``name,us_per_call,derived`` CSV rows (one per paper
table/figure entry). Measured numbers are CPU-host timings of the REAL
shard_map collectives (relative behaviour); 'derived' carries the analytic
TPU-v5e prediction from the paper's cost models so both views are recorded.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class WorkerTimeoutError(RuntimeError):
    """A bench subprocess exceeded its wall-clock budget on every attempt.

    Raised instead of the raw ``subprocess.TimeoutExpired`` so suites can
    catch it and record the point as timed out (``derived.timeout=true``)
    rather than dropping it silently or crashing the whole sweep."""


def run_worker(code: str, devices: int, timeout: int = 560, retries: int = 0) -> dict:
    pre = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        f"import sys; sys.path.insert(0, {SRC!r})\n"
    )
    last = None
    for attempt in range(retries + 1):
        try:
            proc = subprocess.run(
                [sys.executable, "-c", pre + code],
                capture_output=True,
                text=True,
                timeout=timeout,
            )
        except subprocess.TimeoutExpired as e:
            # a hung worker gets one more honest shot (transient host load);
            # a reproducible hang surfaces as the typed error below
            last = e
            continue
        if proc.returncode != 0:
            raise RuntimeError(f"bench worker failed:\n{proc.stderr[-3000:]}")
        # last line is the JSON payload
        return json.loads(proc.stdout.strip().splitlines()[-1])
    raise WorkerTimeoutError(
        f"bench worker timed out after {timeout}s on {retries + 1} attempt(s) "
        f"(devices={devices})"
    ) from last


MEASURE_SNIPPET = """
import time, json
import numpy as np, jax, jax.numpy as jnp
from repro.core import bcast_stacked

def measure(algo, M, n, reps=5):
    elems = max(M // 4, 1)
    mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    xs = jnp.asarray(np.random.RandomState(0).randn(n, elems).astype(np.float32))
    def run():
        return bcast_stacked(xs, mesh, "data", root=0, algo=algo)
    out = run(); out.block_until_ready()   # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter(); run().block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
"""
