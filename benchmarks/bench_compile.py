"""Compile-cost benchmark — unrolled vs compiled executor program size.

The compiled schedule executor's claim is structural, so this suite measures
it rather than asserting it: for points across the tuner grid it traces and
lowers the SAME :class:`~repro.comm.CollectivePlan` through both executors
(``comm.executors.execute_collective`` unrolled vs ``execute_compiled``
fori_loop) and records jaxpr equation counts, HLO instruction counts, and
trace+lower wall time. Rows land in the schema-gated
``experiments/compile_table.json`` (``comm.tables.load_compile_table``);
:func:`repro.comm.tables.check_compile_flatness` is the CI compile-size
regression gate — the compiled executor's HLO instruction count must be
FLAT in ``num_chunks`` while the unrolled one grows monotonically.

Counts and lower times are host-side quantities (nothing executes), so
``--dryrun`` runs the same measurement on a smaller grid; entries are
branded ``dryrun`` all the same so downstream consumers know which grid
produced them.
"""
from __future__ import annotations

import json
import os

from repro.comm.tables import check_compile_flatness, load_compile_table

from .common import WorkerTimeoutError, run_worker

RANKS = [8, 16]
# (op, algo, M, num_chunks sweep) — chain-family points sweep the chunk
# count (the HLO-growth axis); ring-family points pin K == n by design
POINTS = [
    ("bcast", "pipelined_chain", 1 << 22, (4, 16, 64)),
    ("bcast", "bidir_chain", 1 << 22, (4, 16, 64)),
    ("allreduce", "fused_rsb", 1 << 22, (4, 16, 64)),
    ("allreduce", "ring_allreduce", 1 << 22, (None,)),
    ("allgather", "ring_allgather", 1 << 22, (None,)),
    ("reduce_scatter", "ring_reduce_scatter", 1 << 22, (None,)),
]

WORKER = """
import json, time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import plan_collective, apply_plan


def _sub_jaxprs(v):
    import jax.core as jc
    if isinstance(v, jc.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jc.Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _sub_jaxprs(x)


def eqn_count(jaxpr):
    total = len(jaxpr.eqns)
    for eq in jaxpr.eqns:
        for v in eq.params.values():
            for sub in _sub_jaxprs(v):
                total += eqn_count(sub)
    return total


def hlo_count(text):
    return sum(1 for line in text.splitlines() if " = " in line)


def bench(n, points):
    mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    table = {}
    for op, algo, M, K in points:
        kw = {} if K is None else {"num_chunks": K}
        plan = plan_collective(op, M, n, algo=algo, **kw)
        lowered_sched = plan.lowered()
        elems = max(M // 4, 1)
        shape = (elems // n,) if op == "allgather" else (elems,)
        sds = jax.ShapeDtypeStruct(shape, jnp.float32)
        entry = {
            "M": M,
            "num_rounds": max(lowered_sched.num_rounds, 1),
            "lane_classes": max(lowered_sched.num_classes, 1),
        }
        for mode, flag in (("unrolled", False), ("compiled", True)):
            def g(b, flag=flag):
                return apply_plan(plan, b, "data", compiled=flag)
            f = jax.shard_map(g, mesh=mesh, in_specs=(P(),), out_specs=P(),
                              check_vma=False)
            entry[f"{mode}_jaxpr_eqns"] = max(
                eqn_count(jax.make_jaxpr(f)(sds).jaxpr), 1
            )
            t0 = time.perf_counter()
            low = jax.jit(f).lower(sds)
            entry[f"{mode}_lower_s"] = time.perf_counter() - t0
            entry[f"{mode}_hlo"] = max(hlo_count(low.as_text()), 1)
        table[f"n{n}/{op}/{algo}/K{plan.num_chunks}"] = entry
    return table
"""


def _point_worker(n, pt):
    return WORKER + f"""
print(json.dumps(bench({n}, {[pt]!r})))
"""


def rows(quick: bool = False, dryrun: bool = False, timeout: int = 560):
    ranks = RANKS[:1] if (quick or dryrun) else RANKS
    points = [
        (op, algo, M, ks[:2] if dryrun else ks) for op, algo, M, ks in POINTS
    ]
    table = {}
    timed_out = []
    for n in ranks:
        flat_points = [
            (op, algo, M, k) for op, algo, M, ks in points for k in ks
        ]
        worker = WORKER + f"""
print(json.dumps(bench({n}, {flat_points!r})))
"""
        try:
            table.update(run_worker(worker, devices=n, timeout=timeout, retries=1))
        except WorkerTimeoutError:
            # the whole-rank batch hung twice: re-run one worker PER POINT so
            # a single pathological point can't take the rest of the sweep
            # down with it — each point still gets the single retry
            for pt in flat_points:
                try:
                    table.update(
                        run_worker(
                            _point_worker(n, pt), devices=n,
                            timeout=timeout, retries=1,
                        )
                    )
                except WorkerTimeoutError:
                    op, algo, M, k = pt
                    timed_out.append((f"n{n}/{op}/{algo}/K{k or n}", M))
    if dryrun:
        for entry in table.values():
            entry["dryrun"] = True
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/compile_table.json", "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
    table = load_compile_table("experiments/compile_table.json")  # schema gate
    check_compile_flatness(table)  # compile-size regression gate at source
    # timed-out points are recorded as explicit bench rows (derived.timeout),
    # NOT written into the schema-gated table — the gates only see measured
    # entries, and downstream consumers can see exactly which points are gone
    out = [
        {
            "name": f"compile/{key}",
            "us_per_call": float("nan"),
            "derived": {"timeout": True, "M": M},
        }
        for key, M in timed_out
    ]
    for key, e in sorted(table.items()):
        out.append(
            {
                "name": f"compile/{key}",
                "us_per_call": e["compiled_lower_s"] * 1e6,
                "derived": {
                    "unrolled_hlo": e["unrolled_hlo"],
                    "compiled_hlo": e["compiled_hlo"],
                    "unrolled_jaxpr_eqns": e["unrolled_jaxpr_eqns"],
                    "compiled_jaxpr_eqns": e["compiled_jaxpr_eqns"],
                    "unrolled_lower_ms": e["unrolled_lower_s"] * 1e3,
                    "compiled_lower_ms": e["compiled_lower_s"] * 1e3,
                    "num_rounds": e["num_rounds"],
                    "lane_classes": e["lane_classes"],
                },
            }
        )
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in rows(quick=not args.full, dryrun=args.dryrun):
        print(r["name"], f"{r['us_per_call']:.1f}", json.dumps(r["derived"]))
