"""Compressed-wire collective benchmark — bytes-vs-precision sweep.

For points across (op, algo, n, M), runs the SAME
:class:`~repro.comm.CollectivePlan` under each wire format
('bf16' passthrough, 'fp8', 'int8'), recording the plan-layer wire-byte
accounting, the achieved reduction ratio, the measured wall-clock, and the
worst observed element error vs the full-precision result. Rows land in the
schema-gated ``experiments/compress_table.json``
(``comm.tables.load_compress_table``), whose loader IS the regression gate:
wire bytes exactly equal to the closed form
(``comm.plan.expected_wire_bytes``), reduction ratio within tolerance of the
format's nominal 4x (and never above it), and at each group's largest M the
compressed wall-clock no worse than the bf16 passthrough.

``--dryrun`` replaces the device worker with the analytic
``cost_model.cost_wire`` clock (which prices the bandwidth saving against
the quantize HBM toll) at the same points — the wire-byte columns are
host-side plan accounting either way, so the exact-equality gates bite in
CI too. Entries are branded ``dryrun`` so downstream consumers know which
clock produced them.
"""
from __future__ import annotations

import json
import math
import os

from repro.comm.compress import normalize_wire_format
from repro.comm.plan import expected_wire_bytes, plan_cached
from repro.comm.tables import load_compress_table
from repro.core import cost_model as cm

from .common import WorkerTimeoutError, run_worker

FORMATS = ["bf16", "fp8", "int8"]
# (op, algo) groups; ring-family chunk counts pin K == n by design, the
# chain/fused points take the plan's tuned chunking
GROUPS = [
    ("allreduce", "ring_allreduce"),
    ("bcast", "pipelined_chain"),
    ("allgather", "ring_allgather"),
]
SIZES = [1 << 16, 1 << 20, 8 << 20]

WORKER = """
import json, time
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import apply_plan, plan_cached

n = %d
mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))


def bench(points, reps=3):
    res = {}
    for op, algo, M, fmt in points:
        plan = plan_cached(op, M, n, algo=algo, wire_format=fmt)
        elems = max(M // 4, 1)
        shape = (elems // n,) if op == "allgather" else (elems,)
        xs = jnp.asarray(
            np.random.RandomState(0).randn(n, *shape).astype(np.float32))

        def g(b, plan=plan):
            out = apply_plan(plan, b[0], "data")
            return out[None] if out.ndim == len(shape) else out

        f = jax.jit(jax.shard_map(
            g, mesh=mesh, in_specs=(P("data"),),
            out_specs=P("data") if op != "allgather" else P("data", None),
            check_vma=False))
        out = f(xs); out.block_until_ready()   # compile
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter(); f(xs).block_until_ready()
            ts.append(time.perf_counter() - t0)
        key = "%%s/%%s/%%s/M%%d" %% (op, algo, fmt, M)
        res[key] = {"wall_s": float(np.median(ts)),
                    "wire_bytes": plan.wire_bytes(),
                    "num_chunks": plan.num_chunks}
    return res
"""


def _dryrun_clock(op: str, algo: str, M: int, n: int, num_chunks: int,
                  fmt: str) -> float:
    """Analytic stand-in for the worker wall-clock: the closed-form cost
    under the wire format (bandwidth shrinks by the payload fraction,
    compressed hops pay the quantize HBM toll) — the same pricing the
    OnlineTuner explores with."""
    kw = {}
    if algo in ("pipelined_chain", "bidir_chain", "pipelined_reduce_chain",
                "fused_rsb"):
        kw["C"] = float(math.ceil(M / max(1, num_chunks)))
    return cm.cost_wire(algo, M, n, wire_format=fmt, **kw)


def rows(quick: bool = False, dryrun: bool = False, timeout: int = 560):
    n = 4
    sizes = SIZES[:2] if (quick or dryrun) else SIZES
    points = [(op, algo, M, fmt)
              for op, algo in GROUPS for M in sizes for fmt in FORMATS]
    timed_out = []
    if dryrun:
        measured = {}
        for op, algo, M, fmt in points:
            plan = plan_cached(op, M, n, algo=algo, wire_format=fmt)
            measured[f"{op}/{algo}/{fmt}/M{M}"] = {
                "wall_s": _dryrun_clock(op, algo, M, n, plan.num_chunks, fmt),
                "wire_bytes": plan.wire_bytes(),
                "num_chunks": plan.num_chunks,
            }
    else:
        worker = WORKER % n + f"""
print(json.dumps(bench({points!r})))
"""
        try:
            measured = run_worker(worker, devices=n, timeout=timeout, retries=1)
        except WorkerTimeoutError:
            # re-run one worker per point so a single pathological point
            # can't take the rest of the sweep down with it
            measured = {}
            for pt in points:
                try:
                    measured.update(run_worker(
                        WORKER % n + f"\nprint(json.dumps(bench({[pt]!r})))\n",
                        devices=n, timeout=timeout, retries=1))
                except WorkerTimeoutError:
                    op, algo, M, fmt = pt
                    timed_out.append((f"{op}/n{n}/{algo}/{fmt}/M{M}", M))

    table = {}
    for key, m in measured.items():
        op, algo, fmt, M_str = key.split("/")
        M = int(M_str[1:])
        k = m["num_chunks"]
        full = int(expected_wire_bytes(op, algo, M, n, num_chunks=k))
        wire = int(expected_wire_bytes(op, algo, M, n, num_chunks=k,
                                       wire_format=fmt))
        entry = {
            "wire_bytes": m["wire_bytes"],
            "expected_wire_bytes": wire,
            "full_wire_bytes": full,
            "ratio": full / m["wire_bytes"],
            "num_chunks": k,
            "wall_s": m["wall_s"],
            "predicted_us": _dryrun_clock(op, algo, M, n, k, fmt) * 1e6,
        }
        if dryrun:
            entry["dryrun"] = True
        table[f"{op}/n{n}/{algo}/{fmt}/M{M}"] = entry
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/compress_table.json", "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
    # the loader IS the gate: exact closed-form wire bytes, nominal-ratio
    # reduction, compressed no slower than bf16 at each group's largest M —
    # reject the artifact at the source
    table = load_compress_table("experiments/compress_table.json")
    out = [
        {
            "name": f"compress/{key}",
            "us_per_call": float("nan"),
            "derived": {"timeout": True, "M": M},
        }
        for key, M in timed_out
    ]
    for key, e in sorted(table.items()):
        fmt = key.split("/")[3]
        out.append(
            {
                "name": f"compress/{key}",
                "us_per_call": e["wall_s"] * 1e6,
                "derived": {
                    "wire_bytes": e["wire_bytes"],
                    "full_wire_bytes": e["full_wire_bytes"],
                    "ratio": round(e["ratio"], 4),
                    "nominal_ratio": normalize_wire_format(fmt).nominal_ratio,
                    "num_chunks": e["num_chunks"],
                    "model_us": e["predicted_us"],
                },
            }
        )
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in rows(quick=not args.full, dryrun=args.dryrun):
        print(r["name"], f"{r['us_per_call']:.1f}", json.dumps(r["derived"]))
