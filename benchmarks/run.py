"""Benchmark harness — one module per paper table/figure.

  fig1: intra-pod bcast latency, tuned vs one-shot      (paper Fig. 1)
  fig2: inter-pod hierarchical bcast, 64/128 ranks      (paper Fig. 2)
  fig3: VGG/CNTK application-level data-parallel sync   (paper Fig. 3)
  tuner: the tuning-framework crossover table           (paper Sec. IV-B)
  allreduce: gradient-sync strategies + per-op empirical table (repro.comm)
  overlap: bucket-streamed sync, planned vs simulated   (comm.overlap)
  compile: unrolled-vs-compiled executor program size   (comm.executors)
  inkernel: persistent single-launch executor replay    (comm.executors)
  ragged: allgatherv/alltoallv skew-regime sweep        (comm ragged ops)
  faults: fault-injection contract sweep                (comm.faults)
  streams: multi-stream link scheduler, arbitrated vs naive (comm.streams)
  compress: compressed-wire formats, bytes vs wall-clock   (comm.compress)

Prints ``name,us_per_call,derived`` CSV; also writes experiments/bench.json
(and the tuner/allreduce suites their experiments/*_table.json artifacts —
all schema-validated by ``repro.comm.tables`` at write time).
Pass --full for the complete sweep (slower); --dryrun replaces device-worker
measurements with simulator/cost-model values at tiny sizes so CI can smoke
the whole empirical-table pipeline on CPU in seconds.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--dryrun", action="store_true",
                    help="no device workers: simulator/cost-model numbers only")
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()
    quick = not args.full

    from . import (
        bench_allreduce,
        bench_compile,
        bench_compress,
        bench_faults,
        bench_inkernel,
        bench_internode,
        bench_intranode,
        bench_overlap,
        bench_ragged,
        bench_streams,
        bench_tuner_table,
        bench_vgg_cntk,
    )

    suites = {
        "tuner": bench_tuner_table.rows,
        "allreduce": bench_allreduce.rows,
        "overlap": bench_overlap.rows,
        "compile": bench_compile.rows,
        "inkernel": bench_inkernel.rows,
        "ragged": bench_ragged.rows,
        "faults": bench_faults.rows,
        "streams": bench_streams.rows,
        "compress": bench_compress.rows,
        "fig1": bench_intranode.rows,
        "fig2": bench_internode.rows,
        "fig3": bench_vgg_cntk.rows,
    }
    all_rows = []
    failed = []
    print("name,us_per_call,derived")
    for key, fn in suites.items():
        if args.only and args.only not in key:
            continue
        try:
            for r in fn(quick=quick, dryrun=args.dryrun):
                if args.dryrun:
                    # measured columns are simulator/cost-model stand-ins;
                    # never let them read as device measurements downstream
                    r.setdefault("derived", {})["dryrun"] = True
                all_rows.append(r)
                print(f"{r['name']},{r['us_per_call']:.2f},{json.dumps(r['derived'])}")
                sys.stdout.flush()
        except Exception as e:
            failed.append((key, repr(e)))
            traceback.print_exc()
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench.json", "w") as f:
        json.dump(all_rows, f, indent=1)
    from repro.comm.tables import load_bench

    load_bench("experiments/bench.json")  # schema gate at write time
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
