"""Tuning-framework artifact — the crossover table (paper Sec. IV-B):
which algorithm + chunk count the tuner selects per (message size, ranks),
for intra- and inter-pod paths, for BOTH the broadcast op and the gradient
sync (allreduce) op. Written to experiments/tuner_table.json in the schema
``repro.comm.tables.load_tuner_table`` validates."""
from __future__ import annotations

import json
import os

from repro.comm.tables import load_tuner_table
from repro.core.tuner import Tuner


def rows(quick: bool = False, dryrun: bool = False):
    del dryrun  # this suite is analytic already — same table either way
    tuner = Tuner()
    out = []
    table = {}
    sizes = [1 << p for p in range(8, 31, 2)]
    ranks = [4, 16, 32, 256] if quick else [2, 4, 8, 16, 32, 64, 128, 256, 512]
    for inter_pod in (False, True):
        for n in ranks:
            for M in sizes:
                d = tuner.select(M, n, inter_pod=inter_pod)
                sync = tuner.select(M, n, op="allreduce", inter_pod=inter_pod)
                key = f"{'inter' if inter_pod else 'intra'}/n{n}/M{M}"
                table[key] = {
                    "algo": d.algo,
                    "num_chunks": d.num_chunks,
                    "predicted_us": d.predicted_s * 1e6,
                    "sync": sync.algo,
                    "sync_num_chunks": sync.num_chunks,
                    "sync_predicted_us": sync.predicted_s * 1e6,
                }
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/tuner_table.json", "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
    load_tuner_table("experiments/tuner_table.json")  # schema gate at source

    # summarize crossover points per rank count (intra-pod)
    for n in ranks:
        crossings, sync_crossings = [], []
        prev = sync_prev = None
        for M in sizes:
            entry = table[f"intra/n{n}/M{M}"]
            if entry["algo"] != prev:
                crossings.append(f"{entry['algo']}@{M}")
                prev = entry["algo"]
            if entry["sync"] != sync_prev:
                sync_crossings.append(f"{entry['sync']}@{M}")
                sync_prev = entry["sync"]
        out.append(
            {
                "name": f"tuner_crossover/n{n}",
                "us_per_call": table[f"intra/n{n}/M{1 << 20}"]["predicted_us"],
                "derived": {"windows": crossings, "sync_windows": sync_crossings},
            }
        )
    return out


if __name__ == "__main__":
    for r in rows(quick=True):
        print(r["name"], r["us_per_call"], json.dumps(r["derived"]))
