"""Tuning-framework artifact — the crossover table (paper Sec. IV-B):
which algorithm + chunk count the tuner selects per (message size, ranks),
for intra- and inter-pod paths. Written to experiments/tuner_table.json."""
from __future__ import annotations

import json
import os

from repro.core.tuner import Tuner


def rows(quick: bool = False):
    tuner = Tuner()
    out = []
    table = {}
    sizes = [1 << p for p in range(8, 31, 2)]
    ranks = [4, 16, 32, 256] if quick else [2, 4, 8, 16, 32, 64, 128, 256, 512]
    for inter_pod in (False, True):
        for n in ranks:
            for M in sizes:
                d = tuner.select(M, n, inter_pod=inter_pod)
                key = f"{'inter' if inter_pod else 'intra'}/n{n}/M{M}"
                table[key] = {
                    "algo": d.algo,
                    "num_chunks": d.num_chunks,
                    "predicted_us": d.predicted_s * 1e6,
                }
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/tuner_table.json", "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)

    # summarize crossover points per rank count (intra-pod)
    for n in ranks:
        crossings = []
        prev = None
        for M in sizes:
            algo = table[f"intra/n{n}/M{M}"]["algo"]
            if algo != prev:
                crossings.append(f"{algo}@{M}")
                prev = algo
        out.append(
            {
                "name": f"tuner_crossover/n{n}",
                "us_per_call": table[f"intra/n{n}/M{1 << 20}"]["predicted_us"],
                "derived": {"windows": crossings},
            }
        )
    return out


if __name__ == "__main__":
    for r in rows(quick=True):
        print(r["name"], r["us_per_call"], json.dumps(r["derived"]))
