"""Fig. 3 analogue — application-level data-parallel training.

Two parts:
  (a) VGG-16 bucket trace: CNTK "divides the communication based on the
      process count", so the per-iteration broadcast mix is every VGG
      parameter tensor, bucketed. We price that mix per rank count under
      the tuned library vs the one-shot baseline (TPU model), reproducing
      the paper's observation that the mostly-large-message VGG regime
      yields single-digit-% end-to-end gains (7% on 32 GPUs in the paper).
  (b) measured end-to-end: small-model training throughput with
      sync_mode=param_bcast vs grad_allreduce on 8 host devices.
"""
from __future__ import annotations

import json

import numpy as np

from repro.core import cost_model as cm
from repro.core.tuner import Tuner

from .common import run_worker

# VGG-16 parameter tensors (Simonyan & Zisserman 2014), conv (kh,kw,cin,cout)
# + fc layers; f32 bytes.
VGG16_SHAPES = [
    (3, 3, 3, 64), (64,), (3, 3, 64, 64), (64,),
    (3, 3, 64, 128), (128,), (3, 3, 128, 128), (128,),
    (3, 3, 128, 256), (256,), (3, 3, 256, 256), (256,), (3, 3, 256, 256), (256,),
    (3, 3, 256, 512), (512,), (3, 3, 512, 512), (512,), (3, 3, 512, 512), (512,),
    (3, 3, 512, 512), (512,), (3, 3, 512, 512), (512,), (3, 3, 512, 512), (512,),
    (25088, 4096), (4096,), (4096, 4096), (4096,), (1000, 4096), (1000,),
]


def vgg_messages(n_ranks: int) -> list[int]:
    """Per-iteration bcast message sizes: CNTK splits each tensor across the
    process count (paper Sec. V-D)."""
    return [max(int(np.prod(s)) * 4 // n_ranks, 4) for s in VGG16_SHAPES]


def trace_cost(n: int, tuner: Tuner) -> dict:
    tuned = 0.0
    oneshot = 0.0
    algos = {}
    for M in vgg_messages(n):
        dec = tuner.select(M, n)
        tuned += cm.cost(dec.algo, M, n)
        oneshot += cm.cost("nccl_ring", M, n)   # NCCL 1.x: ring regardless of M
        algos[dec.algo] = algos.get(dec.algo, 0) + 1
    return {"tuned_s": tuned, "oneshot_s": oneshot, "algos": algos}


def rows(quick: bool = False, dryrun: bool = False):
    tuner = Tuner()
    out = []
    for n in ([32] if quick else [8, 32, 64, 128]):
        c = trace_cost(n, tuner)
        comm_speedup = c["oneshot_s"] / c["tuned_s"]
        # end-to-end at a c_frac communication share (Amdahl): the paper sees
        # 7% on VGG/32 GPUs — reproduced at ~10% comm fraction.
        e2e = {
            f"e2e_gain_at_{int(f*100)}pct_comm": 1.0 / ((1 - f) + f / comm_speedup) - 1.0
            for f in (0.05, 0.10, 0.20)
        }
        out.append(
            {
                "name": f"fig3_vgg_trace/n{n}",
                "us_per_call": c["tuned_s"] * 1e6,
                "derived": {
                    "oneshot_us": c["oneshot_s"] * 1e6,
                    "comm_speedup": comm_speedup,
                    "algo_mix": c["algos"],
                    "total_bytes": sum(vgg_messages(n)),
                    **e2e,
                },
            }
        )

    if dryrun:  # CI smoke: skip the end-to-end training worker
        return out

    # measured end-to-end small-model training
    worker = """
import time, json
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.train.trainer import Trainer
from repro.launch.mesh import make_local_mesh

res = {}
for mode in ("param_bcast", "tuned_allreduce", "grad_allreduce"):
    run = RunConfig(total_steps=6, warmup_steps=1, sync_mode=mode, learning_rate=1e-3)
    tr = Trainer(get_config("xlstm-350m-smoke"), run, mesh=make_local_mesh(1))
    t0 = time.time()
    _, _, hist = tr.train(batch=8, seq=64, steps=6, log_every=6)
    res[mode] = {"total_s": time.time() - t0, "final_loss": hist[-1]["loss"]}
print(json.dumps(res))
"""
    m = run_worker(worker, devices=8)
    out.append(
        {
            "name": "fig3_train_e2e/xlstm-smoke/8dev",
            "us_per_call": m["param_bcast"]["total_s"] * 1e6 / 6,
            "derived": {
                "allreduce_us_per_step": m["grad_allreduce"]["total_s"] * 1e6 / 6,
                "tuned_allreduce_us_per_step": m["tuned_allreduce"]["total_s"] * 1e6 / 6,
                "bcast_final_loss": m["param_bcast"]["final_loss"],
                "tuned_allreduce_final_loss": m["tuned_allreduce"]["final_loss"],
                "allreduce_final_loss": m["grad_allreduce"]["final_loss"],
            },
        }
    )
    return out


if __name__ == "__main__":
    for r in rows(quick=True):
        print(r["name"], r["us_per_call"], json.dumps(r["derived"]))
