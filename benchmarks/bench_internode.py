"""Fig. 2 analogue — inter-pod (64/128 rank) broadcast: hierarchical tuned
bcast vs flat one-shot, driven through the ``repro.comm`` plan layer.

Measured on a (2, 4) pod x data mesh on host devices via ``comm.pbcast``
(the plan-layer entry point — per-level ``CollectivePlan``s resolved through
``plan_cached``, inter-pod level priced with the tuner's inter-pod
constants); TPU-v5e predictions use the two-level cost model. Wire-byte
accounting is planned-vs-measured: the worker reports the wire bytes of the
plans it actually executed, and this process re-plans the same points and
asserts the numbers agree — the accounting the streams table leans on."""
from __future__ import annotations

import json

from repro.comm.plan import plan_cached
from repro.core import cost_model as cm
from repro.core.tuner import Tuner

from .common import run_worker

SIZES = [4 << 10, 256 << 10, 4 << 20, 64 << 20]
RANKS = [64, 128]
MEASURED_MESH = (2, 4)  # (pod, data) host-device worker mesh


def _model_hierarchical(M: int, n_pods: int, per_pod: int, tuner: Tuner) -> float:
    """Inter-pod level over n_pods leaders + intra-pod fanout (paper's
    hierarchical design)."""
    inter = tuner.select(M, n_pods, inter_pod=True)
    intra = tuner.select(M, per_pod)
    t_inter = cm.cost(inter.algo, M, n_pods, inter_pod=True) if n_pods > 1 else 0.0
    t_intra = cm.cost(intra.algo, M, per_pod)
    return t_inter + t_intra


def _planned_wire_bytes(M: int, n_pods: int, per_pod: int, tuner: Tuner) -> int:
    """Host-side plan-layer accounting for one hierarchical bcast: the
    inter-pod leader level plus the intra-pod fanout, each through the
    SAME ``plan_cached`` path the worker executes."""
    total = 0
    if n_pods > 1:
        total += plan_cached("bcast", M, n_pods, tuner=tuner,
                             inter_pod=True).wire_bytes()
    total += plan_cached("bcast", M, per_pod, tuner=tuner).wire_bytes()
    return total


def rows(quick: bool = False, dryrun: bool = False):
    tuner = Tuner()
    out = []
    # measured: (pod=2, data=4) mesh on 8 host devices, broadcast through
    # the plan layer (comm.pbcast) — per-level plans, inter-pod level first
    worker = """
import time, json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import pbcast
from repro.comm.plan import plan_cached

mesh = jax.make_mesh(%r, ("pod", "data"), axis_types=(jax.sharding.AxisType.Auto,)*2)

def measure(M, algo, reps=5):
    elems = max(M // 4, 1)
    xs = jnp.asarray(np.random.RandomState(0).randn(2, 4, elems).astype(np.float32))
    @jax.jit
    def run(xs):
        def f(b):
            if algo == "hier":
                out = pbcast(b[0, 0], "pod", root=0, inter_pod=True)
                out = pbcast(out, "data", root=0)
            else:
                out = pbcast(pbcast(b[0, 0], "pod", algo=algo), "data", algo=algo)
            return out[None, None]
        return jax.shard_map(f, mesh=mesh, in_specs=(P("pod", "data"),), out_specs=P("pod", "data"))(xs)
    run(xs).block_until_ready()
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter(); run(xs).block_until_ready(); ts.append(time.perf_counter() - t0)
    return float(np.median(ts))

res = {}
for M in %s:
    wire = plan_cached("bcast", M, 2, inter_pod=True).wire_bytes() \\
        + plan_cached("bcast", M, 4).wire_bytes()
    res[str(M)] = {"hier": measure(M, "hier"), "xla_psum": measure(M, "xla_psum"),
                   "wire_bytes": wire}
print(json.dumps(res))
""" % (MEASURED_MESH, SIZES[:2] if quick else SIZES[:3])
    # dryrun: skip the device worker; the measured columns fall back to 0
    # and the analytic two-level model carries the row (CI smoke)
    measured = {} if dryrun else run_worker(worker, devices=8)

    # planned-vs-measured wire bytes: the worker's executed plans must
    # account exactly the bytes this process plans for the same points
    for M_str, m in measured.items():
        M = int(M_str)
        planned = _planned_wire_bytes(M, MEASURED_MESH[0],
                                      MEASURED_MESH[1], tuner)
        if planned != m["wire_bytes"]:
            raise AssertionError(
                f"wire-byte accounting drifted at M={M}: planned {planned} "
                f"vs worker-executed {m['wire_bytes']}"
            )

    for n in RANKS:
        n_pods = 2 if n > 64 else 1
        per_pod = n // n_pods
        for M in SIZES[:2] if quick else SIZES:
            t_hier = _model_hierarchical(M, n_pods, per_pod, tuner)
            # flat NCCL-style ring spanning both pods: (n-1) hops at the
            # slowest (inter-pod) link bandwidth, fixed slices
            t_flat = cm.cost("nccl_ring", M, n, inter_pod=True)
            m = measured.get(str(M), {})
            out.append(
                {
                    "name": f"fig2_internode/n{n}/M{M}",
                    "us_per_call": (m.get("hier", 0.0)) * 1e6,
                    "derived": {
                        "measured_xla_psum_us": m.get("xla_psum", 0.0) * 1e6,
                        "measured_wire_bytes": m.get("wire_bytes", 0),
                        "planned_wire_bytes": _planned_wire_bytes(
                            M, n_pods, per_pod, tuner
                        ),
                        "tpu_model_hier_us": t_hier * 1e6,
                        "tpu_model_flat_us": t_flat * 1e6,
                        "model_speedup": t_flat / max(t_hier, 1e-12),
                    },
                }
            )
    return out


if __name__ == "__main__":
    for r in rows(quick=True):
        print(r["name"], r["us_per_call"], json.dumps(r["derived"]))
