"""Allreduce benchmark — the gradient-sync op through the repro.comm plans.

Measures every allreduce strategy (reduce_then_bcast / fused_rsb /
ring_allreduce) against the one-shot ``xla_psum`` baseline on simulated host
devices, and records a per-op empirical table from the measurements —
persisted with ``Tuner.save`` to ``experiments/allreduce_table.json``, the
exact format ``Tuner.load`` consumes. A real-device run of this file plus
``RunConfig(sync_mode='tuned_allreduce',
tuner_table='experiments/allreduce_table.json')`` switches the trainer from
analytic to measured decisions.

``dryrun=True`` replaces the subprocess measurements with the round-accurate
simulator clock (``CollectivePlan.timed_rounds_s``) — tiny sizes, no worker
processes — so CI can exercise the full empirical-table pipeline on CPU.
"""
from __future__ import annotations

import json
import os

from repro.comm import plan_collective
from repro.core import cost_model as cm
from repro.core.tuner import Tuner

from .common import run_worker

SIZES = [1 << 10, 64 << 10, 1 << 20, 16 << 20]
RANKS = [4, 8]

ALGOS = ("reduce_then_bcast", "fused_rsb", "ring_allreduce")

MEASURE_ALLREDUCE = """
import time, json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import pallreduce

def measure(algo, M, n, num_chunks=None, reps=5):
    elems = max(M // 4, 1)
    mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    xs = jnp.asarray(np.random.RandomState(0).randn(n, elems).astype(np.float32))
    @jax.jit
    def run(xs):
        f = lambda b: pallreduce(b[0], "data", algo=algo, num_chunks=num_chunks)[None]
        return jax.shard_map(f, mesh=mesh, in_specs=(P("data"),), out_specs=P("data"))(xs)
    run(xs).block_until_ready()   # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter(); run(xs).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
"""


def _sim_measure(algo: str, M: int, n: int) -> float:
    """Dry-run 'measurement': the simulator clock of the algorithm's OWN
    planned schedule (same chunking the real-device worker executes)."""
    return plan_collective("allreduce", M, n, algo=algo).timed_rounds_s()


def rows(quick: bool = False, dryrun: bool = False):
    tuner = Tuner()
    calibrated = Tuner()
    sizes = SIZES[:3] if quick else SIZES
    ranks = RANKS[:1] if quick else RANKS
    out = []
    for n in ranks:
        if dryrun:
            res = {
                str(M): {
                    **{a: _sim_measure(a, M, n) for a in ALGOS},
                    "xla_psum": 0.0,
                }
                for M in sizes
            }
        else:
            worker = MEASURE_ALLREDUCE + f"""
res = {{}}
for M in {sizes}:
    row = {{a: measure(a, M, {n}) for a in {ALGOS!r}}}
    row["xla_psum"] = measure("xla_psum", M, {n})
    res[str(M)] = row
print(json.dumps(res))
"""
            res = run_worker(worker, devices=n)
        for M_str, r in res.items():
            M = int(M_str)
            # record the per-op empirical table from what we "measured"; the
            # chunk count is the plan's own (what the measurement executed)
            for a in ALGOS:
                k = plan_collective("allreduce", M, n, algo=a).num_chunks
                calibrated.record(M, n, a, k, r[a], op="allreduce")
            dec = tuner.select(M, n, op="allreduce")
            best = min((v, k) for k, v in r.items() if k != "xla_psum")
            out.append(
                {
                    "name": f"allreduce/n{n}/M{M}/{dec.algo}",
                    "us_per_call": r[dec.algo] * 1e6,
                    "derived": {
                        "measured_best": best[1],
                        "measured_best_us": best[0] * 1e6,
                        "xla_psum_us": r["xla_psum"] * 1e6,
                        "tpu_model_us": {
                            a: cm.cost(a, M, n) * 1e6 for a in ALGOS
                        },
                        "tuned_algo": dec.algo,
                        "tuned_num_chunks": dec.num_chunks,
                    },
                }
            )
    os.makedirs("experiments", exist_ok=True)
    # dryrun tables are branded so Tuner.load refuses to seed empirical
    # decisions from simulator stand-ins (allow_dryrun only schema-checks)
    calibrated.save("experiments/allreduce_table.json", dryrun=dryrun)
    # round-trip through the persistence layer as a schema gate
    Tuner.load("experiments/allreduce_table.json", allow_dryrun=dryrun)
    return out


if __name__ == "__main__":
    for r in rows(quick=True, dryrun=True):
        print(r["name"], r["us_per_call"], json.dumps(r["derived"]))
