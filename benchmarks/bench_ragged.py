"""Ragged-collective benchmark — allgatherv/alltoallv across skew regimes.

For a sweep of (rank count, size-vector pattern) points this suite plans
each ragged op through the skew-aware tuner (``comm.plan_collective`` with
``sizes=``), prices every candidate algorithm analytically, replays the
chosen schedule in the round-accurate simulator clock, and records the
schedule's wire-byte accounting. Rows land in the schema-gated
``experiments/ragged_table.json`` (``comm.tables.load_ragged_table`` —
the gate rebuilds every schedule from its size vector and rejects entries
whose wire bytes drift from the closed-form accounting).

The sweep spans the regimes the skew-aware cost model separates: uniform
vectors (bandwidth-bound, ring territory), one-hot skew (latency-bound,
doubling territory), zero-sized ranks, and incast alltoallv matrices
(store-and-forward ring territory). ``dryrun=True`` brands every entry —
the numbers are cost-model/simulator stand-ins, not measurements; the
non-dryrun mode additionally measures the SPMD entry points
(``pallgatherv``/``palltoallv``) on simulated host devices.
"""
from __future__ import annotations

import json
import os

from repro.comm.plan import expected_wire_bytes, plan_collective
from repro.comm.tables import load_ragged_table
from repro.core.cost_model import skew_ratio
from repro.core.tuner import Tuner

from .common import run_worker

RANKS = [4, 8]
ROW_BYTES = 4096  # bytes per ragged row (elems * itemsize)

# (pattern, per-rank row counts as a function of n)
GATHERV_PATTERNS = [
    ("uniform", lambda n: [8] * n),
    ("skewed", lambda n: [8 * (r + 1) for r in range(n)]),
    ("onehot", lambda n: [64] + [0] * (n - 1)),
    ("zero_rank", lambda n: [8] * (n - 1) + [0]),
]
A2AV_PATTERNS = [
    ("uniform", lambda n: [[4] * n for _ in range(n)]),
    ("incast", lambda n: [[16 if d == 0 else 1 for d in range(n)] for _ in range(n)]),
    ("zero_blocks", lambda n: [[(s + d) % 3 for d in range(n)] for s in range(n)]),
]

MEASURE_RAGGED = """
import time, json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.comm import pallgatherv, palltoallv

def measure(op, n, sizes, elems, reps=5):
    mesh = Mesh(np.array(jax.devices()[:n]), ("x",))
    rng = np.random.RandomState(0)
    if op == "allgatherv":
        rows = max(max(sizes), 1)
        fn = lambda v: pallgatherv(v, "x", sizes=tuple(sizes))
    else:
        m = np.asarray(sizes).reshape(n, n)
        rows = max(int(m.sum(axis=1).max()), 1)
        fn = lambda v: palltoallv(v, "x", sizes=[list(r) for r in m])
    x = jnp.asarray(rng.randn(n * rows, elems).astype(np.float32))
    out_spec = P() if op == "allgatherv" else P("x")
    f = jax.jit(shard_map(fn, mesh=mesh, in_specs=P("x"),
                          out_specs=out_spec, check_rep=False))
    jax.block_until_ready(f(x))  # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter(); jax.block_until_ready(f(x))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
"""


def _flat(sizes):
    if sizes and isinstance(sizes[0], list):
        return [v for row in sizes for v in row]
    return list(sizes)


def rows(quick: bool = False, dryrun: bool = False):
    ranks = RANKS[:1] if quick else RANKS
    table = {}
    out = []
    for n in ranks:
        points = [("allgatherv", name, fn(n)) for name, fn in GATHERV_PATTERNS]
        points += [("alltoallv", name, fn(n)) for name, fn in A2AV_PATTERNS]
        for op, pattern, sizes in points:
            flat = _flat(sizes)
            total = sum(flat)
            M = total * ROW_BYTES
            auto = plan_collective(op, M, n, tuner=Tuner(), sizes=sizes)
            candidates = (
                ("ring_allgatherv", "doubling_allgatherv")
                if op == "allgatherv"
                else ("pairwise_alltoallv", "ring_alltoallv")
            )
            for algo in candidates:
                if algo == "doubling_allgatherv" and n & (n - 1):
                    continue
                plan = plan_collective(op, M, n, algo=algo, tuner=Tuner(), sizes=sizes)
                canonical = list(plan.sizes)
                entry = {
                    "sizes": canonical,
                    "row_bytes": ROW_BYTES,
                    "wire_bytes": plan.wire_bytes(),
                    "predicted_us": plan.predicted_s * 1e6,
                    "rounds": len(plan.schedule.rounds),
                    "auto_algo": auto.algo,
                    "skew": skew_ratio(canonical),
                }
                if dryrun:
                    entry["dryrun"] = True
                assert plan.wire_bytes() == expected_wire_bytes(
                    op, algo, M, n, sizes=tuple(canonical)
                ), f"wire accounting drift at {op}/{algo}/n{n}/{pattern}"
                table[f"{op}/{algo}/n{n}/{pattern}"] = entry
                derived = {
                    "pattern": pattern,
                    "skew": entry["skew"],
                    "wire_bytes": entry["wire_bytes"],
                    "rounds": entry["rounds"],
                    "chosen": auto.algo,
                    "timed_rounds_us": plan.timed_rounds_s() * 1e6,
                }
                if not dryrun and algo == auto.algo:
                    worker = MEASURE_RAGGED + f"""
res = {{"t": measure({op!r}, {n}, {flat!r}, {ROW_BYTES // 4})}}
print(json.dumps(res))
"""
                    res = run_worker(worker, devices=n)
                    derived["measured_us"] = res["t"] * 1e6
                out.append(
                    {
                        "name": f"ragged/{op}/n{n}/{pattern}/{algo}",
                        "us_per_call": entry["predicted_us"],
                        "derived": derived,
                    }
                )
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/ragged_table.json", "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
    load_ragged_table("experiments/ragged_table.json")  # schema gate at source
    return out


if __name__ == "__main__":
    for r in rows(quick=True, dryrun=True):
        print(r["name"], r["us_per_call"], json.dumps(r["derived"]))
