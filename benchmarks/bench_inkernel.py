"""In-kernel executor benchmark — one persistent launch per schedule replay.

The in-kernel executor's claim is structural, so this suite measures it
rather than asserting it: for points across the tuner grid it traces the
SAME :class:`~repro.comm.CollectivePlan` through the in-kernel executor
(``comm.executors.execute_inkernel``, one persistent Pallas launch) and the
compiled executor (``execute_compiled``, two launches per round), recording
the pallas launch count in the traced jaxpr, HLO instruction counts, and
per-round replay wall time. Rows land in the schema-gated
``experiments/inkernel_table.json`` (``comm.tables.load_inkernel_table``),
whose loader IS the regression gate: exactly ONE launch per replay, the
in-kernel round count equal to the compiled executor's, HLO flat in
``num_chunks``, and strictly below the compiled program at each group's
deepest point.

Counts and lower times are host-side quantities, but ``round_us`` executes
the replay, so ``--dryrun`` runs a smaller grid; entries are branded
``dryrun`` all the same so downstream consumers know which grid produced
them.
"""
from __future__ import annotations

import json
import os

from repro.comm.tables import load_inkernel_table

from .common import WorkerTimeoutError, run_worker

RANKS = [4, 8]
# (op, algo, M, num_chunks sweep) — chain-family points sweep the chunk
# count (the flatness axis); ring-family points pin K == n by design
POINTS = [
    ("bcast", "pipelined_chain", 1 << 16, (4, 8, 16)),
    ("bcast", "bidir_chain", 1 << 16, (4, 8, 16)),
    ("allreduce", "fused_rsb", 1 << 16, (4, 8, 16)),
    ("allreduce", "ring_allreduce", 1 << 16, (None,)),
    ("allgather", "ring_allgather", 1 << 16, (None,)),
    ("reduce_scatter", "ring_reduce_scatter", 1 << 16, (None,)),
]

WORKER = """
import json, time
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import plan_collective, apply_plan


def _sub_jaxprs(v):
    import jax.core as jc
    if isinstance(v, jc.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jc.Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _sub_jaxprs(x)


def eqn_count(jaxpr):
    total = len(jaxpr.eqns)
    for eq in jaxpr.eqns:
        for v in eq.params.values():
            for sub in _sub_jaxprs(v):
                total += eqn_count(sub)
    return total


def count_pallas(jaxpr):
    total = 0
    for eq in jaxpr.eqns:
        if eq.primitive.name == "pallas_call":
            total += 1
        for v in eq.params.values():
            for sub in _sub_jaxprs(v):
                total += count_pallas(sub)
    return total


def hlo_count(text):
    return sum(1 for line in text.splitlines() if " = " in line)


def bench(n, points):
    mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    table = {}
    for op, algo, M, K in points:
        kw = {} if K is None else {"num_chunks": K}
        plan = plan_collective(op, M, n, algo=algo, **kw)
        lowered_sched = plan.lowered()
        rounds = max(lowered_sched.num_rounds, 1)
        elems = max(M // 4, 1)
        shape = (elems // n,) if op == "allgather" else (elems,)
        sds = jax.ShapeDtypeStruct(shape, jnp.float32)

        def g_ink(b):
            return apply_plan(plan, b, "data", inkernel=True)

        def g_cmp(b):
            return apply_plan(plan, b, "data", compiled=True)

        f_ink = jax.shard_map(g_ink, mesh=mesh, in_specs=(P(),), out_specs=P(),
                              check_vma=False)
        f_cmp = jax.shard_map(g_cmp, mesh=mesh, in_specs=(P(),), out_specs=P(),
                              check_vma=False)
        closed = jax.make_jaxpr(f_ink)(sds)
        t0 = time.perf_counter()
        low = jax.jit(f_ink).lower(sds)
        lower_s = time.perf_counter() - t0
        # the compiled executor walks the SAME lowered schedule object, so
        # its round count is recorded from its own plan lowering — the
        # loader gate rejects any drift between the two executors
        entry = {
            "M": M,
            "num_rounds": rounds,
            "compiled_rounds": max(plan.lowered().num_rounds, 1),
            "lane_classes": max(lowered_sched.num_classes, 1),
            "inkernel_launches": count_pallas(closed.jaxpr),
            "inkernel_jaxpr_eqns": max(eqn_count(closed.jaxpr), 1),
            "inkernel_lower_s": lower_s,
            "inkernel_hlo": max(hlo_count(low.as_text()), 1),
            "compiled_hlo": max(hlo_count(jax.jit(f_cmp).lower(sds).as_text()), 1),
        }
        x = jnp.zeros(shape, jnp.float32)
        fn = jax.jit(f_ink)
        fn(x).block_until_ready()
        reps = 3
        t0 = time.perf_counter()
        for _ in range(reps):
            fn(x).block_until_ready()
        entry["round_us"] = (time.perf_counter() - t0) / reps / rounds * 1e6
        table[f"n{n}/{op}/{algo}/K{plan.num_chunks}"] = entry
    return table
"""


def _point_worker(n, pt):
    return WORKER + f"""
print(json.dumps(bench({n}, {[pt]!r})))
"""


def rows(quick: bool = False, dryrun: bool = False, timeout: int = 560):
    ranks = RANKS[:1] if (quick or dryrun) else RANKS
    points = [
        (op, algo, M, ks[:2] if dryrun else ks) for op, algo, M, ks in POINTS
    ]
    table = {}
    timed_out = []
    for n in ranks:
        flat_points = [
            (op, algo, M, k) for op, algo, M, ks in points for k in ks
        ]
        worker = WORKER + f"""
print(json.dumps(bench({n}, {flat_points!r})))
"""
        try:
            table.update(run_worker(worker, devices=n, timeout=timeout, retries=1))
        except WorkerTimeoutError:
            # the whole-rank batch hung twice: re-run one worker PER POINT so
            # a single pathological point can't take the rest of the sweep
            # down with it — each point still gets the single retry
            for pt in flat_points:
                try:
                    table.update(
                        run_worker(
                            _point_worker(n, pt), devices=n,
                            timeout=timeout, retries=1,
                        )
                    )
                except WorkerTimeoutError:
                    op, algo, M, k = pt
                    timed_out.append((f"n{n}/{op}/{algo}/K{k or n}", M))
    if dryrun:
        for entry in table.values():
            entry["dryrun"] = True
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/inkernel_table.json", "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
    # the loader IS the gate: single launch, matching round counts, flat and
    # compiled-beating HLO — reject the artifact at the source
    table = load_inkernel_table("experiments/inkernel_table.json")
    # timed-out points are recorded as explicit bench rows (derived.timeout),
    # NOT written into the schema-gated table — the gates only see measured
    # entries, and downstream consumers can see exactly which points are gone
    out = [
        {
            "name": f"inkernel/{key}",
            "us_per_call": float("nan"),
            "derived": {"timeout": True, "M": M},
        }
        for key, M in timed_out
    ]
    for key, e in sorted(table.items()):
        out.append(
            {
                "name": f"inkernel/{key}",
                "us_per_call": e["round_us"],
                "derived": {
                    "inkernel_launches": e["inkernel_launches"],
                    "inkernel_hlo": e["inkernel_hlo"],
                    "compiled_hlo": e["compiled_hlo"],
                    "inkernel_jaxpr_eqns": e["inkernel_jaxpr_eqns"],
                    "inkernel_lower_ms": e["inkernel_lower_s"] * 1e3,
                    "num_rounds": e["num_rounds"],
                    "lane_classes": e["lane_classes"],
                },
            }
        )
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in rows(quick=not args.full, dryrun=args.dryrun):
        print(r["name"], f"{r['us_per_call']:.1f}", json.dumps(r["derived"]))
