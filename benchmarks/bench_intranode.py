"""Fig. 1 analogue — intra-pod broadcast latency vs message size, for 2/4/8/16
ranks: the tuned library (MV2-GDR-Opt analogue) vs the XLA one-shot
collectives (NCCL stand-in). Measured on simulated host devices + modelled
for TPU v5e."""
from __future__ import annotations

import json

from repro.core import cost_model as cm
from repro.core.tuner import Tuner

from .common import MEASURE_SNIPPET, run_worker

SIZES = [1 << 10, 16 << 10, 256 << 10, 4 << 20, 32 << 20]
RANKS = [2, 4, 8, 16]


def _dryrun_point(M: int, n: int, tuner: Tuner) -> dict:
    """Simulator-clock stand-ins for the worker measurements (CI smoke)."""
    from repro.comm import plan_collective

    dec = tuner.select(M, n)
    plan = plan_collective("bcast", M, n)
    return {
        "tuned": plan.timed_rounds_s(),
        "tuned_algo": dec.algo,
        "xla_psum": cm.cost("nccl_ring", M, n),
        "xla_allgather": cm.cost("nccl_ring", M, n),
    }


def rows(quick: bool = False, dryrun: bool = False):
    tuner = Tuner()
    ranks = [4, 8] if quick else RANKS
    sizes = SIZES[:3] if quick else SIZES
    out = []
    for n in ranks:
        if dryrun:
            res = {str(M): _dryrun_point(M, n, tuner) for M in sizes}
            out.extend(_emit(res, n, tuner))
            continue
        worker = MEASURE_SNIPPET + f"""
res = {{}}
for M in {sizes}:
    from repro.core.tuner import Tuner
    dec = Tuner().select(M, {n})
    res[str(M)] = {{
        "tuned": measure(dec.algo, M, {n}),
        "tuned_algo": dec.algo,
        "xla_psum": measure("xla_psum", M, {n}),
        "xla_allgather": measure("xla_allgather", M, {n}),
    }}
print(json.dumps(res))
"""
        res = run_worker(worker, devices=n)
        out.extend(_emit(res, n, tuner))
    return out


def _emit(res: dict, n: int, tuner: Tuner) -> list:
    out = []
    for M_str, r in res.items():
        M = int(M_str)
        dec = tuner.select(M, n)
        model_tuned = cm.cost(dec.algo, M, n) if dec.algo in cm.ALGO_COSTS else 0
        # NCCL stand-in: fixed-slice pipelined ring (no tuning)
        model_nccl = cm.cost("nccl_ring", M, n)
        out.append(
            {
                "name": f"fig1_intranode/n{n}/M{M}/{r['tuned_algo']}",
                "us_per_call": r["tuned"] * 1e6,
                "derived": {
                    # measured CPU numbers are dominated by the host
                    # backend's fixed per-collective overhead (ts ~ 0.3 s);
                    # they validate round-count scaling, not bandwidth.
                    "xla_psum_us": r["xla_psum"] * 1e6,
                    "xla_allgather_us": r["xla_allgather"] * 1e6,
                    "tpu_model_tuned_us": model_tuned * 1e6,
                    "tpu_model_nccl_ring_us": model_nccl * 1e6,
                    "tpu_model_speedup_vs_nccl": model_nccl / max(model_tuned, 1e-12),
                },
            }
        )
    return out


if __name__ == "__main__":
    for r in rows(quick=True):
        print(r["name"], r["us_per_call"], json.dumps(r["derived"]))
