"""Fig. 1 analogue — intra-pod broadcast latency vs message size, for 2/4/8/16
ranks: the tuned library (MV2-GDR-Opt analogue) vs the XLA one-shot
collectives (NCCL stand-in). Measured on simulated host devices + modelled
for TPU v5e.

Like ``bench_internode``, the measured sweep drives the ``repro.comm`` plan
layer end to end: the worker broadcasts through ``comm.pbcast`` (per-point
``CollectivePlan``s resolved via ``plan_cached``) and reports the wire bytes
of the plans it actually executed; this process re-plans the same points and
asserts the accounting agrees exactly."""
from __future__ import annotations

import json

from repro.core import cost_model as cm
from repro.core.tuner import Tuner

from .common import run_worker

SIZES = [1 << 10, 16 << 10, 256 << 10, 4 << 20, 32 << 20]
RANKS = [2, 4, 8, 16]


def _dryrun_point(M: int, n: int, tuner: Tuner) -> dict:
    """Simulator-clock stand-ins for the worker measurements (CI smoke).

    The one-shot baselines get DISTINCT cost paths: the psum-based bcast
    reduces and rebroadcasts the full buffer (ring-allreduce traffic
    pattern), while the allgather-based bcast gathers an n-rank stack of
    the masked buffer (ring-allgather over the n*M gathered payload) —
    pricing both as ``nccl_ring`` made the baseline columns identical and
    hid the allgather baseline's n-fold payload blowup."""
    from repro.comm import plan_cached

    dec = tuner.select(M, n)
    plan = plan_cached("bcast", M, n)
    return {
        "tuned": plan.timed_rounds_s(),
        "tuned_algo": dec.algo,
        "wire_bytes": plan.wire_bytes(),
        "xla_psum": cm.cost("ring_allreduce", M, n),
        "xla_allgather": cm.cost("ring_allgather", n * M, n),
    }


def rows(quick: bool = False, dryrun: bool = False):
    tuner = Tuner()
    ranks = [4, 8] if quick else RANKS
    sizes = SIZES[:3] if quick else SIZES
    out = []
    for n in ranks:
        if dryrun:
            res = {str(M): _dryrun_point(M, n, tuner) for M in sizes}
            out.extend(_emit(res, n, tuner))
            continue
        worker = """
import time, json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import pbcast
from repro.comm.plan import plan_cached

n = %d
mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))

def measure(M, algo, reps=5):
    elems = max(M // 4, 1)
    xs = jnp.asarray(np.random.RandomState(0).randn(n, elems).astype(np.float32))
    @jax.jit
    def run(xs):
        f = lambda b: pbcast(b[0], "data", root=0, algo=algo)[None]
        return jax.shard_map(f, mesh=mesh, in_specs=(P("data"),),
                             out_specs=P("data"), check_vma=False)(xs)
    run(xs).block_until_ready()   # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter(); run(xs).block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))

res = {}
for M in %s:
    plan = plan_cached("bcast", M, n)
    res[str(M)] = {
        "tuned": measure(M, "auto"),
        "tuned_algo": plan.decision.algo,
        "wire_bytes": plan.wire_bytes(),
        "xla_psum": measure(M, "xla_psum"),
        "xla_allgather": measure(M, "xla_allgather"),
    }
print(json.dumps(res))
""" % (n, sizes)
        res = run_worker(worker, devices=n)
        # planned-vs-measured wire bytes: the worker's executed plans must
        # account exactly the bytes this process plans for the same points
        from repro.comm import plan_cached

        for M_str, r in res.items():
            planned = plan_cached("bcast", int(M_str), n).wire_bytes()
            if planned != r["wire_bytes"]:
                raise AssertionError(
                    f"wire-byte accounting drifted at n={n} M={M_str}: planned "
                    f"{planned} vs worker-executed {r['wire_bytes']}"
                )
        out.extend(_emit(res, n, tuner))
    return out


def _emit(res: dict, n: int, tuner: Tuner) -> list:
    out = []
    for M_str, r in res.items():
        M = int(M_str)
        dec = tuner.select(M, n)
        model_tuned = cm.cost(dec.algo, M, n) if dec.algo in cm.ALGO_COSTS else 0
        # NCCL stand-in: fixed-slice pipelined ring (no tuning)
        model_nccl = cm.cost("nccl_ring", M, n)
        out.append(
            {
                "name": f"fig1_intranode/n{n}/M{M}/{r['tuned_algo']}",
                "us_per_call": r["tuned"] * 1e6,
                "derived": {
                    # measured CPU numbers are dominated by the host
                    # backend's fixed per-collective overhead (ts ~ 0.3 s);
                    # they validate round-count scaling, not bandwidth.
                    "xla_psum_us": r["xla_psum"] * 1e6,
                    "xla_allgather_us": r["xla_allgather"] * 1e6,
                    "wire_bytes": r["wire_bytes"],
                    "tpu_model_tuned_us": model_tuned * 1e6,
                    "tpu_model_nccl_ring_us": model_nccl * 1e6,
                    "tpu_model_speedup_vs_nccl": model_nccl / max(model_tuned, 1e-12),
                },
            }
        )
    return out


if __name__ == "__main__":
    for r in rows(quick=True):
        print(r["name"], r["us_per_call"], json.dumps(r["derived"]))
