"""Overlap-engine benchmark — planned vs simulated overlap efficiency.

For a sweep of (rank count, bucket mix, hidden-compute budget) points this
suite plans the bucket-streamed gradient sync (``comm.plan_overlap``),
prices the barrier schedule against the overlapped one
(``cost_model.t_bucketed_barrier`` / ``t_overlapped``), replays both in the
round-accurate overlap simulator (``comm.simulate_overlap``), and records
the tuned in-flight window. Rows land in the schema-gated
``experiments/overlap_table.json`` (``comm.tables.load_overlap_table``).

Tuned per-bucket windows also persist as depth-only Tuner entries in
``experiments/overlap_depths.json`` (``Tuner.record_overlap`` →
``Tuner.save``), the table ``plan_overlap(tuner=Tuner.load(...))`` reads
calibrated depths from. ``dryrun=True`` marks every entry ``dryrun``
(planner/simulator numbers — no devices were harmed) so downstream
consumers can never mistake the stand-ins for measurements; the non-dryrun
mode additionally measures the real barrier-vs-overlap tree executors on
simulated host devices.
"""
from __future__ import annotations

import json
import os

import numpy as np

import jax

from repro.comm import plan_overlap, simulate_overlap
from repro.comm.tables import load_overlap_table
from repro.core.tuner import Tuner

from .common import run_worker

RANKS = [4, 8]
# bucket mixes: (num_leaves, leaf_elems) synthetic gradient trees — a few
# large buckets plus a tail of small ones, the paper's Sec. V-D spectrum
MIXES = [
    ("uniform8", [4096] * 8),
    ("mixed", [65536, 65536, 4096, 4096, 512, 512, 64, 64]),
    ("two_big", [262144, 262144]),
]
COMPUTE_S = [0.0, 1e-3]

MEASURE_OVERLAP = """
import time, json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import pallreduce_tree, overlap_allreduce_tree

def measure(n, leaves, overlap, reps=5):
    mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    tree = {f"l{i}": jnp.asarray(rng.randn(n, e).astype(np.float32))
            for i, e in enumerate(leaves)}
    specs = jax.tree.map(lambda _: P("data"), tree)
    def g(t):
        sub = jax.tree.map(lambda x: x[0], t)
        if overlap:
            out = overlap_allreduce_tree(sub, ["data"], bucket_bytes=64 << 10)
        else:
            out = pallreduce_tree(sub, ["data"], bucket_bytes=64 << 10)
        return jax.tree.map(lambda x: x[None], out)
    f = jax.jit(lambda t: jax.shard_map(g, mesh=mesh, in_specs=(specs,),
                                        out_specs=specs, check_vma=False)(t))
    jax.block_until_ready(f(tree))   # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter(); jax.block_until_ready(f(tree))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))
"""


def _grads_like(leaves):
    return {
        f"l{i}": jax.ShapeDtypeStruct((e,), np.float32)
        for i, e in enumerate(leaves)
    }


def rows(quick: bool = False, dryrun: bool = False):
    ranks = RANKS[:1] if quick else RANKS
    mixes = MIXES[:2] if quick else MIXES
    # planning and recording are SEPARATE tuners: every point's depth must
    # come from its own analytic sweep at its own compute budget — a depth
    # recorded for one point must not short-circuit the next point's sweep
    # (tuner keys carry no compute dimension)
    calibrated = Tuner()
    table = {}
    out = []
    for n in ranks:
        for mix_name, leaves in mixes:
            for compute_s in COMPUTE_S:
                tree = _grads_like(leaves)
                oplan = plan_overlap(
                    tree, [("data", n)], tuner=Tuner(),
                    bucket_bytes=64 << 10, compute_s=compute_s,
                )
                sim = simulate_overlap(oplan)
                # the tuned window lands in the per-op tuner table alongside
                # num_chunks (Tuner.record_overlap), keyed by each bucket
                for M in oplan.spec.bucket_bytes():
                    if M:
                        calibrated.record_overlap(M, n, oplan.overlap_depth, op="allreduce")
                M_total = sum(oplan.spec.bucket_bytes())
                key = f"n{n}/K{oplan.num_buckets}/M{M_total}"
                entry = {
                    "overlap_depth": oplan.overlap_depth,
                    "depth_source": oplan.depth_source,
                    "barrier_us": sim["barrier_s"] * 1e6,
                    "overlapped_us": sim["overlapped_s"] * 1e6,
                    "efficiency": sim["efficiency"],
                    "idle_rounds_barrier": sim["idle_rounds_barrier"],
                    "idle_rounds_overlap": sim["idle_rounds_overlap"],
                    "wire_bytes": sim["wire_bytes"],
                    "compute_us": compute_s * 1e6,
                }
                if dryrun:
                    entry["dryrun"] = True
                # one entry per (n, K, M_total) point: keep the
                # largest-compute row (the regime overlap exists for)
                if key not in table or compute_s * 1e6 >= table[key]["compute_us"]:
                    table[key] = entry
                derived = {
                    "mix": mix_name,
                    "compute_us": compute_s * 1e6,
                    "depth": oplan.overlap_depth,
                    "depth_source": oplan.depth_source,
                    "barrier_us": sim["barrier_s"] * 1e6,
                    "efficiency": sim["efficiency"],
                    "idle_rounds": [sim["idle_rounds_barrier"], sim["idle_rounds_overlap"]],
                    "wire_bytes": sim["wire_bytes"],
                }
                if not dryrun and compute_s == 0.0:
                    worker = MEASURE_OVERLAP + f"""
res = {{"barrier": measure({n}, {leaves!r}, False),
       "overlap": measure({n}, {leaves!r}, True)}}
print(json.dumps(res))
"""
                    res = run_worker(worker, devices=n)
                    derived["measured_barrier_us"] = res["barrier"] * 1e6
                    derived["measured_overlap_us"] = res["overlap"] * 1e6
                out.append(
                    {
                        "name": f"overlap/n{n}/{mix_name}/c{int(compute_s * 1e6)}",
                        "us_per_call": sim["overlapped_s"] * 1e6,
                        "derived": derived,
                    }
                )
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/overlap_table.json", "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
    load_overlap_table("experiments/overlap_table.json")  # schema gate at source
    # the per-bucket depth records persist in Tuner.save format (depth-only
    # entries), so a run points `plan_overlap(tuner=Tuner.load(...))` at
    # calibrated windows; dryrun-branded like the allreduce table
    calibrated.save("experiments/overlap_depths.json", dryrun=dryrun)
    Tuner.load("experiments/overlap_depths.json", allow_dryrun=dryrun)
    return out


if __name__ == "__main__":
    for r in rows(quick=True, dryrun=True):
        print(r["name"], r["us_per_call"], json.dumps(r["derived"]))
