"""Integration: the broadcast path and the serving engine agree with the
repro.dist layout — weights broadcast over the data axes land with exactly
the layout ``param_specs`` declares, and ``hierarchical_bcast`` derives its
per-level axes from the same mesh metadata. Runs on simulated host devices
(``XLA_FLAGS=--xla_force_host_platform_device_count`` via conftest's
``run_distributed``)."""
from __future__ import annotations

import pytest


@pytest.mark.dist
def test_distribute_weights_lands_on_param_specs(dist):
    """Root weights reach every data rank AND end up laid out per
    param_specs (TP-only serving layout) on a (pod, data, model) mesh."""
    dist(
        """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.dist.sharding import param_specs
from repro.models import Model
from repro.serve.engine import distribute_weights

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,)*3)
cfg = get_config("minitron-8b-smoke")
m = Model(cfg)
params = m.init(jax.random.PRNGKey(0))
pspecs = param_specs(m.param_shapes(), mesh, fsdp=False, attn_fallback="head_dim")
out = distribute_weights(params, mesh, specs=pspecs)

flat_out = jax.tree_util.tree_leaves_with_path(out)
flat_spec = jax.tree_util.tree_leaves(pspecs, is_leaf=lambda s: isinstance(s, P))
assert len(flat_out) == len(flat_spec)
n_sharded = 0
for (path, leaf), spec in zip(flat_out, flat_spec):
    want = NamedSharding(mesh, spec)
    assert leaf.sharding.is_equivalent_to(want, leaf.ndim), (
        jax.tree_util.keystr(path), leaf.sharding, spec)
    if any(e is not None for e in spec):
        n_sharded += 1
assert n_sharded > 0, "expected at least one model-sharded leaf"
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
    np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
print("PASS")
""",
        devices=8,
        timeout=420,
    )


@pytest.mark.dist
def test_hierarchical_bcast_axes_from_mesh(dist):
    """mesh-derived axes (dist.topology.bcast_axes) == the explicit axis
    list: inter-pod level first, identical broadcast result."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import hierarchical_bcast
from repro.dist import topology

mesh = jax.make_mesh((2, 4), ("pod", "data"), axis_types=(jax.sharding.AxisType.Auto,)*2)
assert topology.bcast_axes(mesh) == ("pod", "data")
rng = np.random.RandomState(5)
xs = jnp.asarray(rng.randn(2, 4, 321).astype(np.float32))

@jax.jit
def run(xs):
    def f(b):
        derived = hierarchical_bcast(b[0, 0], mesh=mesh, root=0, algo="auto")
        explicit = hierarchical_bcast(b[0, 0], ("pod", "data"), root=0, algo="auto")
        return derived[None, None], explicit[None, None]
    return jax.shard_map(f, mesh=mesh, in_specs=(P("pod", "data"),),
                         out_specs=(P("pod", "data"), P("pod", "data")))(xs)

derived, explicit = run(xs)
np.testing.assert_array_equal(np.asarray(derived), np.asarray(explicit))
want = np.asarray(xs[0, 0])
for p in range(2):
    for d in range(4):
        np.testing.assert_allclose(np.asarray(derived)[p, d], want, rtol=1e-6)
print("PASS")
"""
    )


@pytest.mark.dist
def test_engine_on_mesh_uses_dist_layout(dist):
    """An Engine handed a 4-device (data, model) mesh places weights per
    param_specs and still decodes greedily to the same tokens as the
    single-layout reference run."""
    dist(
        """
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.serve.engine import Engine

cfg = get_config("minitron-8b-smoke")
params = __import__("repro.models", fromlist=["Model"]).Model(cfg).init(jax.random.PRNGKey(0))
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, 500, (4, 8)))}

ref = Engine(cfg, params).generate(batch, steps=4)

mesh = jax.make_mesh((2, 2), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
eng = Engine(cfg, params, mesh=mesh)
res = eng.generate(batch, steps=4)
assert res.tokens.shape == (4, 4)
np.testing.assert_array_equal(res.tokens, ref.tokens)
assert np.isfinite(res.logprobs).all()
print("PASS")
""",
        devices=4,
        timeout=420,
    )
