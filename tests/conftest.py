"""Shared test infrastructure.

IMPORTANT: this process keeps the default single CPU device (the dry-run's
512-device override is NOT set here — per the assignment, smoke tests and
benches must see 1 device). Multi-device collective behaviour is tested in
subprocesses via ``run_distributed``.
"""
from __future__ import annotations

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)


def run_distributed(code: str, devices: int = 8, timeout: int = 560,
                    env: dict | None = None) -> str:
    """Run ``code`` in a subprocess with N simulated host devices (the CPU
    device-count override: ``XLA_FLAGS=--xla_force_host_platform_device_count``
    is set before any jax import, so collectives and sharding see a real
    multi-device platform without accelerators or network access).

    The snippet must print 'PASS' as its last line on success.
    ``env``: extra environment overrides for the subprocess.
    """
    preamble = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={devices}'\n"
        "import sys\n"
        f"sys.path.insert(0, {SRC!r})\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", preamble + code],
        capture_output=True,
        text=True,
        timeout=timeout,
        cwd=os.path.dirname(SRC),
        env={**os.environ, **(env or {})},
    )
    if proc.returncode != 0 or "PASS" not in proc.stdout:
        raise AssertionError(
            f"distributed snippet failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout[-4000:]}\n--- stderr ---\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def dist():
    return run_distributed
