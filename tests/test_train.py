"""Training-path tests: both sync modes on 8 simulated devices (subprocess)
+ optimizer unit tests on 1 device."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.optimizers import adamw, clip_by_global_norm, get_optimizer, lion, sgdm
from repro.optim.schedules import warmup_cosine


def test_adamw_converges_quadratic():
    opt = adamw(weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, state = opt.update(grads, state, params, jnp.asarray(0.05))
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)


def test_sgdm_and_lion_step():
    for opt in (sgdm(), lion()):
        params = {"w": jnp.ones((4,))}
        state = opt.init(params)
        grads = {"w": jnp.ones((4,))}
        new, state = opt.update(grads, state, params, jnp.asarray(0.1))
        assert float(new["w"][0]) < 1.0
        assert int(state["step"]) == 1


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) > 30


def test_warmup_cosine_shape():
    lr = warmup_cosine(1e-3, 10, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < float(lr(50)) < float(lr(10))


def test_sync_modes_agree(dist):
    """grad_allreduce (GSPMD) and param_bcast (paper) trajectories match."""
    dist(
        """
import jax, numpy as np
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.train.trainer import Trainer
from repro.launch.mesh import make_local_mesh

cfg = get_config("minitron-8b-smoke")
mesh = make_local_mesh(model_parallel=2)
run = RunConfig(total_steps=6, warmup_steps=2, num_microbatches=2,
                sync_mode="grad_allreduce", learning_rate=1e-3)
_, _, h1 = Trainer(cfg, run, mesh=mesh).train(batch=8, seq=32, steps=6, log_every=5)

mesh = make_local_mesh(model_parallel=1)
run2 = RunConfig(total_steps=6, warmup_steps=2, sync_mode="param_bcast",
                 bcast_algo="auto", learning_rate=1e-3)
_, _, h2 = Trainer(cfg, run2, mesh=mesh).train(batch=8, seq=32, steps=6, log_every=5)

assert h1[-1]["loss"] < h1[0]["loss"], h1
assert h2[-1]["loss"] < h2[0]["loss"], h2
assert abs(h1[0]["loss"] - h2[0]["loss"]) < 0.02, (h1[0], h2[0])
assert abs(h1[-1]["loss"] - h2[-1]["loss"]) < 0.15, (h1[-1], h2[-1])
print("PASS")
""",
        timeout=580,
    )


def test_bcast_sync_each_algorithm(dist):
    """The paper's sync path works with every broadcast algorithm."""
    dist(
        """
import jax, numpy as np
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.train.trainer import Trainer
from repro.launch.mesh import make_local_mesh

cfg = get_config("xlstm-350m-smoke")
losses = {}
for algo in ("pipelined_chain", "binomial", "scatter_allgather", "xla_psum", "ring_allreduce"):
    run = RunConfig(total_steps=3, warmup_steps=1, sync_mode="param_bcast",
                    bcast_algo=algo, learning_rate=1e-3, seed=7)
    tr = Trainer(cfg, run, mesh=make_local_mesh(1))
    _, _, hist = tr.train(batch=8, seq=32, steps=3, log_every=2)
    losses[algo] = [h["loss"] for h in hist]
vals = list(losses.values())
for v in vals[1:]:
    assert abs(v[0] - vals[0][0]) < 1e-3, losses   # same first-step loss
    assert abs(v[-1] - vals[0][-1]) < 0.05, losses # same trajectory
print("PASS")
""",
        # five trainer builds in one subprocess: ~495 s on an idle 8-core
        # runner; the old 580 s budget timed out under suite-level load
        timeout=840,
    )
