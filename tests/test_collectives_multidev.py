"""On-device collective correctness (8 simulated devices, subprocess)."""
from __future__ import annotations


def test_all_algorithms_all_roots(dist):
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.core import bcast_stacked
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(0)
xs = jnp.asarray(rng.randn(8, 777).astype(np.float32))
for algo in ["direct", "chain", "binomial", "knomial", "scatter_allgather",
             "pipelined_chain", "bidir_chain", "xla_psum", "xla_allgather", "auto"]:
    for root in (0, 5):
        out = bcast_stacked(xs, mesh, "data", root=root, algo=algo)
        np.testing.assert_allclose(np.asarray(out), np.tile(np.asarray(xs[root]), (8, 1)),
                                   rtol=1e-6, err_msg=f"{algo}/{root}")
print("PASS")
"""
    )


def test_dtypes_and_sizes(dist):
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.core import bcast_stacked
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(1)
for size in (1, 7, 64, 4097):
    for dt in (jnp.float32, jnp.bfloat16, jnp.int32):
        xs = jnp.asarray((rng.randn(8, size) * 50), dt)
        out = bcast_stacked(xs, mesh, "data", root=5, algo="pipelined_chain")
        np.testing.assert_array_equal(np.asarray(out), np.tile(np.asarray(xs[5]), (8, 1)))
print("PASS")
"""
    )


def test_reduce_and_tree_bcast(dist):
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import preduce_sum, pbcast_tree
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(2)
xs = jnp.asarray(rng.randn(8, 100).astype(np.float32))

@jax.jit
def red(xs):
    f = lambda b: preduce_sum(b[0], "data", root=2)[None]
    return jax.shard_map(f, mesh=mesh, in_specs=(P("data"),), out_specs=P("data"))(xs)
out = np.asarray(red(xs))
np.testing.assert_allclose(out[2], np.asarray(xs).sum(0), rtol=1e-4, atol=1e-5)

tree = {"a": jnp.arange(300, dtype=jnp.float32), "b": {"c": jnp.ones((17,), jnp.bfloat16)}}
ts = jax.tree.map(lambda l: jnp.broadcast_to(l, (8,) + l.shape) *
                  jnp.arange(1, 9, dtype=l.dtype).reshape((8,) + (1,) * l.ndim), tree)
@jax.jit
def tb(ts):
    def f(b):
        sl = jax.tree.map(lambda l: l[0], b)
        out = pbcast_tree(sl, "data", root=4, bucket_bytes=256)
        return jax.tree.map(lambda l: l[None], out)
    return jax.shard_map(f, mesh=mesh, in_specs=(P("data"),), out_specs=P("data"))(ts)
out = tb(ts)
for l in jax.tree.leaves(out):
    arr = np.asarray(l, np.float32)
    for r in range(8):
        np.testing.assert_allclose(arr[r], arr[4])
print("PASS")
"""
    )


def test_hierarchical_two_level(dist):
    """Intra/inter-pod hierarchy on a (pod=2, data=4) mesh."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import hierarchical_bcast
mesh = jax.make_mesh((2, 4), ("pod", "data"), axis_types=(jax.sharding.AxisType.Auto,)*2)
rng = np.random.RandomState(3)
xs = jnp.asarray(rng.randn(2, 4, 500).astype(np.float32))

@jax.jit
def run(xs):
    def f(b):
        out = hierarchical_bcast(b[0, 0], ("pod", "data"), root=0, algo="auto")
        return out[None, None]
    return jax.shard_map(f, mesh=mesh, in_specs=(P("pod", "data"),), out_specs=P("pod", "data"))(xs)
out = np.asarray(run(xs))
want = np.asarray(xs[0, 0])
for p in range(2):
    for d in range(4):
        np.testing.assert_allclose(out[p, d], want, rtol=1e-6)
print("PASS")
"""
    )


def test_fused_equals_unrolled_pipelined_chain(dist):
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.algorithms import pipelined_chain_fused, execute_schedule
from repro.core.schedules import pipelined_chain
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(4)
K, chunk = 12, 64
xs = jnp.asarray(rng.randn(8, K, chunk).astype(np.float32))
sched = pipelined_chain(8, 3, num_chunks=K)

@jax.jit
def both(xs):
    def f(b):
        fused = pipelined_chain_fused(b[0], "data", root=3)
        unrolled = execute_schedule(sched, b[0], "data")
        return fused[None], unrolled[None]
    return jax.shard_map(f, mesh=mesh, in_specs=(P("data"),),
                         out_specs=(P("data"), P("data")))(xs)
f, u = both(xs)
np.testing.assert_array_equal(np.asarray(f), np.asarray(u))
np.testing.assert_array_equal(np.asarray(f), np.tile(np.asarray(xs[3]), (8, 1, 1)))
print("PASS")
"""
    )


def test_ring_allreduce(dist):
    """Paper Sec. VII future work: explicit ring allreduce == psum."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import ring_allreduce
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(7)
for size in (1, 7, 1000, 4097):
    xs = jnp.asarray(rng.randn(8, size).astype(np.float32))
    @jax.jit
    def run(xs):
        f = lambda b: ring_allreduce(b[0], "data")[None]
        return jax.shard_map(f, mesh=mesh, in_specs=(P("data"),), out_specs=P("data"))(xs)
    out = np.asarray(run(xs))
    want = np.asarray(xs).sum(0)
    for r in range(8):
        np.testing.assert_allclose(out[r], want, rtol=1e-5, atol=1e-5)
print("PASS")
"""
    )
