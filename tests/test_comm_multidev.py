"""On-device correctness for the repro.comm ops (simulated devices,
subprocess) + the trainer's tuned_allreduce acceptance test."""
from __future__ import annotations


def test_allreduce_allgather_reduce_scatter_pow2(dist):
    """Every comm op against its XLA one-shot reference on 8 ranks."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import pallreduce, pallgather, preduce_scatter, preduce

mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(0)
xs = jnp.asarray(rng.randn(8, 1013).astype(np.float32))
want_sum = np.asarray(xs).sum(0)

def run(fn, xs=xs):
    @jax.jit
    def f(xs):
        g = lambda b: fn(b[0])[None]
        # check_vma=False: the compiled executor's Pallas merge kernel has
        # no shard_map replication rule (same requirement as stage=True)
        return jax.shard_map(g, mesh=mesh, in_specs=(P("data"),),
                             out_specs=P("data"), check_vma=False)(xs)
    return np.asarray(f(xs))

for algo in ("auto", "reduce_then_bcast", "fused_rsb", "ring_allreduce", "xla_psum"):
    out = run(lambda b, a=algo: pallreduce(b, "data", algo=a))
    for r in range(8):
        np.testing.assert_allclose(out[r], want_sum, rtol=2e-5, atol=2e-5, err_msg=algo)
# unrolled (exact executor) == compiled fori_loop executor, pinned here in
# addition to the dedicated parity sweep (this one rides the pallreduce
# entry point end-to-end)
u = run(lambda b: pallreduce(b, "data", algo="fused_rsb", num_chunks=12, compiled=False))
f = run(lambda b: pallreduce(b, "data", algo="fused_rsb", num_chunks=12, compiled=True))
np.testing.assert_array_equal(u, f)

sh = jnp.asarray(rng.randn(8, 37).astype(np.float32))
for algo in ("auto", "ring_allgather", "doubling_allgather", "xla_allgather"):
    @jax.jit
    def ag(xs, a=algo):
        g = lambda b: pallgather(b[0], "data", algo=a)[None]
        return jax.shard_map(g, mesh=mesh, in_specs=(P("data"),),
                             out_specs=P("data", None), check_vma=False)(xs)
    out = np.asarray(ag(sh))
    for r in range(8):
        np.testing.assert_array_equal(out[r], np.asarray(sh), err_msg=algo)

x = jnp.asarray(rng.randn(8, 96).astype(np.float32))
out = run(lambda b: preduce_scatter(b, "data"), xs=x)
full = np.asarray(x).sum(0)
for r in range(8):
    np.testing.assert_allclose(out[r], full[r*12:(r+1)*12], rtol=2e-5, atol=2e-5)

out = run(lambda b: preduce(b, "data", root=3, algo="pipelined_reduce_chain"))
np.testing.assert_allclose(out[3], want_sum, rtol=2e-5, atol=2e-5)
print("PASS")
"""
    )


def test_allreduce_non_pow2_ranks(dist):
    """Schedule-based allreduce/allgather on 6 ranks (no pow2 anywhere)."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import pallreduce, pallgather

mesh = jax.make_mesh((6,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(1)
xs = jnp.asarray(rng.randn(6, 501).astype(np.float32))
want = np.asarray(xs).sum(0)
for algo in ("auto", "reduce_then_bcast", "fused_rsb", "ring_allreduce"):
    @jax.jit
    def f(xs, a=algo):
        g = lambda b: pallreduce(b[0], "data", algo=a)[None]
        return jax.shard_map(g, mesh=mesh, in_specs=(P("data"),),
                             out_specs=P("data"), check_vma=False)(xs)
    out = np.asarray(f(xs))
    for r in range(6):
        np.testing.assert_allclose(out[r], want, rtol=2e-5, atol=2e-5, err_msg=algo)
sh = jnp.asarray(rng.randn(6, 19).astype(np.float32))
@jax.jit
def ag(xs):
    g = lambda b: pallgather(b[0], "data", algo="ring_allgather")[None]
    return jax.shard_map(g, mesh=mesh, in_specs=(P("data"),),
                         out_specs=P("data", None), check_vma=False)(xs)
out = np.asarray(ag(sh))
for r in range(6):
    np.testing.assert_array_equal(out[r], np.asarray(sh))
print("PASS")
""",
        devices=6,
    )


def test_hierarchical_bcast_degenerate_meshes(dist):
    """hierarchical_bcast on degenerate topologies: single axis, 1-pod,
    1-rank data axis, and axes derived from the mesh itself."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import hierarchical_bcast

def check(mesh_shape, names):
    mesh = jax.make_mesh(mesh_shape, names,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(names))
    rng = np.random.RandomState(42)
    xs = jnp.asarray(rng.randn(*mesh_shape, 257).astype(np.float32))
    spec = P(*names)
    zeros = (0,) * len(names)
    @jax.jit
    def run(xs):
        def f(b):
            out = hierarchical_bcast(b[zeros], mesh=mesh, root=0)
            return out[(None,) * len(names)]
        return jax.shard_map(f, mesh=mesh, in_specs=(spec,), out_specs=spec)(xs)
    out = np.asarray(run(xs))
    want = np.asarray(xs[zeros])
    flat = out.reshape(-1, 257)
    for r in range(flat.shape[0]):
        np.testing.assert_allclose(flat[r], want, rtol=1e-6,
                                   err_msg=f"{mesh_shape}/{names} rank {r}")

check((8,), ("data",))              # single axis, no pod level
check((1, 8), ("pod", "data"))      # single pod (1-rank inter level)
check((8, 1), ("pod", "data"))      # 1-rank data axis (pods of one)
check((2, 4), ("pod", "data"))      # the standard two-level hierarchy

# 3-axis mesh: the bcast covers pod+data but leaves the model axis alone —
# every (p, d) converges to the root's value per model coordinate
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 3)
rng = np.random.RandomState(7)
xs = jnp.asarray(rng.randn(2, 2, 2, 129).astype(np.float32))
@jax.jit
def run3(xs):
    def f(b):
        out = hierarchical_bcast(b[0, 0, 0], mesh=mesh, root=0)
        return out[None, None, None]
    return jax.shard_map(f, mesh=mesh, in_specs=(P("pod", "data", "model"),),
                         out_specs=P("pod", "data", "model"))(xs)
out = np.asarray(run3(xs))
for p in range(2):
    for d in range(2):
        for m in range(2):
            np.testing.assert_allclose(out[p, d, m], np.asarray(xs[0, 0, m]),
                                       rtol=1e-6, err_msg=f"{p},{d},{m}")
print("PASS")
"""
    )


def test_overlap_tree_matches_barrier_tree(dist):
    """ISSUE acceptance: the overlap scheduler's per-bucket results equal
    the barrier pallreduce_tree results for random pytrees — pow2 and
    non-pow2 rank counts, flat and hierarchical (inter-pod) path classes,
    across depths, with and without chunked_copy staging."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import pallreduce_tree, overlap_allreduce_tree

rng = np.random.RandomState(0)

def check(mesh_shape, names, axes, inter_pod_axes):
    mesh = jax.make_mesh(mesh_shape, names,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(names))
    nd = int(np.prod(mesh_shape))
    tree = {"w": jnp.asarray(rng.randn(nd, 517).astype(np.float32)),
            "b": jnp.asarray(rng.randn(nd, 1201).astype(np.float32)),
            "s": jnp.asarray(rng.randn(nd, 33).astype(np.float32))}
    specs = jax.tree.map(lambda _: P(*names), tree)

    def run(fn):
        def g(t):
            sub = jax.tree.map(lambda x: x.reshape(x.shape[-1]), t)
            out = fn(sub)
            return jax.tree.map(lambda x: x[(None,) * len(names)], out)
        f = jax.jit(lambda t: jax.shard_map(
            g, mesh=mesh, in_specs=(specs,), out_specs=specs, check_vma=False)(t))
        return jax.tree.map(np.asarray, f(jax.tree.map(
            lambda x: x.reshape(mesh_shape + (x.shape[-1],)), tree)))

    barrier = run(lambda t: pallreduce_tree(
        t, axes, bucket_bytes=2048, inter_pod_axes=inter_pod_axes))
    for depth in (None, 1, 2, 4):
        for stage in (False, True):
            ov = run(lambda t, d=depth, s=stage: overlap_allreduce_tree(
                t, axes, bucket_bytes=2048, inter_pod_axes=inter_pod_axes,
                overlap_depth=d, stage=s))
            for k in barrier:
                np.testing.assert_array_equal(
                    barrier[k], ov[k],
                    err_msg=f"{mesh_shape} depth={depth} stage={stage} leaf={k}")

check((8,), ("data",), ["data"], ())            # pow2, flat
check((6,), ("data",), ["data"], ())            # non-pow2
check((2, 4), ("pod", "data"), ["data", "pod"], ("pod",))  # hierarchical
print("PASS")
""",
        timeout=580,
    )


def test_reduce_family_pad_tails_non_divisible(dist):
    """Satellite regression: zero-padded tails of non-divisible buffers
    never corrupt reduce-family results — preduce / pallreduce /
    preduce_scatter at awkward sizes and chunk counts, plus the max/min
    combiner routing (one-shot path, combined before padding)."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import pallreduce, preduce, preduce_scatter

n = 6
mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(3)

def run(fn, xs):
    @jax.jit
    def f(xs):
        g = lambda b: fn(b[0])[None]
        return jax.shard_map(g, mesh=mesh, in_specs=(P("data"),),
                             out_specs=P("data"), check_vma=False)(xs)
    return np.asarray(f(xs))

# sizes chosen so every chunking (schedule num_chunks, ring n-chunks)
# leaves a pad tail: primes and prime-ish odd sizes
for elems in (1, 7, 101, 1013):
    xs = jnp.asarray(rng.randn(n, elems).astype(np.float32))
    want = np.asarray(xs).sum(0)
    for algo, kw in (("fused_rsb", {"num_chunks": 7}),
                     ("ring_allreduce", {}), ("reduce_then_bcast", {})):
        out = run(lambda b, a=algo, k=kw: pallreduce(b, "data", algo=a, **k), xs)
        for r in range(n):
            np.testing.assert_allclose(out[r], want, rtol=2e-5, atol=2e-5,
                                       err_msg=f"{algo}/{elems}")
    out = run(lambda b: preduce(b, "data", root=2, algo="pipelined_reduce_chain",
                                num_chunks=5), xs)
    np.testing.assert_allclose(out[2], want, rtol=2e-5, atol=2e-5, err_msg=str(elems))
    out = run(lambda b: preduce_scatter(b, "data"), xs)
    shard = -(-elems // n)
    padded = np.concatenate([want, np.zeros(n * shard - elems, np.float32)])
    for r in range(n):
        np.testing.assert_allclose(out[r], padded[r*shard:(r+1)*shard],
                                   rtol=2e-5, atol=2e-5, err_msg=f"rs/{elems}")
    # max/min combiners: routed through the XLA one-shots, pad appended
    # AFTER combining (a zero tail must never win a max of negatives)
    neg = jnp.asarray(-np.abs(np.asarray(xs)) - 1.0)
    out = run(lambda b: pallreduce(b, "data", combiner="max"), neg)
    np.testing.assert_allclose(out[0], np.asarray(neg).max(0), rtol=1e-6)
    out = run(lambda b: preduce_scatter(b, "data", combiner="max"), neg)
    wmax = np.concatenate([np.asarray(neg).max(0),
                           np.zeros(n * shard - elems, np.float32)])
    for r in range(n):
        np.testing.assert_allclose(out[r], wmax[r*shard:(r+1)*shard], rtol=1e-6,
                                   err_msg=f"max-rs/{elems}")
print("PASS")
""",
        devices=6,
        timeout=580,
    )


def test_serving_double_buffer_distribution_matches_barrier(dist):
    """serve.engine.distribute_weights double-buffered mode: bucket k+1
    stages through chunked_copy while bucket k broadcasts — identical
    distributed weights to the barrier replay."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.serve.engine import distribute_weights

mesh = jax.make_mesh((2, 4), ("pod", "data"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
rng = np.random.RandomState(11)
params = {"w1": jnp.asarray(rng.randn(64, 33).astype(np.float32)),
          "w2": jnp.asarray(rng.randn(257,).astype(np.float32)),
          "w3": jnp.asarray(rng.randn(5, 7, 3).astype(np.float32))}
base = distribute_weights(params, mesh, bucket_bytes=2048)
for depth in (1, 2, 3):
    dbl = distribute_weights(params, mesh, bucket_bytes=2048,
                             double_buffer=True, overlap_depth=depth)
    for k in params:
        np.testing.assert_array_equal(np.asarray(base[k]), np.asarray(dbl[k]),
                                      err_msg=f"{k}@depth{depth}")
print("PASS")
"""
    )


def _compiled_parity_snippet(n: int) -> str:
    return f"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import plan_collective, apply_plan

n = {n}
mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(0)

def run(fn, xs, out_spec=P("data")):
    @jax.jit
    def f(xs):
        g = lambda b: fn(b[0])[None]
        return jax.shard_map(g, mesh=mesh, in_specs=(P("data"),),
                             out_specs=out_spec, check_vma=False)(xs)
    return np.asarray(f(xs))

# (op, algo, plan kwargs) x (divisible, ragged) element counts. Both
# executors replay the SAME plan; results must be bit-identical.
cases = [
    ("bcast", "pipelined_chain", {{"num_chunks": 12}}),
    ("bcast", "bidir_chain", {{"num_chunks": 12}}),
    ("bcast", "binomial", {{}}),
    ("reduce", "pipelined_reduce_chain", {{"num_chunks": 5}}),
    ("reduce", "binomial_reduce", {{}}),
    ("allreduce", "fused_rsb", {{"num_chunks": 12}}),
    ("allreduce", "ring_allreduce", {{}}),
    ("allreduce", "reduce_then_bcast", {{}}),
    ("reduce_scatter", "ring_reduce_scatter", {{}}),
]
for elems in (8 * 12, 1013):
    for op, algo, kw in cases:
        xs = jnp.asarray(rng.randn(n, elems).astype(np.float32))
        plan = plan_collective(op, elems * 4, n, algo=algo, **kw)
        u = run(lambda b: apply_plan(plan, b, "data", compiled=False), xs)
        c = run(lambda b: apply_plan(plan, b, "data", compiled=True), xs)
        np.testing.assert_array_equal(u, c, err_msg=f"{{op}}/{{algo}}/{{elems}}")
        # the unrolled executor is the long-standing reference; pin the
        # compiled result to the op's semantics too via rank 0
        if op == "allreduce":
            np.testing.assert_allclose(c[0], np.asarray(xs).sum(0),
                                       rtol=2e-5, atol=2e-5, err_msg=algo)
        elif op == "bcast":
            np.testing.assert_array_equal(c[1], np.asarray(xs[0]), err_msg=algo)

    # allgather stacks (n, shard): shard shapes per rank
    sh = jnp.asarray(rng.randn(n, 37).astype(np.float32))
    algos = ["ring_allgather"] + (["doubling_allgather"] if n & (n - 1) == 0 else [])
    for algo in algos:
        plan = plan_collective("allgather", n * 37 * 4, n, algo=algo)
        u = run(lambda b: apply_plan(plan, b, "data", compiled=False)[None][0],
                sh, out_spec=P("data", None))
        c = run(lambda b: apply_plan(plan, b, "data", compiled=True)[None][0],
                sh, out_spec=P("data", None))
        np.testing.assert_array_equal(u, c, err_msg=algo)
        for r in range(n):
            np.testing.assert_array_equal(c[r], np.asarray(sh), err_msg=algo)
print("PASS")
"""


def test_compiled_executor_parity_pow2(dist):
    """ISSUE acceptance: the generic compiled executor (fori_loop over the
    lowered round tables + fused Pallas combine) is bit-identical to the
    unrolled execute_collective for every op on 8 ranks, divisible and
    ragged sizes."""
    dist(_compiled_parity_snippet(8), timeout=580)


def test_compiled_executor_parity_non_pow2(dist):
    """Same sweep on 6 ranks (no power of two anywhere)."""
    dist(_compiled_parity_snippet(6), devices=6, timeout=580)


def test_compiled_path_engages_and_matches_in_consumers(dist):
    """The tuned routing policy + explicit compiled pins inside the consumer
    entry points: pallreduce/pbcast with compiled=True equal their unrolled
    twins on awkward sizes, and a huge-round plan auto-routes to the
    compiled executor (old fused-executor territory) while still matching."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import pallreduce, pbcast, plan_collective
from repro.comm.api import _use_compiled

mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(5)
xs = jnp.asarray(rng.randn(8, 1013).astype(np.float32))

def run(fn):
    @jax.jit
    def f(xs):
        g = lambda b: fn(b[0])[None]
        return jax.shard_map(g, mesh=mesh, in_specs=(P("data"),),
                             out_specs=P("data"), check_vma=False)(xs)
    return np.asarray(f(xs))

want = np.asarray(xs).sum(0)
for algo in ("fused_rsb", "ring_allreduce"):
    u = run(lambda b, a=algo: pallreduce(b, "data", algo=a, compiled=False))
    c = run(lambda b, a=algo: pallreduce(b, "data", algo=a, compiled=True))
    np.testing.assert_array_equal(u, c, err_msg=algo)
    np.testing.assert_allclose(c[0], want, rtol=2e-5, atol=2e-5, err_msg=algo)
u = run(lambda b: pbcast(b, "data", algo="pipelined_chain", num_chunks=9,
                         compiled=False))
c = run(lambda b: pbcast(b, "data", algo="pipelined_chain", num_chunks=9,
                         compiled=True))
np.testing.assert_array_equal(u, c)

# auto policy: >256-round chain plans route compiled (the deleted
# hand-written fused executors' territory); ring allgather is zero-waste
# and routes compiled at its small round count too
big = plan_collective("allreduce", 4096 * 4, 8, algo="fused_rsb", num_chunks=300)
assert big.schedule.num_rounds > 256
assert _use_compiled(big, fused=True, compiled=None)
assert not _use_compiled(big, fused=False, compiled=None)
ring = plan_collective("allgather", 8 * 64 * 4, 8, algo="ring_allgather")
assert not _use_compiled(ring, fused=True, compiled=None)  # 7 rounds: unrolled
# ring_allreduce is zero-waste (both phases on one class), so it keeps the
# old always-fused behavior from 2(n-1) >= 8 rounds on
ring_ar = plan_collective("allreduce", 4096 * 4, 8, algo="ring_allreduce")
assert _use_compiled(ring_ar, fused=True, compiled=None)
small = plan_collective("allreduce", 4096 * 4, 8, algo="fused_rsb", num_chunks=8)
assert not _use_compiled(small, fused=True, compiled=None)

u = run(lambda b: pallreduce(b, "data", algo="fused_rsb", num_chunks=300,
                             compiled=False))
c = run(lambda b: pallreduce(b, "data", algo="fused_rsb", num_chunks=300))
np.testing.assert_array_equal(u, c)
print("PASS")
""",
        timeout=580,
    )


def test_inkernel_executor_parity_all_ops(dist):
    """ISSUE acceptance (PR 8): the in-kernel executor — ONE persistent
    Pallas launch replaying the whole lowered schedule — is bit-identical
    to the unrolled executor for every dense op through the public entry
    points on 8 ranks."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import (pallgather, pallreduce, pbcast, preduce,
                        preduce_scatter)

n = 8
mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.RandomState(9)

def run(fn, xs, out_spec=P("data")):
    @jax.jit
    def f(xs):
        g = lambda b: fn(b[0])[None]
        return jax.shard_map(g, mesh=mesh, in_specs=(P("data"),),
                             out_specs=out_spec, check_vma=False)(xs)
    return np.asarray(f(xs))

def parity(fn, xs, out_spec=P("data")):
    # inkernel=True forces the single-launch replay; inkernel=False +
    # compiled=False pins the long-standing unrolled reference
    ink = run(lambda b: fn(b, inkernel=True), xs, out_spec)
    unr = run(lambda b: fn(b, inkernel=False, compiled=False), xs, out_spec)
    np.testing.assert_array_equal(ink, unr)
    return ink

for elems in (8 * 12, 1013):
    xs = jnp.asarray(rng.randn(n, elems).astype(np.float32))
    out = parity(lambda b, **k: pbcast(b, "data", algo="pipelined_chain",
                                       num_chunks=12, **k), xs)
    np.testing.assert_array_equal(out[5], np.asarray(xs[0]))
    parity(lambda b, **k: pbcast(b, "data", algo="bidir_chain",
                                 num_chunks=12, **k), xs)
    out = parity(lambda b, **k: preduce(b, "data", root=3,
                                        algo="pipelined_reduce_chain",
                                        num_chunks=5, **k), xs)
    np.testing.assert_allclose(out[3], np.asarray(xs).sum(0),
                               rtol=2e-5, atol=2e-5)
    for algo in ("fused_rsb", "ring_allreduce"):
        kw = {"num_chunks": 12} if algo == "fused_rsb" else {}
        out = parity(lambda b, a=algo, k=kw, **kk: pallreduce(
            b, "data", algo=a, **k, **kk), xs)
        np.testing.assert_allclose(out[0], np.asarray(xs).sum(0),
                                   rtol=2e-5, atol=2e-5, err_msg=algo)
    out = parity(lambda b, **k: preduce_scatter(b, "data", **k), xs)
    shard = -(-elems // n)
    full = np.concatenate([np.asarray(xs).sum(0),
                           np.zeros(n * shard - elems, np.float32)])
    for r in range(n):
        np.testing.assert_allclose(out[r], full[r*shard:(r+1)*shard],
                                   rtol=2e-5, atol=2e-5)

sh = jnp.asarray(rng.randn(n, 37).astype(np.float32))
for algo in ("ring_allgather", "doubling_allgather"):
    out = parity(lambda b, a=algo, **k: pallgather(b, "data", algo=a, **k)[None][0],
                 sh, out_spec=P("data", None))
    for r in range(n):
        np.testing.assert_array_equal(out[r], np.asarray(sh), err_msg=algo)
print("PASS")
""",
        timeout=580,
    )


def test_inkernel_executor_parity_ragged(dist):
    """The ragged pair through the in-kernel replay on 4 ranks, including
    zero-sized ranks: pallgatherv/palltoallv with inkernel=True equal the
    unrolled reference bit-for-bit and the host-side oracle."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.comm import palltoallv, pallgatherv

n, E = 4, 3
mesh = Mesh(np.array(jax.devices()[:n]), ("x",))
rng = np.random.RandomState(4)

for sizes in [(3, 1, 0, 2), (5, 0, 0, 7)]:
    smax = max(sizes); total = sum(sizes)
    full = rng.randn(total, E).astype(np.float32)
    off = np.concatenate([[0], np.cumsum(sizes)])
    loc = np.full((n, smax, E), 99.0, np.float32)
    for r in range(n):
        loc[r, :sizes[r]] = full[off[r]:off[r + 1]]
    outs = {}
    for label, kw in (("ink", dict(inkernel=True)),
                      ("unr", dict(inkernel=False, compiled=False))):
        f = shard_map(
            lambda v, k=kw: pallgatherv(v, "x", sizes=sizes, **k),
            mesh=mesh, in_specs=P("x"), out_specs=P(), check_rep=False)
        outs[label] = np.asarray(f(jnp.asarray(loc.reshape(n * smax, E))))
    assert np.array_equal(outs["ink"], outs["unr"]), sizes
    assert np.array_equal(outs["ink"], full), sizes

m = np.array([[2, 0, 1, 3], [0, 0, 0, 0], [1, 4, 0, 0], [2, 2, 2, 2]], np.int64)
send = m.sum(axis=1); recv = m.sum(axis=0)
smax = max(int(send.max()), 1); rmax = max(int(recv.max()), 1)
blocks = {(s, d): rng.randn(int(m[s, d]), E).astype(np.float32)
          for s in range(n) for d in range(n)}
xin = np.full((n, smax, E), 88.0, np.float32)
for s in range(n):
    xin[s, :send[s]] = np.concatenate(
        [blocks[(s, d)] for d in range(n)] + [np.zeros((0, E), np.float32)])
exp = np.zeros((n, rmax, E), np.float32)
for r in range(n):
    exp[r, :recv[r]] = np.concatenate(
        [blocks[(s, r)] for s in range(n)] + [np.zeros((0, E), np.float32)])
for algo in ("pairwise_alltoallv", "ring_alltoallv"):
    outs = {}
    for label, kw in (("ink", dict(inkernel=True)),
                      ("unr", dict(inkernel=False, compiled=False))):
        f = shard_map(
            lambda v, a=algo, k=kw: palltoallv(v, "x", sizes=m.tolist(),
                                               algo=a, **k),
            mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_rep=False)
        outs[label] = np.asarray(
            f(jnp.asarray(xin.reshape(n * smax, E)))).reshape(n, rmax, E)
    assert np.array_equal(outs["ink"], outs["unr"]), algo
    assert np.array_equal(outs["ink"], exp), algo
print("PASS")
""",
        devices=4,
        timeout=580,
    )


def test_trainer_tuned_allreduce_matches_psum_baseline(dist):
    """ISSUE acceptance: sync_mode='tuned_allreduce' produces params
    allclose to the GSPMD/psum baseline on a multi-device mesh (identical
    math, summation order aside — bf16 params tolerate 1-2 ulp)."""
    dist(
        """
import jax, numpy as np
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.train.trainer import Trainer
from repro.launch.mesh import make_local_mesh

cfg = get_config("xlstm-350m-smoke")
mesh = make_local_mesh(1)
runs = {}
for mode in ("grad_allreduce", "tuned_allreduce"):
    run = RunConfig(total_steps=4, warmup_steps=1, sync_mode=mode,
                    learning_rate=1e-3, seed=7)
    params, _, hist = Trainer(cfg, run, mesh=mesh).train(
        batch=8, seq=32, steps=4, log_every=3)
    runs[mode] = (jax.device_get(params), hist)

p1, h1 = runs["grad_allreduce"]; p2, h2 = runs["tuned_allreduce"]
assert abs(h1[0]["loss"] - h2[0]["loss"]) < 2e-3, (h1[0], h2[0])
assert abs(h1[-1]["loss"] - h2[-1]["loss"]) < 2e-2, (h1[-1], h2[-1])
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               atol=5e-3, rtol=1e-2)
print("PASS")
""",
        timeout=580,
    )


def test_trainer_overlap_allreduce_matches_tuned(dist):
    """ISSUE acceptance (transitive leg): sync_mode='overlap_allreduce'
    tracks sync_mode='tuned_allreduce' to float32 tolerance — same
    per-bucket plans and summation order, only the dispatch schedule
    differs. Together with the psum-baseline test this closes
    overlap == tuned == psum."""
    dist(
        """
import jax, numpy as np
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.train.trainer import Trainer
from repro.launch.mesh import make_local_mesh

cfg = get_config("xlstm-350m-smoke")
mesh = make_local_mesh(1)
runs = {}
for mode in ("tuned_allreduce", "overlap_allreduce"):
    run = RunConfig(total_steps=4, warmup_steps=1, sync_mode=mode,
                    learning_rate=1e-3, seed=7)
    params, _, hist = Trainer(cfg, run, mesh=mesh).train(
        batch=8, seq=32, steps=4, log_every=3)
    runs[mode] = (jax.device_get(params), hist)

(pt, ht), (po, ho) = runs["tuned_allreduce"], runs["overlap_allreduce"]
assert abs(ht[-1]["loss"] - ho[-1]["loss"]) < 1e-4, (ht[-1], ho[-1])
for a, b in zip(jax.tree.leaves(pt), jax.tree.leaves(po)):
    np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                               atol=1e-6, rtol=1e-6)
print("PASS")
""",
        timeout=580,
    )


def test_trainer_tuned_allreduce_each_algorithm(dist):
    """Every allreduce strategy drives the same training trajectory."""
    dist(
        """
import numpy as np
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.train.trainer import Trainer
from repro.launch.mesh import make_local_mesh

cfg = get_config("xlstm-350m-smoke")
losses = {}
for algo in ("auto", "fused_rsb", "ring_allreduce", "xla_psum"):
    run = RunConfig(total_steps=2, warmup_steps=1, sync_mode="tuned_allreduce",
                    allreduce_algo=algo, learning_rate=1e-3, seed=7)
    tr = Trainer(cfg, run, mesh=make_local_mesh(1))
    _, _, hist = tr.train(batch=8, seq=32, steps=2, log_every=1)
    losses[algo] = [h["loss"] for h in hist]
vals = list(losses.values())
for v in vals[1:]:
    assert abs(v[0] - vals[0][0]) < 1e-3, losses
    assert abs(v[-1] - vals[0][-1]) < 0.05, losses
print("PASS")
""",
        # four trainer builds in one subprocess: ~565 s on an idle 8-core
        # runner, which left the old 580 s budget ~2% of headroom and
        # timed out under suite-level load
        timeout=840,
    )
