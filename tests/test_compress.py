"""Compressed-wire collectives: quantize kernel properties, error-feedback
boundedness, wire-byte accounting, the online bandit tuning loop, and the
compress-table artifact gate (ISSUE: compressed-wire collectives with error
feedback + online bandit autotuning)."""
from __future__ import annotations

import json
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.comm.compress import (
    CompressionState,
    WireFormat,
    normalize_wire_format,
    roundtrip,
    wire_chunk_bytes,
)
from repro.comm.plan import cache_stats, expected_wire_bytes, plan_cached
from repro.comm.tables import TableSchemaError, load_compress_table
from repro.core.cost_model import (
    TPU_V5E,
    calibrate_link_classes,
    cost_link_class,
    cost_wire,
)
from repro.core.tuner import OnlineTuner, Tuner
from repro.kernels.ops import dequantize_blocks, quantize_blocks
from repro.kernels.quantize import BLOCK_ELEMS


def _rt(x, fmt):
    v, s = quantize_blocks(jnp.asarray(x), fmt, interpret=True)
    return np.asarray(
        dequantize_blocks(v, s, out_cols=x.shape[1], interpret=True)
    )


def _block_amax(x):
    """Per-element abs-max of the 256-block each element belongs to."""
    B, C = x.shape
    Cp = -(-C // BLOCK_ELEMS) * BLOCK_ELEMS
    xp = np.pad(np.abs(x), ((0, 0), (0, Cp - C)))
    amax = xp.reshape(B, -1, BLOCK_ELEMS).max(axis=2)
    return np.repeat(amax, BLOCK_ELEMS, axis=1)[:, :C]


# ---------------------------------------------------------------------------
# quantize -> dequantize roundtrip error bounds (per format, per block shape)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cols", [BLOCK_ELEMS, 4 * BLOCK_ELEMS, 300, 100])
def test_int8_roundtrip_error_within_half_step(cols):
    """Symmetric abs-max int8: the worst element error is half a
    quantization step, amax/(2*127), per 256-block."""
    x = np.random.RandomState(0).randn(3, cols).astype(np.float32) * 10.0
    err = np.abs(x - _rt(x, "int8"))
    bound = _block_amax(x) / (2 * 127.0) * (1 + 1e-5) + 1e-12
    assert (err <= bound).all(), float((err - bound).max())


@pytest.mark.parametrize("cols", [BLOCK_ELEMS, 4 * BLOCK_ELEMS, 300])
def test_fp8_roundtrip_error_within_relative_ulp(cols):
    """e4m3 payload: relative error bounded by a half-ulp of the 3-bit
    mantissa (2^-4) plus the subnormal step near zero."""
    x = np.random.RandomState(1).randn(3, cols).astype(np.float32)
    err = np.abs(x - _rt(x, "fp8"))
    bound = np.abs(x) / 16.0 + _block_amax(x) / 448.0 * 2.0**-9 + 1e-12
    assert (err <= bound * (1 + 1e-5)).all(), float((err - bound).max())


def test_fp8_extreme_values_saturate_not_nan():
    """float8_e4m3fn has no inf: an out-of-range cast is NaN, so the
    kernel's clip-before-cast is what keeps +-3e38 inputs finite."""
    x = np.zeros((1, BLOCK_ELEMS), np.float32)
    x[0, 0], x[0, 1], x[0, 2] = 3e38, -3e38, 1.0
    out = _rt(x, "fp8")
    assert np.isfinite(out).all(), out[0, :4]
    assert out[0, 0] > 0 and out[0, 1] < 0
    np.testing.assert_allclose(out[0, 0], 3e38, rtol=0.07)


@pytest.mark.parametrize("fmt", ["int8", "fp8"])
def test_zero_block_roundtrips_to_exact_zeros(fmt):
    x = np.zeros((2, 2 * BLOCK_ELEMS), np.float32)
    assert (_rt(x, fmt) == 0.0).all()


@pytest.mark.parametrize("fmt", ["int8", "fp8"])
def test_zero_sized_and_ragged_shapes(fmt):
    v, s = quantize_blocks(jnp.zeros((0, 300), jnp.float32), fmt, interpret=True)
    assert v.shape == (0, 2 * BLOCK_ELEMS) and s.shape == (0, 2)
    out = dequantize_blocks(v, s, out_cols=300, interpret=True)
    assert out.shape == (0, 300)
    # ragged tail: padded to the block on the wire, sliced off on the way out
    x = np.random.RandomState(2).randn(2, 300).astype(np.float32)
    v, s = quantize_blocks(jnp.asarray(x), fmt, interpret=True)
    assert v.shape == (2, 2 * BLOCK_ELEMS) and s.shape == (2, 2)
    assert dequantize_blocks(v, s, out_cols=300, interpret=True).shape == (2, 300)


def test_quantize_unknown_format_rejected():
    with pytest.raises(ValueError, match="unknown quantize format"):
        quantize_blocks(jnp.zeros((1, 256), jnp.float32), "int4", interpret=True)


def test_bf16_roundtrip_is_identity():
    x = jnp.asarray(np.random.RandomState(3).randn(7, 33), jnp.bfloat16)
    assert roundtrip(x, "bf16") is x
    y = roundtrip(x, "int8", interpret=True)
    assert y.dtype == x.dtype and y.shape == x.shape


# ---------------------------------------------------------------------------
# error-feedback residual
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt,delta", [("int8", 1 / 127.0), ("fp8", 1 / 8.0)])
def test_ef_residual_stays_bounded(fmt, delta):
    """e_{t+1} = c_t - Q(c_t) with c_t = g + e_t: with per-hop relative
    error delta the residual norm stays under delta*|g|/(1-delta) — it
    accumulates nothing across steps."""
    g = {"w": jnp.asarray(np.random.RandomState(4).randn(3, 700), jnp.float32)}
    e = CompressionState.init(g)
    gnorm = float(jnp.linalg.norm(g["w"]))
    bound = delta * gnorm / (1 - delta)
    for _ in range(12):
        c = CompressionState.compensate(g, e)
        e = CompressionState.update(c, fmt, interpret=True)
        assert float(jnp.linalg.norm(e["w"])) <= bound, fmt


def test_ef_passthrough_residual_is_zero():
    g = {"w": jnp.ones((2, 300), jnp.float32)}
    e = CompressionState.update(CompressionState.compensate(g, CompressionState.init(g)), "bf16")
    assert float(jnp.abs(e["w"]).max()) == 0.0


# ---------------------------------------------------------------------------
# wire-byte accounting
# ---------------------------------------------------------------------------


def test_wire_chunk_bytes_closed_form():
    assert wire_chunk_bytes("bf16", 12345) == 12345
    assert wire_chunk_bytes("int8", 0) == 0
    for nbytes in (4, 1024, 1025, 4096, 123456):
        elems = -(-nbytes // 4)
        blocks = -(-elems // BLOCK_ELEMS)
        assert wire_chunk_bytes("fp8", nbytes) == blocks * (BLOCK_ELEMS + 4)
    with pytest.raises(ValueError, match="unknown wire format"):
        wire_chunk_bytes("int4", 1024)


@pytest.mark.parametrize("op,algo", [
    ("allreduce", "ring_allreduce"),
    ("allreduce", "fused_rsb"),
    ("bcast", "pipelined_chain"),
    ("bcast", "scatter_allgather"),
    ("allgather", "ring_allgather"),
    ("reduce_scatter", "ring_reduce_scatter"),
    ("reduce", "pipelined_reduce_chain"),
])
@pytest.mark.parametrize("fmt", ["bf16", "fp8", "int8"])
def test_plan_wire_bytes_match_closed_form(op, algo, fmt):
    """The schedule-walk accounting (plan.wire_bytes sums physical transfer
    sizes) and the closed form agree exactly for every format."""
    for M in (4096, 1 << 20):
        plan = plan_cached(op, M, 8, algo=algo, wire_format=fmt)
        want = expected_wire_bytes(op, algo, M, 8, num_chunks=plan.num_chunks,
                                   wire_format=fmt)
        assert plan.wire_bytes() == int(want), (op, algo, fmt, M)
        if fmt != "bf16" and M >= 1 << 20:
            # at block-aligned chunk sizes the physical ratio sits just
            # under the nominal 4x (scale sidecar); tiny chunks pay real
            # block padding and are excluded (they ship those bytes too)
            full = expected_wire_bytes(op, algo, M, 8, num_chunks=plan.num_chunks)
            ratio = full / plan.wire_bytes()
            assert 3.4 <= ratio <= 4.0, (op, algo, M, ratio)


def test_compressed_rejections():
    # one-shot baselines have no per-hop seam to compress at
    with pytest.raises(ValueError, match="one-shot"):
        plan_cached("bcast", 4096, 4, algo="xla_psum", wire_format="int8")
    # ragged plans carry per-rank size vectors the block quantizer does not
    with pytest.raises(ValueError):
        plan_cached("allgatherv", 4096, 4, sizes=(1, 2, 3, 4),
                    wire_format="int8")
    # the in-kernel executor replays raw copy/combine rounds — no seam
    from repro.comm.api import _resolve_exec_path

    plan = plan_cached("allreduce", 1 << 16, 4, algo="ring_allreduce",
                       wire_format="int8")
    with pytest.raises(ValueError, match="in-kernel executor does not support"):
        _resolve_exec_path(plan, inkernel=True)
    _resolve_exec_path(plan)  # policy path: silently avoids inkernel


# ---------------------------------------------------------------------------
# tuner: record extras registry + online bandit loop
# ---------------------------------------------------------------------------


def test_record_unknown_dimension_rejected_eagerly():
    t = Tuner(TPU_V5E)
    with pytest.raises(ValueError, match="unknown record dimension"):
        t.record(1 << 20, 8, "ring_allreduce", 8, 1e-3, op="allreduce",
                 extras={"compression_level": 3})
    # eagerly: even a non-improving measurement must not smuggle a typo past
    t.record(1 << 20, 8, "ring_allreduce", 8, 1e-3, op="allreduce",
             extras={"wire_format": "int8"})
    with pytest.raises(ValueError):
        t.record(1 << 20, 8, "ring_allreduce", 8, 5.0, op="allreduce",
                 extras={"wire_fmt": "int8"})


def test_record_rejects_bad_wire_format_value():
    t = Tuner(TPU_V5E)
    with pytest.raises(ValueError):
        t.record(1 << 20, 8, "ring_allreduce", 8, 1e-3, op="allreduce",
                 extras={"wire_format": "int4"})


def test_online_tuner_rejects_ragged_ops():
    with pytest.raises(ValueError, match="ragged"):
        OnlineTuner(Tuner(TPU_V5E), "allgatherv", 1 << 20, 8)


def test_online_tuner_converges_to_planted_best():
    """Untried arms are visited first in deterministic order, so a rigged
    landscape's best (algo, wire_format) arm is found within len(arms)
    steps; the winning exploration lands in the table and every cached plan
    for the point is invalidated through the tuner fingerprint."""
    M, n = 1 << 20, 8
    t = Tuner(TPU_V5E)
    ot = OnlineTuner(
        t, "allreduce", M, n, epsilon=0.0,
        arms=[("reduce_then_bcast", None, "bf16"),
              ("ring_allreduce", None, "bf16"),
              ("ring_allreduce", None, "int8")],
    )
    # monotonically improving rig (record is improvement-only, so each
    # observation must beat the last to land): planted best is the
    # compressed ring
    rig = {("reduce_then_bcast", "bf16"): 5e-3,
           ("ring_allreduce", "bf16"): 3e-3,
           ("ring_allreduce", "int8"): 1e-3}
    fp0 = t.fingerprint()
    plan_cached("allreduce", M, n, tuner=t)
    misses0 = cache_stats()["misses"]
    seen = []
    for _ in range(len(ot.arms)):
        dec, _s = ot.step(lambda d: rig[(d.algo, d.wire_format or "bf16")])
        seen.append((dec.algo, dec.wire_format or "bf16"))
    assert seen == list(rig)  # deterministic untried-first order
    assert ot.best_arm()[0] == "ring_allreduce" and ot.best_arm()[2] == "int8"
    assert t.fingerprint() != fp0
    # post-convergence, the planned decision IS the planted best arm
    dec = ot.propose()
    assert (dec.algo, dec.wire_format) == ("ring_allreduce", "int8")
    # the fingerprint bump forces a re-plan: same point, new cache key
    plan = plan_cached("allreduce", M, n, tuner=t)
    assert cache_stats()["misses"] > misses0
    assert plan.wire_format is WireFormat.INT8


def test_online_tuner_cost_wire_prices_compression():
    """The explorer's predicted times come from cost_wire: at bandwidth-
    bound sizes the compressed wire must price cheaper than bf16, and the
    quantize HBM toll must keep it above the naive 260/1024 scaling."""
    M, n = 64 << 20, 8
    full = cost_wire("ring_allreduce", M, n, wire_format="bf16")
    comp = cost_wire("ring_allreduce", M, n, wire_format="int8")
    assert comp < full
    assert comp > full * (260.0 / 1024.0)


# ---------------------------------------------------------------------------
# link-class calibration (asymmetric links price differently)
# ---------------------------------------------------------------------------


def test_calibrate_link_classes_recovers_planted_constants():
    bw, ts = 2.5e10, 3e-6
    samples = {"ici": [(B, ts + B / bw) for B in (1 << 10, 1 << 16, 1 << 22)]}
    got = calibrate_link_classes(samples)["ici"]
    np.testing.assert_allclose(got.bw, bw, rtol=1e-6)
    np.testing.assert_allclose(got.ts, ts, rtol=1e-6)


def test_asymmetric_link_classes_price_differently():
    classes = calibrate_link_classes({
        "up": [(B, 1e-6 + B / 4e10) for B in (1 << 12, 1 << 20)],
        "down": [(B, 1e-6 + B / 1e10) for B in (1 << 12, 1 << 20)],
    })
    fast = cost_link_class("ring_allreduce", 8 << 20, 8, classes["up"])
    slow = cost_link_class("ring_allreduce", 8 << 20, 8, classes["down"])
    assert slow > 2.0 * fast, (fast, slow)


def test_calibrate_link_classes_rejects_unidentifiable_fits():
    with pytest.raises(ValueError):
        calibrate_link_classes({"ici": [(1024, 1e-3)]})  # one size
    with pytest.raises(ValueError):
        calibrate_link_classes({"ici": [(1024, 1e-3), (1 << 20, 1e-3)]})  # flat


# ---------------------------------------------------------------------------
# compress-table artifact gate
# ---------------------------------------------------------------------------


def _table_entry(op, algo, M, n, fmt, wall_s):
    plan = plan_cached(op, M, n, algo=algo, wire_format=fmt)
    k = plan.num_chunks
    full = int(expected_wire_bytes(op, algo, M, n, num_chunks=k))
    wire = plan.wire_bytes()
    return {
        "wire_bytes": wire,
        "expected_wire_bytes": wire,
        "full_wire_bytes": full,
        "ratio": full / wire,
        "num_chunks": k,
        "wall_s": wall_s,
    }


def test_load_compress_table_accepts_valid_and_rejects_tamper(tmp_path):
    M, n = 1 << 20, 4
    table = {
        f"allreduce/n{n}/ring_allreduce/bf16/M{M}":
            _table_entry("allreduce", "ring_allreduce", M, n, "bf16", 2e-3),
        f"allreduce/n{n}/ring_allreduce/int8/M{M}":
            _table_entry("allreduce", "ring_allreduce", M, n, "int8", 1e-3),
    }
    p = tmp_path / "compress_table.json"
    p.write_text(json.dumps(table))
    loaded = load_compress_table(str(p))
    assert len(loaded) == 2

    # tamper 1: hand-edited wire bytes drift from the closed form
    bad = json.loads(json.dumps(table))
    key = f"allreduce/n{n}/ring_allreduce/int8/M{M}"
    bad[key]["wire_bytes"] //= 2
    bad[key]["expected_wire_bytes"] //= 2
    bad[key]["ratio"] = bad[key]["full_wire_bytes"] / bad[key]["wire_bytes"]
    p.write_text(json.dumps(bad))
    with pytest.raises(TableSchemaError):
        load_compress_table(str(p))

    # tamper 2: ratio field inconsistent with its own byte columns
    bad = json.loads(json.dumps(table))
    bad[key]["ratio"] = 2.0
    p.write_text(json.dumps(bad))
    with pytest.raises(TableSchemaError):
        load_compress_table(str(p))

    # tamper 3: compressed slower than bf16 at the group's largest M —
    # shipping a quarter of the bytes stopped paying for itself
    bad = json.loads(json.dumps(table))
    bad[key]["wall_s"] = 3e-3
    p.write_text(json.dumps(bad))
    with pytest.raises(TableSchemaError):
        load_compress_table(str(p))

    # tamper 4: an all-bf16 table gates nothing
    bad = {k: v for k, v in table.items() if "/bf16/" in k}
    p.write_text(json.dumps(bad))
    with pytest.raises(TableSchemaError):
        load_compress_table(str(p))


def test_committed_compress_table_loads():
    table = load_compress_table("experiments/compress_table.json")
    assert any("/int8/" in k or "/fp8/" in k for k in table)


# ---------------------------------------------------------------------------
# multi-device: compressed executors vs the psum oracle; EF trainer
# ---------------------------------------------------------------------------


def test_compressed_allreduce_matches_psum_oracle(dist):
    """Per-hop compressed execution vs the one-shot psum: int8 within ~2%
    (error compounds over the ring's 2(n-1) hops), fp8 within ~9%, bf16
    passthrough bit-identical to the uncompressed plan."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import pallreduce

n = 4
mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
xs = jnp.asarray(np.random.RandomState(0).randn(n, 2048).astype(np.float32))

def run(algo, fmt):
    f = lambda b: pallreduce(b[0], "data", algo=algo, wire_format=fmt)[None]
    return np.asarray(jax.jit(jax.shard_map(
        f, mesh=mesh, in_specs=(P("data"),), out_specs=P("data"),
        check_vma=False))(xs))[0]

oracle = np.asarray(xs).sum(axis=0)
scale = np.abs(oracle).max()
for fmt, tol in (("int8", 0.02), ("fp8", 0.09)):
    got = run("ring_allreduce", fmt)
    rel = np.abs(got - oracle).max() / scale
    assert rel <= tol, (fmt, rel)

np.testing.assert_array_equal(run("ring_allreduce", "bf16"),
                              run("ring_allreduce", None))

# non-sum combiners have no compression seam (executors combine by sum only)
try:
    run_max = lambda b: pallreduce(b[0], "data", combiner="max",
                                   wire_format="int8")[None]
    jax.jit(jax.shard_map(run_max, mesh=mesh, in_specs=(P("data"),),
                          out_specs=P("data"), check_vma=False))(xs)
    raise SystemExit("non-sum combiner + compressed wire must be rejected")
except ValueError as e:
    assert "sum" in str(e), e
print("PASS")
""",
        devices=4,
        timeout=300,
    )


def test_trainer_compressed_allreduce_tracks_baseline(dist):
    """ISSUE acceptance: sync_mode='compressed_allreduce' with the bf16
    passthrough is bit-identical to tuned_allreduce (same grads cross the
    wire, the EF path is compiled out), and the int8 error-feedback run
    tracks the full-precision loss trajectory within tolerance."""
    dist(
        """
import jax, numpy as np
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.train.trainer import Trainer
from repro.launch.mesh import make_local_mesh

cfg = get_config("xlstm-350m-smoke")
mesh = make_local_mesh(1)
runs = {}
for mode, fmt in (("tuned_allreduce", "bf16"), ("compressed_allreduce", "bf16"),
                  ("compressed_allreduce", "int8")):
    run = RunConfig(total_steps=3, warmup_steps=1, sync_mode=mode,
                    wire_format=fmt, learning_rate=1e-3, seed=7)
    params, opt, hist = Trainer(cfg, run, mesh=mesh).train(
        batch=8, seq=32, steps=3, log_every=2)
    runs[(mode, fmt)] = (jax.device_get(params), jax.device_get(opt), hist)

pt, _, ht = runs[("tuned_allreduce", "bf16")]
pp, op_pass, hp = runs[("compressed_allreduce", "bf16")]
pi, op_int8, hi = runs[("compressed_allreduce", "int8")]

# passthrough: bit-identical params and losses
for a, b in zip(jax.tree.leaves(pt), jax.tree.leaves(pp)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
assert ht[-1]["loss"] == hp[-1]["loss"], (ht[-1], hp[-1])
# passthrough residual stays identically zero
assert all(float(np.abs(e).max()) == 0.0 for e in jax.tree.leaves(op_pass["ef"]))

# int8 EF: same start, tracks the full-precision trajectory
assert hi[0]["loss"] == ht[0]["loss"], (hi[0], ht[0])
assert abs(hi[-1]["loss"] - ht[-1]["loss"]) < 0.05, (hi[-1], ht[-1])
# a compressed run actually carries a nonzero residual
assert any(float(np.abs(e).max()) > 0.0 for e in jax.tree.leaves(op_int8["ef"]))
print("PASS")
""",
        devices=4,
        timeout=580,
    )
