"""Host-side tests for the schedule compiler (core.schedules.lower_schedule)
and the compile-cost artifact gate.

ISSUE acceptance: the lowering's dense round tables replay bit-identically
to the schedule-level numpy simulator for every op/algo across pow2 and
non-pow2 rank counts and chunk sweeps; lane partitions are hoisted (one
lowering per schedule, cached) with pinned lane counts for the multi-lane
schedules; the committed ``experiments/compile_table.json`` passes the
compile-size regression gate (compiled HLO flat in num_chunks, unrolled
growing, trace+lower cheaper at the grid's largest chunk points).
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.comm import schedules as comm_schedules
from repro.comm.schedules import build_op, fused_rsb, ring_allreduce_schedule
from repro.comm.tables import (
    TableSchemaError,
    check_compile_flatness,
    load_compile_table,
)
from repro.core.schedules import (
    bidirectional_chain,
    build,
    lane_partition,
    lower_schedule,
)
from repro.core.simulator import simulate_collective, simulate_lowered

REPO = os.path.join(os.path.dirname(__file__), "..")
RNG = np.random.RandomState(0)


def _schedules(n: int, K: int):
    yield build("pipelined_chain", n, 1 % n, num_chunks=K)
    yield build("bidir_chain", n, 0, num_chunks=K)
    yield fused_rsb(n, 0, K)
    yield build("binomial", n)
    yield build("chain", n)
    yield build("direct", n)
    yield ring_allreduce_schedule(n)
    yield build_op("allgather", "ring_allgather", n)
    yield build_op("reduce_scatter", "ring_reduce_scatter", n)
    yield build_op("reduce", "pipelined_reduce_chain", n, num_chunks=K)
    yield build_op("reduce", "binomial_reduce", n)
    if n & (n - 1) == 0 and n >= 4:
        yield build("scatter_allgather", n)
        yield build_op("allgather", "doubling_allgather", n)


@pytest.mark.parametrize("n", [2, 3, 4, 6, 8])
@pytest.mark.parametrize("K", [1, 4, 7])
def test_lowered_replay_matches_simulator(n, K):
    """simulate_lowered (the compiled executor's numpy twin) is bit-identical
    to simulate_collective on the original schedule — every algo, pow2 and
    non-pow2 n, divisible and awkward chunk counts."""
    for sched in _schedules(n, K):
        data = [RNG.randn(sched.num_chunks, 3) for _ in range(n)]
        want = simulate_collective(sched, data)
        got = simulate_lowered(lower_schedule(sched), data)
        for r in range(n):
            assert np.array_equal(want[r], got[r]), (sched.name, n, K, r)


def test_lowering_is_cached_per_schedule():
    """The O(T^2) lane partition runs once per schedule, not once per use:
    two equal schedules share one lowering object."""
    a = lower_schedule(fused_rsb(8, 0, 16))
    b = lower_schedule(fused_rsb(8, 0, 16))
    assert a is b


def test_lane_counts_pinned_bidir_and_fused_rsb():
    """Satellite: pinned lane counts for the multi-lane schedules. The bidir
    chain splits every steady-state round into exactly two direction lanes;
    fused_rsb runs a reduce lane + a bcast lane concurrently once the bcast
    phase wakes up."""
    n, K = 8, 16
    bidir = lower_schedule(bidirectional_chain(n, 0, K))
    counts = bidir.lane_counts()
    # fill rounds ramp up; the steady middle is 2 lanes (right + left chain)
    assert max(counts) == 2
    assert counts[K // 2] == 2
    assert bidir.num_classes == 2

    fr = lower_schedule(fused_rsb(n, 0, K))
    counts = fr.lane_counts()
    # first rounds are reduce-only (1 lane); once chunk 0 is fully reduced
    # (round n-1) the bcast chain joins: exactly 2 lanes mid-schedule
    assert counts[0] == 1
    assert counts[n] == 2
    assert max(counts) == 2
    assert fr.num_classes == 2
    # one class carries the (combining) reduce lane, the other the
    # (overwriting) bcast lane — combine flags are per ROUND per class
    assert fr.classes[0].combine.any() and not fr.classes[1].combine.any()

    # single-lane schedules stay single-class; ring_allreduce's two phases
    # (combining reduce-scatter rounds, then overwriting allgather rounds)
    # share ONE class thanks to the per-round combine flag
    assert lower_schedule(build("pipelined_chain", n, 0, num_chunks=K)).num_classes == 1
    assert lower_schedule(build_op("allgather", "ring_allgather", n)).num_classes == 1
    ring_ar = lower_schedule(ring_allreduce_schedule(n))
    assert ring_ar.num_classes == 1
    assert ring_ar.classes[0].combine[: n - 1].all()
    assert not ring_ar.classes[0].combine[n - 1:].any()


def test_lowering_wire_accounting():
    """Exact wire accounting matches the schedule; the ring family —
    ring_allreduce included, its two phases on one class — is zero-waste
    under the compiled replay (its constant permutation is fully active
    every round), chains are not (fill/drain garbage)."""
    ring = lower_schedule(build_op("allgather", "ring_allgather", 8))
    assert ring.wire_chunks_exact() == ring.wire_chunks_compiled()
    assert ring.zero_waste
    assert lower_schedule(ring_allreduce_schedule(8)).zero_waste

    sched = build("pipelined_chain", 8, 0, num_chunks=16)
    low = lower_schedule(sched)
    assert low.wire_chunks_exact() == sched.wire_chunks()
    assert low.wire_chunks_compiled() > low.wire_chunks_exact()
    assert not low.zero_waste


def test_lane_partition_invariants():
    """Within a lane: each rank a source at most once, a destination at most
    once, one combine flag — for every round of every lowered schedule."""
    for sched in (fused_rsb(6, 2, 9), bidirectional_chain(7, 3, 5),
                  ring_allreduce_schedule(6)):
        for rnd in sched.rounds:
            for lane in lane_partition(rnd.transfers):
                srcs = [t.src for t in lane]
                dsts = [t.dst for t in lane]
                assert len(set(srcs)) == len(srcs)
                assert len(set(dsts)) == len(dsts)
                assert len({t.combine for t in lane}) == 1


def test_reduce_then_bcast_lowering_parity():
    """The composite allreduce (barrier reduce + tuned bcast rounds, varying
    chunk_count across phases) lowers correctly too: block-height clipping
    plus the lo/hi windows keep the replay exact."""
    for n in (3, 4, 6):
        bcast = build("pipelined_chain", n, 0, num_chunks=5)
        sched = comm_schedules.reduce_then_bcast(n, 0, bcast)
        data = [RNG.randn(sched.num_chunks, 2) for _ in range(n)]
        want = simulate_collective(sched, data)
        got = simulate_lowered(lower_schedule(sched), data)
        for r in range(n):
            assert np.array_equal(want[r], got[r]), (n, r)


# ---------------------------------------------------------------------------
# compile-cost artifact: committed table passes the regression gate
# ---------------------------------------------------------------------------


def test_committed_compile_table_passes_gate():
    table = load_compile_table(os.path.join(REPO, "experiments", "compile_table.json"))
    gated = check_compile_flatness(table)
    assert gated >= 2  # at least two (op, algo) groups swept over num_chunks


def test_committed_compile_table_shows_lowering_win():
    """ISSUE acceptance: at the tuner grid's largest chunk points the
    compiled executor's trace+lower wall time beats the unrolled one (the
    committed artifact's values are frozen, so this asserts the shape of the
    result, not CI-machine timing)."""
    table = load_compile_table(os.path.join(REPO, "experiments", "compile_table.json"))
    groups: dict[tuple, list] = {}
    for key, e in table.items():
        n, op, algo, K = key.split("/")
        groups.setdefault((n, op, algo), []).append((int(K[1:]), e))
    wins = 0
    for _g, pts in groups.items():
        if len(pts) < 2:
            continue
        _K, biggest = max(pts)
        assert biggest["compiled_lower_s"] < biggest["unrolled_lower_s"], _g
        assert biggest["compiled_hlo"] < biggest["unrolled_hlo"], _g
        assert biggest["compiled_jaxpr_eqns"] < biggest["unrolled_jaxpr_eqns"], _g
        wins += 1
    assert wins >= 2


def test_compile_table_loader_rejects_rot(tmp_path):
    import json

    good = {
        "n8/bcast/pipelined_chain/K4": {
            "unrolled_hlo": 100, "compiled_hlo": 50,
            "unrolled_jaxpr_eqns": 60, "compiled_jaxpr_eqns": 20,
            "unrolled_lower_s": 0.1, "compiled_lower_s": 0.05,
            "num_rounds": 10, "lane_classes": 1,
        }
    }
    p = tmp_path / "t.json"
    p.write_text(json.dumps(good))
    assert load_compile_table(str(p))

    for mutate in (
        lambda t: t.__setitem__("bogus-key", next(iter(t.values()))),
        lambda t: next(iter(t.values())).__setitem__("compiled_hlo", -1),
        lambda t: next(iter(t.values())).__setitem__("unrolled_lower_s", float("nan")),
        lambda t: next(iter(t.values())).pop("num_rounds"),
        lambda t: next(iter(t.values())).__setitem__("surprise", 1),
    ):
        bad = json.loads(json.dumps(good))
        mutate(bad)
        p.write_text(json.dumps(bad))
        with pytest.raises(TableSchemaError):
            load_compile_table(str(p))

    # the flatness gate itself: a compiled count that grows with K must fail
    grown = json.loads(json.dumps(good))
    e2 = json.loads(json.dumps(good["n8/bcast/pipelined_chain/K4"]))
    e2["compiled_hlo"] = 500
    e2["unrolled_hlo"] = 400
    grown["n8/bcast/pipelined_chain/K16"] = e2
    with pytest.raises(TableSchemaError):
        check_compile_flatness(grown)


# ---------------------------------------------------------------------------
# in-kernel executor: single-launch replay parity + artifact gate
# ---------------------------------------------------------------------------


def _shared_from(data):
    return np.stack([np.asarray(d, np.float32) for d in data])


@pytest.mark.parametrize("n", [2, 3, 4, 6, 8])
@pytest.mark.parametrize("K", [1, 4, 7])
def test_inkernel_replay_matches_simulator(n, K):
    """The persistent single-launch kernel (interpret-mode emulation), its
    numpy oracle, and the lowered simulator agree bit-for-bit on the shared
    buffer — every algo, pow2 and non-pow2 n, divisible and awkward chunk
    counts."""
    import jax.numpy as jnp

    from repro.core.schedules import pack_tables
    from repro.kernels.inkernel_collective import inkernel_replay_shared
    from repro.kernels.ref import inkernel_shared_ref

    for sched in _schedules(n, K):
        low = lower_schedule(sched)
        data = [RNG.randn(sched.num_chunks, 3).astype(np.float32) for _ in range(n)]
        want = simulate_lowered(low, data)
        oracle = inkernel_shared_ref(pack_tables(low), _shared_from(data))
        got = np.asarray(inkernel_replay_shared(low, jnp.asarray(_shared_from(data))))
        for r in range(n):
            assert np.array_equal(want[r], oracle[r]), (sched.name, n, K, r)
            assert np.array_equal(want[r], got[r]), (sched.name, n, K, r)


@pytest.mark.parametrize(
    "op,algo,sizes",
    [
        ("allgatherv", "ring_allgatherv", (3, 0, 2, 0)),
        ("allgatherv", "doubling_allgatherv", (0, 4, 1, 2)),
        ("alltoallv", "pairwise_alltoallv",
         (0, 1, 2, 0, 3, 0, 0, 1, 1, 0, 0, 2, 2, 1, 0, 0)),
        ("alltoallv", "ring_alltoallv",
         (1, 0, 0, 2, 0, 0, 1, 0, 2, 1, 0, 0, 0, 0, 3, 1)),
    ],
)
def test_inkernel_replay_matches_simulator_ragged(op, algo, sizes):
    """Ragged parity including zero-sized ranks: the in-kernel replay of the
    allgatherv/alltoallv schedules is bit-identical to the simulator."""
    import jax.numpy as jnp

    from repro.core.schedules import pack_tables
    from repro.kernels.inkernel_collective import inkernel_replay_shared
    from repro.kernels.ref import inkernel_shared_ref

    n = 4
    sched = build_op(op, algo, n, 0, sizes=sizes)
    low = lower_schedule(sched)
    data = [RNG.randn(sched.num_chunks, 2).astype(np.float32) for _ in range(n)]
    want = simulate_lowered(low, data)
    oracle = inkernel_shared_ref(pack_tables(low), _shared_from(data))
    got = np.asarray(inkernel_replay_shared(low, jnp.asarray(_shared_from(data))))
    for r in range(n):
        assert np.array_equal(want[r], oracle[r]), (op, algo, r)
        assert np.array_equal(want[r], got[r]), (op, algo, r)


def test_inkernel_single_launch_and_flat_jaxpr():
    """ISSUE acceptance, structural half: ONE pallas_call per schedule replay
    and a traced program whose size is independent of both chunk count and
    round count."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.inkernel_collective import inkernel_replay_shared

    def count_pallas(jaxpr):
        import jax.core as jc

        def subs(v):
            if isinstance(v, jc.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jc.Jaxpr):
                yield v
            elif isinstance(v, (list, tuple)):
                for x in v:
                    yield from subs(x)

        total = 0
        for eq in jaxpr.eqns:
            if eq.primitive.name == "pallas_call":
                total += 1
            for v in eq.params.values():
                for sub in subs(v):
                    total += count_pallas(sub)
        return total

    sizes = {}
    for K in (4, 16, 64):
        low = lower_schedule(build("pipelined_chain", 4, 0, num_chunks=K))
        shared = jnp.zeros((4, K, 8), jnp.float32)
        closed = jax.make_jaxpr(
            lambda s, low=low: inkernel_replay_shared(low, s)
        )(shared)
        assert count_pallas(closed.jaxpr) == 1, K
        sizes[K] = len(closed.jaxpr.eqns)
    assert len(set(sizes.values())) == 1, sizes


def test_committed_inkernel_table_passes_gate():
    """ISSUE acceptance, artifact half: the committed table shows exactly one
    launch per replay, HLO flat in K and strictly below the compiled
    executor's at each group's deepest point — all enforced by the loader."""
    from repro.comm.tables import load_inkernel_table

    table = load_inkernel_table(
        os.path.join(REPO, "experiments", "inkernel_table.json")
    )
    assert all(e["inkernel_launches"] == 1 for e in table.values())
    multi_k = {}
    for key in table:
        n, op, algo, _K = key.split("/")
        multi_k[(n, op, algo)] = multi_k.get((n, op, algo), 0) + 1
    assert sum(1 for v in multi_k.values() if v >= 2) >= 2


def test_inkernel_table_loader_rejects_rot(tmp_path):
    import json

    from repro.comm.tables import load_inkernel_table

    good = {
        "n4/bcast/pipelined_chain/K4": {
            "inkernel_launches": 1, "inkernel_hlo": 170, "compiled_hlo": 210,
            "num_rounds": 6, "compiled_rounds": 6, "round_us": 50.0,
        },
        "n4/bcast/pipelined_chain/K16": {
            "inkernel_launches": 1, "inkernel_hlo": 172, "compiled_hlo": 211,
            "num_rounds": 18, "compiled_rounds": 18, "round_us": 20.0,
        },
    }
    p = tmp_path / "t.json"
    p.write_text(json.dumps(good))
    assert load_inkernel_table(str(p))

    def k16(t):
        return t["n4/bcast/pipelined_chain/K16"]

    for mutate in (
        # a second launch: the whole point of the executor regressed
        lambda t: k16(t).__setitem__("inkernel_launches", 2),
        # executor round-count drift
        lambda t: k16(t).__setitem__("compiled_rounds", 19),
        # HLO no longer flat in K
        lambda t: k16(t).__setitem__("inkernel_hlo", 400),
        # not smaller than the compiled program at the deepest K
        lambda t: k16(t).__setitem__("inkernel_hlo", 211),
        lambda t: t.__setitem__("bogus-key", dict(k16(t))),
        lambda t: k16(t).__setitem__("round_us", float("nan")),
        lambda t: k16(t).pop("num_rounds"),
        lambda t: k16(t).__setitem__("surprise", 1),
    ):
        bad = json.loads(json.dumps(good))
        mutate(bad)
        p.write_text(json.dumps(bad))
        with pytest.raises(TableSchemaError):
            load_inkernel_table(str(p))

    # a table with no multi-K sweep at all is not a gateable artifact
    single = {"n4/bcast/pipelined_chain/K4": good["n4/bcast/pipelined_chain/K4"]}
    p.write_text(json.dumps(single))
    with pytest.raises(TableSchemaError):
        load_inkernel_table(str(p))
