"""Host-level tests for the overlap engine (repro.comm.overlap) and the
edge-case bugfix sweep that rode along (ISSUE 4):

  * overlap scheduler: dispatch order covers every bucket, per-bucket
    schedules converge in the numpy simulator to the same values as the
    barrier path, overlapped wire bytes equal the sum of the per-bucket
    plan accounting;
  * the overlap simulator shows STRICTLY fewer network-idle rounds than
    the barrier schedule for >= 2 buckets at n >= 4 (ISSUE acceptance);
  * cost model: t_overlapped never exceeds t_bucketed_barrier and is
    monotone non-increasing in depth;
  * Tuner: empirical hits with out-of-range num_chunks are clamped at hit
    time and at load; overlap_depth round-trips through record/select/
    save/load; dryrun-branded tables cannot seed empirical decisions;
  * comm.api: zero-pad is guarded as sum-only; non-sum combiners route to
    the XLA one-shots; n == 1 early-outs keep the communicating path's
    dtype/shape contract across all five ops.
"""
from __future__ import annotations

import json
import math
import os

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback — see tests/_compat.py
    from _compat import given, settings, strategies as st

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.comm import (
    TableSchemaError,
    load_overlap_table,
    plan_overlap,
    simulate_overlap,
)
from repro.comm.api import _chunked
from repro.core import cost_model
from repro.core.simulator import simulate_collective
from repro.core.tuner import Tuner

REPO = os.path.join(os.path.dirname(__file__), "..")


def _grads_like(leaf_elems, dtype=np.float32):
    return {f"l{i}": jax.ShapeDtypeStruct((e,), dtype) for i, e in enumerate(leaf_elems)}


# --------------------------- overlap scheduler ------------------------------


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(2, 17),
    num_leaves=st.integers(1, 7),
    size_seed=st.integers(0, 1000),
    inter_pod=st.booleans(),
    depth=st.integers(1, 4),
)
def test_overlap_plan_order_and_wire_accounting(n, num_leaves, size_seed, inter_pod, depth):
    """Dispatch order is a permutation in reverse tree-flatten order, and
    the overlapped schedule's wire bytes are EXACTLY the sum of the
    per-bucket plan accounting (overlap reorders, never adds traffic)."""
    rng = np.random.RandomState(size_seed)
    leaves = [int(rng.randint(1, 5000)) for _ in range(num_leaves)]
    tree = _grads_like(leaves)
    oplan = plan_overlap(
        tree, [("data", n)], bucket_bytes=4096, overlap_depth=depth,
        inter_pod_axes=("data",) if inter_pod else (),
    )
    assert sorted(oplan.order) == list(range(oplan.num_buckets))
    assert oplan.order == tuple(reversed(range(oplan.num_buckets)))
    per_bucket = sum(
        p.wire_bytes() for ax in oplan.axes for p in oplan.plans[ax]
    )
    assert oplan.wire_bytes() == per_bucket
    assert simulate_overlap(oplan)["wire_bytes"] == per_bucket


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(2, 12),
    num_leaves=st.integers(1, 6),
    seed=st.integers(0, 99),
)
def test_overlap_per_bucket_results_match_barrier(n, num_leaves, seed):
    """The overlap scheduler's per-bucket collectives are the SAME plans the
    barrier ``pallreduce_tree`` path runs: replaying each bucket's schedule
    in dispatch order through the numpy simulator converges every rank to
    the bucket's reference sum — dispatch order cannot change any value."""
    rng = np.random.RandomState(seed)
    leaves = [int(rng.randint(1, 3000)) for _ in range(num_leaves)]
    oplan = plan_overlap(_grads_like(leaves), [("data", n)], bucket_bytes=4096)
    for k in oplan.order:
        plan = oplan.plans["data"][k]
        if plan.schedule is None:
            continue
        sched = plan.schedule
        data = [rng.randn(sched.num_chunks, 3) for _ in range(n)]
        ref = np.sum(data, axis=0)
        out = simulate_collective(sched, data)
        for r in range(n):
            np.testing.assert_allclose(out[r], ref, rtol=1e-9, err_msg=f"bucket {k} rank {r}")


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(4, 32),
    num_buckets=st.integers(2, 10),
    compute_us=st.integers(0, 2000),
    seed=st.integers(0, 99),
)
def test_overlap_strictly_fewer_idle_rounds(n, num_buckets, compute_us, seed):
    """ISSUE acceptance: for >= 2 buckets at n >= 4 the overlapped schedule
    has STRICTLY fewer network-idle rounds than the barrier schedule."""
    rng = np.random.RandomState(seed)
    # every leaf exceeds the bucket budget, forcing one bucket per leaf
    leaves = [int(rng.randint(1100, 4000)) for _ in range(num_buckets)]
    oplan = plan_overlap(
        _grads_like(leaves), [("data", n)], bucket_bytes=4096,
        compute_s=compute_us * 1e-6,
    )
    assert oplan.num_buckets >= 2
    sim = simulate_overlap(oplan)
    assert sim["idle_rounds_overlap"] < sim["idle_rounds_barrier"], sim
    assert sim["overlap_span_rounds"] < sim["barrier_span_rounds"], sim


@settings(max_examples=40, deadline=None)
@given(
    num_buckets=st.integers(0, 8),
    compute_us=st.integers(0, 5000),
    seed=st.integers(0, 99),
)
def test_t_overlapped_bounds(num_buckets, compute_us, seed):
    """t_overlapped never exceeds the barrier time and is monotone
    non-increasing in depth (a deeper window can only help)."""
    rng = np.random.RandomState(seed)
    comm = [float(rng.uniform(1e-6, 1e-3)) for _ in range(num_buckets)]
    stage = [float(rng.uniform(0, 5e-4)) for _ in range(num_buckets)]
    compute_s = compute_us * 1e-6
    barrier = cost_model.t_bucketed_barrier(comm, compute_s, stage)
    prev = None
    for depth in range(1, max(num_buckets, 1) + 1):
        t = cost_model.t_overlapped(comm, compute_s, depth=depth, stage_s=stage)
        assert t <= barrier + 1e-12, (depth, t, barrier)
        if prev is not None:
            assert t <= prev + 1e-12
        prev = t
    d = cost_model.optimal_overlap_depth(comm, compute_s, stage_s=stage)
    assert 1 <= d <= max(num_buckets, 1)


def test_overlap_depth_resolution_order():
    """Depth precedence: manual > tuner-table (empirical) > analytic."""
    tree = _grads_like([3000, 3000, 500])
    manual = plan_overlap(tree, [("data", 8)], bucket_bytes=4096, overlap_depth=5)
    assert manual.overlap_depth == 5 and manual.depth_source == "manual"

    t = Tuner()
    analytic = plan_overlap(tree, [("data", 8)], tuner=t, bucket_bytes=4096)
    assert analytic.depth_source == "analytic"

    # record a tuned depth against the largest bucket — the planner must
    # pick it up as empirical, while the underlying algorithm decision
    # stays ANALYTIC (a depth-only record must never masquerade as a
    # measured algorithm choice)
    sizes = analytic.spec.bucket_bytes()
    M_big = max(sizes)
    t.record_overlap(M_big, 8, 4, op="allreduce")
    emp = plan_overlap(tree, [("data", 8)], tuner=t, bucket_bytes=4096)
    assert emp.overlap_depth == 4 and emp.depth_source == "empirical"
    d = t.select(M_big, 8, op="allreduce")
    assert d.source == "analytic" and d.overlap_depth == 4
    # a depth-only entry never blocks a real measurement from landing, and
    # its depth does NOT float onto the newly measured algorithm (it was
    # tuned against whatever 'auto' picked at plan time)
    t.record(M_big, 8, "ring_allreduce", 8, 1e-6, op="allreduce")
    after = t.select(M_big, 8, op="allreduce")
    assert after.source == "empirical" and after.overlap_depth is None


# ------------------------------- tuner fixes --------------------------------


def test_select_clamps_rotten_empirical_num_chunks():
    """Satellite regression: an empirical hit whose num_chunks exceeds
    max_chunks (or is < 1) must not flow into a Decision unclamped."""
    t = Tuner(max_chunks=16)
    M, n = 1 << 20, 8
    key = t._key(M, n, False, "allreduce")
    t.table[key] = {"algo": "fused_rsb", "num_chunks": 4096, "measured_s": 1e-6}
    d = t.select(M, n, op="allreduce")
    assert d.source == "empirical" and d.num_chunks == 16
    assert d.chunk_bytes == math.ceil(M / 16)
    t.table[key] = {"algo": "fused_rsb", "num_chunks": -3, "measured_s": 1e-6}
    assert t.select(M, n, op="allreduce").num_chunks == 1


def test_load_clamps_and_validates(tmp_path):
    t = Tuner(max_chunks=8)
    t.record(1 << 20, 4, "fused_rsb", 6, 1e-6, op="allreduce")
    p = str(tmp_path / "t.json")
    t.save(p)
    # hand-corrupt: num_chunks beyond the saved max_chunks gets clamped
    payload = json.load(open(p))
    key = next(iter(payload["table"]))
    payload["table"][key]["num_chunks"] = 9999
    json.dump(payload, open(p, "w"))
    loaded = Tuner.load(p)
    assert loaded.select(1 << 20, 4, op="allreduce").num_chunks == 8
    # non-int / < 1 still raise
    payload["table"][key]["num_chunks"] = 0
    json.dump(payload, open(p, "w"))
    with pytest.raises(ValueError, match="positive int"):
        Tuner.load(p)
    # bad overlap_depth raises too
    payload["table"][key]["num_chunks"] = 4
    payload["table"][key]["overlap_depth"] = 0
    json.dump(payload, open(p, "w"))
    with pytest.raises(ValueError, match="overlap_depth"):
        Tuner.load(p)


def test_overlap_depth_roundtrip_and_dryrun_gate(tmp_path):
    t = Tuner()
    M, n = 1 << 20, 8
    t.record(M, n, "ring_allreduce", n, 1e-6, op="allreduce",
             extras={"overlap_depth": 3})
    assert t.select(M, n, op="allreduce").overlap_depth == 3
    # a faster measurement of the SAME algorithm keeps the tuned depth alive
    t.record(M, n, "ring_allreduce", n, 8e-7, op="allreduce")
    assert t.select(M, n, op="allreduce").overlap_depth == 3
    # ... but a DIFFERENT algorithm drops it: a depth tuned against one
    # round/staging profile must not float onto another
    t.record(M, n, "fused_rsb", 4, 5e-7, op="allreduce")
    assert t.select(M, n, op="allreduce").overlap_depth is None
    t.record(M, n, "fused_rsb", 4, 4e-7, op="allreduce",
             extras={"overlap_depth": 3})
    p = str(tmp_path / "t.json")
    t.save(p)
    assert Tuner.load(p).select(M, n, op="allreduce").overlap_depth == 3
    # dryrun-branded tables refuse a plain load; allow_dryrun drops the
    # MEASURED entries but keeps depth-only ones (a window is a schedule-
    # structure choice, not a timing) — the overlap_depths.json contract
    t.record_overlap(2 << 20, n, 5, op="allreduce")
    t.save(p, dryrun=True)
    with pytest.raises(ValueError, match="dryrun"):
        Tuner.load(p)
    kept = Tuner.load(p, allow_dryrun=True)
    assert all(set(e) == {"overlap_depth"} for e in kept.table.values())
    assert kept.select(2 << 20, n, op="allreduce").overlap_depth == 5
    assert kept.select(M, n, op="allreduce").source == "analytic"


# --------------------------- comm.api pad / n==1 ----------------------------


def test_chunked_pad_is_sum_only():
    """Satellite regression: the zero pad a non-divisible buffer grows is
    only the identity for sum — any other combiner must be rejected before
    it can corrupt the last chunk."""
    flat = jnp.arange(10, dtype=jnp.float32)
    buf, pad = _chunked(flat, 4, combiner="sum")
    assert buf.shape == (4, 3) and pad == 2
    np.testing.assert_array_equal(np.asarray(buf).ravel()[10:], 0.0)
    with pytest.raises(ValueError, match="identity"):
        _chunked(flat, 4, combiner="max")
    # divisible buffers never pad, so any combiner passes through
    buf, pad = _chunked(jnp.arange(12, dtype=jnp.float32), 4, combiner="max")
    assert pad == 0 and buf.shape == (4, 3)


def test_unknown_combiner_rejected():
    from repro.comm import pallreduce

    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="unknown combiner"):
        jax.shard_map(
            lambda x: pallreduce(x, "data", combiner="median"),
            mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False,
        )(jnp.ones((4,)))


def test_degenerate_axis_contract_all_ops():
    """Satellite regression: n == 1 early-outs must return the same
    dtype/shape contract as the communicating path for all five ops —
    a committed jnp array (numpy input normalized), same result shapes."""
    from repro.comm import pallgather, pallreduce, pbcast, preduce, preduce_scatter

    mesh = jax.make_mesh((1,), ("data",))

    def run(fn, x):
        return jax.shard_map(
            fn, mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False
        )(x)

    x_np = np.arange(10, dtype=np.int32).reshape(2, 5)  # numpy, not jax
    for fn, want_shape in [
        (lambda x: pbcast(x, "data"), (2, 5)),
        (lambda x: preduce(x, "data"), (2, 5)),
        (lambda x: pallreduce(x, "data"), (2, 5)),
        (lambda x: pallgather(x, "data"), (1, 2, 5)),
        (lambda x: preduce_scatter(x, "data"), (10,)),
    ]:
        out = run(fn, x_np)
        assert isinstance(out, jax.Array), fn
        assert out.shape == want_shape, (fn, out.shape)
        assert out.dtype == jnp.int32, (fn, out.dtype)
    # values are the identity at n == 1
    np.testing.assert_array_equal(np.asarray(run(lambda x: pallreduce(x, "data"), x_np)), x_np)
    np.testing.assert_array_equal(
        np.asarray(run(lambda x: preduce_scatter(x, "data"), x_np)), x_np.ravel()
    )


def test_nonsum_combiner_degenerate_and_validation():
    """combiner='max'/'min' with a pinned schedule algo is rejected; at
    n == 1 the combiner is irrelevant and the contract holds."""
    from repro.comm import pallreduce

    mesh = jax.make_mesh((1,), ("data",))
    out = jax.shard_map(
        lambda x: pallreduce(x, "data", combiner="max"),
        mesh=mesh, in_specs=(P(),), out_specs=P(), check_vma=False,
    )(np.ones((3,), np.float32))
    assert isinstance(out, jax.Array) and out.shape == (3,)


# ------------------------------ overlap table -------------------------------


def test_committed_overlap_table_validates():
    table = load_overlap_table(os.path.join(REPO, "experiments", "overlap_table.json"))
    assert table
    for key, entry in table.items():
        assert entry["overlapped_us"] <= entry["barrier_us"] * (1 + 1e-9), key
        if "idle_rounds_overlap" in entry and "idle_rounds_barrier" in entry:
            assert entry["idle_rounds_overlap"] <= entry["idle_rounds_barrier"], key


@pytest.mark.parametrize(
    "mutate, msg_part",
    [
        (lambda t: t.update({"bogus": {"overlap_depth": 2, "barrier_us": 2.0, "overlapped_us": 1.0, "efficiency": 0.5}}), "unknown key"),
        (lambda t: t.update({"n1/K2/M64": {"overlap_depth": 2, "barrier_us": 2.0, "overlapped_us": 1.0, "efficiency": 0.5}}), ">= 2 ranks"),
        (lambda t: t.update({"n4/K2/M64": {"overlap_depth": 0, "barrier_us": 2.0, "overlapped_us": 1.0, "efficiency": 0.5}}), "positive int"),
        (lambda t: t.update({"n4/K2/M64": {"overlap_depth": 2, "barrier_us": 1.0, "overlapped_us": 2.0, "efficiency": 0.5}}), "rotten"),
        (lambda t: t.update({"n4/K2/M64": {"overlap_depth": 2, "barrier_us": 2.0, "overlapped_us": 1.0, "efficiency": 1.5}}), "efficiency"),
        (lambda t: t.update({"n4/K2/M64": {"overlap_depth": 2, "barrier_us": 2.0, "overlapped_us": 1.0}}), "missing required"),
        (lambda t: t.update({"n4/K2/M64": {"overlap_depth": 2, "barrier_us": 2.0, "overlapped_us": 1.0, "efficiency": 0.5, "huh": 1}}), "unknown entry fields"),
    ],
)
def test_overlap_table_rejects_bad_schemas(tmp_path, mutate, msg_part):
    table = {
        "n4/K3/M4096": {
            "overlap_depth": 2,
            "barrier_us": 10.0,
            "overlapped_us": 8.0,
            "efficiency": 0.2,
        }
    }
    mutate(table)
    p = tmp_path / "overlap_table.json"
    p.write_text(json.dumps(table))
    with pytest.raises(TableSchemaError, match=msg_part):
        load_overlap_table(str(p))
