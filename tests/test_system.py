"""End-to-end behaviour tests: train -> checkpoint -> resume -> serve, and
the paper's full sync path on a multi-device mesh (subprocess)."""
from __future__ import annotations


def test_end_to_end_train_ckpt_serve(dist, tmp_path):
    dist(
        f"""
import jax, numpy as np, jax.numpy as jnp
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.train.trainer import Trainer
from repro.train import checkpoint as ck
from repro.launch.mesh import make_local_mesh
from repro.serve.engine import Engine

cfg = get_config("minitron-8b-smoke")
run = RunConfig(total_steps=10, warmup_steps=2, sync_mode="param_bcast",
                learning_rate=1e-3)
tr = Trainer(cfg, run, mesh=make_local_mesh(1), ckpt_dir={str(tmp_path / "ck")!r})
params, opt, hist = tr.train(batch=8, seq=32, steps=5, log_every=2, ckpt_every=5)
assert hist[-1]["loss"] < hist[0]["loss"]

# resume
step = ck.latest_step({str(tmp_path / "ck")!r})
assert step == 5
tr2 = Trainer(cfg, run, mesh=make_local_mesh(1), ckpt_dir={str(tmp_path / "ck")!r})
p2, o2, step2 = tr2.restore_or_init()
assert step2 == 5
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
    np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))

# serve the trained weights
eng = Engine(cfg, params)
res = eng.generate({{"tokens": jnp.asarray(np.zeros((2, 8), np.int32))}}, steps=3)
assert res.tokens.shape == (2, 3)
print("PASS")
""",
        devices=4,
        timeout=420,
    )


def test_weight_distribution_bcast(dist):
    """serve.distribute_weights pushes root weights to every data rank."""
    dist(
        """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.serve.engine import distribute_weights
mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
params = {"w": jnp.arange(1000, dtype=jnp.float32), "b": {"x": jnp.ones((33,), jnp.bfloat16)}}
out = distribute_weights(params, mesh)
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
    np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
print("PASS")
"""
    )
