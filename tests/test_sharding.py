"""Sharding-rule tests: divisibility fallbacks and spec validity."""
from __future__ import annotations

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES
from repro.dist.sharding import batch_specs, cache_specs, param_specs
from repro.models import Model


class FakeMesh:
    """Just enough mesh surface for the rule code (no devices needed)."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


MESH = FakeMesh((16, 16), ("data", "model"))
MESH3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _leaf_specs(tree, specs):
    return list(
        zip(
            jax.tree_util.tree_leaves_with_path(tree),
            jax.tree_util.tree_leaves(specs, is_leaf=lambda s: isinstance(s, P)),
        )
    )


@pytest.mark.parametrize("arch", ["minitron-8b", "hymba-1.5b", "mixtral-8x7b",
                                  "qwen1.5-32b", "qwen3-moe-30b-a3b", "whisper-large-v3"])
def test_param_specs_divide_evenly(arch):
    """Every sharded dim is divisible by its mesh axis (no uneven shards)."""
    cfg = get_config(arch)
    shapes = Model(cfg).param_shapes()
    specs = param_specs(shapes, MESH)
    sizes = _axis_sizes(MESH)
    for (path, leaf), spec in _leaf_specs(shapes, specs):
        assert len(spec) == leaf.ndim, (path, spec, leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(np.prod([sizes[a] for a in axes]))
            assert dim % n == 0, (jax.tree_util.keystr(path), leaf.shape, spec)


def test_fallbacks():
    """The specific non-divisible cases fall back as documented."""
    sizes = _axis_sizes(MESH)

    def find(tree, specs, substr):
        for (path, leaf), spec in _leaf_specs(tree, specs):
            if substr in jax.tree_util.keystr(path):
                return leaf, spec
        raise KeyError(substr)

    # hymba: 25 heads not divisible -> train/prefill replicate attention on
    # model (head_dim sharding would all-reduce score blocks, S Perf iter 1)
    cfg = get_config("hymba-1.5b")
    shapes = Model(cfg).param_shapes()
    specs = param_specs(shapes, MESH)
    leaf, spec = find(shapes, specs, "attn']['wq")
    assert spec[-2] is None and spec[-1] is None, spec
    # ... while decode uses head_dim sharding for serving memory
    specs = param_specs(shapes, MESH, fsdp=False, attn_fallback="head_dim")
    leaf, spec = find(shapes, specs, "attn']['wq")
    assert spec[-1] == "model", spec
    # paligemma MQA: 1 kv head -> replicated kv projections (train)
    cfg = get_config("paligemma-3b")
    shapes = Model(cfg).param_shapes()
    specs = param_specs(shapes, MESH)
    leaf, spec = find(shapes, specs, "attn']['wk")
    assert spec[-2] is None and spec[-1] is None, spec

    # mixtral: 8 experts < 16 -> expert ffn sharded instead
    cfg = get_config("mixtral-8x7b")
    shapes = Model(cfg).param_shapes()
    specs = param_specs(shapes, MESH)
    leaf, spec = find(shapes, specs, "moe']['w_up")
    assert spec[-3] is None and spec[-1] == "model", spec

    # qwen3: 128 experts -> experts sharded
    cfg = get_config("qwen3-moe-30b-a3b")
    shapes = Model(cfg).param_shapes()
    specs = param_specs(shapes, MESH)
    leaf, spec = find(shapes, specs, "moe']['w_up")
    assert spec[-3] == "model", spec


def test_inference_specs_have_no_fsdp():
    cfg = get_config("minitron-8b")
    shapes = Model(cfg).param_shapes()
    specs = param_specs(shapes, MESH, fsdp=False)
    for (path, leaf), spec in _leaf_specs(shapes, specs):
        assert "data" not in [a for e in spec if e for a in (e if isinstance(e, tuple) else (e,))], (
            jax.tree_util.keystr(path), spec)


def test_batch_and_cache_specs():
    cfg = get_config("minitron-8b")
    m = Model(cfg)
    shape = INPUT_SHAPES["train_4k"]
    bspecs = batch_specs(m.input_specs(shape), MESH3)
    assert jax.tree_util.tree_leaves(bspecs, is_leaf=lambda s: isinstance(s, P))[0][0] == ("pod", "data")

    dec = INPUT_SHAPES["decode_32k"]
    cspecs = cache_specs(m.input_specs(dec)["caches"], MESH, cfg)
    flat = jax.tree_util.tree_leaves_with_path(cspecs, is_leaf=lambda s: isinstance(s, P))
    kv = [s for p, s in flat if "'k'" in jax.tree_util.keystr(p)]
    assert kv, "no kv cache leaves"
    for s in kv:
        # minitron kv=8 not divisible by 16 -> flash-decoding: seq on model
        assert s[-3] in ("model", ("model",)), s

    # long-context batch=1: sequence sharded over data
    lng = INPUT_SHAPES["long_500k"]
    cfg_g = get_config("gemma3-27b")
    mg = Model(cfg_g)
    cspecs = cache_specs(mg.input_specs(lng)["caches"], MESH, cfg_g)
    flat = jax.tree_util.tree_leaves_with_path(cspecs, is_leaf=lambda s: isinstance(s, P))
    kv = [s for p, s in flat if "'k'" in jax.tree_util.keystr(p)]
    assert any(s[-3] is not None and "data" in (s[-3] if isinstance(s[-3], tuple) else (s[-3],)) for s in kv), kv
