"""Offline fallback for ``hypothesis``: fixed-seed deterministic shims.

The tier-1 suite must run from a clean checkout with no network access, so
property tests import hypothesis via::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _compat import given, settings, strategies as st

When real Hypothesis is installed the tests behave exactly as written.
This module provides the same decorator surface but expands each strategy
into a small deterministic example set: the boundary values of every
strategy first, then fixed-seed pseudo-random draws.  Runs are identical
across machines and invocations (no shrinking, no database, no deadlines).

Only the strategy combinators this suite uses are implemented:
``integers``, ``sampled_from``, ``booleans``, ``floats``, ``lists``.
"""
from __future__ import annotations

import functools
import inspect
import itertools
import types

import numpy as np

# Deterministic fallback examples per test. Real hypothesis honors the
# test's own max_examples; the fallback caps at _MAX_EXAMPLES (boundary
# combinations always included) to keep the offline suite fast.
_DEFAULT_EXAMPLES = 20
_MAX_EXAMPLES = 24
_SEED = 0xB0CA57  # "bcast"


class _Strategy:
    def boundary(self):
        raise NotImplementedError

    def draw(self, rng: np.random.RandomState):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo: int, hi: int):
        assert lo <= hi, (lo, hi)
        self.lo, self.hi = int(lo), int(hi)

    def boundary(self):
        vals = [self.lo, self.hi, (self.lo + self.hi) // 2]
        return list(dict.fromkeys(vals))

    def draw(self, rng):
        return int(rng.randint(self.lo, self.hi + 1))


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)
        assert self.elements

    def boundary(self):
        return list(dict.fromkeys([self.elements[0], self.elements[-1]]))

    def draw(self, rng):
        return self.elements[int(rng.randint(len(self.elements)))]


class _Booleans(_Strategy):
    def boundary(self):
        return [False, True]

    def draw(self, rng):
        return bool(rng.randint(2))


class _Floats(_Strategy):
    def __init__(self, lo: float, hi: float):
        assert lo <= hi, (lo, hi)
        self.lo, self.hi = float(lo), float(hi)

    def boundary(self):
        vals = [self.lo, self.hi, (self.lo + self.hi) / 2.0]
        return list(dict.fromkeys(vals))

    def draw(self, rng):
        return float(rng.uniform(self.lo, self.hi))


class _Lists(_Strategy):
    def __init__(self, elem: _Strategy, *, min_size: int = 0, max_size: int = 10):
        self.elem = elem
        self.min_size, self.max_size = int(min_size), int(max_size)

    def boundary(self):
        lo = self.elem.boundary()[0]
        hi = self.elem.boundary()[-1] if len(self.elem.boundary()) > 1 else lo
        shortest = [] if self.min_size == 0 else [lo] * self.min_size
        return [shortest, [hi] * self.max_size]

    def draw(self, rng):
        n = int(rng.randint(self.min_size, self.max_size + 1))
        return [self.elem.draw(rng) for _ in range(n)]


def _examples(strats: dict):
    """Deterministic example stream: boundary combos first (round-robin so
    every strategy's edges appear even when the cartesian product is huge),
    then fixed-seed random draws."""
    names = list(strats)
    bounds = [strats[k].boundary() for k in names]
    # one example per boundary "rank": (lo, lo, ...), (hi, hi, ...), ...
    for rank in range(max(len(b) for b in bounds)):
        yield {k: b[min(rank, len(b) - 1)] for k, b in zip(names, bounds)}
    # a few cross-combinations of extreme values for pairs of strategies
    for i, j in itertools.islice(itertools.combinations(range(len(names)), 2), 4):
        ex = {k: b[0] for k, b in zip(names, bounds)}
        ex[names[i]] = bounds[i][-1]
        ex[names[j]] = bounds[j][0]
        yield ex
    idx = 0
    while True:
        rng = np.random.RandomState((_SEED + idx) % (2**31 - 1))
        yield {k: strats[k].draw(rng) for k in names}
        idx += 1


def given(**strats):
    """Deterministic stand-in for ``hypothesis.given`` (kwargs style only)."""
    for k, s in strats.items():
        if not isinstance(s, _Strategy):
            raise TypeError(f"unsupported strategy for {k!r}: {s!r}")

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = min(getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES), _MAX_EXAMPLES)
            for ex in itertools.islice(_examples(strats), n):
                fn(*args, **kwargs, **ex)

        # Hide the strategy params from pytest's fixture resolution: expose
        # a signature containing only the test's non-strategy (fixture)
        # parameters, and drop __wrapped__ so pytest doesn't look through.
        sig = inspect.signature(fn)
        keep = [p for name, p in sig.parameters.items() if name not in strats]
        del wrapper.__wrapped__
        wrapper.__signature__ = sig.replace(parameters=keep)
        wrapper.hypothesis_compat_fallback = True
        return wrapper

    return deco


def settings(max_examples: int | None = None, deadline=None, **_ignored):
    """Records ``max_examples`` on the wrapped test; other knobs are no-ops
    (the fallback has no shrinking phase or deadline timer)."""

    def deco(fn):
        if max_examples is not None:
            fn._max_examples = int(max_examples)
        return fn

    return deco


strategies = types.SimpleNamespace(
    integers=lambda min_value, max_value: _Integers(min_value, max_value),
    sampled_from=_SampledFrom,
    booleans=_Booleans,
    floats=lambda min_value, max_value: _Floats(min_value, max_value),
    lists=lambda elem, *, min_size=0, max_size=10: _Lists(
        elem, min_size=min_size, max_size=max_size
    ),
)
