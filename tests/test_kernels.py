"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback — see tests/_compat.py
    from _compat import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.RandomState(0)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 70_000),
    chunk=st.sampled_from([256, 1024, 8192]),
    dt=st.sampled_from(["float32", "bfloat16", "int32"]),
)
def test_chunked_copy_property(n, chunk, dt):
    x = jnp.asarray(RNG.randn(n) * 100, jnp.dtype(dt))
    got = ops.chunked_copy(x, chunk_elems=chunk)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.chunked_copy_ref(x)))


@pytest.mark.parametrize("n", [131, 4096, 100_000])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_param_update(n, dt):
    w = jnp.asarray(RNG.randn(n), dt)
    u = jnp.asarray(RNG.randn(n), dt)
    np.testing.assert_allclose(
        np.asarray(ops.mix(w, u, 0.25), np.float32),
        np.asarray(ref.mix_ref(w, u, 0.25), np.float32),
        rtol=1e-2, atol=1e-2,
    )
    np.testing.assert_allclose(
        np.asarray(ops.scaled_add(w, u, 0.01), np.float32),
        np.asarray(ref.scaled_add_ref(w, u, 0.01), np.float32),
        rtol=1e-2, atol=1e-2,
    )


CASES = [
    # B, T, S, H, KV, hd, causal, window, prefix, bq, bk
    (2, 128, 128, 4, 2, 32, True, None, 0, 64, 64),
    (1, 256, 256, 4, 1, 64, True, 64, 0, 64, 64),
    (2, 128, 128, 2, 2, 32, True, None, 32, 64, 32),
    (1, 128, 128, 4, 4, 32, False, None, 0, 128, 128),
    (1, 64, 64, 8, 2, 16, True, 32, 16, 32, 32),
]


@pytest.mark.parametrize("case", CASES)
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_flash_attention(case, dt):
    B, T, S, H, KV, hd, causal, window, prefix, bq, bk = case
    q = jnp.asarray(RNG.randn(B, T, H, hd), dt)
    k = jnp.asarray(RNG.randn(B, S, KV, hd), dt)
    v = jnp.asarray(RNG.randn(B, S, KV, hd), dt)
    got = ops.flash_attention(q, k, v, causal=causal, window=window, prefix=prefix, bq=bq, bk=bk)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window, prefix=prefix)
    tol = 2e-4 if dt == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol
    )


def test_flash_matches_model_attention_path():
    """Kernel agrees with the model's XLA-portable chunked softmax."""
    from repro.models.layers import AttnSpec, _chunked_sdpa

    B, T, H, KV, hd = 1, 256, 4, 2, 32
    q = jnp.asarray(RNG.randn(B, T, H, hd), jnp.float32)
    k = jnp.asarray(RNG.randn(B, T, KV, hd), jnp.float32)
    v = jnp.asarray(RNG.randn(B, T, KV, hd), jnp.float32)
    spec = AttnSpec(num_heads=H, num_kv_heads=KV, head_dim=hd, window=64)
    a = _chunked_sdpa(q * hd**-0.5 / hd**-0.5, k, v, spec, prefix_len=0, block=64)
    b = ops.flash_attention(q, k, v, causal=True, window=64, bq=64, bk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 24),
    c=st.sampled_from([1, 7, 128, 1013, 4096]),
    lo_frac=st.floats(0.0, 1.0),
    combine=st.booleans(),
    dt=st.sampled_from(["float32", "bfloat16", "int32"]),
)
def test_fused_combine_property(b, c, lo_frac, combine, dt):
    """The compiled executor's merge kernel vs the pure-jnp oracle:
    accumulate (mode 2) or overwrite (mode 1) on the [lo, hi) row window,
    bit-exact passthrough (mode 0) elsewhere."""
    import jax.numpy as jnp

    cur = jnp.asarray(RNG.randn(b, c) * 50, jnp.dtype(dt))
    recv = jnp.asarray(RNG.randn(b, c) * 50, jnp.dtype(dt))
    lo = int(lo_frac * b)
    hi = min(b, lo + max(1, b // 2))
    rows = jnp.arange(b, dtype=jnp.int32)
    valid = (rows >= lo) & (rows < hi)
    mode = (valid.astype(jnp.int32) * (2 if combine else 1)).reshape(b, 1)
    got = ops.fused_combine(cur, recv, mode)
    want = ref.fused_combine_ref(cur, recv, mode)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fused_combine_update_window():
    """fused_combine_update applies exactly the [start+lo, start+hi) rows of
    a (K, chunk) buffer and leaves every other row bit-identical."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.combine_update import fused_combine_update

    K, B, C = 11, 4, 33
    buf = jnp.asarray(RNG.randn(K, C).astype(np.float32))
    recv = jnp.asarray(RNG.randn(B, C).astype(np.float32))
    for start, lo, hi, combine in [(3, 1, 4, True), (7, 0, 4, False), (0, 2, 2, True)]:
        out = jax.jit(
            lambda b, r, s=start, l=lo, h=hi, cb=combine: fused_combine_update(
                b, r, jnp.int32(s), jnp.int32(l), jnp.int32(h), combine=cb
            )
        )(buf, recv)
        want = np.asarray(buf).copy()
        if hi > lo:
            win = np.asarray(recv)[lo:hi]
            if combine:
                want[start + lo: start + hi] += win
            else:
                want[start + lo: start + hi] = win
        np.testing.assert_array_equal(np.asarray(out), want, err_msg=str((start, lo, hi, combine)))


def test_chunked_copy_never_materializes_pad():
    """Satellite regression: the ragged tail rides the grid's masked final
    block — no jnp.concatenate pad copy appears in the jaxpr (it was a full
    extra HBM pass of the buffer)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.chunked_copy import chunked_copy

    x = jnp.zeros(1000, jnp.float32)  # 1000 % 256 != 0: ragged tail
    jaxpr = str(jax.make_jaxpr(
        lambda v: chunked_copy(v, chunk_elems=256, interpret=True))(x))
    assert "concatenate" not in jaxpr
    assert "pad" not in jaxpr


# ---------------------------------------------------------------------------
# interpret-mode resolution: one helper, every call site


def _pallas_eqns(jaxpr):
    """Yield every pallas_call eqn, recursing through sub-jaxpr params."""
    import jax.core as jc

    def subs(v):
        if isinstance(v, jc.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jc.Jaxpr):
            yield v
        elif isinstance(v, (list, tuple)):
            for x in v:
                yield from subs(x)

    for eq in jaxpr.eqns:
        if eq.primitive.name == "pallas_call":
            yield eq
        for v in eq.params.values():
            for sub in subs(v):
                yield from _pallas_eqns(sub)


def test_resolve_interpret_tiers():
    """None defers to the backend probe; explicit bools always win."""
    from repro.kernels.ops import on_tpu, resolve_interpret

    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    # in the CPU CI environment the default must interpret; on real TPU
    # hardware the same None must compile
    assert resolve_interpret(None) is (not on_tpu())


def test_cpu_traces_never_embed_compiled_pallas():
    """Satellite regression: with interpret left to default on a CPU
    backend, NO pallas_call in any kernel entry point's jaxpr may carry
    interpret=False — that trace would abort at compile time."""
    import jax
    from repro.kernels.ops import on_tpu

    if on_tpu():
        pytest.skip("CPU-backend regression; interpret defaults off on TPU")

    x = jnp.zeros(1000, jnp.float32)
    w = jnp.zeros(128, jnp.float32)
    q = jnp.zeros((1, 64, 2, 16), jnp.float32)
    kv = jnp.zeros((1, 64, 1, 16), jnp.float32)
    mode = jnp.zeros((4, 1), jnp.int32)
    cases = [
        (lambda: ops.chunked_copy(x, chunk_elems=256), "chunked_copy"),
        (lambda: ops.mix(w, w, 0.5), "mix"),
        (lambda: ops.scaled_add(w, w, 0.1), "scaled_add"),
        (lambda: ops.fused_combine(jnp.zeros((4, 8)), jnp.ones((4, 8)), mode),
         "fused_combine"),
        (lambda: ops.flash_attention(q, kv, kv, causal=True, bq=32, bk=32),
         "flash_attention"),
    ]
    found = 0
    for fn, name in cases:
        jx = jax.make_jaxpr(lambda _=None: fn())()
        eqns = list(_pallas_eqns(jx.jaxpr))
        assert eqns, f"{name}: no pallas_call found in trace"
        for eq in eqns:
            assert eq.params["interpret"] is not False, (
                f"{name}: CPU trace embeds interpret=False"
            )
        found += len(eqns)
    assert found >= len(cases)


def test_inkernel_replay_honors_resolve_interpret():
    """The in-kernel executor's emulation kernel goes through the same
    resolver: its single pallas_call interprets on CPU."""
    import jax
    from repro.core.schedules import build, lower_schedule
    from repro.kernels.inkernel_collective import inkernel_replay_shared
    from repro.kernels.ops import on_tpu

    if on_tpu():
        pytest.skip("CPU-backend regression; interpret defaults off on TPU")

    n, K = 4, 4
    low = lower_schedule(build("pipelined_chain", n, root=0, num_chunks=K))
    shared = jnp.zeros((n, K, 8), jnp.float32)
    jx = jax.make_jaxpr(lambda s: inkernel_replay_shared(low, s))(shared)
    eqns = list(_pallas_eqns(jx.jaxpr))
    assert len(eqns) == 1, "replay must stay a single launch"
    assert eqns[0].params["interpret"] is not False
