"""Data pipeline, bucketing, checkpointing, HLO analysis, serving engine."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback — see tests/_compat.py
    from _compat import given, settings, strategies as st

from repro.configs import get_config
from repro.core.bucketing import pack_buckets, plan_buckets, unpack_buckets
from repro.data.pipeline import SyntheticZipf, batches, make_source
from repro.models import Model
from repro.serve.engine import Engine
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint


# ------------------------------ data ---------------------------------------


def test_data_deterministic_and_shifted():
    cfg = get_config("minitron-8b-smoke")
    src = make_source(cfg, seed=3)
    it1 = batches(src, cfg, batch=4, seq=32)
    it2 = batches(src, cfg, batch=4, seq=32)
    b1, b2 = next(it1), next(it2)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    # labels are next-token shifted
    raw = src.batch(0, 4, 32)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), raw[:, :-1])
    np.testing.assert_array_equal(np.asarray(b1["labels"]), raw[:, 1:])


def test_zipf_is_skewed():
    src = SyntheticZipf(1000, seed=0)
    toks = src.batch(0, 64, 128).ravel()
    assert (toks < 10).mean() > 0.2  # head-heavy
    assert toks.max() < 1000


def test_memmap_source(tmp_path):
    from repro.data.pipeline import MemmapTokens

    path = str(tmp_path / "toks.npy")
    np.save(path, np.arange(10_000, dtype=np.int32) % 257)
    src = MemmapTokens(path, seed=1)
    b = src.batch(0, 3, 16)
    assert b.shape == (3, 17) and b.dtype == np.int32


def test_vlm_audio_batches_have_embeds():
    for arch in ("paligemma-3b-smoke", "whisper-large-v3-smoke"):
        cfg = get_config(arch)
        b = next(batches(make_source(cfg), cfg, batch=2, seq=16))
        assert "embeds" in b and b["embeds"].shape[0] == 2


# ---------------------------- bucketing -------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    sizes=st.lists(st.integers(1, 300), min_size=1, max_size=12),
    bucket_bytes=st.sampled_from([64, 256, 4096]),
)
def test_bucket_roundtrip(sizes, bucket_bytes):
    rng = np.random.RandomState(0)
    tree = {
        f"p{i}": jnp.asarray(rng.randn(s), jnp.float32 if i % 2 else jnp.bfloat16)
        for i, s in enumerate(sizes)
    }
    spec = plan_buckets(tree, bucket_bytes)
    bks = pack_buckets(tree, spec)
    # dtype purity per bucket
    for b, dt in zip(bks, spec.bucket_dtypes):
        assert b.dtype == dt
    out = unpack_buckets(bks, spec)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(out[k], np.float32), np.asarray(tree[k], np.float32))


# ---------------------------- checkpoint ------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(10, dtype=jnp.float32),
        "nest": {"b": jnp.ones((3, 4), jnp.bfloat16) * 1.5, "step": jnp.asarray(7, jnp.int32)},
        "lst": [jnp.zeros((2,)), jnp.full((5,), 2.0, jnp.bfloat16)],
    }
    d = str(tmp_path / "ck")
    save_checkpoint(d, 42, tree, extra={"note": "x"})
    assert latest_step(d) == 42
    like = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)
    out = restore_checkpoint(d, 42, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


# ---------------------------- hlo analysis ----------------------------------


def test_hlo_parser_trip_counts():
    from repro.analysis.hlo import parse_hlo

    def f(ws, x):
        def body(x, w):
            return jax.nn.relu(x @ w), ()
        x, _ = jax.lax.scan(body, x, ws)
        return x.sum()

    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    compiled = jax.jit(f).lower(ws, x).compile()
    mod = parse_hlo(compiled.as_text())
    got = mod.dot_flops()
    want = 5 * 2 * 8 * 64 * 64
    assert abs(got - want) / want < 1e-6, (got, want)
    assert not mod.unknown_trip


def test_roofline_terms_positive():
    import glob
    import json

    rows = [json.load(open(p)) for p in glob.glob("experiments/dryrun/*.json")]
    if not rows:
        pytest.skip("no dry-run artifacts yet")
    for r in rows:
        assert r["t_compute_s"] > 0
        assert r["t_memory_s"] > 0
        assert r["bottleneck"] in ("compute", "memory", "collective")


# ------------------------------ serving -------------------------------------


def test_engine_greedy_generation():
    cfg = get_config("minitron-8b-smoke")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(cfg, params)
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, 500, (2, 12)))}
    res = eng.generate(batch, steps=6)
    assert res.tokens.shape == (2, 6)
    assert np.isfinite(res.logprobs).all()
    # greedy + deterministic weights -> rerunning gives the same tokens
    res2 = eng.generate(batch, steps=6)
    np.testing.assert_array_equal(res.tokens, res2.tokens)


def test_engine_matches_forward():
    """Greedy engine tokens == argmax of the teacher-forced forward pass."""
    cfg = get_config("xlstm-350m-smoke")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    rng = np.random.RandomState(1)
    prompt = jnp.asarray(rng.randint(0, 500, (1, 8)))
    eng = Engine(cfg, params)
    res = eng.generate({"tokens": prompt}, steps=4)
    # teacher-force the generated tokens and check each argmax reproduces
    seq = jnp.concatenate([prompt, jnp.asarray(res.tokens)], axis=1)
    logits, _ = m.forward(params, {"tokens": seq})
    for i in range(4):
        want = int(jnp.argmax(logits[0, 7 + i]))
        assert want == int(res.tokens[0, i]), (i, want, res.tokens)
