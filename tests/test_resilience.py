"""Degraded-mesh replanning and the resilient execution wrapper.

Satellite acceptance (ISSUE 7): for each op x n in {3, 4, 8} x one dead
rank, the survivor-mesh schedule converges in the numpy simulator and its
wire bytes match ``expected_wire_bytes`` on the SHRUNK mesh. Plus:
plan_cached health keying (a health transition can never serve a pre-fault
plan), the typed fallback chain, the straggler watchdog -> Tuner.record ->
fingerprint invalidation loop, and trainer graceful degradation.
"""
from __future__ import annotations

import math

import numpy as np
import pytest

from repro.comm import (
    DeadRankError,
    FallbackExhaustedError,
    FallbackPolicy,
    FaultSpec,
    MeshHealth,
    Watchdog,
    expected_wire_bytes,
    plan_cached,
    plan_collective,
    plan_degraded,
)
from repro.comm import api as comm_api
from repro.comm.faults import FaultError
from repro.core.simulator import simulate_collective
from repro.core.tuner import Tuner

# non-composite algo per op (reduce_then_bcast has no single-phase
# closed-form wire accounting — expected_wire_bytes raises on it by design)
PINNED = {
    "bcast": "pipelined_chain",
    "reduce": "pipelined_reduce_chain",
    "allreduce": "ring_allreduce",
    "allgather": "ring_allgather",
    "reduce_scatter": "ring_reduce_scatter",
    "allgatherv": "ring_allgatherv",
    "alltoallv": "pairwise_alltoallv",
}
DEAD = 1


def _sizes(op, n, rng):
    if op == "allgatherv":
        return tuple(int(rng.integers(1, 5)) for _ in range(n))
    if op == "alltoallv":
        return tuple(int(rng.integers(1, 4)) for _ in range(n * n))
    return None


def _check_converges(plan, rng):
    """Survivor-mesh convergence in the numpy simulator — same conventions
    as tests/test_comm_plans.py, on the plan's (shrunk) logical mesh."""
    sched = plan.schedule
    n, root = sched.n, sched.root
    if plan.op in ("allgatherv", "alltoallv"):
        sz = np.asarray(plan.sizes, dtype=np.int64)
        full = rng.standard_normal((sched.num_chunks, 3))
        owner = (
            np.repeat(np.arange(n), sz)
            if plan.op == "allgatherv"
            else np.repeat(np.arange(n * n) // n, sz)
        )
        data = [np.where((owner == r)[:, None], full, 0.0) for r in range(n)]
        out = simulate_collective(sched, data)
        if plan.op == "allgatherv":
            for r in range(n):
                np.testing.assert_array_equal(out[r], full, err_msg=f"rank {r}")
        else:
            off = np.concatenate([[0], np.cumsum(sz)])
            for r in range(n):
                for s in range(n):
                    b = s * n + r
                    lo, hi = off[b], off[b + 1]
                    np.testing.assert_array_equal(
                        out[r][lo:hi], full[lo:hi], err_msg=f"rank {r} block {s}->{r}"
                    )
        return
    data = [rng.standard_normal((sched.num_chunks, 3)) for _ in range(n)]
    out = simulate_collective(sched, data)
    if plan.op == "bcast":
        for r in range(n):
            np.testing.assert_allclose(out[r], data[root], rtol=1e-9, err_msg=f"rank {r}")
        return
    total = np.sum(data, axis=0)
    if plan.op == "reduce":
        np.testing.assert_allclose(out[root], total, rtol=1e-9)
    elif plan.op == "allreduce":
        for r in range(n):
            np.testing.assert_allclose(out[r], total, rtol=1e-9, err_msg=f"rank {r}")
    elif plan.op == "allgather":
        ref = np.stack([data[r][r] for r in range(n)])
        for r in range(n):
            np.testing.assert_allclose(out[r], ref, rtol=1e-9, err_msg=f"rank {r}")
    elif plan.op == "reduce_scatter":
        for r in range(n):
            np.testing.assert_allclose(out[r][r], total[r], rtol=1e-9, err_msg=f"rank {r}")


# ----------------------- degraded replanning parity -------------------------


@pytest.mark.parametrize("n", [3, 4, 8])
@pytest.mark.parametrize("op,algo", sorted(PINNED.items()))
def test_degraded_replanning_parity(op, algo, n):
    rng = np.random.default_rng((5, n))
    sizes = _sizes(op, n, rng)
    M = (1 << 14) if sizes is None else 512 * sum(sizes)
    health = MeshHealth(n=n, dead_ranks=(DEAD,))
    plan = plan_degraded(op, M, n, health, algo=algo, sizes=sizes)
    assert plan.n == n - 1
    assert plan.survivors == tuple(r for r in range(n) if r != DEAD)
    assert math.isfinite(plan.predicted_s)
    want = expected_wire_bytes(
        op, plan.algo, plan.M, plan.n, plan.num_chunks, sizes=plan.sizes
    )
    assert plan.wire_bytes() == want, (plan.wire_bytes(), want)
    _check_converges(plan, rng)


def test_degraded_ragged_sizes_shrink():
    n = 4
    health = MeshHealth(n=n, dead_ranks=(2,))
    sizes = (5, 2, 3, 1)
    plan = plan_degraded("allgatherv", 1024 * sum(sizes), n, health,
                         algo="ring_allgatherv", sizes=sizes)
    assert plan.sizes == (5, 2, 1)         # dead rank 2's rows drop out
    assert plan.M == 1024 * 8


def test_dead_root_is_typed():
    health = MeshHealth(n=4, dead_ranks=(0,))
    for op in ("bcast", "reduce"):
        with pytest.raises(DeadRankError, match="checkpoint"):
            plan_degraded(op, 1 << 12, 4, health, algo=PINNED[op])
    # rootless ops replan fine with rank 0 gone
    plan = plan_degraded("allreduce", 1 << 12, 4, health, algo="ring_allreduce")
    assert plan.n == 3 and plan.survivors == (1, 2, 3)


def test_all_dead_is_typed():
    with pytest.raises(DeadRankError):
        plan_degraded("allreduce", 1 << 12, 2, MeshHealth(n=2, dead_ranks=(0, 1)))


def test_slow_link_only_reprices_without_shrinking():
    health = MeshHealth(n=4, slow_links=(((0, 1), 8.0),))
    base = plan_collective("allreduce", 1 << 20, 4, algo="ring_allreduce")
    plan = plan_degraded("allreduce", 1 << 20, 4, health, algo="ring_allreduce")
    assert plan.n == 4 and plan.survivors is None
    assert plan.predicted_s > base.predicted_s
    assert plan.decision.source.endswith("+degraded")


# -------------------------- plan cache health keys ---------------------------


def test_plan_cached_health_fingerprint_keying():
    kw = dict(op="allreduce", M=1 << 16, n=8, algo="ring_allreduce")
    healthy = plan_cached(**kw)
    assert plan_cached(**kw) is healthy
    # an explicitly healthy report keys separately but plans identically
    ok = plan_cached(**kw, health=MeshHealth(n=8))
    assert ok.n == 8 and ok.survivors is None
    degraded = plan_cached(**kw, health=MeshHealth(n=8, dead_ranks=(3,)))
    assert degraded is not healthy
    assert degraded.n == 7
    assert degraded.survivors == (0, 1, 2, 4, 5, 6, 7)
    # degraded plans are cached under their health fingerprint
    assert plan_cached(**kw, health=MeshHealth(n=8, dead_ranks=(3,))) is degraded
    # a different health transition gets a different plan
    other = plan_cached(**kw, health=MeshHealth(n=8, dead_ranks=(5,)))
    assert other is not degraded and other.survivors == (0, 1, 2, 3, 4, 6, 7)
    # and the pre-fault plan is still served to healthy callers
    assert plan_cached(**kw) is healthy


# ------------------------------ fallback chain -------------------------------


def test_fallback_policy_validation():
    with pytest.raises(ValueError, match="unknown fallback stages"):
        FallbackPolicy(chain=("compiled", "warp"))
    with pytest.raises(ValueError, match="at least one stage"):
        FallbackPolicy(chain=())
    with pytest.raises(ValueError, match="max_retries"):
        FallbackPolicy(max_retries=-1)


def _fast_policy(**kw):
    kw.setdefault("backoff_s", 0.0)
    return FallbackPolicy(**kw)


def test_fallback_chain_degrades_to_one_shot(monkeypatch):
    plan = plan_collective("allreduce", 1 << 12, 4, algo="ring_allreduce")
    calls = []

    def broken_apply(plan, x, axis_name, *, fused=True, compiled=None,
                     inkernel=None):
        calls.append(
            "inkernel" if inkernel else ("compiled" if compiled else "unrolled")
        )
        raise RuntimeError("executor exploded")

    monkeypatch.setattr(comm_api, "apply_plan", broken_apply)
    monkeypatch.setattr(comm_api, "_one_shot_fallback",
                        lambda plan, x, ax: "one-shot-result")
    events = []
    out = comm_api.apply_plan_resilient(
        plan, None, "data", policy=_fast_policy(max_retries=1),
        on_event=events.append,
    )
    assert out == "one-shot-result"
    # each schedule stage burned its retry before the chain degraded
    assert calls == ["inkernel", "inkernel", "compiled", "compiled",
                     "unrolled", "unrolled"]
    assert [e.outcome for e in events] == ["error"] * 6 + ["ok"]
    assert events[-1].stage == "xla"


def test_inkernel_failure_degrades_to_compiled(monkeypatch):
    """The new chain head: an in-kernel failure falls back to the compiled
    executor and the run SUCCEEDS there — straggler events on the recovery
    stage are still recorded on the way."""
    plan = plan_collective("allreduce", 1 << 12, 4, algo="ring_allreduce")

    def apply(plan, x, axis_name, *, fused=True, compiled=None, inkernel=None):
        if inkernel:
            raise RuntimeError("no in-kernel dma engine")
        import time
        time.sleep(0.02)
        return "compiled-result"

    monkeypatch.setattr(comm_api, "apply_plan", apply)
    events = []
    out = comm_api.apply_plan_resilient(
        plan, None, "data",
        policy=_fast_policy(max_retries=0, timeout_s=1e-4),
        on_event=events.append,
    )
    assert out == "compiled-result"
    assert [(e.stage, e.outcome) for e in events] == [
        ("inkernel", "error"), ("compiled", "straggler"),
    ]


def test_fallback_exhausted_names_every_cause(monkeypatch):
    plan = plan_collective("allreduce", 1 << 12, 4, algo="ring_allreduce")

    def broken(*a, **kw):
        raise RuntimeError("no fabric")

    monkeypatch.setattr(comm_api, "apply_plan", broken)
    monkeypatch.setattr(comm_api, "_one_shot_fallback", broken)
    with pytest.raises(FallbackExhaustedError) as ei:
        comm_api.apply_plan_resilient(
            plan, None, "data", policy=_fast_policy(max_retries=0)
        )
    msg = str(ei.value)
    for stage in ("inkernel[0]", "compiled[0]", "unrolled[0]", "xla[0]"):
        assert stage in msg
    assert "no fabric" in msg


def test_fault_errors_propagate_immediately(monkeypatch):
    plan = plan_collective("allreduce", 1 << 12, 4, algo="ring_allreduce")
    calls = []

    def dead(*a, **kw):
        calls.append(1)
        raise DeadRankError("rank 2 is gone; replan")

    monkeypatch.setattr(comm_api, "apply_plan", dead)
    with pytest.raises(DeadRankError, match="replan"):
        comm_api.apply_plan_resilient(plan, None, "data",
                                      policy=_fast_policy(max_retries=3))
    assert len(calls) == 1  # a diagnosis is not retried
    assert issubclass(DeadRankError, FaultError)


def test_slow_success_is_straggler_not_failure(monkeypatch):
    plan = plan_collective("allreduce", 1 << 12, 4, algo="ring_allreduce")

    def slow_ok(plan, x, axis_name, **kw):
        import time
        time.sleep(0.02)
        return "late-but-right"

    monkeypatch.setattr(comm_api, "apply_plan", slow_ok)
    events = []
    out = comm_api.apply_plan_resilient(
        plan, None, "data", policy=_fast_policy(timeout_s=1e-4),
        on_event=events.append,
    )
    assert out == "late-but-right"
    assert [e.outcome for e in events] == ["straggler"]


# -------------------------------- watchdog -----------------------------------


def test_watchdog_flags_stragglers_into_tuner():
    tuner = Tuner()
    wd = Watchdog(tuner, straggler_factor=3.0)
    plan = plan_collective("allreduce", 1 << 16, 8, algo="ring_allreduce",
                           tuner=tuner)
    fp0 = tuner.fingerprint()
    exp = wd.expected_s(plan)
    assert exp > 0 and math.isfinite(exp)
    assert wd.observe(plan, exp) is None          # on-time: no report
    assert tuner.fingerprint() == fp0
    rep = wd.observe(plan, exp * 10)              # straggler
    assert rep is not None and rep.factor == pytest.approx(10.0)
    assert wd.reports == [rep]
    # the observation landed in the tuner and moved its fingerprint, so
    # plan_cached keys shift off every plan priced with the stale table
    assert tuner.fingerprint() != fp0
    seen = []
    wd2 = Watchdog(straggler_factor=2.0, on_straggler=seen.append)
    assert wd2.observe(plan, exp * 5) is not None
    assert len(seen) == 1
    with pytest.raises(ValueError, match="straggler_factor"):
        Watchdog(straggler_factor=1.0)


# --------------------- trainer graceful degradation --------------------------


def test_trainer_degraded_psum_fallback(dist):
    """A dead-rank MeshHealth overrides sync_mode with the masked
    psum-over-survivors step, and training still converges."""
    dist(
        """
import numpy as np
from repro.comm.faults import MeshHealth
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.launch.mesh import make_local_mesh
from repro.train.trainer import Trainer

cfg = get_config("xlstm-350m-smoke")
run = RunConfig(total_steps=4, warmup_steps=1, sync_mode="tuned_allreduce",
                learning_rate=1e-3, seed=3)
health = MeshHealth(n=8, dead_ranks=(3,))
tr = Trainer(cfg, run, mesh=make_local_mesh(1), health=health)
_, _, hist = tr.train(batch=8, seq=32, steps=4, log_every=3)
losses = [h["loss"] for h in hist]
assert all(np.isfinite(l) for l in losses), losses
assert losses[-1] < losses[0], losses
print("PASS")
""",
        timeout=580,
    )


def test_degraded_psum_survivor_mean_normalization(dist):
    """The masked psum divides by the SURVIVOR count: gradients on a
    degraded mesh equal the plain mean over the surviving ranks' shards
    (dividing by the full world size would silently shrink the LR)."""
    dist(
        """
import jax, jax.numpy as jnp, numpy as np
import repro  # noqa: F401 — installs the jax.sharding.AxisType compat shim
from jax.sharding import PartitionSpec as P

n, dead = 4, 1
mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
alive = np.ones((n,), np.float32); alive[dead] = 0.0
surv = n - 1

def survivor_mean(v):
    r = jax.lax.axis_index("data")
    m = jnp.asarray(alive)[r]
    return jax.lax.psum(v * m, "data") / surv

vals = np.arange(n, dtype=np.float32) + 1.0   # rank r holds r+1
out = jax.shard_map(survivor_mean, mesh=mesh, in_specs=(P("data"),),
                    out_specs=P("data"))(jnp.asarray(vals))
want = (vals.sum() - vals[dead]) / surv
np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)
print("PASS")
""",
        devices=4,
    )
