"""Analytic model sanity (Eqs. 1-6) + tuner behaviour."""
from __future__ import annotations

import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback — see tests/_compat.py
    from _compat import given, settings, strategies as st

from repro.core import cost_model as cm
from repro.core.tuner import Tuner

HW = cm.TPU_V5E
B = HW.link_bw


def test_regimes():
    """Paper Sec. V: trees win small messages, pipelined chain / scatter-
    allgather win large messages."""
    n = 16
    small, large = 1024, 256 << 20
    assert cm.cost("binomial", small, n) < cm.cost("chain", small, n)
    assert cm.cost("binomial", small, n) < cm.cost("pipelined_chain", small, n)
    assert cm.cost("pipelined_chain", large, n) < cm.cost("binomial", large, n)
    assert cm.cost("scatter_allgather", large, n) < cm.cost("binomial", large, n)
    # pipelined chain approaches the bandwidth bound M/B for large M
    t = cm.cost("pipelined_chain", large, n)
    assert t < 2.2 * large / B


def test_direct_worst_at_scale():
    for M in (1024, 1 << 20):
        assert cm.cost("direct", M, 32) > cm.cost("binomial", M, 32)


@settings(max_examples=60, deadline=None)
@given(M=st.integers(1 << 14, 1 << 28), n=st.integers(3, 64))
def test_optimal_chunk_is_near_optimal(M, n):
    """C* (continuous minimizer) is within 2x of the best DISCRETE chunking
    over a wide scan — ceil(M/C) quantization makes exact local optimality
    false, but the closed form must stay competitive."""
    c_star = cm.optimal_chunk_bytes(M, n, HW, B)
    t_star = cm.t_pipelined_chain(M, n, HW, B, C=c_star)
    best = min(
        cm.t_pipelined_chain(M, n, HW, B, C=max(M / k, 1.0))
        for k in (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)
    )
    assert t_star <= 2.0 * best


@settings(max_examples=40, deadline=None)
@given(M=st.integers(1, 1 << 24), n=st.integers(2, 128))
def test_monotone_in_message_size(M, n):
    for algo in ("chain", "binomial", "pipelined_chain", "scatter_allgather"):
        if algo == "scatter_allgather" and (n & (n - 1)):
            continue
        assert cm.cost(algo, M, n) <= cm.cost(algo, 2 * M, n) + 1e-12


def test_host_staging_tradeoff():
    """Eq. 6: staging only pays off when M/B_host is small vs the tree."""
    n = 16
    assert cm.cost("knomial_staged", 256 << 20, n) > cm.cost("pipelined_chain", 256 << 20, n)


def test_interpod_pricing():
    t_intra = cm.cost("pipelined_chain", 64 << 20, 16, inter_pod=False)
    t_inter = cm.cost("pipelined_chain", 64 << 20, 16, inter_pod=True)
    assert t_inter > 2 * t_intra  # interpod bw is 4x slower


# ---------------------------- tuner ----------------------------------------


def test_tuner_windows():
    t = Tuner()
    assert t.select(256, 16).algo in ("binomial", "knomial")
    big = t.select(256 << 20, 16)
    assert big.algo in ("pipelined_chain", "scatter_allgather", "bidir_chain")
    assert big.num_chunks > 1 or big.algo == "scatter_allgather"
    # non-power-of-two n: scatter_allgather must not be chosen
    assert t.select(256 << 20, 12).algo != "scatter_allgather"


def test_tuner_empirical_override(tmp_path):
    t = Tuner()
    M, n = 1 << 20, 8
    analytic = t.select(M, n)
    t.record(M, n, "chain", 1, measured_s=1e-9)  # fake: chain measured fastest
    hit = t.select(M, n)
    assert hit.source == "empirical" and hit.algo == "chain"
    assert analytic.algo != "chain" or analytic.source == "analytic"
    # persistence round-trip
    p = str(tmp_path / "table.json")
    t.save(p)
    t2 = Tuner.load(p)
    assert t2.select(M, n).algo == "chain"


def test_tuner_calibrate_picks_best():
    t = Tuner()
    costs = {"binomial": 3.0, "chain": 1.0, "pipelined_chain": 2.0, "knomial": 4.0,
             "scatter_allgather": 5.0, "direct": 6.0, "bidir_chain": 2.5}

    def fake_measure(algo, M, n, k):
        return costs[algo]

    t.calibrate(fake_measure, sizes=[1 << 16], n=8)
    assert t.select(1 << 16, 8).algo == "chain"
