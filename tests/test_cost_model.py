"""Analytic model sanity (Eqs. 1-6) + tuner behaviour."""
from __future__ import annotations

import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback — see tests/_compat.py
    from _compat import given, settings, strategies as st

from repro.core import cost_model as cm
from repro.core.tuner import Tuner

HW = cm.TPU_V5E
B = HW.link_bw


def test_regimes():
    """Paper Sec. V: trees win small messages, pipelined chain / scatter-
    allgather win large messages."""
    n = 16
    small, large = 1024, 256 << 20
    assert cm.cost("binomial", small, n) < cm.cost("chain", small, n)
    assert cm.cost("binomial", small, n) < cm.cost("pipelined_chain", small, n)
    assert cm.cost("pipelined_chain", large, n) < cm.cost("binomial", large, n)
    assert cm.cost("scatter_allgather", large, n) < cm.cost("binomial", large, n)
    # pipelined chain approaches the bandwidth bound M/B for large M
    t = cm.cost("pipelined_chain", large, n)
    assert t < 2.2 * large / B


def test_direct_worst_at_scale():
    for M in (1024, 1 << 20):
        assert cm.cost("direct", M, 32) > cm.cost("binomial", M, 32)


@settings(max_examples=60, deadline=None)
@given(M=st.integers(1 << 14, 1 << 28), n=st.integers(3, 64))
def test_optimal_chunk_is_near_optimal(M, n):
    """C* (continuous minimizer) is within 2x of the best DISCRETE chunking
    over a wide scan — ceil(M/C) quantization makes exact local optimality
    false, but the closed form must stay competitive."""
    c_star = cm.optimal_chunk_bytes(M, n, HW, B)
    t_star = cm.t_pipelined_chain(M, n, HW, B, C=c_star)
    best = min(
        cm.t_pipelined_chain(M, n, HW, B, C=max(M / k, 1.0))
        for k in (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)
    )
    assert t_star <= 2.0 * best


@settings(max_examples=40, deadline=None)
@given(M=st.integers(1, 1 << 24), n=st.integers(2, 128))
def test_monotone_in_message_size(M, n):
    for algo in ("chain", "binomial", "pipelined_chain", "scatter_allgather"):
        if algo == "scatter_allgather" and (n & (n - 1)):
            continue
        assert cm.cost(algo, M, n) <= cm.cost(algo, 2 * M, n) + 1e-12


def test_host_staging_tradeoff():
    """Eq. 6: staging only pays off when M/B_host is small vs the tree."""
    n = 16
    assert cm.cost("knomial_staged", 256 << 20, n) > cm.cost("pipelined_chain", 256 << 20, n)


def test_interpod_pricing():
    t_intra = cm.cost("pipelined_chain", 64 << 20, 16, inter_pod=False)
    t_inter = cm.cost("pipelined_chain", 64 << 20, 16, inter_pod=True)
    assert t_inter > 2 * t_intra  # interpod bw is 4x slower


# ---------------------------- tuner ----------------------------------------


def test_tuner_windows():
    t = Tuner()
    assert t.select(256, 16).algo in ("binomial", "knomial")
    big = t.select(256 << 20, 16)
    assert big.algo in ("pipelined_chain", "scatter_allgather", "bidir_chain")
    assert big.num_chunks > 1 or big.algo == "scatter_allgather"
    # non-power-of-two n: scatter_allgather must not be chosen
    assert t.select(256 << 20, 12).algo != "scatter_allgather"


def test_tuner_empirical_override(tmp_path):
    t = Tuner()
    M, n = 1 << 20, 8
    analytic = t.select(M, n)
    t.record(M, n, "chain", 1, measured_s=1e-9)  # fake: chain measured fastest
    hit = t.select(M, n)
    assert hit.source == "empirical" and hit.algo == "chain"
    assert analytic.algo != "chain" or analytic.source == "analytic"
    # persistence round-trip
    p = str(tmp_path / "table.json")
    t.save(p)
    t2 = Tuner.load(p)
    assert t2.select(M, n).algo == "chain"


# ------------------- executor-path pricing (PR 8) ---------------------------


def test_t_exec_path_ordering():
    """For any multi-round schedule the single persistent launch is priced
    strictly below the per-round compiled loop, which is strictly below the
    fully unrolled program."""
    for rounds, classes in [(3, 1), (10, 2), (29, 2)]:
        ink = cm.t_exec_path("inkernel", rounds, classes, HW)
        comp = cm.t_exec_path("compiled", rounds, classes, HW)
        unr = cm.t_exec_path("unrolled", rounds, classes, HW)
        assert 0 < ink < comp <= unr
        if classes > 1:
            assert comp < unr
    # a 0-round noop costs at most one boundary on any path
    assert cm.t_exec_path("compiled", 0, 1, HW) == 0.0
    with pytest.raises(ValueError):
        cm.t_exec_path("warp_specialized", 4, 1, HW)


def test_calibrate_t_launch_from_committed_table():
    """The committed compile table must calibrate to a positive per-round
    lowering cost, and the per-n-group medians must agree within ~2x —
    boundary cost is a property of the toolchain, not the rank count."""
    import os

    from repro.comm.tables import load_compile_table

    path = os.path.join(os.path.dirname(__file__), "..",
                        "experiments", "compile_table.json")
    table = load_compile_table(path)
    t = cm.calibrate_t_launch(table)
    assert t > 0
    per_n = {}
    for key in table:
        n_group = key.split("/")[0]
        per_n.setdefault(n_group, {})[key] = table[key]
    medians = {g: cm.calibrate_t_launch(sub) for g, sub in per_n.items()
               if len({k.rsplit("/K", 1)[0] for k in sub}) >= 1}
    vals = [v for v in medians.values() if v > 0]
    assert len(vals) >= 2, f"need >=2 n-groups with multi-K sweeps, got {medians}"
    assert max(vals) <= 2.0 * min(vals), medians


def test_calibrate_t_launch_rejects_flat_table():
    with pytest.raises(ValueError):
        cm.calibrate_t_launch(
            {"n8/bcast/chain/K4": {"num_rounds": 4, "unrolled_lower_s": 0.1}}
        )


def test_tuner_exec_path_roundtrip(tmp_path):
    """record(exec_path=...) -> select() surfaces it; persistence keeps it;
    load() rejects a rotted value."""
    import json

    t = Tuner()
    M, n = 1 << 20, 8
    t.record(M, n, "pipelined_chain", 8, measured_s=1e-9,
             extras={"exec_path": "inkernel"})
    hit = t.select(M, n)
    assert hit.source == "empirical" and hit.exec_path == "inkernel"
    p = str(tmp_path / "table.json")
    t.save(p)
    assert Tuner.load(p).select(M, n).exec_path == "inkernel"
    with pytest.raises(ValueError):
        # a winning measurement with a bogus tier must be rejected, not stored
        t.record(M, n, "chain", 1, measured_s=1e-12,
                 extras={"exec_path": "warp_specialized"})
    from repro.core.tuner import TunerTableError

    blob = json.load(open(p))
    for entry in blob["table"].values():
        if "exec_path" in entry:
            entry["exec_path"] = "warp_specialized"
    bad = str(tmp_path / "bad.json")
    json.dump(blob, open(bad, "w"))
    with pytest.raises(TunerTableError):
        Tuner.load(bad)


def test_tuner_calibrate_picks_best():
    t = Tuner()
    costs = {"binomial": 3.0, "chain": 1.0, "pipelined_chain": 2.0, "knomial": 4.0,
             "scatter_allgather": 5.0, "direct": 6.0, "bidir_chain": 2.5}

    def fake_measure(algo, M, n, k):
        return costs[algo]

    t.calibrate(fake_measure, sizes=[1 << 16], n=8)
    assert t.select(1 << 16, 8).algo == "chain"
