"""MoE routing invariants on a single CPU device.

Covers the routing-bugfix sweep: aux-loss calibration (ce normalized by k),
the non-divisible-T group fallback, the capacity floor clamp, and property
tests on the dispatch/combine tensors produced by ``_route``. Multi-device
alltoallv dispatch parity lives in test_ragged_multidev.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib


def mk_cfg(**kw):
    base = dict(
        name="t", family="moe", num_layers=1, d_model=8, num_heads=2,
        num_kv_heads=2, d_ff=16, vocab_size=32, num_experts=4,
        experts_per_token=2, moe_group_size=8,
    )
    base.update(kw)
    return ModelConfig(**base)


# ---------------------------------------------------------------- aux loss

@pytest.mark.parametrize("k", [1, 2])
def test_aux_loss_calibrated_under_uniform_router(k):
    """With a zeroed router (uniform probs) the GShard aux loss must sit at
    exactly router_aux_coef for ANY top-k width: me_e = 1/E and, with ce
    normalized by k, ce_e = 1/E, so E * sum(me * ce) = 1. Before the fix,
    k=2 doubled ce and the loss came out at 2x the coefficient."""
    cfg = mk_cfg(experts_per_token=k)
    p = dict(moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32))
    p["router"] = jnp.zeros_like(p["router"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8), jnp.float32)
    _, aux = moe_lib.moe_ffn(p, x, cfg)
    assert abs(float(aux) - cfg.router_aux_coef) < 1e-5


def test_ce_sums_to_one_regardless_of_k():
    for k in (1, 2, 3):
        cfg = mk_cfg(experts_per_token=k)
        p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 8), jnp.float32)
        xg = x.reshape(2, 2, 8, 8)
        _, _, _, ce = moe_lib._route(p, xg, cfg)
        assert abs(float(jnp.sum(ce)) - 1.0) < 1e-5, k


# ------------------------------------------------------------ group fallback

@pytest.mark.parametrize(
    "T,group,want",
    [(17, 16, 1), (520, 512, 260), (64, 16, 16), (24, 16, 12)],
)
def test_group_size_falls_back_to_largest_divisor(T, group, want):
    assert moe_lib._group_size(T, mk_cfg(moe_group_size=group)) == want


@pytest.mark.parametrize("T", [17, 520])
def test_moe_ffn_handles_non_divisible_seq_len(T):
    cfg = mk_cfg(moe_group_size=16)
    p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, T, 8), jnp.float32)
    y, aux = moe_lib.moe_ffn(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(float(aux))


# ------------------------------------------------------------ capacity clamp

def test_capacity_floor_clamped_to_slot_supply():
    # S=2, k=1: only 2 slots exist, so the floor of 4 must clamp to 2
    assert moe_lib._capacity(2, 1, 4, 1.25) <= 2
    # the floor still applies when supply allows it
    assert moe_lib._capacity(16, 2, 4, 1.25) >= 4
    # degenerate single-token group
    assert moe_lib._capacity(1, 2, 4, 1.25) == 2


# --------------------------------------------------- dispatch/combine props

def _routed(k=2, E=4, seed=0, S=8):
    cfg = mk_cfg(experts_per_token=k, num_experts=E, moe_group_size=S)
    p = moe_lib.init_moe(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 2 * S, 8), jnp.float32)
    xg = x.reshape(2, 2, S, 8)
    combine, dispatch, me, ce = moe_lib._route(p, xg, cfg)
    C = moe_lib._capacity(S, k, E, cfg.capacity_factor)
    return combine, dispatch, me, ce, C


@pytest.mark.parametrize("k,E,seed", [(1, 4, 0), (2, 4, 3), (2, 6, 7), (3, 4, 11)])
def test_combine_weights_per_token(k, E, seed):
    combine, dispatch, _, _, _ = _routed(k=k, E=E, seed=seed)
    w = np.asarray(combine)
    # non-negative, and each token's total combine weight is at most 1
    # (exactly 1 when none of its k choices were capacity-dropped)
    assert (w >= 0).all()
    tok = w.sum(axis=(3, 4))
    assert (tok <= 1 + 1e-5).all()
    # dispatch is exactly the support of combine
    assert np.array_equal(np.asarray(dispatch) > 0, w > 0)


@pytest.mark.parametrize("k,E,seed", [(2, 4, 0), (3, 4, 5)])
def test_capacity_slots_hold_at_most_one_token(k, E, seed):
    _, dispatch, _, _, C = _routed(k=k, E=E, seed=seed)
    d = np.asarray(dispatch)
    # within a group, each (expert, slot) pair is assigned to <= 1 token...
    assert (d.sum(axis=2) <= 1 + 1e-6).all()
    # ...and no token occupies a slot index >= C (shape is the proof) while
    # per-expert load within a group never exceeds C
    assert d.shape[-1] == C
    assert (d.sum(axis=(2, 4)) <= C + 1e-6).all()


def test_over_capacity_tokens_are_dropped_not_wrapped():
    # capacity_factor tiny -> C == floor -> with one dominant expert some
    # tokens MUST drop; their residual path is the caller's concern, but the
    # combine weight must vanish (no wraparound into slot 0)
    cfg = mk_cfg(experts_per_token=1, capacity_factor=0.01, moe_group_size=16)
    p = dict(moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32))
    # bias the router hard toward expert 0
    r = np.zeros((8, 4), np.float32)
    r[:, 0] = 100.0
    p["router"] = jnp.asarray(r)
    x = jnp.ones((1, 16, 8), jnp.float32)
    xg = x.reshape(1, 1, 16, 8)
    combine, dispatch, _, _ = moe_lib._route(p, xg, cfg)
    C = moe_lib._capacity(16, 1, 4, 0.01)
    d = np.asarray(dispatch)
    # exactly C tokens survive on expert 0, the rest are dropped
    assert d[..., 0, :].sum() == C
    assert np.asarray(combine).sum(axis=(3, 4)).max() <= 1 + 1e-6
    dropped = (np.asarray(combine).sum(axis=(3, 4)) < 1e-6).sum()
    assert dropped == 16 - C


# -------------------------------------------------------- expert partition

def test_expert_partition_contiguous_and_ragged():
    assert moe_lib.expert_partition(6, 4) == (2, 2, 1, 1)
    assert moe_lib.expert_partition(8, 4) == (2, 2, 2, 2)
    assert moe_lib.expert_partition(3, 4) == (1, 1, 1, 0)
    for E, n in [(6, 4), (5, 3), (2, 8)]:
        cnt = moe_lib.expert_partition(E, n)
        assert sum(cnt) == E and len(cnt) == n
        assert all(a >= b for a, b in zip(cnt, cnt[1:]))  # front-loaded
