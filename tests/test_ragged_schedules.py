"""Host-level tests for the ragged collectives (allgatherv / alltoallv).

ISSUE acceptance, numpy side:
  * every ragged builder converges in the numpy simulator across skewed
    size vectors INCLUDING zero-sized ranks, at n in {2, 3, 4, 8};
  * the lowered dense tables replay bit-identically to the IR walk
    (``simulate_lowered`` parity) for ragged schedules;
  * wire-byte accounting: ``CollectivePlan.wire_bytes()`` equals the
    closed forms in ``plan.expected_wire_bytes`` for every ragged algo;
  * the skew-aware tuner inverts: uniform-large picks ring, one-hot skew
    picks doubling (allgatherv); uniform picks pairwise, incast picks the
    store-and-forward ring (alltoallv);
  * the plan cache keys on the size vector;
  * ``load_ragged_table`` rejects accounting drift.
"""
from __future__ import annotations

import json
import os

import numpy as np
import pytest

from repro.comm import plan_cache_clear, plan_cached, plan_collective
from repro.comm import schedules as comm_schedules
from repro.comm.plan import expected_wire_bytes
from repro.comm.tables import TableSchemaError, load_ragged_table
from repro.core.cost_model import skew_ratio
from repro.core.schedules import lane_partition
from repro.core.schedules import lower_schedule
from repro.core.simulator import simulate_collective, simulate_lowered
from repro.core.tuner import Tuner

RNG = np.random.RandomState(7)

# size vectors per rank count: uniform, skewed, one-hot, zero ranks
GATHERV_CASES = {
    2: [(1, 1), (3, 1), (4, 0)],
    3: [(2, 2, 2), (1, 5, 2), (0, 3, 0)],
    4: [(2, 2, 2, 2), (3, 1, 0, 2), (7, 0, 0, 1), (0, 0, 5, 0)],
    8: [(1,) * 8, (5, 0, 1, 3, 0, 2, 4, 1), (9,) + (0,) * 7],
}


def _a2av_cases(n):
    uniform = tuple(tuple(2 for _ in range(n)) for _ in range(n))
    skewed = tuple(tuple((s * n + d) % 4 for d in range(n)) for s in range(n))
    incast = tuple(tuple(5 if d == 0 else 1 for d in range(n)) for s in range(n))
    zero_col = tuple(
        tuple(0 if d == n - 1 else 2 for d in range(n)) for s in range(n)
    )
    return [uniform, skewed, incast, zero_col]


def _owner(op, sizes, n):
    sz = np.asarray(sizes, dtype=np.int64)
    if op == "allgatherv":
        return np.repeat(np.arange(n), sz)
    return np.repeat(np.arange(n * n) // n, sz)


def _scattered(op, sizes, n, full):
    owner = _owner(op, sizes, n)
    return [np.where((owner == r)[:, None], full, 0.0) for r in range(n)]


def _assert_converged(op, sched, sizes, n, out, full):
    off = np.concatenate([[0], np.cumsum(sizes)])
    if op == "allgatherv":
        for r in range(n):
            np.testing.assert_array_equal(out[r], full, err_msg=f"rank {r}")
    else:
        for r in range(n):
            for s in range(n):
                b = s * n + r
                lo, hi = off[b], off[b + 1]
                np.testing.assert_array_equal(
                    out[r][lo:hi], full[lo:hi], err_msg=f"rank {r} block {s}->{r}"
                )


@pytest.mark.parametrize("n", [2, 3, 4, 8])
def test_allgatherv_builders_converge_and_lower(n):
    for sizes in GATHERV_CASES[n]:
        for algo in ("ring_allgatherv", "doubling_allgatherv"):
            if algo == "doubling_allgatherv" and n & (n - 1):
                continue
            sched = comm_schedules.build_op("allgatherv", algo, n, 0, sizes=sizes)
            sched.validate_ranks()
            assert sched.sizes == tuple(sizes)
            assert sched.num_chunks == sum(sizes)
            full = RNG.randn(sched.num_chunks, 3)
            data = _scattered("allgatherv", sizes, n, full)
            out = simulate_collective(sched, data)
            _assert_converged("allgatherv", sched, sizes, n, out, full)
            # lowered dense tables replay bit-identically
            out2 = simulate_lowered(lower_schedule(sched), _scattered("allgatherv", sizes, n, full))
            for r in range(n):
                np.testing.assert_array_equal(out[r], out2[r])


@pytest.mark.parametrize("n", [2, 3, 4, 8])
def test_alltoallv_builders_converge_and_lower(n):
    for m in _a2av_cases(n):
        flat = tuple(v for row in m for v in row)
        if sum(flat) == 0:
            continue
        for algo in ("pairwise_alltoallv", "ring_alltoallv"):
            sched = comm_schedules.build_op("alltoallv", algo, n, 0, sizes=m)
            sched.validate_ranks()
            assert sched.sizes == flat
            assert sched.num_chunks == sum(flat)
            full = RNG.randn(sched.num_chunks, 2)
            data = _scattered("alltoallv", flat, n, full)
            out = simulate_collective(sched, data)
            _assert_converged("alltoallv", sched, flat, n, out, full)
            out2 = simulate_lowered(lower_schedule(sched), _scattered("alltoallv", flat, n, full))
            for r in range(n):
                np.testing.assert_array_equal(out[r], out2[r])


def test_ragged_lane_partition_uniform_height():
    """Every ppermute lane in a ragged round moves a single uniform height —
    the invariant that keeps the unrolled executor's static slices valid."""
    for sched in (
        comm_schedules.ring_allgatherv(4, (3, 1, 0, 2)),
        comm_schedules.ring_alltoallv(4, ((0, 3, 1, 0), (2, 0, 0, 4), (1, 1, 0, 1), (5, 0, 2, 0))),
        comm_schedules.pairwise_alltoallv(3, ((1, 2, 0), (0, 1, 3), (2, 0, 1))),
    ):
        for rnd in sched.rounds:
            for lane in lane_partition(rnd.transfers):
                heights = {t.chunk_count for t in lane}
                assert len(heights) == 1, (sched.name, heights)


@pytest.mark.parametrize("n", [2, 4, 8])
def test_wire_accounting_matches_closed_forms(n):
    row = 512
    for sizes in GATHERV_CASES[n]:
        M = sum(sizes) * row
        for algo in ("ring_allgatherv", "doubling_allgatherv"):
            if algo == "doubling_allgatherv" and n & (n - 1):
                continue
            plan = plan_collective("allgatherv", M, n, algo=algo, sizes=sizes)
            assert plan.wire_bytes() == expected_wire_bytes(
                "allgatherv", algo, M, n, sizes=plan.sizes
            ), (algo, sizes)
    for m in _a2av_cases(n):
        flat = tuple(v for row_ in m for v in row_)
        M = sum(flat) * row
        for algo in ("pairwise_alltoallv", "ring_alltoallv"):
            plan = plan_collective("alltoallv", M, n, algo=algo, sizes=m)
            assert plan.wire_bytes() == expected_wire_bytes(
                "alltoallv", algo, M, n, sizes=plan.sizes
            ), (algo, m)


def test_tuner_skew_inversion():
    """The skew-aware decision path separates the regimes: bandwidth-bound
    uniform vectors ride the ring family, latency/skew-bound vectors the
    doubling family; incast alltoallv matrices pick store-and-forward."""
    t = Tuner()
    n = 8
    # uniform-large allgatherv -> ring (bandwidth-optimal per max-row)
    big = t.select(64 << 20, n, op="allgatherv", sizes=(64,) * n)
    assert big.algo == "ring_allgatherv", big
    # one-hot skew -> doubling (same hop-bytes, log2 startups)
    hot = t.select(64 << 20, n, op="allgatherv", sizes=(512,) + (0,) * (n - 1))
    assert hot.algo == "doubling_allgatherv", hot
    # tiny uniform -> doubling (latency-bound)
    small = t.select(1 << 10, n, op="allgatherv", sizes=(1,) * n)
    assert small.algo == "doubling_allgatherv", small
    # uniform alltoallv -> pairwise; incast -> store-and-forward ring
    uni = tuple(tuple(8 for _ in range(n)) for _ in range(n))
    assert t.select(1 << 20, n, op="alltoallv", sizes=uni).algo == "pairwise_alltoallv"
    incast = tuple(tuple(64 if d == 0 else 1 for d in range(n)) for _ in range(n))
    assert t.select(1 << 20, n, op="alltoallv", sizes=incast).algo == "ring_alltoallv"
    # non-pow2 ranks never route to doubling
    assert t.select(1 << 20, 6, op="allgatherv", sizes=(1, 0, 3, 2, 0, 1)).algo == "ring_allgatherv"
    # sizes= on a non-ragged op is a hard error, not a silent ignore
    with pytest.raises(ValueError):
        t.select(1 << 20, n, op="allgather", sizes=(1,) * n)


def test_skew_bucketed_empirical_keys():
    """Empirical records separate by skew bucket: a measurement recorded for
    a uniform vector must not answer for a heavily skewed one."""
    t = Tuner()
    n, M = 4, 1 << 20
    uniform = (8, 8, 8, 8)
    skewed = (29, 1, 1, 1)
    t.record(M, n, "ring_allgatherv", sum(uniform), 1e-9, op="allgatherv", sizes=uniform)
    hit = t.select(M, n, op="allgatherv", sizes=uniform)
    assert hit.source == "empirical" and hit.algo == "ring_allgatherv"
    miss = t.select(M, n, op="allgatherv", sizes=skewed)
    assert miss.source == "analytic", miss
    assert round(np.log2(skew_ratio(skewed))) >= 1


def test_plan_cache_keys_on_size_vector():
    plan_cache_clear()
    a = plan_cached("allgatherv", 1 << 16, 4, sizes=(3, 1, 0, 2))
    b = plan_cached("allgatherv", 1 << 16, 4, sizes=(2, 2, 1, 1))
    c = plan_cached("allgatherv", 1 << 16, 4, sizes=(3, 1, 0, 2))
    assert a is c and a is not b
    # matrix and flat forms of the same alltoallv sizes share one plan
    m = ((1, 2), (3, 4))
    d = plan_cached("alltoallv", 1 << 16, 2, sizes=m)
    e = plan_cached("alltoallv", 1 << 16, 2, sizes=(1, 2, 3, 4))
    assert d is e


def test_schedule_sizes_validation():
    with pytest.raises(ValueError):
        comm_schedules.ring_allgatherv(4, (1, 2, 3))      # wrong length
    with pytest.raises(ValueError):
        comm_schedules.ring_allgatherv(4, (1, -2, 3, 4))  # negative
    with pytest.raises(ValueError):
        comm_schedules.doubling_allgatherv(6, (1,) * 6)   # non-pow2
    sched = comm_schedules.ring_allgatherv(4, (3, 1, 0, 2))
    sched.validate_ranks()  # sizes vector is checked against num_chunks


def test_committed_ragged_table_loads():
    path = os.path.join(os.path.dirname(__file__), "..", "experiments", "ragged_table.json")
    table = load_ragged_table(path)
    assert table, "committed ragged table must be non-empty"
    for key, entry in table.items():
        assert entry.get("dryrun") is True, f"{key}: committed entries are simulator stand-ins"


def test_ragged_table_rejects_accounting_drift(tmp_path):
    sizes = [3, 1, 0, 2]
    row = 512
    M = sum(sizes) * row
    wire = int(expected_wire_bytes("allgatherv", "ring_allgatherv", M, 4, sizes=tuple(sizes)))
    sched = comm_schedules.ring_allgatherv(4, tuple(sizes))
    good = {
        "allgatherv/ring_allgatherv/n4/t": {
            "sizes": sizes, "row_bytes": row, "wire_bytes": wire,
            "predicted_us": 1.0, "rounds": len(sched.rounds),
        }
    }
    p = tmp_path / "ragged.json"
    p.write_text(json.dumps(good))
    load_ragged_table(str(p))
    # a size vector that disagrees with the recorded wire bytes is rejected
    bad = json.loads(json.dumps(good))
    bad["allgatherv/ring_allgatherv/n4/t"]["sizes"] = [2, 2, 1, 0]
    p.write_text(json.dumps(bad))
    with pytest.raises(TableSchemaError, match="accounting"):
        load_ragged_table(str(p))
    # distribution drift at constant total is caught where the accounting is
    # distribution-sensitive: alltoallv only wires off-diagonal blocks, so
    # shifting rows onto the diagonal changes wire bytes at the same sum
    amat = ((0, 3), (3, 0))
    aM = 6 * row
    awire = int(expected_wire_bytes("alltoallv", "pairwise_alltoallv", aM, 2,
                                    sizes=amat))
    asched = comm_schedules.pairwise_alltoallv(2, amat)
    agood = {
        "alltoallv/pairwise_alltoallv/n2/t": {
            "sizes": [0, 3, 3, 0], "row_bytes": row, "wire_bytes": awire,
            "predicted_us": 1.0, "rounds": len(asched.rounds),
        }
    }
    p.write_text(json.dumps(agood))
    load_ragged_table(str(p))
    abad = json.loads(json.dumps(agood))
    abad["alltoallv/pairwise_alltoallv/n2/t"]["sizes"] = [3, 0, 0, 3]
    p.write_text(json.dumps(abad))
    with pytest.raises(TableSchemaError, match="accounting"):
        load_ragged_table(str(p))
    # wrong round count is rejected too
    bad2 = json.loads(json.dumps(good))
    bad2["allgatherv/ring_allgatherv/n4/t"]["rounds"] += 1
    p.write_text(json.dumps(bad2))
    with pytest.raises(TableSchemaError, match="rounds"):
        load_ragged_table(str(p))
    # all-zero size vectors are rotten
    bad3 = json.loads(json.dumps(good))
    bad3["allgatherv/ring_allgatherv/n4/t"]["sizes"] = [0, 0, 0, 0]
    p.write_text(json.dumps(bad3))
    with pytest.raises(TableSchemaError):
        load_ragged_table(str(p))
