"""Property tests for broadcast schedule generation (hypothesis).

System invariants (independent of JAX):
  * completeness — every rank ends up owning every chunk, for every
    algorithm, rank count, root, and chunking;
  * causality — the simulator rejects any schedule where a rank sends a
    chunk before owning it (checked implicitly: simulate_bcast raises);
  * per-round destination uniqueness (one ppermute per round is legal);
  * round counts match the analytic cost models' step counts.
"""
from __future__ import annotations

import math

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback — see tests/_compat.py
    from _compat import given, settings, strategies as st

from repro.core import schedules as S
from repro.core.simulator import check_complete, simulate_bcast, simulate_reduce, timed_rounds

ALGOS = ["direct", "chain", "binomial", "scatter_allgather", "pipelined_chain", "knomial", "bidir_chain"]


def _build(algo, n, root, chunks, k=3):
    if algo in ("pipelined_chain", "bidir_chain"):
        return S.build(algo, n, root, num_chunks=chunks)
    if algo == "knomial":
        return S.build(algo, n, root, k=k)
    return S.build(algo, n, root)


@settings(max_examples=120, deadline=None)
@given(
    algo=st.sampled_from(ALGOS),
    n=st.integers(1, 33),
    root_seed=st.integers(0, 1000),
    chunks=st.integers(1, 9),
    k=st.integers(2, 5),
)
def test_completeness_and_causality(algo, n, root_seed, chunks, k):
    if algo == "scatter_allgather" and (n & (n - 1)):
        n = 1 << max(n.bit_length() - 1, 0)  # round down to a power of two
    n = max(n, 1)
    root = root_seed % n
    sched = _build(algo, n, root, chunks, k)
    sched.validate_ranks()
    check_complete(sched)  # raises on incompleteness or causality violation


@settings(max_examples=60, deadline=None)
@given(n=st.integers(2, 32), root_seed=st.integers(0, 99), chunks=st.integers(2, 16))
def test_pipelined_chain_round_count(n, root_seed, chunks):
    """Eq. 5's round structure: M/C + n - 2 rounds."""
    sched = S.pipelined_chain(n, root_seed % n, num_chunks=chunks)
    assert sched.num_rounds == chunks + n - 2
    # wire accounting: every edge carries every chunk exactly once
    assert sched.wire_chunks() == (n - 1) * chunks


@settings(max_examples=60, deadline=None)
@given(n=st.integers(2, 64), root_seed=st.integers(0, 99))
def test_binomial_round_count(n, root_seed):
    sched = S.binomial(n, root_seed % n)
    assert sched.num_rounds == math.ceil(math.log2(n))
    # tree: exactly n-1 receives
    assert sched.wire_chunks() == n - 1


@settings(max_examples=40, deadline=None)
@given(n=st.sampled_from([2, 4, 8, 16, 32]), root_seed=st.integers(0, 99))
def test_scatter_allgather_bandwidth_optimal(n, root_seed):
    """Eq. 4: 2*(n-1)/n * M bytes per rank on the wire (x n ranks total)."""
    sched = S.scatter_allgather(n, root_seed % n)
    assert sched.num_chunks == n
    # recursive-halving scatter: n/2 chunks per level x log2(n) levels;
    # ring allgather: n ranks x (n-1) rounds x 1 chunk
    expected = (n // 2) * int(math.log2(n)) + (n - 1) * n
    assert sched.wire_chunks() == expected


def test_reduce_to_root():
    rng = np.random.RandomState(0)
    for n in (2, 3, 8, 12):
        for root in (0, n - 1):
            sched = S.binomial_reduce(n, root)
            data = [rng.randn(1, 5) for _ in range(n)]
            out = simulate_reduce(sched, data)
            np.testing.assert_allclose(out[root], np.sum(data, axis=0), rtol=1e-9)


def test_simulator_values_roundtrip():
    """Data-level (not just ownership) correctness for every algorithm."""
    rng = np.random.RandomState(1)
    for algo in ALGOS:
        for n in (2, 4, 8):
            chunks = {"pipelined_chain": 6, "scatter_allgather": n}.get(algo, 1)
            sched = _build(algo, n, 1 % n, chunks)
            data = [rng.randn(sched.num_chunks, 3) for _ in range(n)]
            out = simulate_bcast(sched, data)
            for r in range(n):
                np.testing.assert_array_equal(out[r], data[1 % n])


def test_timed_rounds_matches_closed_form():
    """The simulator clock agrees with Eq. 2 and Eq. 5 exactly."""
    from repro.core.cost_model import TPU_V5E, t_chain, t_pipelined_chain

    hw, B = TPU_V5E, TPU_V5E.link_bw
    M, n, K = 1 << 20, 8, 16
    chunk = M // K
    sched = S.pipelined_chain(n, 0, num_chunks=K)
    t_sim = timed_rounds(sched, chunk, hw.ts, B)
    t_model = t_pipelined_chain(M, n, hw, B, C=chunk)
    assert abs(t_sim - t_model) / t_model < 1e-9
    sched = S.chain(n, 0)
    assert abs(timed_rounds(sched, M, hw.ts, B) - t_chain(M, n, hw, B)) / t_chain(M, n, hw, B) < 1e-9


def test_duplicate_destination_rejected():
    with pytest.raises(ValueError):
        S.Round((S.Transfer(0, 1), S.Transfer(2, 1)))


@settings(max_examples=40, deadline=None)
@given(n=st.integers(3, 48), root_seed=st.integers(0, 99), chunks=st.integers(1, 16))
def test_bidir_chain_halves_rounds(n, root_seed, chunks):
    """Beyond-paper: both directions carry all chunks; rounds = K + ceil((n-1)/2) - 1."""
    sched = S.bidirectional_chain(n, root_seed % n, num_chunks=chunks)
    hops = (n - 1 + 1) // 2
    assert sched.num_rounds == chunks + hops - 1
    assert sched.num_rounds <= S.pipelined_chain(n, 0, num_chunks=chunks).num_rounds
