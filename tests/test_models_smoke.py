"""Per-architecture smoke tests: REDUCED variant of each assigned arch runs
one forward/train step on CPU; output shapes and finiteness asserted."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import ShapeSpec
from repro.models import Model

SHAPE = ShapeSpec("tiny_train", 64, 2, "train")


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_loss(name):
    cfg = get_config(name + "-smoke")
    assert cfg.d_model <= 512 and cfg.num_layers <= 2 * cfg.pattern_period
    assert cfg.num_experts <= 4
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.sample_batch(SHAPE)
    logits, aux = jax.jit(lambda p, b: m.forward(p, b))(params, batch)
    t_text = SHAPE.seq_len - (cfg.prefix_len if cfg.frontend == "vision" else 0)
    assert logits.shape == (SHAPE.global_batch, t_text, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), name
    loss, metrics = jax.jit(lambda p, b: m.loss(p, b))(params, batch)
    assert np.isfinite(float(loss)), name
    assert float(metrics["nll"]) < 2.5 * np.log(cfg.padded_vocab), name


@pytest.mark.parametrize("name", ["minitron-8b", "qwen3-moe-30b-a3b", "xlstm-350m", "hymba-1.5b"])
def test_one_grad_step_reduces_loss(name):
    from repro.optim.optimizers import get_optimizer

    cfg = get_config(name + "-smoke")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = get_optimizer("adamw")
    state = opt.init(params)
    batch = m.sample_batch(SHAPE)

    @jax.jit
    def step(params, state, batch):
        (loss, _), grads = jax.value_and_grad(lambda p: m.loss(p, batch), has_aux=True)(params)
        params, state = opt.update(grads, state, params, jnp.asarray(1e-3))
        return params, state, loss

    l0 = None
    for _ in range(4):
        params, state, loss = step(params, state, batch)
        l0 = float(loss) if l0 is None else l0
    assert float(loss) < l0, (name, l0, float(loss))
    for leaf in jax.tree.leaves(params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize(
    "name", ["gemma3-27b", "mixtral-8x7b", "whisper-large-v3", "paligemma-3b", "xlstm-350m"]
)
def test_prefill_decode_consistency(name):
    """Incremental decode reproduces the full forward (bf16 tolerance)."""
    T, B = 24, 2
    cfg = get_config(name + "-smoke")
    if cfg.num_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size - 1, (B, T)))}
    if cfg.frontend == "vision":
        batch["embeds"] = jnp.asarray(
            rng.randn(B, cfg.prefix_len, cfg.d_model).astype(np.float32), jnp.bfloat16
        )
    if cfg.arch_type == "encdec":
        batch["embeds"] = jnp.asarray(
            rng.randn(B, cfg.frontend_len, cfg.d_model).astype(np.float32), jnp.bfloat16
        )
    full, _ = m.forward(params, batch)
    Tp = T // 2
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :Tp]
    logits_p, caches = jax.jit(lambda p, b: m.prefill(p, b, max_len=T))(params, pre)
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32), np.asarray(full[:, :Tp], np.float32),
        atol=0.12, rtol=0.12,
    )
    step = jax.jit(m.decode_step)
    offset = cfg.prefix_len if cfg.frontend == "vision" else 0
    for t in range(Tp, T):
        logits_d, caches = step(
            params, batch["tokens"][:, t : t + 1], caches, jnp.asarray(t + offset, jnp.int32)
        )
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32), np.asarray(full[:, t], np.float32),
            atol=0.3, rtol=0.3,
        )


def test_param_counts_reasonable():
    """Full configs' param counts are in the advertised ballpark."""
    expect = {
        "minitron-8b": (6e9, 11e9),
        "mixtral-8x7b": (40e9, 52e9),
        "qwen3-moe-30b-a3b": (25e9, 34e9),
        "gemma3-27b": (22e9, 32e9),
        "qwen1.5-32b": (28e9, 38e9),
        # assigned config (48L x 64e x ff1408) computes to ~28B total
        # (the hf 16B card has 27 layers; the ASSIGNMENT pins 48 - DESIGN.md S6)
        "moonshot-v1-16b-a3b": (24e9, 33e9),
        "xlstm-350m": (0.25e9, 0.5e9),
        "hymba-1.5b": (1.0e9, 2.2e9),
        "whisper-large-v3": (1.2e9, 2.2e9),
        "paligemma-3b": (2.0e9, 3.5e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


def test_long_500k_eligibility():
    sub_q = {n for n in ARCHS if get_config(n).sub_quadratic}
    assert sub_q == {"xlstm-350m", "hymba-1.5b", "gemma3-27b", "mixtral-8x7b"}
