"""Multi-stream link scheduler (comm.streams + cost_model.multi_stream_finish_times).

Covers the refactor's contracts:

* the multi-stream arbiter reduces BIT-EXACTLY to the PR 4 single-stream
  window recurrence (``window_finish_times``) for one stream;
* scheduler properties — fairness (max skip count within the graph's
  bound), no-idle (every dispatch starts at ``max(link_free, min_ready)``),
  per-link serial occupancy, and arbitration never exceeding naive
  serialization (strictly beating it when compute gaps leave link idle);
* backward compat — a 1-entry StreamGraph replays bit-identically to
  ``execute_overlap`` and round-identically in ``simulate_overlap``;
* plan-cache observability — hit/miss/evict counters under LRU pressure
  and fingerprint invalidation across health/exec_path/size/stream keys;
* tuner ``stream:*`` entries round-tripping through save/load;
* faults composing per the PR 7 contract;
* the trainer's ``prefetch_stream`` and the serve distribution graph.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax

from repro.comm import plan as plan_mod
from repro.comm.faults import DeadRankError, FaultSpec, MeshHealth
from repro.comm.overlap import plan_overlap, simulate_overlap
from repro.comm.plan import cache_stats, plan_cache_clear, plan_cached
from repro.comm.streams import (
    StreamGraph,
    StreamGraphError,
    StreamSpec,
    dispatch_schedule,
    plan_streams,
    simulate_streams,
)
from repro.core import cost_model
from repro.core.tuner import Tuner, TunerTableError

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback — see tests/_compat.py
    from _compat import given, settings, strategies as st


def _tree(leaves):
    return {
        f"l{i}": jax.ShapeDtypeStruct((e,), np.float32)
        for i, e in enumerate(leaves)
    }


MIX = [65536, 65536, 4096, 4096, 512, 512, 64, 64]


def _rand_demand(rng, *, link="ici", priority=0, after=()):
    K = rng.randint(1, 6)
    return {
        "avail": sorted(rng.randint(0, 20) for _ in range(K)),
        "stage": [rng.randint(0, 3) for _ in range(K)],
        "comm": [[1] * rng.randint(1, 5) for _ in range(K)],
        "depth": rng.randint(1, 4),
        "priority": priority,
        "link": link,
        "after": after,
    }


# ---------------------------------------------------------------------------
# the scheduler core (cost_model.multi_stream_finish_times)
# ---------------------------------------------------------------------------


def test_one_stream_reduces_to_window_recurrence():
    """The arbiter with a single stream IS the PR 4 greedy window
    recurrence — bit-exact, including quantum decomposition."""
    rng = np.random.RandomState(7)
    for _ in range(200):
        K = rng.randint(1, 8)
        avail = sorted(rng.randint(0, 30) for _ in range(K))
        stage = [rng.randint(0, 4) for _ in range(K)]
        comm = [rng.randint(1, 6) for _ in range(K)]
        depth = rng.randint(1, 5)
        legacy = cost_model.window_finish_times(avail, stage, comm, depth)
        multi = cost_model.multi_stream_finish_times(
            [{"avail": avail, "stage": stage, "comm": comm, "depth": depth}]
        )[0]
        assert multi == legacy
        # quanta decomposition: [r] vs [1]*r commits the same finish times
        quanta = cost_model.multi_stream_finish_times(
            [{"avail": avail, "stage": stage, "comm": [[1] * r for r in comm],
              "depth": depth}]
        )[0]
        assert quanta == legacy


@settings(max_examples=20)
@given(
    seed=st.integers(min_value=0, max_value=10_000),
    num_streams=st.integers(min_value=2, max_value=4),
    bound=st.integers(min_value=1, max_value=5),
)
def test_fairness_and_no_idle_properties(seed, num_streams, bound):
    """Random contending graphs: no stream is passed over beyond
    bound + S - 2, and a ready transfer never waits behind an idle link."""
    rng = np.random.RandomState(seed)
    demands = [
        _rand_demand(rng, priority=rng.randint(0, 3)) for _ in range(num_streams)
    ]
    trace = []
    cost_model.multi_stream_finish_times(
        demands, starvation_bound=bound, trace=trace
    )
    fairness = bound + max(0, num_streams - 2)
    for rec in trace:
        assert rec["skips"] <= fairness, rec
        assert rec["start"] == max(rec["link_free"], rec["min_ready"]), rec


def test_per_link_serial_occupancy():
    """One serial resource per link: committed quanta on the same link
    never overlap; different links run concurrently."""
    rng = np.random.RandomState(3)
    demands = [
        _rand_demand(rng, link="ici"),
        _rand_demand(rng, link="ici"),
        _rand_demand(rng, link="host"),
    ]
    trace = []
    cost_model.multi_stream_finish_times(demands, trace=trace)
    by_link = {}
    for rec in trace:
        by_link.setdefault(rec["link"], []).append((rec["start"], rec["end"]))
    assert set(by_link) == {"ici", "host"}
    for spans in by_link.values():
        spans.sort()
        for (s0, e0), (s1, _e1) in zip(spans, spans[1:]):
            assert s1 >= e0, spans


def test_multi_never_exceeds_naive_serialization():
    """Arbitration reorders transfers; it never adds span."""
    rng = np.random.RandomState(11)
    for _ in range(50):
        S = rng.randint(2, 5)
        demands = [
            _rand_demand(rng, priority=rng.randint(0, 3)) for _ in range(S)
        ]
        ends = cost_model.multi_stream_finish_times(demands)
        chained = [dict(d) for d in demands]
        for i in range(1, S):
            chained[i]["after"] = (i - 1,)
        naive = cost_model.multi_stream_finish_times(chained)
        assert max(e[-1] for e in ends) <= max(e[-1] for e in naive)


def test_strict_win_with_compute_gaps():
    """A compute-gated stream leaves link gaps a second stream fills: the
    arbitrated span is STRICTLY below naive serialization."""
    gated = {"avail": [10, 20, 30], "stage": [0, 0, 0],
             "comm": [2, 2, 2], "depth": 2, "priority": 1}
    filler = {"avail": [0, 0, 0], "stage": [0, 0, 0],
              "comm": [3, 3, 3], "depth": 2, "priority": 0}
    ends = cost_model.multi_stream_finish_times([gated, filler])
    chained = [dict(gated), dict(filler, after=(0,))]
    naive = cost_model.multi_stream_finish_times(chained)
    assert max(e[-1] for e in ends) < max(e[-1] for e in naive)


def test_after_cycle_deadlock_raises():
    d = {"avail": [0], "stage": [0], "comm": [1], "depth": 1}
    with pytest.raises(ValueError, match="deadlock"):
        cost_model.multi_stream_finish_times(
            [dict(d, after=(1,)), dict(d, after=(0,))]
        )


def test_window_finish_times_is_the_one_stream_case():
    """The legacy entry point now derives from the arbiter — same numbers
    on the documented example."""
    assert cost_model.window_finish_times([0, 0, 0], [1, 1, 1], [3, 3, 3], 2) == \
        cost_model.multi_stream_finish_times(
            [{"avail": [0, 0, 0], "stage": [1, 1, 1], "comm": [3, 3, 3],
              "depth": 2}])[0]


# ---------------------------------------------------------------------------
# StreamGraph validation + planning
# ---------------------------------------------------------------------------


def _two_stream_graph(n=4, tuner=None):
    return plan_streams(
        [
            StreamSpec(name="grad_sync", tree=_tree(MIX), axes=(("data", n),),
                       op="allreduce", priority=1, compute_s=1e-3,
                       bucket_bytes=64 << 10, reverse=True),
            StreamSpec(name="weight_prefetch", tree=_tree(MIX),
                       axes=(("data", n),), op="bcast", priority=0,
                       bucket_bytes=64 << 10),
        ],
        tuner=tuner or Tuner(),
    )


def test_graph_validation_errors():
    g = _two_stream_graph()
    e0, e1 = g.entries
    with pytest.raises(StreamGraphError, match="duplicate"):
        StreamGraph((e0, dataclasses.replace(e1, name=e0.name)))
    with pytest.raises(StreamGraphError, match="unknown"):
        StreamGraph((e0, dataclasses.replace(e1, after=("nope",))))
    with pytest.raises(StreamGraphError, match="after itself"):
        StreamGraph((dataclasses.replace(e0, after=(e0.name,)), e1))
    with pytest.raises(StreamGraphError, match="cycle"):
        StreamGraph((
            dataclasses.replace(e0, after=(e1.name,)),
            dataclasses.replace(e1, after=(e0.name,)),
        ))
    with pytest.raises(StreamGraphError, match="starvation_bound"):
        StreamGraph((e0,), starvation_bound=0)


def test_fingerprint_stable_and_spec_sensitive():
    """Same specs -> same key; any spec-level change (priority, DAG edge,
    depth request) -> different key, BEFORE any plan resolves."""
    g1 = _two_stream_graph()
    g2 = _two_stream_graph()
    assert g1.key is not None and g1.key == g2.key
    assert g1.fingerprint() == g1.key

    def variant(**kw):
        specs = [
            StreamSpec(name="grad_sync", tree=_tree(MIX), axes=(("data", 4),),
                       op="allreduce", priority=1, compute_s=1e-3,
                       bucket_bytes=64 << 10, reverse=True),
            StreamSpec(name="weight_prefetch", tree=_tree(MIX),
                       axes=(("data", 4),), op="bcast", priority=0,
                       bucket_bytes=64 << 10, **kw),
        ]
        return plan_streams(specs, tuner=Tuner()).key

    assert variant(after=("grad_sync",)) != g1.key
    base = variant()
    assert base == g1.key
    assert variant(overlap_depth=3) != base
    assert variant(link="host") != base


def test_plan_streams_depth_and_priority_tiers():
    """manual > tuner stream entry > empirical > analytic, and priority
    from the tuner's stream entry when the spec leaves it None."""
    t = Tuner()
    spec = StreamSpec(name="s", tree=_tree(MIX), axes=(("data", 4),),
                      bucket_bytes=64 << 10)
    g = plan_streams([spec], tuner=t)
    assert g.entries[0].depth_source == "analytic"
    assert g.entries[0].priority == 0

    t.record_stream("s", overlap_depth=3, priority=7)
    g = plan_streams([spec], tuner=t)
    assert g.entries[0].overlap_depth == 3
    assert g.entries[0].depth_source == "stream"
    assert g.entries[0].priority == 7

    g = plan_streams([dataclasses.replace(spec, overlap_depth=5)], tuner=t)
    assert g.entries[0].overlap_depth == 5
    assert g.entries[0].depth_source == "manual"

    t2 = Tuner()
    for M in {max(b, 1) for b in plan_overlap(
            _tree(MIX), [("data", 4)], tuner=Tuner(),
            bucket_bytes=64 << 10).spec.bucket_bytes()}:
        t2.record_overlap(M, 4, 2, op="allreduce")
    g = plan_streams([spec], tuner=t2)
    assert g.entries[0].overlap_depth == 2
    assert g.entries[0].depth_source == "empirical"


# ---------------------------------------------------------------------------
# simulator parity + properties on planned graphs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("leaves", [MIX, [4096] * 8, [262144, 262144]])
@pytest.mark.parametrize("n", [2, 4, 8])
def test_one_entry_simulation_matches_simulate_overlap(leaves, n):
    """Round-for-round parity: simulate_overlap on an OverlapPlan equals
    simulate_streams on its 1-entry graph (it IS that call), and the span
    equals the stream's finish round."""
    oplan = plan_overlap(_tree(leaves), [("data", n)], tuner=Tuner(),
                         bucket_bytes=64 << 10, compute_s=1e-3)
    legacy = simulate_overlap(oplan)
    sim = simulate_streams(oplan.as_graph())
    s = sim["streams"]["overlap"]
    assert sim["num_streams"] == 1
    assert legacy["overlap_span_rounds"] == s["finish_round"]
    assert legacy["comm_rounds"] == s["comm_rounds"]
    assert legacy["idle_rounds_overlap"] == s["idle_rounds"]
    assert sim["multi_span_rounds"] == sim["naive_span_rounds"]
    assert sim["idle_while_ready_rounds"] == 0
    assert sim["wire_bytes"] == legacy["wire_bytes"]


def test_two_stream_graph_properties_and_strict_win():
    g = _two_stream_graph(n=4)
    sim = simulate_streams(g)
    assert sim["multi_span_rounds"] < sim["naive_span_rounds"]
    assert sim["max_skips"] <= g.fairness_bound()
    assert sim["idle_while_ready_rounds"] == 0
    assert sim["wire_bytes"] == g.wire_bytes()
    # per-stream accounting is complete and self-consistent
    for name in g.names:
        s = sim["streams"][name]
        assert s["finish_round"] <= sim["multi_span_rounds"]
        assert s["naive_finish_round"] <= sim["naive_span_rounds"]


def test_dispatch_schedule_interleaves_in_stream_order():
    g = _two_stream_graph(n=4)
    sched = dispatch_schedule(g)
    per = {name: [] for name in g.names}
    for name, k in sched:
        per[name].append(k)
    for e in g.entries:
        assert per[e.name] == list(e.order)
    # contention actually interleaves the two streams
    first = {name: min(i for i, (nm, _) in enumerate(sched) if nm == name)
             for name in g.names}
    last = {name: max(i for i, (nm, _) in enumerate(sched) if nm == name)
            for name in g.names}
    assert first["weight_prefetch"] < last["grad_sync"]


def test_faults_compose_with_streams():
    g = _two_stream_graph(n=4)
    spec = FaultSpec(link_slowdown=(((0, 1), 8.0),))
    sim = simulate_streams(g, faults=spec)
    assert sim["fault_slowdown"] >= 1.0
    assert sim["comm_s_faulty"] >= sim["comm_s_healthy"]
    assert sim["fault_fingerprint"] == spec.fingerprint()
    # round structure untouched by the degraded clock
    clean = simulate_streams(g)
    assert sim["multi_span_rounds"] >= 1
    assert sim["comm_rounds"] == clean["comm_rounds"]
    with pytest.raises(DeadRankError):
        simulate_streams(g, faults=FaultSpec(dead_ranks=(1,)))


# ---------------------------------------------------------------------------
# plan-cache observability (satellite: hit/miss/evict counters)
# ---------------------------------------------------------------------------


def test_cache_stats_counters_and_fingerprint_invalidation():
    plan_cache_clear()
    base = cache_stats()
    assert base["hits"] == base["misses"] == base["evictions"] == 0

    p1 = plan_cached("bcast", 1 << 16, 4)
    assert cache_stats()["misses"] == 1
    p2 = plan_cached("bcast", 1 << 16, 4)
    assert p2 is p1
    assert cache_stats()["hits"] == 1

    # every fingerprint dimension is a distinct cache point: sizes,
    # exec_path, mesh health, and the stream-graph key
    plan_cached("bcast", 1 << 17, 4)
    plan_cached("bcast", 1 << 16, 4, exec_path="compiled")
    plan_cached("bcast", 1 << 16, 4,
                health=MeshHealth(n=4, slow_links=(((0, 1), 4.0),)))
    plan_cached("bcast", 1 << 16, 4, stream="aaaa000011112222")
    plan_cached("bcast", 1 << 16, 4, stream="bbbb000011112222")
    st_now = cache_stats()
    assert st_now["misses"] == 6
    assert st_now["hits"] == 1
    # ... and each repeated lookup hits its own entry
    plan_cached("bcast", 1 << 16, 4, stream="aaaa000011112222")
    assert cache_stats()["hits"] == 2


def test_cache_stats_evictions_under_lru_pressure():
    plan_cache_clear()
    maxsize = cache_stats()["maxsize"]
    # distinct (M, stream) points overflow the LRU: evictions are counted
    # and the size cap holds
    for i in range(maxsize + 40):
        plan_cached("bcast", 1 << 12, 2, stream=f"g{i:04d}")
    st_now = cache_stats()
    assert st_now["evictions"] >= 40
    assert st_now["size"] <= maxsize
    # the evicted earliest key re-resolves as a miss, not a hit
    before = cache_stats()["misses"]
    plan_cached("bcast", 1 << 12, 2, stream="g0000")
    assert cache_stats()["misses"] == before + 1
    plan_cache_clear()
    cleared = cache_stats()
    assert cleared["size"] == cleared["hits"] == cleared["misses"] == \
        cleared["evictions"] == 0


# ---------------------------------------------------------------------------
# tuner stream entries (record/save/load round trip)
# ---------------------------------------------------------------------------


def test_record_stream_roundtrip_and_gating(tmp_path):
    t = Tuner()
    v0 = t._version
    t.record_stream("grad_sync", overlap_depth=3, priority=2)
    t.record_stream("weight_prefetch", priority=0)
    assert t._version > v0
    v1 = t._version
    t.record_stream("grad_sync", overlap_depth=3, priority=2)  # idempotent
    assert t._version == v1
    assert t.stream_decision("grad_sync") == {"overlap_depth": 3, "priority": 2}
    assert t.stream_decision("nope") == {}

    path = tmp_path / "streams.json"
    t.save(str(path))
    back = Tuner.load(str(path))
    assert back.stream_decision("grad_sync") == {"overlap_depth": 3,
                                                 "priority": 2}
    assert back.stream_decision("weight_prefetch") == {"priority": 0}

    # dryrun-branded tables keep stream entries (they are planner
    # decisions, not measurements) — unlike empirical crossover rows
    t.save(str(path), dryrun=True)
    kept = Tuner.load(str(path), allow_dryrun=True)
    assert kept.stream_decision("grad_sync") == {"overlap_depth": 3,
                                                 "priority": 2}

    # malformed stream entries are rejected at load
    import json
    bad = {"table": {"stream:x": {"overlap_depth": 2, "num_chunks": 4}}}
    (tmp_path / "bad.json").write_text(json.dumps(bad))
    with pytest.raises(TunerTableError, match="overlap_depth/priority"):
        Tuner.load(str(tmp_path / "bad.json"))
    bad2 = {"table": {"stream:x": {"priority": "high"}}}
    (tmp_path / "bad2.json").write_text(json.dumps(bad2))
    with pytest.raises(TunerTableError, match="priority must be an int"):
        Tuner.load(str(tmp_path / "bad2.json"))


# ---------------------------------------------------------------------------
# serve distribution graph (host-side shape; execution is covered on-device)
# ---------------------------------------------------------------------------


def test_distribution_graph_shape_single_device():
    from repro.launch.mesh import make_local_mesh
    from repro.serve.engine import distribution_stream_graph

    mesh = make_local_mesh(1)
    params = {"w": jax.ShapeDtypeStruct((256, 8), np.float32)}
    graph, spec, plans = distribution_stream_graph(
        params, mesh, double_buffer=True, drain=True, bucket_bytes=1 << 12
    )
    assert graph.names == ("ckpt_drain", "distribute")
    drain, dist = graph.entries
    assert drain.link == "host" and drain.axes == () and drain.plans == {}
    assert drain.priority > dist.priority
    assert dist.after == ("ckpt_drain",)
    assert dist.overlap_depth == 2
    assert graph.key is not None
    sim = simulate_streams(graph)
    assert sim["multi_span_rounds"] <= sim["naive_span_rounds"]
    assert sim["idle_while_ready_rounds"] == 0
    # no drain -> single entry, depth 1 without double buffering
    g2, _, _ = distribution_stream_graph(params, mesh, bucket_bytes=1 << 12)
    assert g2.names == ("distribute",)
    assert g2.entries[0].overlap_depth == 1
    assert g2.key != graph.key


# ---------------------------------------------------------------------------
# on-device: backward compat + the trainer's prefetch stream
# ---------------------------------------------------------------------------


def test_one_entry_graph_bit_identical_to_execute_overlap(dist):
    """Across n in {2, 4, 8} and depths: the 1-entry StreamGraph replay
    (execute_streams AND execute_stream_entry) is bit-identical to the
    PR 4 execute_overlap path, and matches the psum baseline."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comm import execute_overlap, plan_overlap
from repro.comm.streams import execute_stream_entry, execute_streams
from repro.core.tuner import Tuner

leaves = [65536, 4096, 4096, 512, 64]
for n in (2, 4, 8):
    mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    rng = np.random.RandomState(0)
    tree = {f"l{i}": jnp.asarray(rng.randn(n, e).astype(np.float32))
            for i, e in enumerate(leaves)}
    specs = jax.tree.map(lambda _: P("data"), tree)
    abstract = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), tree)
    for depth in (1, 2, 4):
        oplan = plan_overlap(abstract, [("data", n)], tuner=Tuner(),
                             bucket_bytes=64 << 10, overlap_depth=depth)
        graph = oplan.as_graph()
        def run(mode):
            def g(t):
                sub = jax.tree.map(lambda x: x[0], t)
                if mode == "overlap":
                    out = execute_overlap(oplan, sub)
                elif mode == "entry":
                    out = execute_stream_entry(graph.entries[0], sub)
                else:
                    out = execute_streams(graph, {"overlap": sub})["overlap"]
                return jax.tree.map(lambda x: x[None], out)
            f = jax.jit(lambda t: jax.shard_map(g, mesh=mesh, in_specs=(specs,),
                                                out_specs=specs, check_vma=False)(t))
            return jax.tree.map(np.asarray, f(tree))
        a = run("overlap"); b = run("entry"); c = run("streams")
        jax.tree.map(np.testing.assert_array_equal, a, b)
        jax.tree.map(np.testing.assert_array_equal, a, c)
        want = jax.tree.map(lambda x: np.asarray(x).sum(0), tree)
        got = jax.tree.map(lambda x: x[0], a)
        jax.tree.map(lambda g, w: np.testing.assert_allclose(g, w, rtol=2e-5, atol=2e-5),
                     got, want)
print("PASS")
""",
        devices=8,
    )


def test_trainer_prefetch_stream_bit_identical(dist):
    """sync_mode='overlap_allreduce' with prefetch_stream=True produces
    bit-identical params/opt state to the same mode without it (the
    prefetch bcast is value-identical), and the tuner records the
    stream entries."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from repro.configs.base import ModelConfig, RunConfig
from repro.models.model import Model
from repro.optim.optimizers import get_optimizer
from repro.core.tuner import Tuner
from repro.train.train_step import make_overlap_allreduce_train_step

cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64, num_heads=2,
                  num_kv_heads=2, d_ff=128, vocab_size=128, dtype="float32")
model = Model(cfg)
mesh = jax.make_mesh((4,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
opt = get_optimizer("adamw")
params = model.init(jax.random.PRNGKey(0))
opt_state = opt.init(params)
lr_fn = lambda s: 1e-3
tuner = Tuner()
kw = dict(sync_mode="overlap_allreduce", bcast_bucket_bytes=1 << 14)
step_p = make_overlap_allreduce_train_step(
    model, RunConfig(prefetch_stream=True, **kw), opt, lr_fn, mesh, tuner=tuner)
step_0 = make_overlap_allreduce_train_step(
    model, RunConfig(prefetch_stream=False, **kw), opt, lr_fn, mesh)
assert tuner.stream_decision("grad_sync")["priority"] == 1
assert tuner.stream_decision("weight_prefetch")["priority"] == 0
rng = np.random.RandomState(0)
tok = jnp.asarray(rng.randint(0, 128, size=(8, 16)).astype(np.int32))
batch = {"tokens": tok, "labels": tok}
with mesh:
    p1, o1, out1 = jax.jit(step_p)(params, opt_state, batch)
    p0, o0, out0 = jax.jit(step_0)(params, opt_state, batch)
jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
             p1, p0)
jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
             o1, o0)
assert float(out1["loss"]) == float(out0["loss"])
print("PASS")
""",
        devices=4,
    )


# ---------------------------------------------------------------------------
# the committed artifact stays valid
# ---------------------------------------------------------------------------


def test_committed_streams_table_loads():
    from repro.comm.tables import load_streams_table

    table = load_streams_table("experiments/streams_table.json")
    assert any(k.startswith("sync_prefetch/") for k in table)
    assert any(k.startswith("distribute_drain/") for k in table)
    assert any(len(e["streams"]) == 1 for e in table.values())
