"""Crash-safety and corrupt-input robustness: atomic checkpoints, hardened
table/tuner loaders, bench-worker timeouts, the committed fault-sweep
artifact, and the serve drain-to-checkpoint path.
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import WorkerTimeoutError, run_worker  # noqa: E402

from repro.comm.tables import TableSchemaError, load_bench, load_fault_table
from repro.core.tuner import Tuner, TunerTableError
from repro.train import checkpoint as ckpt

REPO = os.path.join(os.path.dirname(__file__), "..")


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((4, 3)).astype(np.float32),
            "b": rng.standard_normal((3,)).astype(np.float32)}


def _like(tree):
    import jax
    return jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


# --------------------------- atomic checkpoints ------------------------------


def test_save_checkpoint_is_atomic_and_clean(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 3, _tree())
    files = os.listdir(d)
    assert "ckpt_00000003.npz" in files and "ckpt_00000003.json" in files
    assert not any(f.endswith(".tmp") for f in files)
    assert ckpt.latest_step(d) == 3


def test_crash_between_npz_and_marker_resumes_previous(tmp_path, monkeypatch):
    """A crash after the npz landed but before the json commit marker must
    resume from the PREVIOUS complete checkpoint, not the torn one."""
    d = str(tmp_path)
    t1 = _tree(1)
    ckpt.save_checkpoint(d, 1, t1)

    def crash(*a, **kw):
        raise RuntimeError("simulated crash before the commit marker")

    monkeypatch.setattr(ckpt.json, "dumps", crash)
    with pytest.raises(RuntimeError, match="simulated crash"):
        ckpt.save_checkpoint(d, 2, _tree(2))
    monkeypatch.undo()
    assert os.path.exists(os.path.join(d, "ckpt_00000002.npz"))  # torn save
    assert ckpt.latest_step(d) == 1
    restored = ckpt.restore_checkpoint(d, 1, _like(t1))
    np.testing.assert_array_equal(np.asarray(restored["w"]), t1["w"])


def test_crash_mid_npz_write_leaves_only_tmp(tmp_path, monkeypatch):
    """A crash DURING the npz write leaves a .tmp — the final path never
    holds a partial file, and latest_step still points at the last commit."""
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 1, _tree(1))

    def torn_write(f, **arrays):
        f.write(b"PK\x03\x04 partial npz bytes")
        raise RuntimeError("disk vanished mid-write")

    monkeypatch.setattr(ckpt.np, "savez", torn_write)
    with pytest.raises(RuntimeError, match="disk vanished"):
        ckpt.save_checkpoint(d, 2, _tree(2))
    monkeypatch.undo()
    assert not os.path.exists(os.path.join(d, "ckpt_00000002.npz"))
    assert os.path.exists(os.path.join(d, "ckpt_00000002.npz.tmp"))
    assert ckpt.latest_step(d) == 1
    # and a later healthy save of the same step wins cleanly
    ckpt.save_checkpoint(d, 2, _tree(2))
    assert ckpt.latest_step(d) == 2


def test_latest_step_ignores_stray_files(tmp_path):
    d = str(tmp_path)
    ckpt.save_checkpoint(d, 5, _tree())
    # an orphan npz (no marker) and leftover tmps must not count
    open(os.path.join(d, "ckpt_00000009.npz"), "wb").write(b"torn")
    open(os.path.join(d, "ckpt_00000010.npz.tmp"), "wb").write(b"torn")
    assert ckpt.latest_step(d) == 5


# ------------------------ hardened loaders -----------------------------------


def test_tuner_load_corrupt_json_is_typed(tmp_path):
    p = tmp_path / "table.json"
    p.write_text('{"hw": {"name": "TPU_V5E"}, "table": {')   # truncated
    with pytest.raises(TunerTableError) as ei:
        Tuner.load(str(p))
    msg = str(ei.value)
    assert str(p) in msg and "truncated" in msg.lower() or "corrupt" in msg.lower()
    assert str(p) in msg
    assert isinstance(ei.value, ValueError)  # existing callers keep working


def test_tuner_load_missing_file_and_bad_schema(tmp_path):
    with pytest.raises(TunerTableError, match="unreadable"):
        Tuner.load(str(tmp_path / "nope.json"))
    p = tmp_path / "bad.json"
    p.write_text(json.dumps([1, 2, 3]))
    with pytest.raises(TunerTableError):
        Tuner.load(str(p))


def test_table_loaders_corrupt_json_names_file(tmp_path):
    p = tmp_path / "bench.json"
    p.write_text('[{"name": "x",')
    with pytest.raises(TableSchemaError) as ei:
        load_bench(str(p))
    assert str(p) in str(ei.value)
    assert "regenerate" in str(ei.value)
    with pytest.raises(TableSchemaError):
        load_fault_table(str(tmp_path / "missing.json"))


# ------------------------- fault-sweep artifact gate --------------------------


def test_committed_fault_table_loads():
    table = load_fault_table(os.path.join(REPO, "experiments", "fault_table.json"))
    keys = set(table)
    ops = {"bcast", "reduce", "allreduce", "allgather", "reduce_scatter",
           "allgatherv", "alltoallv"}
    faults = {"slow_link", "stalled_round", "transient_drop", "dead_rank"}
    for op in ops:
        for fault in faults:
            assert f"{op}/{fault}/n4" in keys, (op, fault)
    # every dead-rank entry carries a replan on a strictly smaller mesh
    for key, e in table.items():
        if "/dead_rank/" in key:
            assert e["outcome"] == "typed_error" and e["error"] == "DeadRankError"
            assert e["replanned"]["n"] < int(key.rsplit("/n", 1)[1])


def test_fault_table_gate_rejects_wire_byte_drift(tmp_path):
    src = json.load(open(os.path.join(REPO, "experiments", "fault_table.json")))
    key = next(k for k in src if "/dead_rank/" in k)
    src[key]["replanned"]["wire_bytes"] += 1
    p = tmp_path / "tampered.json"
    p.write_text(json.dumps(src))
    with pytest.raises(TableSchemaError, match="wire_bytes"):
        load_fault_table(str(p))


def test_fault_table_gate_rejects_silent_outcomes(tmp_path):
    entry = {"algo": "ring_allreduce", "seed": 0, "outcome": "mostly_fine"}
    p = tmp_path / "bad.json"
    p.write_text(json.dumps({"allreduce/slow_link/n4": entry}))
    with pytest.raises(TableSchemaError, match="no third state"):
        load_fault_table(str(p))
    entry = {"algo": "ring_allreduce", "seed": 0, "outcome": "bit_identical",
             "baseline_us": 10.0, "faulty_us": 5.0}
    p.write_text(json.dumps({"allreduce/slow_link/n4": entry}))
    with pytest.raises(TableSchemaError, match="cannot speed a schedule up"):
        load_fault_table(str(p))


# ------------------------- bench worker timeouts ------------------------------


def test_run_worker_timeout_is_typed_and_retried():
    t0 = time.time()
    with pytest.raises(WorkerTimeoutError, match="2 attempt"):
        run_worker("import time; time.sleep(60)", devices=1, timeout=1, retries=1)
    assert time.time() - t0 >= 2.0  # both attempts ran their full budget


def test_run_worker_success_path_unchanged():
    out = run_worker('import json; print(json.dumps({"ok": 1}))', devices=1)
    assert out == {"ok": 1}


# ---------------------- serve drain-to-checkpoint ----------------------------


def test_distribute_weights_drains_on_failure(dist):
    """An unrecoverable failure mid-distribution drains the pre-distribution
    weights to an atomic checkpoint and raises the typed WeightSyncError;
    the drained checkpoint restores bit-identically."""
    dist(
        """
import os, tempfile
import numpy as np, jax
import repro.serve.engine as eng
from repro.comm.faults import WeightSyncError
from repro.train import checkpoint as ckpt
from repro.launch.mesh import make_local_mesh

mesh = make_local_mesh(1)
params = {"w": np.arange(48, dtype=np.float32).reshape(6, 8),
          "b": np.ones((8,), np.float32)}

def boom(*a, **kw):
    raise RuntimeError("fabric lost a device mid-broadcast")

eng.comm.apply_plan = boom
drain = tempfile.mkdtemp()
try:
    eng.distribute_weights(dict(params), mesh, drain_dir=drain)
except WeightSyncError as e:
    assert "drained" in str(e), e
    assert e.__cause__ is not None
else:
    raise AssertionError("expected WeightSyncError")
assert ckpt.latest_step(drain) == 0
like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
restored = ckpt.restore_checkpoint(drain, 0, like)
np.testing.assert_array_equal(np.asarray(restored["w"]), params["w"])
np.testing.assert_array_equal(np.asarray(restored["b"]), params["b"])
print("PASS")
""",
        devices=4,
    )
