"""Fault-injection layer: FaultSpec/MeshHealth semantics and the simulator's
fault threading.

The contract under test (the subsystem's one-sentence spec): under every
injected fault class, a replay either converges bit-identically to the
fault-free oracle or raises a typed FaultError — never a silent wrong
answer. Clock-only faults (slow links, stalls, in-budget drops) must not
touch values; value-affecting faults (dead ranks, drop streaks past budget)
must raise with a named recovery action.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.comm import plan_collective, plan_overlap, simulate_overlap
from repro.comm.faults import (
    DeadRankError,
    FaultError,
    FaultSpec,
    MeshHealth,
    TransientDropError,
)
from repro.core import cost_model
from repro.core.simulator import simulate_collective, simulate_lowered, timed_rounds

# (op, algo) points covering overwrite, combine, and ragged value paths
POINTS = [
    ("bcast", "pipelined_chain"),
    ("allreduce", "ring_allreduce"),
    ("reduce_scatter", "ring_reduce_scatter"),
]


def _plan(op, algo, n=5, M=1 << 14):
    return plan_collective(op, M, n, algo=algo)


def _data(plan, rng):
    return [rng.standard_normal((plan.schedule.num_chunks, 3)) for _ in range(plan.n)]


# ------------------------------- FaultSpec ----------------------------------


def test_fault_spec_normalization_and_validation():
    spec = FaultSpec(dead_ranks=(3, 1, 3), stalled_rounds=(2, 0, 2),
                     link_slowdown={(1, 0): 2.0, (0, 1): 4.0})
    assert spec.dead_ranks == (1, 3)
    assert spec.stalled_rounds == (0, 2)
    assert spec.slowdown(0, 1) == 4.0
    assert spec.slowdown(1, 0) == 2.0
    assert spec.slowdown(2, 3) == 1.0
    with pytest.raises(ValueError, match="slowdown factor"):
        FaultSpec(link_slowdown=(((0, 1), 0.5),))
    with pytest.raises(ValueError, match="drop_prob"):
        FaultSpec(drop_prob=1.0)
    with pytest.raises(ValueError, match="max_drop_retries"):
        FaultSpec(max_drop_retries=-1)


def test_fault_spec_identity():
    assert FaultSpec().healthy
    assert not FaultSpec(drop_prob=0.1).healthy
    assert FaultSpec(seed=1).fingerprint() == FaultSpec(seed=1).fingerprint()
    assert FaultSpec(seed=1).fingerprint() != FaultSpec(seed=2).fingerprint()
    assert FaultSpec().retry_factor == 1.0
    assert FaultSpec(drop_prob=0.5).retry_factor == pytest.approx(2.0)


def test_fault_errors_are_typed():
    for err in (DeadRankError, TransientDropError):
        assert issubclass(err, FaultError)
        assert issubclass(err, RuntimeError)


def test_retries_deterministic_in_seed():
    spec = FaultSpec(seed=11, drop_prob=0.4, max_drop_retries=50)
    draws = [spec.retries(r, s, d) for r in range(4) for s in range(3) for d in range(3)]
    again = [spec.retries(r, s, d) for r in range(4) for s in range(3) for d in range(3)]
    assert draws == again
    assert any(k > 0 for k in draws)  # p=0.4 over 36 draws


# ------------------------------- MeshHealth ---------------------------------


def test_mesh_health_survivors_and_links():
    h = MeshHealth(n=6, dead_ranks=(4, 1),
                   slow_links=(((0, 2), 3.0), ((1, 2), 9.0)))
    assert h.survivors() == (0, 2, 3, 5)
    # the slow link touching dead rank 1 drops out of degraded pricing
    assert h.surviving_slow_links() == (((0, 2), 3.0),)
    assert not h.healthy
    assert MeshHealth(n=6).healthy
    assert h.fingerprint() != MeshHealth(n=6).fingerprint()
    with pytest.raises(ValueError, match="outside mesh"):
        MeshHealth(n=4, dead_ranks=(4,))


def test_mesh_health_from_fault_spec():
    spec = FaultSpec(dead_ranks=(2,), link_slowdown=(((0, 1), 2.0),))
    h = MeshHealth.from_fault_spec(5, spec)
    assert h.n == 5 and h.dead_ranks == (2,) and h.slow_links == spec.link_slowdown


# --------------------------- simulator threading ----------------------------


@pytest.mark.parametrize("op,algo", POINTS)
def test_clock_faults_are_bit_identical(op, algo):
    """Slow links, stalls, and in-budget drops never change values — on the
    schedule IR replay AND the lowered dense-table replay."""
    plan = _plan(op, algo)
    rng = np.random.default_rng(0)
    data = _data(plan, rng)
    oracle = simulate_collective(plan.schedule, data)
    spec = FaultSpec(seed=3, link_slowdown=(((0, 1), 8.0),), stalled_rounds=(0,),
                     drop_prob=0.3, max_drop_retries=64)
    report = {}
    faulty = simulate_collective(plan.schedule, data, faults=spec, report=report)
    for r in range(plan.n):
        np.testing.assert_array_equal(faulty[r], oracle[r])
    assert report["retries"] >= 0
    assert report["stalled_rounds"] == 1
    low_report = {}
    lowered = simulate_lowered(plan.lowered(), data, faults=spec, report=low_report)
    for r in range(plan.n):
        np.testing.assert_array_equal(lowered[r], oracle[r])
    assert low_report["retries"] >= 0


@pytest.mark.parametrize("op,algo", POINTS)
def test_dead_rank_raises_on_both_replays(op, algo):
    plan = _plan(op, algo)
    data = _data(plan, np.random.default_rng(0))
    spec = FaultSpec(dead_ranks=(2,))
    with pytest.raises(DeadRankError, match="dead rank 2"):
        simulate_collective(plan.schedule, data, faults=spec)
    with pytest.raises(DeadRankError, match="dead rank 2"):
        simulate_lowered(plan.lowered(), data, faults=spec)


def test_drop_streak_past_budget_is_typed():
    plan = _plan("bcast", "pipelined_chain")
    data = _data(plan, np.random.default_rng(0))
    spec = FaultSpec(seed=0, drop_prob=0.9, max_drop_retries=0)
    with pytest.raises(TransientDropError, match="budget"):
        simulate_collective(plan.schedule, data, faults=spec)


def test_timed_rounds_degradation():
    plan = _plan("allreduce", "ring_allreduce", n=4)
    sched = plan.schedule
    base = timed_rounds(sched, 256, 1e-6, 1e9)
    # a healthy spec prices identically to no spec
    assert timed_rounds(sched, 256, 1e-6, 1e9, faults=FaultSpec()) == base
    slow = timed_rounds(sched, 256, 1e-6, 1e9,
                        faults=FaultSpec(link_slowdown=(((0, 1), 4.0),)))
    assert slow > base
    stall = timed_rounds(sched, 256, 1e-6, 1e9,
                         faults=FaultSpec(stalled_rounds=(0, 1), stall_s=1e-3))
    assert stall == pytest.approx(base + 2e-3)
    drop = timed_rounds(sched, 256, 1e-6, 1e9, faults=FaultSpec(drop_prob=0.5))
    assert drop > base
    with pytest.raises(DeadRankError):
        timed_rounds(sched, 256, 1e-6, 1e9, faults=FaultSpec(dead_ranks=(0,)))


# ------------------------- degraded cost modelling --------------------------


def test_worst_link_factor_forms():
    assert cost_model.worst_link_factor(()) == 1.0
    assert cost_model.worst_link_factor({(0, 1): 3.0, (1, 2): 5.0}) == 5.0
    assert cost_model.worst_link_factor((((0, 1), 2.5),)) == 2.5


def test_cost_degraded_matches_and_degrades():
    M, n = 1 << 20, 8
    base = cost_model.cost("ring_allreduce", M, n)
    assert cost_model.cost_degraded("ring_allreduce", M, n) == base
    worse = cost_model.cost_degraded(
        "ring_allreduce", M, n, slow_links=(((0, 1), 4.0),)
    )
    assert worse > base
    # startup terms are unchanged: degradation is bounded by the bw factor
    assert worse < 4.0 * base + 1e-12


def test_degraded_bandwidth():
    assert cost_model.degraded_bandwidth(8e9, ()) == 8e9
    assert cost_model.degraded_bandwidth(8e9, {(0, 1): 4.0}) == pytest.approx(2e9)


# ------------------------------ overlap faults ------------------------------


def _oplan(n=4, leaves=3):
    tree = {f"l{i}": jax.ShapeDtypeStruct((2048,), np.float32) for i in range(leaves)}
    return plan_overlap(tree, [("data", n)], bucket_bytes=4096)


def test_simulate_overlap_fault_keys():
    oplan = _oplan()
    base = simulate_overlap(oplan)
    assert "fault_slowdown" not in base
    spec = FaultSpec(link_slowdown=(((0, 1), 3.0),), stalled_rounds=(0,))
    sim = simulate_overlap(oplan, faults=spec)
    assert sim["comm_s_faulty"] > sim["comm_s_healthy"]
    assert sim["fault_slowdown"] > 1.0
    assert sim["fault_fingerprint"] == spec.fingerprint()
    # the healthy clock agrees with the per-bucket plan clocks
    expected = sum(p.timed_rounds_s() for ax in oplan.axes for p in oplan.plans[ax])
    assert sim["comm_s_healthy"] == pytest.approx(expected)


def test_simulate_overlap_dead_rank_raises():
    with pytest.raises(DeadRankError):
        simulate_overlap(_oplan(), faults=FaultSpec(dead_ranks=(1,)))
