"""Host-level tests for the repro.comm collective-plan subsystem.

Property invariants (ISSUE acceptance):
  * every op's schedule converges in the numpy simulator — all ranks hold
    the op's reference result — across pow2 AND non-pow2 rank counts;
  * bytes-on-wire from the schedule (CollectivePlan.wire_bytes) match the
    cost-model accounting (plan.expected_wire_bytes);
  * both path classes (intra / inter_pod) produce valid plans;
  * manual decisions carry a finite predicted_s (the old NaN bug);
  * the experiments/*.json loaders accept the committed artifacts and fail
    loudly on schema violations.
"""
from __future__ import annotations

import json
import math
import os

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # offline fallback — see tests/_compat.py
    from _compat import given, settings, strategies as st

from repro.comm import (
    CollectivePlan,
    TableSchemaError,
    decide,
    expected_wire_bytes,
    load_bench,
    load_tuner_table,
    plan_collective,
    tuner_from_table,
)
from repro.comm import schedules as comm_schedules
from repro.core.schedules import Round, Transfer
from repro.core.simulator import simulate_collective
from repro.core.tuner import OPS, Tuner

REPO = os.path.join(os.path.dirname(__file__), "..")

# (op, algo, needs_pow2)
OP_ALGOS = [
    ("reduce", "binomial_reduce", False),
    ("reduce", "pipelined_reduce_chain", False),
    ("allreduce", "reduce_then_bcast", False),
    ("allreduce", "fused_rsb", False),
    ("allreduce", "ring_allreduce", False),
    ("allgather", "ring_allgather", False),
    ("allgather", "doubling_allgather", True),
    ("reduce_scatter", "ring_reduce_scatter", False),
]


def _reference(op: str, data: list[np.ndarray], root: int):
    if op == "bcast":
        return data[root]
    total = np.sum(data, axis=0)
    if op in ("reduce", "allreduce"):
        return total
    if op == "allgather":
        return np.stack([data[r][r] for r in range(len(data))])
    if op == "reduce_scatter":
        return total
    raise AssertionError(op)


def _check_ragged(plan: CollectivePlan, rng) -> None:
    """Ragged convergence on the global row frame: each rank starts with its
    own rows valid (zeros elsewhere) and must end holding every row it is
    owed — all rows for allgatherv, its incoming (s, r) blocks for
    alltoallv."""
    sched = plan.schedule
    n = sched.n
    sz = np.asarray(plan.sizes, dtype=np.int64)
    full = rng.randn(sched.num_chunks, 3)
    off = np.concatenate([[0], np.cumsum(sz)])
    owner = np.zeros(sched.num_chunks, dtype=np.int64)
    if plan.op == "allgatherv":
        owner = np.repeat(np.arange(n), sz)
    else:
        owner = np.repeat(np.arange(n * n) // n, sz)
    data = [np.where((owner == r)[:, None], full, 0.0) for r in range(n)]
    out = simulate_collective(sched, data)
    if plan.op == "allgatherv":
        for r in range(n):
            np.testing.assert_array_equal(out[r], full, err_msg=f"rank {r}")
    else:
        m = sz.reshape(n, n)
        for r in range(n):
            for s in range(n):
                b = s * n + r
                lo, hi = off[b], off[b + 1]
                np.testing.assert_array_equal(
                    out[r][lo:hi], full[lo:hi], err_msg=f"rank {r} block {s}->{r}"
                )


def _check_plan(plan: CollectivePlan, rng) -> None:
    if plan.op in ("allgatherv", "alltoallv"):
        return _check_ragged(plan, rng)
    sched = plan.schedule
    n, root = sched.n, sched.root
    data = [rng.randn(sched.num_chunks, 3) for _ in range(n)]
    out = simulate_collective(sched, data)
    ref = _reference(plan.op, data, root)
    if plan.op == "bcast":
        for r in range(n):
            np.testing.assert_allclose(out[r], ref, rtol=1e-9, err_msg=f"rank {r}")
    elif plan.op == "reduce":
        np.testing.assert_allclose(out[root], ref, rtol=1e-9)
    elif plan.op == "allreduce":
        for r in range(n):
            np.testing.assert_allclose(out[r], ref, rtol=1e-9, err_msg=f"rank {r}")
    elif plan.op == "allgather":
        for r in range(n):
            np.testing.assert_allclose(out[r], ref, rtol=1e-9, err_msg=f"rank {r}")
    elif plan.op == "reduce_scatter":
        for r in range(n):
            np.testing.assert_allclose(out[r][r], ref[r], rtol=1e-9, err_msg=f"rank {r}")


def _expected_bytes(plan: CollectivePlan) -> float:
    """Cost-model accounting, including the reduce_then_bcast composite."""
    if plan.algo == "reduce_then_bcast":
        K = plan.schedule.num_chunks
        chunk = math.ceil(plan.M / K)
        inner = plan.schedule.name.split("[", 1)[1].rstrip("]")
        reduce_part = (plan.n - 1) * K * chunk
        return reduce_part + expected_wire_bytes("bcast", inner, plan.M, plan.n, K)
    return expected_wire_bytes(plan.op, plan.algo, plan.M, plan.n, plan.num_chunks)


@settings(max_examples=150, deadline=None)
@given(
    case=st.sampled_from(OP_ALGOS),
    n=st.integers(2, 33),
    root_seed=st.integers(0, 1000),
    chunks=st.integers(1, 9),
    inter_pod=st.booleans(),
    size_exp=st.integers(6, 24),
)
def test_op_schedules_converge_and_account(case, n, root_seed, chunks, inter_pod, size_exp):
    op, algo, needs_pow2 = case
    if needs_pow2:
        n = 1 << max(n.bit_length() - 1, 1)
    root = root_seed % n
    M = 1 << size_exp
    kw = {"num_chunks": chunks} if algo in ("pipelined_reduce_chain", "fused_rsb") else {}
    plan = plan_collective(op, M, n, root=root, algo=algo, inter_pod=inter_pod, **kw)
    plan.schedule.validate_ranks()
    _check_plan(plan, np.random.RandomState(root_seed))
    assert plan.wire_bytes() == _expected_bytes(plan), (
        plan.algo, plan.n, plan.num_chunks, plan.wire_bytes(), _expected_bytes(plan)
    )
    assert math.isfinite(plan.predicted_s)


@settings(max_examples=60, deadline=None)
@given(n=st.integers(2, 40), chunks=st.integers(1, 16), root_seed=st.integers(0, 99))
def test_fused_rsb_round_count(n, chunks, root_seed):
    """fused_rsb matches its closed form: K + 2n - 3 rounds, 2K(n-1) chunk
    transfers (each chunk crosses every edge once per phase)."""
    sched = comm_schedules.fused_rsb(n, root_seed % n, num_chunks=chunks)
    assert sched.num_rounds == chunks + 2 * n - 3
    assert sched.wire_chunks() == 2 * chunks * (n - 1)


def test_auto_plans_for_every_op():
    """'auto' resolves every op at every path class, pow2 or not."""
    t = Tuner()
    for op in OPS:
        for n in (2, 5, 8, 24):
            for inter_pod in (False, True):
                for M in (256, 1 << 20, 64 << 20):
                    plan = plan_collective(op, M, n, tuner=t, inter_pod=inter_pod)
                    assert math.isfinite(plan.predicted_s), (op, n, M)
                    if plan.schedule is not None:
                        plan.schedule.validate_ranks()
                        _check_plan(plan, np.random.RandomState(0))


def test_allreduce_tuner_windows():
    t = Tuner()
    assert t.select(256, 16, op="allreduce").algo == "reduce_then_bcast"
    big = t.select(256 << 20, 256, op="allreduce")
    assert big.algo == "ring_allreduce"  # bandwidth-optimal at scale
    mid = t.select(16 << 20, 8, op="allreduce")
    assert mid.algo in ("fused_rsb", "ring_allreduce")
    # non-pow2 ranks still tune (ring/fused need no pow2)
    assert t.select(1 << 20, 12, op="allreduce").algo != "noop"
    # allgather: doubling only on pow2
    assert t.select(1 << 20, 8, op="allgather").algo == "doubling_allgather"
    assert t.select(1 << 20, 12, op="allgather").algo == "ring_allgather"


def test_per_op_empirical_override_and_roundtrip(tmp_path):
    t = Tuner()
    M, n = 1 << 20, 8
    t.record(M, n, "ring_allreduce", n, measured_s=1e-9, op="allreduce")
    hit = t.select(M, n, op="allreduce")
    assert hit.source == "empirical" and hit.algo == "ring_allreduce"
    # the bcast table is keyed separately — unaffected
    assert t.select(M, n).source == "analytic"
    p = str(tmp_path / "table.json")
    t.save(p)
    assert Tuner.load(p).select(M, n, op="allreduce").algo == "ring_allreduce"


def test_manual_decisions_have_finite_predictions():
    """The old core.bcast._decide returned predicted_s=NaN for manual algos;
    manual and auto must now be comparable in reports/benchmark JSON."""
    from repro.core.bcast import _decide

    for algo in ("chain", "binomial", "pipelined_chain", "bidir_chain", "scatter_allgather"):
        if algo == "scatter_allgather":
            d = _decide(1 << 20, 8, algo, None, None, False)
        else:
            d = _decide(1 << 20, 12, algo, None, None, False)
        assert math.isfinite(d.predicted_s), (algo, d)
        assert d.source == "manual"
    for op in ("reduce", "allreduce", "allgather", "reduce_scatter"):
        for algo in ("pipelined_reduce_chain", "fused_rsb", "ring_allgather", "ring_reduce_scatter"):
            try:
                d = decide(op, 1 << 20, 8, algo=algo)
            except KeyError:
                continue  # algo not applicable to this op
            assert math.isfinite(d.predicted_s), (op, algo, d)


def test_one_shot_op_compatibility():
    """An op/one-shot mismatch raises instead of silently running the wrong
    collective (xla_psum for reduce_scatter would return the full sum)."""
    with pytest.raises(ValueError, match="cannot implement"):
        decide("reduce_scatter", 1 << 20, 8, algo="xla_psum")
    with pytest.raises(ValueError, match="cannot implement"):
        decide("allreduce", 1 << 20, 8, algo="xla_allgather")
    assert decide("allreduce", 1 << 20, 8, algo="xla_psum").algo == "xla_psum"
    assert decide("reduce", 1 << 20, 8, algo="xla_psum").algo == "xla_psum"


def test_trainer_tuner_table_knob(tmp_path):
    """RunConfig.tuner_table loads a calibrated table into the explicit sync
    modes (the bench_allreduce -> trainer pipeline)."""
    from repro.configs.base import RunConfig

    t = Tuner()
    t.record(1 << 20, 8, "ring_allreduce", 8, 1e-9, op="allreduce")
    p = str(tmp_path / "table.json")
    t.save(p)
    run = RunConfig(sync_mode="tuned_allreduce", tuner_table=p)
    loaded = Tuner.load(run.tuner_table)
    assert loaded.select(1 << 20, 8, op="allreduce").source == "empirical"


def test_round_allows_disjoint_dst_ranges_only():
    # fused_rsb's pattern: same dst, disjoint chunks — legal
    Round((Transfer(0, 1, 0, 1, combine=True), Transfer(2, 1, 1, 1)))
    # overlapping ranges at one dst — rejected
    with pytest.raises(ValueError):
        Round((Transfer(0, 1, 0, 1), Transfer(2, 1, 0, 1)))


# ---------------------------- experiments/ loaders --------------------------


def test_committed_artifacts_validate():
    table = load_tuner_table(os.path.join(REPO, "experiments", "tuner_table.json"))
    rows = load_bench(os.path.join(REPO, "experiments", "bench.json"))
    assert table and rows
    tuner = tuner_from_table(os.path.join(REPO, "experiments", "tuner_table.json"))
    # the loaded table drives decisions: pick any committed entry and check
    # the tuner reproduces it as an empirical hit
    key, entry = next(iter(table.items()))
    path_cls, n_s, M_s = key.split("/")
    d = tuner.select(int(M_s[1:]), int(n_s[1:]), inter_pod=(path_cls == "inter"))
    assert d.source == "empirical" and d.algo == entry["algo"]


@pytest.mark.parametrize(
    "mutate, msg_part",
    [
        (lambda t: t.update({"bogus/n8/M256": {"algo": "binomial", "num_chunks": 1, "predicted_us": 1.0}}), "unknown key"),
        (lambda t: t.update({"intra/n12/M256": {"algo": "binomial", "num_chunks": 1, "predicted_us": 1.0}}), "power of two"),
        (lambda t: t.update({"intra/n8/M256": {"algo": "binomial", "num_chunks": 1, "predicted_us": 1.0, "surprise": 2}}), "unknown entry fields"),
        (lambda t: t.update({"intra/n8/M256": {"algo": "warp_drive", "num_chunks": 1, "predicted_us": 1.0}}), "unknown bcast algo"),
        (lambda t: t.update({"intra/n8/M256": {"algo": "binomial", "num_chunks": 1, "predicted_us": float("nan")}}), "finite"),
        (lambda t: t.update({"intra/n8/M256": {"algo": "binomial", "num_chunks": 1}}), "missing required"),
    ],
)
def test_table_loader_rejects_bad_schemas(tmp_path, mutate, msg_part):
    table = {"intra/n4/M1024": {"algo": "binomial", "num_chunks": 1, "predicted_us": 3.0}}
    mutate(table)
    p = tmp_path / "tuner_table.json"
    p.write_text(json.dumps(table))
    with pytest.raises(TableSchemaError, match=msg_part):
        load_tuner_table(str(p))


def test_bench_loader_rejects_bad_rows(tmp_path):
    p = tmp_path / "bench.json"
    p.write_text(json.dumps([{"name": "x", "us_per_call": 1.0, "derived": {}, "huh": 1}]))
    with pytest.raises(TableSchemaError, match="unknown fields"):
        load_bench(str(p))
    p.write_text(json.dumps({"not": "a list"}))
    with pytest.raises(TableSchemaError, match="array"):
        load_bench(str(p))


# ---------------------------------------------------------------------------
# host-side plan cache (comm.plan.plan_cached)
# ---------------------------------------------------------------------------


def test_plan_cache_hits_and_keying():
    from repro.comm import plan_cache_clear, plan_cache_info, plan_cached

    plan_cache_clear()
    t = Tuner()
    a = plan_cached("allreduce", 1 << 20, 8, tuner=t)
    b = plan_cached("allreduce", 1 << 20, 8, tuner=t)
    assert a is b  # identical point -> the SAME frozen plan object
    info = plan_cache_info()
    assert info["hits"] == 1 and info["misses"] == 1
    # any key component splits the entry
    assert plan_cached("allreduce", 1 << 20, 8, tuner=t, inter_pod=True) is not a
    assert plan_cached("allreduce", 1 << 20, 6, tuner=t) is not a
    assert plan_cached("reduce", 1 << 20, 8, tuner=t) is not a
    assert plan_cached("allreduce", 1 << 20, 8, tuner=t, algo="fused_rsb") is not a
    # two tuners with EQUAL content share entries (fingerprint keying, not id)
    assert plan_cached("allreduce", 1 << 20, 8, tuner=Tuner()) is a


def test_plan_cache_invalidated_by_tuner_record():
    """Satellite (ISSUE): Tuner.record of a new empirical row must change
    the cache-key fingerprint — stale plans are never replayed after
    calibration."""
    from repro.comm import plan_cache_clear, plan_cached

    plan_cache_clear()
    t = Tuner()
    M, n = 1 << 20, 8
    before = plan_cached("allreduce", M, n, tuner=t)
    assert before.decision.source == "analytic"
    fp0 = t.fingerprint()
    t.record(M, n, "ring_allreduce", n, 1e-4, op="allreduce")
    assert t.fingerprint() != fp0
    after = plan_cached("allreduce", M, n, tuner=t)
    assert after is not before
    assert after.decision.source == "empirical"
    assert after.algo == "ring_allreduce"
    # re-querying the calibrated point hits the new entry, not the stale one
    assert plan_cached("allreduce", M, n, tuner=t) is after
    # record_overlap (a depth-only row) must also invalidate
    fp1 = t.fingerprint()
    t.record_overlap(M, n, 3, op="allreduce")
    assert t.fingerprint() != fp1
    deeper = plan_cached("allreduce", M, n, tuner=t)
    assert deeper is not after and deeper.decision.overlap_depth == 3


def test_plan_cache_bounded():
    from repro.comm import plan_cache_clear, plan_cache_info, plan_cached
    from repro.comm.plan import _PLAN_CACHE_MAX

    plan_cache_clear()
    t = Tuner()
    for i in range(_PLAN_CACHE_MAX + 40):
        plan_cached("bcast", 1024 + i, 4, tuner=t)
    assert plan_cache_info()["size"] <= _PLAN_CACHE_MAX


def test_decision_fused_path_roundtrip(tmp_path):
    """The tuned fused-path flag rides the empirical table: record ->
    select -> save/load all preserve it, and apply_plan's routing honors it
    over the round-count policy."""
    from repro.comm.api import _use_compiled

    t = Tuner()
    t.record(1 << 20, 8, "fused_rsb", 16, 1e-4, op="allreduce",
             extras={"fused_path": True})
    dec = t.select(1 << 20, 8, op="allreduce")
    assert dec.fused_path is True
    p = tmp_path / "table.json"
    t.save(str(p))
    dec2 = Tuner.load(str(p)).select(1 << 20, 8, op="allreduce")
    assert dec2.fused_path is True

    plan = plan_collective("allreduce", 1 << 20, 8, tuner=t)
    assert plan.schedule.num_rounds <= 256  # policy alone would say unrolled
    assert _use_compiled(plan, fused=True, compiled=None)
    assert not _use_compiled(plan, fused=True, compiled=False)

    bad = {"hw": "tpu-v5e", "max_chunks": 64,
           "table": {"allreduce:8:20:0": {"algo": "fused_rsb", "num_chunks": 4,
                                          "measured_s": 1.0, "fused_path": "yes"}}}
    p.write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="fused_path"):
        Tuner.load(str(p))
