"""Property-style checks for the repro.dist layout rules, beyond the seed
contract: every sharded dim divides evenly on 2-axis and 3-axis meshes for
every assigned architecture, MoE expert-dim sharding, and the documented
replication fallbacks."""
from __future__ import annotations

import math

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES, get_config
from repro.dist import topology
from repro.dist.sharding import batch_specs, cache_specs, param_specs
from repro.models import Model


class FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.empty(shape)


MESH2 = FakeMesh((16, 16), ("data", "model"))
MESH3 = FakeMesh((2, 16, 16), ("pod", "data", "model"))
MESHES = {"2axis": MESH2, "3axis": MESH3}


def _axis_sizes(mesh):
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _leaf_specs(tree, specs):
    return list(
        zip(
            jax.tree_util.tree_leaves_with_path(tree),
            jax.tree_util.tree_leaves(specs, is_leaf=lambda s: isinstance(s, P)),
        )
    )


def _check_divisible(tree, specs, mesh):
    sizes = _axis_sizes(mesh)
    for (path, leaf), spec in _leaf_specs(tree, specs):
        assert len(spec) == leaf.ndim, (jax.tree_util.keystr(path), spec, leaf.shape)
        for dim, ax in zip(leaf.shape, spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            n = int(math.prod(sizes[a] for a in axes))
            assert n and dim % n == 0, (jax.tree_util.keystr(path), leaf.shape, spec)


@pytest.mark.parametrize("mesh_name", sorted(MESHES))
@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("fsdp,fallback", [(True, "replicate"), (False, "head_dim")])
def test_param_specs_divide_all_archs(arch, mesh_name, fsdp, fallback):
    """Full-rank specs with even shards for every arch x mesh x mode."""
    mesh = MESHES[mesh_name]
    shapes = Model(get_config(arch)).param_shapes()
    specs = param_specs(shapes, mesh, fsdp=fsdp, attn_fallback=fallback)
    _check_divisible(shapes, specs, mesh)


@pytest.mark.parametrize("mesh_name", sorted(MESHES))
@pytest.mark.parametrize("arch", ["mixtral-8x7b", "qwen3-moe-30b-a3b", "moonshot-v1-16b-a3b"])
def test_moe_expert_dim_rule(arch, mesh_name):
    """Experts shard on `model` when divisible, else the expert FFN width."""
    mesh = MESHES[mesh_name]
    cfg = get_config(arch)
    shapes = Model(cfg).param_shapes()
    specs = param_specs(shapes, mesh)
    tp = _axis_sizes(mesh)["model"]
    seen = 0
    for (path, leaf), spec in _leaf_specs(shapes, specs):
        key = jax.tree_util.keystr(path)
        if "moe']['w_" not in key or "shared" in key:
            continue
        seen += 1
        if cfg.num_experts % tp == 0:
            assert spec[-3] == "model", (key, spec)
        else:
            assert spec[-3] is None, (key, spec)
            ff = spec[-1] if "w_down" not in key else spec[-2]
            assert ff == "model", (key, spec)
    assert seen, "no expert leaves found"


@pytest.mark.parametrize("mesh_name", sorted(MESHES))
def test_no_data_axis_without_fsdp(mesh_name):
    mesh = MESHES[mesh_name]
    for arch in ("minitron-8b", "qwen3-moe-30b-a3b", "hymba-1.5b"):
        shapes = Model(get_config(arch)).param_shapes()
        for _, spec in _leaf_specs(shapes, param_specs(shapes, mesh, fsdp=False)):
            for e in spec:
                axes = e if isinstance(e, tuple) else (e,)
                assert "data" not in axes and "pod" not in axes, (arch, spec)


@pytest.mark.parametrize("mesh_name", sorted(MESHES))
@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k", "decode_32k"])
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_batch_and_cache_specs_divide(arch, shape_name, mesh_name):
    mesh = MESHES[mesh_name]
    cfg = get_config(arch)
    m = Model(cfg)
    tree = m.input_specs(INPUT_SHAPES[shape_name])
    caches = tree.pop("caches", None)
    _check_divisible(tree, batch_specs(tree, mesh), mesh)
    if caches is not None:
        _check_divisible(caches, cache_specs(caches, mesh, cfg), mesh)


def test_cache_rule_kv_vs_seq():
    """kv-heads on `model` when divisible; otherwise the sequence dim takes
    it (flash-decoding); batch=1 long context spills sequence onto 'data'."""
    cfg = get_config("gemma3-27b")  # kv=16 divides
    m = Model(cfg)
    caches = m.input_specs(INPUT_SHAPES["long_500k"])["caches"]
    flat = jax.tree_util.tree_leaves_with_path(
        cache_specs(caches, MESH2, cfg), is_leaf=lambda s: isinstance(s, P)
    )
    kv = [s for p, s in flat if "'k'" in jax.tree_util.keystr(p)]
    assert kv
    for s in kv:
        assert s[-2] == "model", s            # kv-heads sharded
        seq = s[-3] if isinstance(s[-3], tuple) else (s[-3],)
        assert "data" in seq, s               # batch=1 -> seq over data


def test_topology_roles():
    assert topology.dp_axes(MESH3) == ("pod", "data")
    assert topology.dp_axes(MESH2) == ("data",)
    assert topology.dp_size(MESH3) == 32
    assert topology.tp_axis(MESH2) == "model" and topology.tp_size(MESH3) == 16
    assert topology.inter_pod_axes(MESH3) == ("pod",)
    assert topology.inter_pod_axes(MESH2) == ()
    # hierarchical broadcast order: pod leaders first, then intra-pod data
    assert topology.bcast_axes(MESH3) == ("pod", "data")
    assert topology.bcast_axes(MESH2) == ("data",)
    assert topology.is_inter_pod("pod") and not topology.is_inter_pod("data")
