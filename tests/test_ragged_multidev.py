"""On-device correctness for the ragged collectives (simulated devices,
subprocess): pallgatherv/palltoallv across skewed size vectors including
zero-sized ranks, unrolled vs compiled executors bit-for-bit, and the MoE
alltoallv expert-dispatch transport against the einsum oracle."""
from __future__ import annotations


def test_pallgatherv_skewed_and_zero_ranks(dist):
    """Ragged allgather on 4 ranks: every rank holds its segment in the
    valid prefix of a max-padded shard; garbage beyond the prefix must not
    leak into the gathered result, for both executors."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.comm import pallgatherv

n = 4
mesh = Mesh(np.array(jax.devices()[:n]), ("x",))
rng = np.random.RandomState(0)
for sizes in [(3, 1, 0, 2), (1, 1, 1, 1), (5, 0, 0, 7)]:
    smax = max(sizes); total = sum(sizes); E = 3
    full = rng.randn(total, E).astype(np.float32)
    off = np.concatenate([[0], np.cumsum(sizes)])
    loc = np.full((n, smax, E), 99.0, np.float32)  # poison beyond prefix
    for r in range(n):
        loc[r, :sizes[r]] = full[off[r]:off[r + 1]]
    for compiled in (False, True):
        f = shard_map(
            lambda v, c=compiled: pallgatherv(v, "x", sizes=sizes, compiled=c),
            mesh=mesh, in_specs=P("x"), out_specs=P(), check_rep=False)
        out = np.asarray(f(jnp.asarray(loc.reshape(n * smax, E))))
        assert out.shape == (total, E), (out.shape, total)
        assert np.array_equal(out, full), (sizes, compiled)
print("PASS")
""",
        devices=4,
    )


def test_palltoallv_compact_all_algos(dist):
    """Compact-layout alltoallv on 4 ranks across random block matrices,
    including a rank that receives nothing and a rank that sends nothing,
    for {auto, pairwise, ring} x {unrolled, compiled} — bit-exact against
    the host-side reshuffle."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.comm import palltoallv

n, E = 4, 2
mesh = Mesh(np.array(jax.devices()[:n]), ("x",))
rng = np.random.RandomState(1)
for trial in range(3):
    m = rng.randint(0, 4, size=(n, n)).astype(np.int64)
    if trial == 1: m[:, 2] = 0   # rank 2 receives nothing
    if trial == 2: m[1, :] = 0   # rank 1 sends nothing
    if m.sum() == 0: m[0, 0] = 1
    send = m.sum(axis=1); recv = m.sum(axis=0)
    smax = max(int(send.max()), 1); rmax = max(int(recv.max()), 1)
    blocks = {(s, d): rng.randn(int(m[s, d]), E).astype(np.float32)
              for s in range(n) for d in range(n)}
    xin = np.full((n, smax, E), 88.0, np.float32)
    for s in range(n):
        xin[s, :send[s]] = np.concatenate(
            [blocks[(s, d)] for d in range(n)] + [np.zeros((0, E), np.float32)])
    exp = np.zeros((n, rmax, E), np.float32)
    for r in range(n):
        exp[r, :recv[r]] = np.concatenate(
            [blocks[(s, r)] for s in range(n)] + [np.zeros((0, E), np.float32)])
    for compiled in (False, True):
        for algo in ("auto", "pairwise_alltoallv", "ring_alltoallv"):
            f = shard_map(
                lambda v, a=algo, c=compiled: palltoallv(
                    v, "x", sizes=m.tolist(), algo=a, compiled=c),
                mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_rep=False)
            out = np.asarray(f(jnp.asarray(xin.reshape(n * smax, E))))
            out = out.reshape(n, rmax, E)
            assert np.array_equal(out, exp), (trial, algo, compiled)
print("PASS")
""",
        devices=4,
    )


def test_palltoallv_padded_round_trip(dist):
    """Padded-in -> padded-out layout on a matrix with an all-zero source
    row: block (s, d) lands at out[d][s]'s valid prefix, padding inert."""
    dist(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.comm import palltoallv

n, E = 4, 2
mesh = Mesh(np.array(jax.devices()[:n]), ("x",))
rng = np.random.RandomState(2)
m = np.array([[2, 0, 1, 3], [0, 0, 0, 0], [1, 4, 0, 0], [2, 2, 2, 2]], np.int64)
bmax = int(m.max())
blocks = {(s, d): rng.randn(int(m[s, d]), E).astype(np.float32)
          for s in range(n) for d in range(n)}
xin = np.full((n, n, bmax, E), 77.0, np.float32)
for s in range(n):
    for d in range(n):
        xin[s, d, :m[s, d]] = blocks[(s, d)]
exp = np.zeros((n, n, bmax, E), np.float32)
for r in range(n):
    for s in range(n):
        exp[r, s, :m[s, r]] = blocks[(s, r)]
f = shard_map(
    lambda v: palltoallv(v, "x", sizes=m.tolist(), in_padded=True, out_padded=True),
    mesh=mesh, in_specs=P("x"), out_specs=P("x"), check_rep=False)
out = np.asarray(f(jnp.asarray(xin.reshape(n * n, bmax, E)))).reshape(n, n, bmax, E)
assert np.array_equal(out, exp)
print("PASS")
""",
        devices=4,
    )


def test_moe_alltoallv_matches_einsum_oracle(dist):
    """The explicit expert-parallel transport (moe_dispatch='alltoallv',
    E=6 over 4 ranks -> ragged partition (2,2,1,1), shared experts on)
    reproduces the single-host einsum path bit-for-bit, aux loss included
    (me/ce are pmean'd, so aux is the global-batch value)."""
    dist(
        """
import dataclasses
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.configs.base import ModelConfig
from repro.models import moe as moe_lib

cfg = ModelConfig(
    name="t", family="moe", num_layers=1, d_model=8, num_heads=2,
    num_kv_heads=2, d_ff=16, vocab_size=32, num_experts=6,
    experts_per_token=2, moe_group_size=8, num_shared_experts=1)
cfga = dataclasses.replace(cfg, moe_dispatch="alltoallv")
p = moe_lib.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
B, T, D = 8, 16, 8
x = jax.random.normal(jax.random.PRNGKey(1), (B, T, D), jnp.float32)
y_ref, aux_ref = moe_lib.moe_ffn(p, x, cfg)

mesh = Mesh(np.array(jax.devices()[:4]), ("dp",))
f = shard_map(
    lambda pp, xx: moe_lib.moe_ffn(pp, xx, cfga, axis_name="dp"),
    mesh=mesh, in_specs=(P(), P("dp")), out_specs=(P("dp"), P()),
    check_rep=False)
y, aux = f(p, x)
err = float(jnp.max(jnp.abs(y - y_ref)))
aerr = abs(float(aux) - float(aux_ref))
assert err == 0.0, err
assert aerr < 1e-6, (float(aux), float(aux_ref))
print("PASS")
""",
        devices=4,
    )
