"""repro.comm — the unified collective-plan subsystem.

Promotes the paper's tuned-broadcast stack into a collective-communication
library: one op family (bcast / reduce / allreduce / allgather /
reduce_scatter) sharing the schedule IR (``core.schedules``), the numpy
simulator, the analytic cost models, and the per-op tuner
(``Tuner.select(M, n, op=..., inter_pod=...)``).

Layering (DESIGN.md Sec. 3):

    core.schedules (IR)  ->  comm.schedules (per-op builders)
                         ->  comm.plan      (CollectivePlan: decide + build)
                         ->  comm.executors (shard_map replay, fused loops)
                         ->  comm.api       (pbcast/pallreduce/... + *_tree)
                         ->  comm.streams   (multi-stream link scheduler;
                                             comm.overlap = 1-stream case)
                         ->  comm.tables    (validated experiments/ artifacts)

Consumers: ``train.train_step`` (sync_mode='tuned_allreduce'),
``serve.engine.distribute_weights``, ``launch.hillclimb_bcast``,
``benchmarks/``. ``core.bcast`` remains as a thin compatibility facade.
"""
from ..core.tuner import OPS, Decision, OnlineTuner, Tuner, default_tuner
from .compress import (
    CompressedWire,
    CompressionState,
    WireFormat,
    normalize_wire_format,
    wire_chunk_bytes,
)
from .api import (
    apply_plan,
    apply_plan_resilient,
    hierarchical_allreduce_axes,
    pallgather,
    pallgatherv,
    pallreduce,
    pallreduce_tree,
    pbcast,
    pbcast_tree,
    palltoallv,
    preduce,
    preduce_scatter,
)
from .executors import execute_collective, execute_compiled, execute_inkernel
from .faults import (
    DeadRankError,
    FallbackExhaustedError,
    FaultError,
    FaultSpec,
    MeshHealth,
    TransientDropError,
    WeightSyncError,
)
from .overlap import (
    OverlapPlan,
    execute_overlap,
    overlap_allreduce_tree,
    plan_overlap,
    simulate_overlap,
)
from .plan import (
    CollectivePlan,
    cache_stats,
    decide,
    expected_wire_bytes,
    plan_cache_clear,
    plan_cache_info,
    plan_cached,
    plan_collective,
    plan_degraded,
)
from .resilience import FallbackEvent, FallbackPolicy, StragglerReport, Watchdog
from .streams import (
    StreamEntry,
    StreamGraph,
    StreamGraphError,
    StreamSpec,
    dispatch_schedule,
    execute_stream_entry,
    execute_streams,
    graph_key,
    plan_streams,
    simulate_streams,
)
from .tables import (
    TableSchemaError,
    load_bench,
    load_compile_table,
    load_compress_table,
    load_fault_table,
    load_inkernel_table,
    load_overlap_table,
    load_streams_table,
    load_tuner_table,
    tuner_from_table,
)

__all__ = [
    "OPS",
    "Decision",
    "Tuner",
    "OnlineTuner",
    "default_tuner",
    "WireFormat",
    "CompressedWire",
    "CompressionState",
    "normalize_wire_format",
    "wire_chunk_bytes",
    "CollectivePlan",
    "plan_collective",
    "plan_degraded",
    "plan_cached",
    "plan_cache_info",
    "plan_cache_clear",
    "cache_stats",
    "decide",
    "expected_wire_bytes",
    "execute_collective",
    "execute_compiled",
    "execute_inkernel",
    "apply_plan",
    "apply_plan_resilient",
    "pbcast",
    "pbcast_tree",
    "preduce",
    "preduce_scatter",
    "pallreduce",
    "pallgather",
    "pallgatherv",
    "palltoallv",
    "pallreduce_tree",
    "hierarchical_allreduce_axes",
    "OverlapPlan",
    "plan_overlap",
    "simulate_overlap",
    "execute_overlap",
    "overlap_allreduce_tree",
    "StreamSpec",
    "StreamEntry",
    "StreamGraph",
    "StreamGraphError",
    "graph_key",
    "plan_streams",
    "simulate_streams",
    "dispatch_schedule",
    "execute_streams",
    "execute_stream_entry",
    "TableSchemaError",
    "load_tuner_table",
    "load_bench",
    "load_overlap_table",
    "load_streams_table",
    "load_compile_table",
    "load_fault_table",
    "load_inkernel_table",
    "load_compress_table",
    "tuner_from_table",
    "FaultError",
    "DeadRankError",
    "TransientDropError",
    "FallbackExhaustedError",
    "WeightSyncError",
    "FaultSpec",
    "MeshHealth",
    "FallbackPolicy",
    "FallbackEvent",
    "StragglerReport",
    "Watchdog",
]
