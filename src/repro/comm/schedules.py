"""Schedule builders for the non-broadcast collectives (DESIGN.md Sec. 3).

Every builder emits a :class:`repro.core.schedules.Schedule` on the same IR
the broadcast library uses — reduce-family transfers carry ``combine=True``
and accumulate at the destination. The reduce builders are literal mirrors
of their broadcast counterparts (rounds reversed, src/dst swapped), the
allreduce builders compose reduce + broadcast phases, and the allgather /
reduce_scatter rings generalize the two phases of the power-of-two
``scatter_allgather`` broadcast (Eq. 4) to any rank count.

Data conventions (buffer is ``(num_chunks, chunk_elems)`` everywhere):

  * reduce / allreduce — every rank contributes its full buffer; on exit the
    root (reduce) or every rank (allreduce) holds the element-wise sum.
  * allgather — ``num_chunks == n``; rank r contributes row r; on exit every
    rank holds all rows.
  * reduce_scatter — ``num_chunks == n``; every rank contributes all rows;
    on exit rank r's row r holds the sum of everyone's row r.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from ..core.schedules import (
    Round,
    Schedule,
    Transfer,
    binomial_reduce,
    pipelined_chain,
    _rot,
)

__all__ = [
    "reverse_for_reduce",
    "binomial_reduce",
    "pipelined_reduce_chain",
    "reduce_then_bcast",
    "fused_rsb",
    "ring_allreduce_schedule",
    "ring_allgather",
    "doubling_allgather",
    "ring_reduce_scatter",
    "OP_BUILDERS",
    "build_op",
]


def reverse_for_reduce(sched: Schedule, name: str) -> Schedule:
    """Mirror a bcast schedule into a reduce-to-root schedule: reverse the
    rounds, swap src/dst, and mark every transfer combining. The chunk-level
    pipelining (and therefore the cost model) carries over unchanged."""
    rounds = tuple(
        Round(tuple(
            Transfer(t.dst, t.src, t.chunk_start, t.chunk_count, combine=True)
            for t in r.transfers
        ))
        for r in reversed(sched.rounds)
    )
    return dataclasses.replace(sched, name=name, rounds=rounds, kind="reduce")


def pipelined_reduce_chain(n: int, root: int = 0, num_chunks: int = 8) -> Schedule:
    """Chunk-pipelined reduce-to-root: the paper's pipelined chain (Eq. 5)
    reversed — partial sums stream toward the root one chunk per hop, so the
    cost keeps Eq. 5's (M/C + n - 2)(ts + C/B) form."""
    return reverse_for_reduce(
        pipelined_chain(n, root, num_chunks), "pipelined_reduce_chain"
    )


def reduce_then_bcast(n: int, root: int, bcast_sched: Schedule) -> Schedule:
    """Two-phase allreduce with a barrier: reversed-binomial reduce-to-root
    over the whole buffer, then the tuned broadcast schedule (any algorithm,
    any chunking). The reduce rounds move the full chunk range at once."""
    num_chunks = bcast_sched.num_chunks
    red = binomial_reduce(n, root)
    red_rounds = tuple(
        Round(tuple(
            Transfer(t.src, t.dst, 0, num_chunks, combine=True)
            for t in r.transfers
        ))
        for r in red.rounds
    )
    return Schedule(
        f"reduce_then_bcast[{bcast_sched.name}]",
        n,
        root,
        num_chunks,
        red_rounds + bcast_sched.rounds,
        kind="allreduce",
    )


def fused_rsb(n: int, root: int = 0, num_chunks: int = 8) -> Schedule:
    """Fused pipelined reduce-chain + bcast-chain allreduce ("fused_rsb").

    Logical chain positions 0 (the head, at ``root``) .. n-1. Chunk c's
    partial sums hop head-ward, fully reduced at position 0 at round
    c + n - 2; the head immediately streams it back tail-ward while later
    chunks are still reducing. Round s carries, concurrently on the two
    directions of each full-duplex link:

      * reduce: edge p -> p-1 moves chunk s - (n - 1 - p)   (combine)
      * bcast:  edge p -> p+1 moves chunk s - (n - 1) - p   (overwrite)

    Total rounds: num_chunks + 2n - 3, matching t_fused_rsb in the cost
    model. A destination appears twice in a round (one reduce chunk, one
    bcast chunk) — the relaxed Round invariant allows it because the chunk
    ranges are disjoint.
    """
    if n == 1:
        return Schedule("fused_rsb", n, root, num_chunks, (), kind="allreduce")
    rounds = []
    for s in range(num_chunks + 2 * n - 3):
        transfers = []
        for p in range(1, n):  # reduce edge p -> p-1
            c = s - (n - 1 - p)
            if 0 <= c < num_chunks:
                transfers.append(
                    Transfer(_rot(p, root, n), _rot(p - 1, root, n), c, 1, combine=True)
                )
        for p in range(n - 1):  # bcast edge p -> p+1
            c = s - (n - 1) - p
            if 0 <= c < num_chunks:
                transfers.append(
                    Transfer(_rot(p, root, n), _rot(p + 1, root, n), c, 1)
                )
        if transfers:
            rounds.append(Round(tuple(transfers)))
    return Schedule("fused_rsb", n, root, num_chunks, tuple(rounds), kind="allreduce")


def ring_allreduce_schedule(n: int, root: int = 0) -> Schedule:
    """Bandwidth-optimal ring allreduce on the IR: n-1 combining
    reduce-scatter rounds, then n-1 allgather rounds (``root`` is irrelevant
    — the result is symmetric). ``num_chunks == n``; works for any n."""
    if n == 1:
        return Schedule("ring_allreduce", n, root, 1, (), kind="allreduce")
    rounds = []
    # reduce-scatter: round s, rank r sends its partial of chunk (r - s) mod n
    # to r+1; after n-1 rounds rank r owns the full sum of chunk (r+1) mod n.
    for s in range(n - 1):
        rounds.append(Round(tuple(
            Transfer(r, (r + 1) % n, (r - s) % n, 1, combine=True) for r in range(n)
        )))
    # allgather: circulate the reduced chunks.
    for s in range(n - 1):
        rounds.append(Round(tuple(
            Transfer(r, (r + 1) % n, (r + 1 - s) % n, 1) for r in range(n)
        )))
    return Schedule("ring_allreduce", n, root, n, tuple(rounds), kind="allreduce")


def ring_allgather(n: int, root: int = 0) -> Schedule:
    """Ring allgather for ANY rank count — the generalization of the
    power-of-two scatter_allgather bcast's second phase. Rank r starts
    owning row r; round s moves row (r - s) mod n over edge r -> r+1."""
    if n == 1:
        return Schedule("ring_allgather", n, root, 1, (), kind="allgather")
    rounds = tuple(
        Round(tuple(Transfer(r, (r + 1) % n, (r - s) % n, 1) for r in range(n)))
        for s in range(n - 1)
    )
    return Schedule("ring_allgather", n, root, n, rounds, kind="allgather")


def doubling_allgather(n: int, root: int = 0) -> Schedule:
    """Recursive-doubling allgather (power-of-two n): round t pairs rank r
    with r XOR 2^t and exchanges the 2^t contiguous rows each side owns —
    log2(n) startups for the same total bytes as the ring."""
    if n & (n - 1):
        raise ValueError(f"doubling_allgather requires power-of-two n, got {n}")
    if n == 1:
        return Schedule("doubling_allgather", n, root, 1, (), kind="allgather")
    rounds = []
    span = 1
    while span < n:
        transfers = []
        for r in range(n):
            base = (r // span) * span
            transfers.append(Transfer(r, r ^ span, base, span))
        rounds.append(Round(tuple(transfers)))
        span *= 2
    return Schedule("doubling_allgather", n, root, n, tuple(rounds), kind="allgather")


def ring_reduce_scatter(n: int, root: int = 0) -> Schedule:
    """Ring reduce-scatter for any n: n-1 combining rounds after which rank
    r's row r holds the element-wise sum of everyone's row r."""
    if n == 1:
        return Schedule("ring_reduce_scatter", n, root, 1, (), kind="reduce_scatter")
    rounds = tuple(
        Round(tuple(
            Transfer(r, (r + 1) % n, (r - s - 1) % n, 1, combine=True)
            for r in range(n)
        ))
        for s in range(n - 1)
    )
    return Schedule("ring_reduce_scatter", n, root, n, rounds, kind="reduce_scatter")


# ---------------------------------------------------------------------------
# Registry (reduce_then_bcast is composite — built in plan.py, where the
# inner bcast decision is available)
# ---------------------------------------------------------------------------

OP_BUILDERS: dict[str, dict[str, Callable[..., Schedule]]] = {
    "reduce": {
        "binomial_reduce": lambda n, root, num_chunks=1: binomial_reduce(n, root),
        "pipelined_reduce_chain": pipelined_reduce_chain,
    },
    "allreduce": {
        "fused_rsb": fused_rsb,
        "ring_allreduce": lambda n, root, num_chunks=None: ring_allreduce_schedule(n, root),
    },
    "allgather": {
        "ring_allgather": lambda n, root, num_chunks=None: ring_allgather(n, root),
        "doubling_allgather": lambda n, root, num_chunks=None: doubling_allgather(n, root),
    },
    "reduce_scatter": {
        "ring_reduce_scatter": lambda n, root, num_chunks=None: ring_reduce_scatter(n, root),
    },
}


def build_op(op: str, algo: str, n: int, root: int = 0, *, num_chunks: int = 1) -> Schedule:
    """Build + validate a non-bcast op schedule by name."""
    try:
        builder = OP_BUILDERS[op][algo]
    except KeyError:
        have = {o: sorted(a) for o, a in OP_BUILDERS.items()}
        raise KeyError(f"no builder for op={op!r} algo={algo!r}; have {have}") from None
    sched = builder(n, root, num_chunks=num_chunks)
    sched.validate_ranks()
    return sched
