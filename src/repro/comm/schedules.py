"""Schedule builders for the non-broadcast collectives (DESIGN.md Sec. 3).

Every builder emits a :class:`repro.core.schedules.Schedule` on the same IR
the broadcast library uses — reduce-family transfers carry ``combine=True``
and accumulate at the destination. The reduce builders are literal mirrors
of their broadcast counterparts (rounds reversed, src/dst swapped), the
allreduce builders compose reduce + broadcast phases, and the allgather /
reduce_scatter rings generalize the two phases of the power-of-two
``scatter_allgather`` broadcast (Eq. 4) to any rank count.

Data conventions (buffer is ``(num_chunks, chunk_elems)`` everywhere):

  * reduce / allreduce — every rank contributes its full buffer; on exit the
    root (reduce) or every rank (allreduce) holds the element-wise sum.
  * allgather — ``num_chunks == n``; rank r contributes row r; on exit every
    rank holds all rows.
  * reduce_scatter — ``num_chunks == n``; every rank contributes all rows;
    on exit rank r's row r holds the sum of everyone's row r.

Ragged ops view the chunk axis as a *row* axis (``Schedule.sizes``):

  * allgatherv — ``num_chunks == sum(sizes)``; rank r starts owning the row
    segment ``[off[r], off[r] + sizes[r])``; on exit every rank holds the
    full concatenation. Zero-sized ranks contribute nothing (their segment
    is never put on the wire).
  * alltoallv — rows are partitioned into n*n blocks laid out row-major by
    (src, dst); block (s, d) has ``sizes[s*n + d]`` rows at a fixed global
    offset, so a transfer reads and writes the SAME row range on both ends
    (the IR's invariant). Rank s fills blocks (s, *); on exit rank d's
    blocks (*, d) are valid. Diagonal blocks never travel.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from ..core.schedules import (
    Round,
    Schedule,
    Transfer,
    binomial_reduce,
    pipelined_chain,
    _rot,
)

__all__ = [
    "reverse_for_reduce",
    "binomial_reduce",
    "pipelined_reduce_chain",
    "reduce_then_bcast",
    "fused_rsb",
    "ring_allreduce_schedule",
    "ring_allgather",
    "doubling_allgather",
    "ring_reduce_scatter",
    "ragged_offsets",
    "alltoallv_matrix",
    "shrink_sizes",
    "ring_allgatherv",
    "doubling_allgatherv",
    "pairwise_alltoallv",
    "ring_alltoallv",
    "OP_BUILDERS",
    "RAGGED_OPS",
    "build_op",
]


def reverse_for_reduce(sched: Schedule, name: str) -> Schedule:
    """Mirror a bcast schedule into a reduce-to-root schedule: reverse the
    rounds, swap src/dst, and mark every transfer combining. The chunk-level
    pipelining (and therefore the cost model) carries over unchanged."""
    rounds = tuple(
        Round(tuple(
            Transfer(t.dst, t.src, t.chunk_start, t.chunk_count, combine=True)
            for t in r.transfers
        ))
        for r in reversed(sched.rounds)
    )
    return dataclasses.replace(sched, name=name, rounds=rounds, kind="reduce")


def pipelined_reduce_chain(n: int, root: int = 0, num_chunks: int = 8) -> Schedule:
    """Chunk-pipelined reduce-to-root: the paper's pipelined chain (Eq. 5)
    reversed — partial sums stream toward the root one chunk per hop, so the
    cost keeps Eq. 5's (M/C + n - 2)(ts + C/B) form."""
    return reverse_for_reduce(
        pipelined_chain(n, root, num_chunks), "pipelined_reduce_chain"
    )


def reduce_then_bcast(n: int, root: int, bcast_sched: Schedule) -> Schedule:
    """Two-phase allreduce with a barrier: reversed-binomial reduce-to-root
    over the whole buffer, then the tuned broadcast schedule (any algorithm,
    any chunking). The reduce rounds move the full chunk range at once."""
    num_chunks = bcast_sched.num_chunks
    red = binomial_reduce(n, root)
    red_rounds = tuple(
        Round(tuple(
            Transfer(t.src, t.dst, 0, num_chunks, combine=True)
            for t in r.transfers
        ))
        for r in red.rounds
    )
    return Schedule(
        f"reduce_then_bcast[{bcast_sched.name}]",
        n,
        root,
        num_chunks,
        red_rounds + bcast_sched.rounds,
        kind="allreduce",
    )


def fused_rsb(n: int, root: int = 0, num_chunks: int = 8) -> Schedule:
    """Fused pipelined reduce-chain + bcast-chain allreduce ("fused_rsb").

    Logical chain positions 0 (the head, at ``root``) .. n-1. Chunk c's
    partial sums hop head-ward, fully reduced at position 0 at round
    c + n - 2; the head immediately streams it back tail-ward while later
    chunks are still reducing. Round s carries, concurrently on the two
    directions of each full-duplex link:

      * reduce: edge p -> p-1 moves chunk s - (n - 1 - p)   (combine)
      * bcast:  edge p -> p+1 moves chunk s - (n - 1) - p   (overwrite)

    Total rounds: num_chunks + 2n - 3, matching t_fused_rsb in the cost
    model. A destination appears twice in a round (one reduce chunk, one
    bcast chunk) — the relaxed Round invariant allows it because the chunk
    ranges are disjoint.
    """
    if n == 1:
        return Schedule("fused_rsb", n, root, num_chunks, (), kind="allreduce")
    rounds = []
    for s in range(num_chunks + 2 * n - 3):
        transfers = []
        for p in range(1, n):  # reduce edge p -> p-1
            c = s - (n - 1 - p)
            if 0 <= c < num_chunks:
                transfers.append(
                    Transfer(_rot(p, root, n), _rot(p - 1, root, n), c, 1, combine=True)
                )
        for p in range(n - 1):  # bcast edge p -> p+1
            c = s - (n - 1) - p
            if 0 <= c < num_chunks:
                transfers.append(
                    Transfer(_rot(p, root, n), _rot(p + 1, root, n), c, 1)
                )
        if transfers:
            rounds.append(Round(tuple(transfers)))
    return Schedule("fused_rsb", n, root, num_chunks, tuple(rounds), kind="allreduce")


def ring_allreduce_schedule(n: int, root: int = 0) -> Schedule:
    """Bandwidth-optimal ring allreduce on the IR: n-1 combining
    reduce-scatter rounds, then n-1 allgather rounds (``root`` is irrelevant
    — the result is symmetric). ``num_chunks == n``; works for any n."""
    if n == 1:
        return Schedule("ring_allreduce", n, root, 1, (), kind="allreduce")
    rounds = []
    # reduce-scatter: round s, rank r sends its partial of chunk (r - s) mod n
    # to r+1; after n-1 rounds rank r owns the full sum of chunk (r+1) mod n.
    for s in range(n - 1):
        rounds.append(Round(tuple(
            Transfer(r, (r + 1) % n, (r - s) % n, 1, combine=True) for r in range(n)
        )))
    # allgather: circulate the reduced chunks.
    for s in range(n - 1):
        rounds.append(Round(tuple(
            Transfer(r, (r + 1) % n, (r + 1 - s) % n, 1) for r in range(n)
        )))
    return Schedule("ring_allreduce", n, root, n, tuple(rounds), kind="allreduce")


def ring_allgather(n: int, root: int = 0) -> Schedule:
    """Ring allgather for ANY rank count — the generalization of the
    power-of-two scatter_allgather bcast's second phase. Rank r starts
    owning row r; round s moves row (r - s) mod n over edge r -> r+1."""
    if n == 1:
        return Schedule("ring_allgather", n, root, 1, (), kind="allgather")
    rounds = tuple(
        Round(tuple(Transfer(r, (r + 1) % n, (r - s) % n, 1) for r in range(n)))
        for s in range(n - 1)
    )
    return Schedule("ring_allgather", n, root, n, rounds, kind="allgather")


def doubling_allgather(n: int, root: int = 0) -> Schedule:
    """Recursive-doubling allgather (power-of-two n): round t pairs rank r
    with r XOR 2^t and exchanges the 2^t contiguous rows each side owns —
    log2(n) startups for the same total bytes as the ring."""
    if n & (n - 1):
        raise ValueError(f"doubling_allgather requires power-of-two n, got {n}")
    if n == 1:
        return Schedule("doubling_allgather", n, root, 1, (), kind="allgather")
    rounds = []
    span = 1
    while span < n:
        transfers = []
        for r in range(n):
            base = (r // span) * span
            transfers.append(Transfer(r, r ^ span, base, span))
        rounds.append(Round(tuple(transfers)))
        span *= 2
    return Schedule("doubling_allgather", n, root, n, tuple(rounds), kind="allgather")


def ring_reduce_scatter(n: int, root: int = 0) -> Schedule:
    """Ring reduce-scatter for any n: n-1 combining rounds after which rank
    r's row r holds the element-wise sum of everyone's row r."""
    if n == 1:
        return Schedule("ring_reduce_scatter", n, root, 1, (), kind="reduce_scatter")
    rounds = tuple(
        Round(tuple(
            Transfer(r, (r + 1) % n, (r - s - 1) % n, 1, combine=True)
            for r in range(n)
        ))
        for s in range(n - 1)
    )
    return Schedule("ring_reduce_scatter", n, root, n, rounds, kind="reduce_scatter")


# ---------------------------------------------------------------------------
# Ragged collectives (allgatherv / alltoallv)
# ---------------------------------------------------------------------------


def _gatherv_sizes(n: int, sizes) -> tuple[int, ...]:
    """Validated per-rank row counts for the allgatherv builders."""
    flat = tuple(int(s) for s in sizes)
    if len(flat) != n:
        raise ValueError(f"allgatherv sizes must have n={n} entries, got {len(flat)}")
    return flat


def ragged_offsets(sizes) -> tuple[tuple[int, ...], int]:
    """Prefix offsets of a size vector: ``(off_0..off_k, total)`` with a
    sentinel ``off[k] == total`` so segment k spans ``[off[k], off[k+1])``."""
    off, acc = [], 0
    for s in sizes:
        if s < 0:
            raise ValueError(f"sizes must be non-negative: {tuple(sizes)}")
        off.append(acc)
        acc += int(s)
    off.append(acc)
    return tuple(off), acc


def alltoallv_matrix(sizes, n: int) -> tuple[tuple[int, ...], ...]:
    """Normalize an alltoallv size spec to an n x n matrix ``M[src][dst]``.

    Accepts a length-n vector (every source sends ``sizes[d]`` rows to rank
    d — the expert-dispatch case, where capacity is per destination), a flat
    length-n*n row-major vector, or a full matrix."""
    sizes = tuple(sizes)
    if len(sizes) and isinstance(sizes[0], (tuple, list)):
        m = tuple(tuple(int(v) for v in row) for row in sizes)
        if len(m) != n or any(len(row) != n for row in m):
            raise ValueError(f"alltoallv matrix must be {n}x{n}")
        return m
    if len(sizes) == n:
        row = tuple(int(v) for v in sizes)
        return tuple(row for _ in range(n))
    if len(sizes) == n * n:
        flat = tuple(int(v) for v in sizes)
        return tuple(flat[s * n:(s + 1) * n] for s in range(n))
    raise ValueError(f"alltoallv sizes must have n, n*n, or matrix shape; got {len(sizes)}")


def shrink_sizes(op: str, sizes, survivors) -> tuple[int, ...]:
    """Remap a ragged size vector onto a survivor mesh: the dead ranks'
    segments (allgatherv) or source rows AND destination columns (alltoallv)
    drop out of the global row frame. ``survivors`` lists physical ranks in
    ascending order; the result is indexed by the survivor-mesh logical
    rank. Flat tuples in, flat tuple out (alltoallv row-major)."""
    surv = tuple(int(r) for r in survivors)
    sizes = tuple(sizes)
    if op == "allgatherv":
        return tuple(int(sizes[r]) for r in surv)
    if op != "alltoallv":
        raise ValueError(f"shrink_sizes is for ragged ops, not {op!r}")
    if sizes and isinstance(sizes[0], (tuple, list)):
        n = len(sizes)
    else:
        n = int(round(len(sizes) ** 0.5))
        if n * n != len(sizes):
            raise ValueError(
                f"alltoallv sizes must be an n x n matrix or flat n*n vector, "
                f"got length {len(sizes)}"
            )
    m = alltoallv_matrix(sizes, n)
    return tuple(int(m[s][d]) for s in surv for d in surv)


def ring_allgatherv(n: int, sizes, root: int = 0) -> Schedule:
    """Ring allgatherv: round s forwards the segment that originated at rank
    (r - s) mod n over edge r -> r+1. Empty segments never enter the ring,
    so zero-sized ranks cost nothing; every round is gated by the largest
    segment in flight — under skew the ring's bandwidth advantage evaporates
    (see cost_model.t_ring_allgatherv)."""
    sizes = _gatherv_sizes(n, sizes)
    off, total = ragged_offsets(sizes)
    if n == 1 or total == 0:
        return Schedule("ring_allgatherv", n, root, total, (), kind="allgatherv",
                        sizes=tuple(int(s) for s in sizes))
    rounds = []
    for s in range(n - 1):
        transfers = []
        for r in range(n):
            seg = (r - s) % n
            if sizes[seg]:
                transfers.append(
                    Transfer(r, (r + 1) % n, off[seg], int(sizes[seg]))
                )
        if transfers:
            rounds.append(Round(tuple(transfers)))
    return Schedule("ring_allgatherv", n, root, total, tuple(rounds),
                    kind="allgatherv", sizes=tuple(int(s) for s in sizes))


def doubling_allgatherv(n: int, sizes, root: int = 0) -> Schedule:
    """Recursive-doubling allgatherv (power-of-two n): round t exchanges the
    contiguous group of 2^t segments each side has gathered so far. Ragged
    groups are still contiguous row ranges, so each exchange is ONE
    variable-height transfer — log2(n) startups regardless of skew."""
    if n & (n - 1):
        raise ValueError(f"doubling_allgatherv requires power-of-two n, got {n}")
    sizes = _gatherv_sizes(n, sizes)
    off, total = ragged_offsets(sizes)
    if n == 1 or total == 0:
        return Schedule("doubling_allgatherv", n, root, total, (), kind="allgatherv",
                        sizes=tuple(int(s) for s in sizes))
    rounds = []
    span = 1
    while span < n:
        transfers = []
        for r in range(n):
            base = (r // span) * span
            cnt = off[base + span] - off[base]
            if cnt:
                transfers.append(Transfer(r, r ^ span, off[base], cnt))
        if transfers:
            rounds.append(Round(tuple(transfers)))
        span *= 2
    return Schedule("doubling_allgatherv", n, root, total, tuple(rounds),
                    kind="allgatherv", sizes=tuple(int(s) for s in sizes))


def pairwise_alltoallv(n: int, sizes, root: int = 0) -> Schedule:
    """Pairwise-exchange alltoallv: step s (1..n-1) sends block (r, r+s)
    directly to its destination — every block crosses the wire exactly once,
    n-1 startups, each step gated by its largest block."""
    m = alltoallv_matrix(sizes, n)
    flat = tuple(v for row in m for v in row)
    off, total = ragged_offsets(flat)
    rounds = []
    for s in range(1, n):
        transfers = []
        for r in range(n):
            d = (r + s) % n
            cnt = m[r][d]
            if cnt:
                transfers.append(Transfer(r, d, off[r * n + d], cnt))
        if transfers:
            rounds.append(Round(tuple(transfers)))
    return Schedule("pairwise_alltoallv", n, root, total, tuple(rounds),
                    kind="alltoallv", sizes=flat)


def ring_alltoallv(n: int, sizes, root: int = 0) -> Schedule:
    """Store-and-forward ring alltoallv: block (s, d) hops s -> s+1 -> ... -> d.
    At round t every block still in transit is at rank (s + t) mod n, and all
    blocks leaving rank r that round share the source s = (r - t) mod n, so
    their destination set is a cyclic interval — at most two contiguous row
    ranges per edge per round. Neighbor-only traffic, but each block pays its
    hop count in wire bytes."""
    m = alltoallv_matrix(sizes, n)
    flat = tuple(v for row in m for v in row)
    off, total = ragged_offsets(flat)
    rounds = []
    for t in range(n - 1):
        transfers = []
        for r in range(n):
            s = (r - t) % n
            # destinations still ahead of this block: (d - s) mod n > t
            ds = [d for d in range(n) if (d - s) % n > t]
            if not ds:
                continue
            # split the cyclic interval into contiguous column runs
            runs, run = [], [ds[0]]
            for d in ds[1:]:
                if d == run[-1] + 1:
                    run.append(d)
                else:
                    runs.append(run)
                    run = [d]
            runs.append(run)
            for run in runs:
                lo, hi = run[0], run[-1]
                cnt = off[s * n + hi + 1] - off[s * n + lo]
                if cnt:
                    transfers.append(Transfer(r, (r + 1) % n, off[s * n + lo], cnt))
        if transfers:
            rounds.append(Round(tuple(transfers)))
    return Schedule("ring_alltoallv", n, root, total, tuple(rounds),
                    kind="alltoallv", sizes=flat)


# ---------------------------------------------------------------------------
# Registry (reduce_then_bcast is composite — built in plan.py, where the
# inner bcast decision is available)
# ---------------------------------------------------------------------------

OP_BUILDERS: dict[str, dict[str, Callable[..., Schedule]]] = {
    "reduce": {
        "binomial_reduce": lambda n, root, num_chunks=1: binomial_reduce(n, root),
        "pipelined_reduce_chain": pipelined_reduce_chain,
    },
    "allreduce": {
        "fused_rsb": fused_rsb,
        "ring_allreduce": lambda n, root, num_chunks=None: ring_allreduce_schedule(n, root),
    },
    "allgather": {
        "ring_allgather": lambda n, root, num_chunks=None: ring_allgather(n, root),
        "doubling_allgather": lambda n, root, num_chunks=None: doubling_allgather(n, root),
    },
    "reduce_scatter": {
        "ring_reduce_scatter": lambda n, root, num_chunks=None: ring_reduce_scatter(n, root),
    },
    # ragged ops take a size vector instead of num_chunks; sizes=None falls
    # back to the uniform one-row-per-rank layout (the plain op's shape)
    "allgatherv": {
        "ring_allgatherv": lambda n, root, sizes=None: ring_allgatherv(
            n, sizes if sizes is not None else (1,) * n, root),
        "doubling_allgatherv": lambda n, root, sizes=None: doubling_allgatherv(
            n, sizes if sizes is not None else (1,) * n, root),
    },
    "alltoallv": {
        "pairwise_alltoallv": lambda n, root, sizes=None: pairwise_alltoallv(
            n, sizes if sizes is not None else (1,) * n, root),
        "ring_alltoallv": lambda n, root, sizes=None: ring_alltoallv(
            n, sizes if sizes is not None else (1,) * n, root),
    },
}

RAGGED_OPS = ("allgatherv", "alltoallv")


def build_op(op: str, algo: str, n: int, root: int = 0, *, num_chunks: int = 1,
             sizes=None) -> Schedule:
    """Build + validate a non-bcast op schedule by name."""
    try:
        builder = OP_BUILDERS[op][algo]
    except KeyError:
        have = {o: sorted(a) for o, a in OP_BUILDERS.items()}
        raise KeyError(f"no builder for op={op!r} algo={algo!r}; have {have}") from None
    if op in RAGGED_OPS:
        sched = builder(n, root, sizes=sizes)
    else:
        sched = builder(n, root, num_chunks=num_chunks)
    sched.validate_ranks()
    return sched
