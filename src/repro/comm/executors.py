"""shard_map executors for the generalized (combine-aware) schedule IR.

:func:`execute_collective` replays ANY :class:`core.schedules.Schedule` —
bcast, reduce, allreduce, allgather, reduce_scatter — with one
``lax.ppermute`` per lane per round; combining transfers accumulate at the
destination. :func:`fused_rsb_fused` is the production-path fori_loop
executor for the fused allreduce chain (two ppermutes per iteration, HLO
size independent of chunk count), mirroring
``core.algorithms.pipelined_chain_fused``.

Lanes within a round are applied sequentially at trace level; builders
guarantee no same-round read-after-write at any rank (the numpy simulator
uses strict round-snapshot semantics, and the fused-vs-generic equality
tests would catch a violation).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from ..core.schedules import Schedule

__all__ = ["execute_collective", "fused_rsb_fused"]


def _per_rank(values: np.ndarray, axis_name):
    return jnp.asarray(values)[lax.axis_index(axis_name)]


def _lanes(transfers):
    """Partition a round's transfers into ppermute lanes: within one lane
    each rank is a source at most once AND a destination at most once, and
    all transfers share the combine flag. Multi-lane rounds (bidir chain,
    fused_rsb) run on disjoint full-duplex links concurrently on TPU."""
    lanes: list[list] = []
    for t in transfers:
        for lane in lanes:
            if (
                lane[0].combine == t.combine
                and all(t.src != u.src and t.dst != u.dst for u in lane)
            ):
                lane.append(t)
                break
        else:
            lanes.append([t])
    return lanes


def _execute_lane(transfers, buf, axis_name, n):
    count = transfers[0].chunk_count
    combine = transfers[0].combine
    send_start = np.zeros(n, np.int32)
    recv_start = np.zeros(n, np.int32)
    is_dst = np.zeros(n, bool)
    for t in transfers:
        send_start[t.src] = t.chunk_start
        recv_start[t.dst] = t.chunk_start
        is_dst[t.dst] = True
    perm = [(t.src, t.dst) for t in transfers]
    s0 = _per_rank(send_start, axis_name)
    operand = lax.dynamic_slice(buf, (s0, 0), (count, buf.shape[1]))
    received = lax.ppermute(operand, axis_name, perm)
    r0 = _per_rank(recv_start, axis_name)
    current = lax.dynamic_slice(buf, (r0, 0), (count, buf.shape[1]))
    on_dst = _per_rank(is_dst, axis_name)
    if combine:
        merged = current + jnp.where(on_dst, received, jnp.zeros_like(received))
    else:
        merged = jnp.where(on_dst, received, current)
    return lax.dynamic_update_slice(buf, merged, (r0, 0))


def execute_collective(schedule: Schedule, buf: jax.Array, axis_name) -> jax.Array:
    """Replay any schedule over a ``(num_chunks, chunk_elems)`` buffer."""
    assert buf.ndim == 2 and buf.shape[0] == schedule.num_chunks, (
        buf.shape,
        schedule.num_chunks,
    )
    n = schedule.n
    for rnd in schedule.rounds:
        if not rnd.transfers:
            continue
        for lane in _lanes(rnd.transfers):
            buf = _execute_lane(lane, buf, axis_name, n)
    return buf


def fused_rsb_fused(buf: jax.Array, axis_name, *, root: int = 0, unroll: int = 1) -> jax.Array:
    """Fused fori_loop executor for the fused_rsb allreduce chain.

    ``buf``: (num_chunks, chunk_elems) — every rank's local contribution on
    entry, the element-wise sum on exit at every rank. Emits exactly two
    ppermutes (reduce lane + bcast lane) inside a loop of
    ``num_chunks + 2n - 3`` rounds; equals the unrolled
    ``comm.schedules.fused_rsb`` schedule transfer-for-transfer.
    """
    n = lax.axis_size(axis_name)
    if n == 1:
        return buf
    K, chunk = buf.shape
    pos = (lax.axis_index(axis_name) - root) % n
    red_perm = [((root + p) % n, (root + p - 1) % n) for p in range(1, n)]
    bc_perm = [((root + p) % n, (root + p + 1) % n) for p in range(n - 1)]

    def body(s, b):
        # operands read the round-start buffer; the two write chunks are
        # disjoint whenever both are valid (see comm.schedules.fused_rsb)
        c_rs = jnp.clip(s - (n - 1 - pos), 0, K - 1)
        red_out = lax.dynamic_slice(b, (c_rs, 0), (1, chunk))
        c_bs = jnp.clip(s - (n - 1) - pos, 0, K - 1)
        bc_out = lax.dynamic_slice(b, (c_bs, 0), (1, chunk))
        red_in = lax.ppermute(red_out, axis_name, red_perm)
        bc_in = lax.ppermute(bc_out, axis_name, bc_perm)

        c_rin = s - (n - 2) + pos           # chunk arriving on the reduce lane
        red_valid = (pos <= n - 2) & (c_rin >= 0) & (c_rin < K)
        c_rin_c = jnp.clip(c_rin, 0, K - 1)
        cur = lax.dynamic_slice(b, (c_rin_c, 0), (1, chunk))
        merged = jnp.where(red_valid, cur + red_in, cur)
        b = lax.dynamic_update_slice(b, merged, (c_rin_c, 0))

        c_bin = s - (n - 2) - pos           # chunk arriving on the bcast lane
        bc_valid = (pos >= 1) & (c_bin >= 0) & (c_bin < K)
        c_bin_c = jnp.clip(c_bin, 0, K - 1)
        cur = lax.dynamic_slice(b, (c_bin_c, 0), (1, chunk))
        merged = jnp.where(bc_valid, bc_in, cur)
        return lax.dynamic_update_slice(b, merged, (c_bin_c, 0))

    return lax.fori_loop(0, K + 2 * n - 3, body, buf, unroll=unroll)
