"""shard_map executors for the generalized (combine-aware) schedule IR.

Three replay strategies for ANY :class:`core.schedules.Schedule` — bcast,
reduce, allreduce, allgather, reduce_scatter:

* :func:`execute_collective` — the *unrolled* (exact) executor: one
  ``lax.ppermute`` per lane per round, each round emitted into HLO. Sends
  exactly the schedule's transfers, but program size grows as
  O(num_chunks x rounds).
* :func:`execute_compiled` — the *compiled* executor: replays the host-side
  lowering (``core.schedules.lower_schedule`` — dense per-round index
  tables + one static permutation per lane class) with ONE ``lax.fori_loop``
  over rounds. HLO size is O(num_lane_classes), independent of chunk count
  and round count; the round's merge runs through the fused Pallas
  combine-update kernel (:mod:`repro.kernels.combine_update`) in one VMEM
  pass. Inactive (fill/drain) rounds of a class carry masked garbage blocks,
  exactly like the old hand-written fori_loop executors
  (``pipelined_chain_fused`` / the deleted ``fused_rsb_fused``) — which are
  special cases of this generic path.
* :func:`execute_inkernel` — the *in-kernel* executor: the whole schedule
  replays inside ONE persistent Pallas launch
  (:mod:`repro.kernels.inkernel_collective`); the kernel itself moves each
  round's block (TPU async remote copy; shared-buffer emulation under
  interpret) and merges in the same VMEM pass. HLO size is O(1) in rounds
  AND classes, and the per-round launch boundary disappears.

Lanes within a round are applied sequentially at trace level; builders
guarantee no same-round read-after-write at any rank (the numpy simulator
uses strict round-snapshot semantics, and the compiled-vs-unrolled equality
tests would catch a violation). The lane partition itself is hoisted into
the host-side lowering — computed once per schedule, never at trace time.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from ..core.schedules import LoweredSchedule, Schedule, lower_schedule
from ..kernels.combine_update import fused_combine_update
from ..kernels.inkernel_collective import inkernel_replay

__all__ = ["execute_collective", "execute_compiled", "execute_inkernel"]


def _per_rank(values, axis_name):
    return jnp.asarray(values)[lax.axis_index(axis_name)]


def _wire_permute(block, axis_name, perm, wire):
    """Ship ``block`` across one ppermute hop under the plan's wire format:
    passthrough (``wire is None``) permutes the full-precision block;
    compressed formats quantize, permute the payload and the per-block
    scales as two permutes of the SAME pattern, and dequantize back to the
    buffer dtype on the receiving side — so the combine arithmetic that
    follows always runs in full precision."""
    if wire is None:
        return lax.ppermute(block, axis_name, perm)
    values, scales = wire.compress(block.astype(jnp.float32))
    values = lax.ppermute(values, axis_name, perm)
    scales = lax.ppermute(scales, axis_name, perm)
    return wire.decompress(values, scales, out_cols=block.shape[1],
                           dtype=block.dtype)


def _execute_lane(transfers, buf, axis_name, n, wire=None):
    count = transfers[0].chunk_count
    combine = transfers[0].combine
    send_start = np.zeros(n, np.int32)
    recv_start = np.zeros(n, np.int32)
    is_dst = np.zeros(n, bool)
    for t in transfers:
        send_start[t.src] = t.chunk_start
        recv_start[t.dst] = t.chunk_start
        is_dst[t.dst] = True
    perm = [(t.src, t.dst) for t in transfers]
    s0 = _per_rank(send_start, axis_name)
    operand = lax.dynamic_slice(buf, (s0, 0), (count, buf.shape[1]))
    received = _wire_permute(operand, axis_name, perm, wire)
    r0 = _per_rank(recv_start, axis_name)
    current = lax.dynamic_slice(buf, (r0, 0), (count, buf.shape[1]))
    on_dst = _per_rank(is_dst, axis_name)
    if combine:
        # where(on_dst, cur + recv, cur) — the same masked-row form as the
        # compiled executor's fused kernel, so the two are bit-identical
        merged = jnp.where(on_dst, current + received, current)
    else:
        merged = jnp.where(on_dst, received, current)
    return lax.dynamic_update_slice(buf, merged, (r0, 0))


def execute_collective(schedule: Schedule, buf: jax.Array, axis_name, *,
                       wire=None) -> jax.Array:
    """Replay any schedule over a ``(num_chunks, chunk_elems)`` buffer,
    round by round (unrolled HLO). The lane partition comes from the cached
    host-side lowering — once per schedule, not once per trace. ``wire``
    (a :class:`repro.comm.compress.CompressedWire`) compresses every hop at
    the ppermute seam; ``None`` is the bit-identical passthrough."""
    assert buf.ndim == 2 and buf.shape[0] == schedule.num_chunks, (
        buf.shape,
        schedule.num_chunks,
    )
    n = schedule.n
    for lanes in lower_schedule(schedule).round_lanes:
        for lane in lanes:
            buf = _execute_lane(lane, buf, axis_name, n, wire)
    return buf


def execute_compiled(
    schedule: Schedule | LoweredSchedule,
    buf: jax.Array,
    axis_name,
    *,
    unroll: int = 1,
    interpret: bool | None = None,
    wire=None,
) -> jax.Array:
    """Compiled replay: one ``lax.fori_loop`` over rounds, one ppermute +
    one fused Pallas combine-update per lane class per iteration. ``wire``
    compresses every class's hop at the ppermute seam (fill/drain rounds
    quantize masked garbage blocks, which is harmless — the fused kernel's
    row mode keeps those rows).

    ``buf``: (num_chunks, chunk_elems). The per-round index tables ride
    along as small int32 constants indexed ``[round, rank]`` inside the
    loop, so HLO size does not depend on ``num_chunks`` or the round count.
    Donation contract: callers jit with the buffer donated
    (``jax.jit(..., donate_argnums)``) — the loop carry plus the kernel's
    ``input_output_aliases`` then update the buffer in place, so no round
    materializes a second full copy.

    shard_map note: the fused Pallas kernel has no replication rule on
    jax 0.4.x, so the surrounding ``shard_map`` must pass
    ``check_vma=False`` — the same requirement the ``chunked_copy`` staging
    paths already impose; every in-repo consumer does.
    """
    lowered = (
        schedule if isinstance(schedule, LoweredSchedule) else lower_schedule(schedule)
    )
    assert buf.ndim == 2 and buf.shape[0] == lowered.num_chunks, (
        buf.shape,
        lowered.num_chunks,
    )
    if lowered.num_rounds == 0:
        return buf
    chunk = buf.shape[1]
    rank = lax.axis_index(axis_name)
    tables = [
        (
            cls,
            jnp.asarray(cls.send_start),
            jnp.asarray(cls.recv_start),
            jnp.asarray(cls.lo),
            jnp.asarray(cls.hi),
            jnp.asarray(cls.combine),
        )
        for cls in lowered.classes
    ]

    def body(s, b):
        for cls, send, recv, lo, hi, combine in tables:
            block = lax.dynamic_slice(b, (send[s, rank], 0), (cls.block, chunk))
            received = _wire_permute(block, axis_name, cls.perm, wire)
            b = fused_combine_update(
                b,
                received,
                recv[s, rank],
                lo[s, rank],
                hi[s, rank],
                combine=combine[s],
                interpret=interpret,
            )
        return b

    return lax.fori_loop(0, lowered.num_rounds, body, buf, unroll=unroll)


def execute_inkernel(
    schedule: Schedule | LoweredSchedule,
    buf: jax.Array,
    axis_name,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """In-kernel replay: ONE persistent Pallas launch for the whole schedule.

    Same calling convention and bit-identity contract as the other two
    executors (``buf``: (num_chunks, chunk_elems), inside ``shard_map`` with
    ``check_vma=False``). On TPU the kernel issues the round transfers itself
    via async remote copy; off-TPU the mesh is emulated through an
    ``all_gather``-assembled shared buffer and the identical kernel control
    flow runs under the Pallas interpreter.
    """
    lowered = (
        schedule if isinstance(schedule, LoweredSchedule) else lower_schedule(schedule)
    )
    assert buf.ndim == 2 and buf.shape[0] == lowered.num_chunks, (
        buf.shape,
        lowered.num_chunks,
    )
    if lowered.num_rounds == 0:
        return buf
    return inkernel_replay(lowered, buf, axis_name, interpret=interpret)
