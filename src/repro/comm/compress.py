"""Wire formats for compressed collectives.

A :class:`WireFormat` is the per-plan choice of what bytes actually cross
the link for each chunk transfer:

* ``bf16`` — passthrough. The buffer ships unmodified (named for the
  canonical training dtype; any dtype passes through bit-identically).
  This is the default and preserves the repo-wide contract that every
  executor path is bit-identical to the unrolled oracle.
* ``int8`` — symmetric per-block abs-max quantization to int8, one f32
  scale per 256-element block.
* ``fp8`` — same blocking to ``float8_e4m3fn`` (saturation range ±448).

Compression is applied PER HOP at the executor's ``ppermute`` seam: the
sender quantizes the outgoing block, the values and per-block scales cross
the wire as two permutes, and the receiver dequantizes before the local
combine — so arithmetic (reduce combines, root writes) always happens in
full precision and only the wire payload is low-precision. Per-hop
quantization error is what the trainer's error-feedback residual
(:class:`CompressionState`) compensates across steps.

Wire-byte accounting is physical: :func:`wire_chunk_bytes` counts the
block-padded payload plus the scale sidecar, so
``CollectivePlan.wire_bytes()`` and ``expected_wire_bytes`` describe the
bytes a transport would actually move, and the compress-table gate can
demand exact equality against measured transfers.
"""
from __future__ import annotations

import dataclasses
import enum

import jax
import jax.numpy as jnp

from ..kernels.quantize import BLOCK_ELEMS

__all__ = [
    "WireFormat",
    "normalize_wire_format",
    "wire_chunk_bytes",
    "CompressedWire",
    "CompressionState",
    "roundtrip",
]

# one f32 scale per BLOCK_ELEMS single-byte payload elements
_SCALE_BYTES = 4
_BLOCK_WIRE_BYTES = BLOCK_ELEMS + _SCALE_BYTES  # 260


class WireFormat(str, enum.Enum):
    """What a chunk looks like on the wire."""

    BF16 = "bf16"   # passthrough, bit-identical
    FP8 = "fp8"     # float8_e4m3fn payload + f32 block scales
    INT8 = "int8"   # int8 payload + f32 block scales

    @property
    def compressed(self) -> bool:
        return self is not WireFormat.BF16

    @property
    def nominal_ratio(self) -> float:
        """Declared payload reduction vs the f32 wire domain (the scale
        sidecar and block padding make the physical ratio slightly lower —
        4 * 256 / 260 ≈ 3.94 for a block-aligned chunk)."""
        return 4.0 if self.compressed else 1.0


def normalize_wire_format(fmt) -> WireFormat:
    """``None`` / strings / enum members -> :class:`WireFormat`."""
    if fmt is None:
        return WireFormat.BF16
    try:
        return WireFormat(fmt)
    except ValueError:
        raise ValueError(
            f"unknown wire format {fmt!r}; expected one of "
            f"{[f.value for f in WireFormat]}"
        ) from None


def wire_chunk_bytes(fmt, chunk_bytes: int) -> int:
    """Physical bytes on the wire for one transfer of a ``chunk_bytes``
    full-precision chunk under ``fmt``.

    Compressed formats operate on the f32 wire domain (entry points cast to
    f32 before chunking, so ``chunk_bytes`` is ``4 * elems`` exactly): the
    payload is one byte per element zero-padded to the 256-element scale
    block, plus one f32 scale per block — ``260 * ceil(elems / 256)``. The
    padding is counted because it is genuinely transferred (the kernels
    quantize whole blocks). ``bf16`` passthrough ships ``chunk_bytes``
    unchanged.
    """
    fmt = normalize_wire_format(fmt)
    if chunk_bytes <= 0:
        return 0
    if not fmt.compressed:
        return int(chunk_bytes)
    elems = -(-int(chunk_bytes) // 4)
    blocks = -(-elems // BLOCK_ELEMS)
    return blocks * _BLOCK_WIRE_BYTES


@dataclasses.dataclass(frozen=True)
class CompressedWire:
    """Executor hook: compress/decompress one (rows, cols) f32 block at the
    ``ppermute`` seam. ``compress`` returns the wire arrays (payload,
    scales); ``decompress`` inverts them back to the buffer dtype. Both are
    trace-safe (called inside jit/shard_map)."""

    fmt: WireFormat
    interpret: bool | None = None

    def compress(self, block: jax.Array) -> tuple[jax.Array, jax.Array]:
        from ..kernels.ops import quantize_blocks

        return quantize_blocks(block, self.fmt.value, interpret=self.interpret)

    def decompress(self, values: jax.Array, scales: jax.Array, *,
                   out_cols: int, dtype) -> jax.Array:
        from ..kernels.ops import dequantize_blocks

        out = dequantize_blocks(values, scales, out_cols=out_cols,
                                interpret=self.interpret)
        return out.astype(dtype)


def roundtrip(x: jax.Array, fmt, *, interpret: bool | None = None) -> jax.Array:
    """One local quantize->dequantize hop of ``x`` (any shape) under
    ``fmt`` — the error-feedback residual's model of what one wire hop
    loses. ``bf16`` is the identity."""
    fmt = normalize_wire_format(fmt)
    if not fmt.compressed or x.size == 0:
        return x
    from ..kernels.ops import dequantize_blocks, quantize_blocks

    flat = x.reshape(1, -1).astype(jnp.float32)
    v, s = quantize_blocks(flat, fmt.value, interpret=interpret)
    out = dequantize_blocks(v, s, out_cols=flat.shape[1], interpret=interpret)
    return out.reshape(x.shape).astype(x.dtype)


class CompressionState:
    """Error-feedback residual helpers for compressed gradient sync.

    The residual tree ``e`` lives in the optimizer state (under ``"ef"``)
    so it is donated/checkpointed with the rest of training state. Each
    step the trainer sends the compensated gradient ``c = g + e`` through
    the compressed collective and carries forward what one quantization
    hop lost: ``e' = c - roundtrip(c)``. With relative quantization error
    ``δ`` per hop the residual stays bounded (``|e| <= δ|g| / (1 - δ)``),
    which is what keeps the compressed loss trajectory within tolerance of
    the full-precision baseline.
    """

    @staticmethod
    def init(params) -> dict:
        """Zero residuals shaped like ``params`` (f32)."""
        return jax.tree.map(
            lambda p: jnp.zeros(jnp.shape(p), jnp.float32), params
        )

    @staticmethod
    def compensate(grads, residual):
        """``c = g + e`` in f32 — the gradient actually synced."""
        return jax.tree.map(
            lambda g, e: g.astype(jnp.float32) + e, grads, residual
        )

    @staticmethod
    def update(compensated, fmt, *, interpret: bool | None = None):
        """``e' = c - roundtrip(c)``: the local single-hop quantization
        error carried into the next step."""
        fmt = normalize_wire_format(fmt)
        if not fmt.compressed:
            return jax.tree.map(jnp.zeros_like, compensated)
        return jax.tree.map(
            lambda c: c - roundtrip(c, fmt, interpret=interpret), compensated
        )
