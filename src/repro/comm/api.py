"""Collective entry points (callable inside ``jax.shard_map``).

Every function resolves a :class:`CollectivePlan` at trace time (tuned
decision + schedule) and executes it with the generalized executor — the
per-op analogue of how ``MPI_Bcast``/``MPI_Allreduce`` route through
MVAPICH2-GDR's tuned tables. ``*_tree`` variants communicate whole pytrees
through same-dtype buckets (``core.bucketing``), optionally staging each
packed bucket through the :func:`repro.kernels.chunked_copy` Pallas pipeline
(the paper's pipelined-copy primitive, Sec. IV-C).
"""
from __future__ import annotations

import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

import functools

from ..core import algorithms, bucketing
from ..core.tuner import Tuner
from .compress import CompressedWire, normalize_wire_format
from .executors import execute_collective, execute_compiled, execute_inkernel
from .plan import ONE_SHOT, CollectivePlan, plan_cached
from .schedules import alltoallv_matrix

__all__ = [
    "apply_plan",
    "apply_plan_resilient",
    "pbcast",
    "pbcast_tree",
    "preduce",
    "pallreduce",
    "pallgather",
    "pallgatherv",
    "palltoallv",
    "preduce_scatter",
    "pallreduce_tree",
    "hierarchical_allreduce_axes",
]

# unrolled-executor round budget before the auto policy switches to the
# compiled fori_loop replay (HLO size; core.algorithms.schedule_bcast
# applies the same policy). Zero-waste lowerings (the ring family,
# ring_allreduce included — per-round combine flags let both its phases
# share one fully-active class) switch much earlier: compiled then
# strictly dominates on both HLO size and wire bytes, so only the very
# smallest rings stay on the exact unrolled replay.
_MAX_UNROLLED_ROUNDS = 256
_MIN_COMPILED_ROUNDS_ZERO_WASTE = 8


def _use_compiled(plan: CollectivePlan, *, fused: bool, compiled: bool | None) -> bool:
    """Executor routing: an explicit ``compiled`` wins; then a tuned
    ``Decision.fused_path`` flag; then the round-count/zero-waste policy.
    ``fused=False`` forces the exact unrolled replay (the parity baseline).
    """
    if compiled is not None:
        return compiled
    if not fused:
        return False
    if plan.decision.fused_path is not None:
        return plan.decision.fused_path
    lowered = plan.lowered()
    if lowered is None or lowered.num_rounds == 0:
        return False
    if lowered.zero_waste:
        return lowered.num_rounds >= _MIN_COMPILED_ROUNDS_ZERO_WASTE
    return lowered.num_rounds > _MAX_UNROLLED_ROUNDS


_EXECUTORS = {
    "inkernel": execute_inkernel,
    "compiled": execute_compiled,
    "unrolled": execute_collective,
}


def _resolve_exec_path(
    plan: CollectivePlan,
    *,
    fused: bool = True,
    compiled: bool | None = None,
    inkernel: bool | None = None,
) -> str:
    """Three-tier executor routing: an explicit ``inkernel=`` flag wins;
    then a tuned ``Decision.exec_path``; then the compiled/unrolled policy
    (:func:`_use_compiled` — which itself honors an explicit ``compiled=``
    and ``Decision.fused_path``). Returns 'inkernel'|'compiled'|'unrolled'.

    The auto policy never picks inkernel on its own: the in-kernel executor
    enters only through the explicit flag or a tuned table entry.
    ``inkernel=False`` vetoes a tuned 'inkernel' without disturbing a tuned
    'compiled'/'unrolled'; an explicit ``compiled=`` bypasses the tuned tier
    entirely (it is a stronger, caller-level pin).

    Compressed wire formats veto the in-kernel path: the persistent kernel
    moves raw buffer blocks and has no quantize seam, so an explicit
    ``inkernel=True`` on a compressed plan raises, and a tuned 'inkernel'
    entry silently falls through to the compiled/unrolled policy (a stale
    table row must not disable compression).
    """
    compressed = plan.wire_format.compressed
    if inkernel:
        if compressed:
            raise ValueError(
                "the in-kernel executor does not support compressed wire "
                f"formats (plan wire_format={plan.wire_format.value!r}); "
                "use the compiled or unrolled executor"
            )
        return "inkernel"
    if compiled is None and fused:
        tuned = plan.decision.exec_path
        if tuned == "inkernel" and inkernel is None and not compressed:
            return "inkernel"
        if tuned in ("compiled", "unrolled"):
            return tuned
    return "compiled" if _use_compiled(plan, fused=fused, compiled=compiled) else "unrolled"


def _flat(x: jax.Array):
    flat = jnp.ravel(x)
    return flat, flat.size * flat.dtype.itemsize


# Reduce-family combiners the comm layer understands. The schedule executors
# (execute_collective / execute_compiled) implement SUM only; max/min route to
# the XLA one-shot collectives. Identity elements justify the pad tail a
# non-divisible buffer grows before chunking: a pad lane must never perturb
# the combined value (zeros are only sound for sum — the original bug).
_COMBINERS = ("sum", "max", "min")
_ONE_SHOT_REDUCERS = {"max": lax.pmax, "min": lax.pmin}


def _check_combiner(combiner: str, op: str) -> None:
    if combiner not in _COMBINERS:
        raise ValueError(f"unknown combiner {combiner!r} for {op}; have {_COMBINERS}")


def _chunked(flat: jax.Array, k: int, *, combiner: str | None = None):
    """Pad + reshape a flat buffer to (k, ceil(size/k)). ``k`` is honored
    even when it exceeds the element count (tiny buffers pad up), because
    the schedule's chunk count is load-bearing for the executor.

    ``combiner`` declares the reduce-family combine the schedule will apply
    to this buffer (``None`` for overwrite-only ops like bcast/allgather).
    Zero padding is the identity for SUM only; any other combiner must have
    been routed off the schedule path before the buffer grows a pad tail —
    this guard is what keeps a future combiner from silently corrupting the
    last chunk."""
    k = max(1, k)
    chunk_elems = max(1, -(-flat.size // k))
    pad = k * chunk_elems - flat.size
    if pad:
        if combiner is not None and combiner != "sum":
            raise ValueError(
                f"zero pad is only the identity for the 'sum' combiner, got "
                f"{combiner!r} — route non-sum reduces through the XLA "
                "one-shot collectives (pmax/pmin)"
            )
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(k, chunk_elems), pad


def _unchunked(buf: jax.Array, pad: int, shape, dtype):
    out = buf.reshape(-1)
    if pad:
        out = out[: out.size - pad]
    return out.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# ragged layout tables (host-side numpy, lifted to traced constants)
#
# The ragged schedules move rows of one global (total_rows, elems) buffer
# whose layout is fixed by the size vector: allgatherv concatenates the
# per-rank segments in rank order; alltoallv lays the n^2 blocks out
# row-major by (src, dst). The SPMD entry points scatter each rank's local
# shard into that global frame, replay the schedule, and gather the rank's
# result back out — all index arithmetic happens here on the host, so the
# traced program only sees constant gather tables and one `where` mask.
# ---------------------------------------------------------------------------


def _gatherv_tables(sizes, n: int):
    """allgatherv scatter layout: global row ``g`` is owned by rank
    ``src_of[g]`` and lives at row ``loc[g]`` of that rank's local shard."""
    sz = np.asarray(sizes, dtype=np.int64)
    off = np.concatenate([[0], np.cumsum(sz)])
    src_of = np.repeat(np.arange(n, dtype=np.int64), sz)
    loc = np.arange(int(off[-1]), dtype=np.int64) - off[src_of]
    return src_of, loc


def _a2av_tables(m: np.ndarray, n: int, *, in_padded: bool, out_padded: bool,
                 in_rows: int):
    """alltoallv scatter/gather layout for block matrix ``m`` (rows rank s
    sends to rank d). Returns host arrays:

    - ``src_of[g]``/``loc[g]``: global row ``g`` (row-major (s, d) blocks)
      is owned by rank ``src_of[g]`` at local row ``loc[g]``. For compact
      inputs ``loc`` indexes the destination-major concatenation; for padded
      inputs it indexes the flattened ``(n, in_rows)`` block layout.
    - ``gidx``/``gvalid``: per-rank output gather table. Row ``i`` of rank
      r's output is global row ``gidx[r, i]`` where ``gvalid[r, i]``, zero
      elsewhere. Compact outputs are the source-major concatenation (width
      ``max_r recv_r``); padded outputs are ``(n, bmax)`` blocks with each
      incoming block at a valid prefix (``bmax = m.max()``).
    """
    total = int(m.sum())
    boff = np.concatenate([[0], np.cumsum(m.reshape(-1))])
    bmax = int(m.max())
    recv = m.sum(axis=0)
    in_off = np.concatenate(
        [np.zeros((n, 1), np.int64), np.cumsum(m, axis=1)], axis=1
    )
    src_of = np.repeat(np.arange(n * n, dtype=np.int64) // n, m.reshape(-1))
    loc = np.zeros(total, dtype=np.int64)
    for s in range(n):
        for d in range(n):
            b = s * n + d
            j = np.arange(int(m[s, d]), dtype=np.int64)
            loc[boff[b]:boff[b + 1]] = (d * in_rows + j) if in_padded else (in_off[s, d] + j)
    out_rows = n * bmax if out_padded else max(int(recv.max()), 1)
    gidx = np.zeros((n, out_rows), dtype=np.int64)
    gvalid = np.zeros((n, out_rows), dtype=bool)
    for r in range(n):
        pos = 0
        for s in range(n):
            b = s * n + r
            h = int(m[s, r])
            lo = s * bmax if out_padded else pos
            gidx[r, lo:lo + h] = np.arange(boff[b], boff[b] + h)
            gvalid[r, lo:lo + h] = True
            pos += h
    return src_of, loc, gidx, gvalid, bmax


def _ragged_scatter(x2d: jax.Array, src_of, loc, axis_name) -> jax.Array:
    """Build the global (total_rows, elems) buffer: this rank's rows in
    place, zeros elsewhere (the executors' pre-condition for ragged ops)."""
    rank = lax.axis_index(axis_name)
    owned = jnp.asarray(src_of)[:, None] == rank
    return jnp.where(owned, x2d[jnp.asarray(loc)], jnp.zeros((), x2d.dtype))


def _run_allgatherv(plan: CollectivePlan, x: jax.Array, axis_name, run):
    sz = plan.sizes
    total = sum(sz)
    x2d = jnp.reshape(x, (x.shape[0], -1))
    src_of, loc = _gatherv_tables(sz, plan.n)
    out = run(plan.schedule, _ragged_scatter(x2d, src_of, loc, axis_name), axis_name)
    return out.reshape((total,) + x.shape[1:])


def _run_alltoallv(plan: CollectivePlan, x: jax.Array, axis_name, run, *,
                   in_padded: bool, out_padded: bool):
    n = plan.n
    m = np.asarray(plan.sizes, dtype=np.int64).reshape(n, n)
    elem = x.shape[2:] if in_padded else x.shape[1:]
    if in_padded and x.shape[0] != n:
        raise ValueError(f"in_padded alltoallv expects a (n={n}, bmax, ...) "
                         f"block layout, got leading dim {x.shape[0]}")
    in_rows = x.shape[1] if in_padded else x.shape[0]
    src_of, loc, gidx, gvalid, bmax = _a2av_tables(
        m, n, in_padded=in_padded, out_padded=out_padded, in_rows=int(in_rows))
    need = bmax if in_padded else int(m.sum(axis=1).max())
    if in_rows < need:
        raise ValueError(
            f"alltoallv input has {in_rows} rows per "
            f"{'block' if in_padded else 'rank'}, size matrix needs {need}")
    x2d = jnp.reshape(x, (-1, math.prod(elem) if elem else 1))
    out = run(plan.schedule, _ragged_scatter(x2d, src_of, loc, axis_name), axis_name)
    rank = lax.axis_index(axis_name)
    idx = jnp.asarray(gidx)[rank]
    valid = jnp.asarray(gvalid)[rank]
    picked = jnp.where(valid[:, None], out[idx], jnp.zeros((), out.dtype))
    if out_padded:
        return picked.reshape((n, bmax) + elem)
    return picked.reshape((picked.shape[0],) + elem)


# ---------------------------------------------------------------------------
# plan execution (consumers that pre-build CollectivePlans host-side —
# serving weight distribution, hillclimb — replay them here verbatim)
# ---------------------------------------------------------------------------


def apply_plan(
    plan: CollectivePlan,
    x: jax.Array,
    axis_name,
    *,
    fused: bool = True,
    compiled: bool | None = None,
    inkernel: bool | None = None,
) -> jax.Array:
    """Execute a pre-built :class:`CollectivePlan` on ``x`` inside
    ``shard_map`` — exactly the schedule the plan carries, no re-deciding.

    bcast/reduce/allreduce take and return the full buffer; allgather takes
    the per-rank shard and returns the ``(n, *shard)`` stack; reduce_scatter
    takes the full buffer and returns the rank's flat shard. The ragged ops
    use the compact conventions: allgatherv takes the valid-prefix row shard
    and returns the ``(sum(sizes), ...)`` concatenation; alltoallv takes the
    destination-major compact rows and returns the source-major compact rows
    (use :func:`palltoallv` for the padded block layouts).

    Executor routing (see :func:`_resolve_exec_path`): ``inkernel=True``
    forces the single-launch persistent-kernel replay (``execute_inkernel``),
    ``inkernel=False`` vetoes a tuned inkernel pin; otherwise
    ``compiled=True`` forces the fori_loop compiled replay
    (``execute_compiled`` — O(1) HLO in chunk count), ``compiled=False`` the
    exact unrolled replay, ``None`` the tuned (``Decision.exec_path`` /
    ``fused_path``) / round-count policy. Donation contract: consumers jit
    the surrounding
    program with the communicated buffers donated
    (``jax.jit(..., donate_argnums)``) so the compiled replay's loop carry
    and the fused kernel's aliasing update the buffer in place.
    """
    if plan.algo == "noop":
        if plan.op in ("allgatherv", "alltoallv"):
            # n == 1: the rank's valid prefix IS the result (alltoallv's
            # 1x1 block matrix degenerates to the same slice)
            return x[: plan.sizes[0]]
        return x if plan.op != "allgather" else x[None]
    if plan.algo == "xla_psum":
        if plan.op == "bcast":
            return algorithms.xla_psum_bcast(x, axis_name, root=plan.root)
        return lax.psum(x, axis_name)
    if plan.algo == "xla_allgather":
        if plan.op == "bcast":
            return algorithms.xla_allgather_bcast(x, axis_name, root=plan.root)
        return lax.all_gather(x, axis_name, axis=0)
    sched = plan.schedule
    path = _resolve_exec_path(plan, fused=fused, compiled=compiled, inkernel=inkernel)
    run = _EXECUTORS[path]
    out_dtype = x.dtype
    if plan.wire_format.compressed:
        # the inkernel path is vetoed above; both remaining executors take
        # the wire seam. The communicated buffer is cast to f32 so the wire
        # accounting (4 bytes/elem full precision vs 1 byte + amortized
        # scale compressed) matches what actually crosses each hop; the
        # result comes back in the caller's dtype.
        run = functools.partial(run, wire=CompressedWire(plan.wire_format))
        x = x.astype(jnp.float32)
    if plan.op == "allgatherv":
        return _run_allgatherv(plan, x, axis_name, run).astype(out_dtype)
    if plan.op == "alltoallv":
        return _run_alltoallv(plan, x, axis_name, run,
                              in_padded=False, out_padded=False).astype(out_dtype)
    if plan.op == "allgather":
        flat = jnp.ravel(x)
        buf = jnp.zeros((plan.n, flat.size), flat.dtype)
        buf = lax.dynamic_update_slice(buf, flat[None], (lax.axis_index(axis_name), 0))
        out = run(sched, buf, axis_name)
        return out.reshape((plan.n,) + x.shape).astype(out_dtype)
    if plan.op == "reduce_scatter":
        buf, _pad = _chunked(jnp.ravel(x), plan.n, combiner="sum")
        out = run(sched, buf, axis_name)
        return lax.dynamic_slice(
            out, (lax.axis_index(axis_name), 0), (1, buf.shape[1])
        )[0].astype(out_dtype)
    flat, _M = _flat(x)
    combiner = "sum" if plan.op in ("reduce", "allreduce") else None
    buf, pad = _chunked(flat, sched.num_chunks, combiner=combiner)
    out = run(sched, buf, axis_name)
    return _unchunked(out, pad, x.shape, out_dtype)


def _one_shot_fallback(plan: CollectivePlan, x: jax.Array, axis_name) -> jax.Array:
    """Terminal fallback stage: implement the plan's op with a single native
    XLA collective, bypassing the schedule executors entirely. Output
    shape/dtype contracts match :func:`apply_plan`. The ragged ops have no
    native one-shot (variable per-rank shapes) — they raise, and the chain
    reports them as exhausted."""
    op = plan.op
    if op == "bcast":
        return algorithms.xla_psum_bcast(x, axis_name, root=plan.root)
    if op in ("reduce", "allreduce"):
        return lax.psum(x, axis_name)
    if op == "allgather":
        return lax.all_gather(x, axis_name, axis=0)
    if op == "reduce_scatter":
        buf, _pad = _chunked(lax.psum(jnp.ravel(x), axis_name), plan.n, combiner="sum")
        return lax.dynamic_slice(buf, (lax.axis_index(axis_name), 0), (1, buf.shape[1]))[0]
    raise RuntimeError(f"no XLA one-shot collective implements ragged op {op!r}")


def apply_plan_resilient(
    plan: CollectivePlan,
    x: jax.Array,
    axis_name,
    *,
    policy=None,
    watchdog=None,
    fused: bool = True,
    on_event=None,
) -> jax.Array:
    """:func:`apply_plan` behind a typed fallback chain.

    Walks ``policy.chain`` (default inkernel -> compiled -> unrolled -> XLA
    one-shot) with per-stage retries and exponential backoff; the first stage that
    completes wins. Typed :class:`~.faults.FaultError`\\ s propagate
    immediately (they are diagnoses with recovery actions, not transient
    failures); any other exception burns a retry and then degrades the
    chain. A completed attempt slower than ``policy.timeout_s`` still
    returns its result but is flagged as a straggler — to the optional
    ``watchdog`` (which can land it in ``Tuner.record``) and the optional
    ``on_event`` callback. All stages failing raises
    :class:`~.faults.FallbackExhaustedError` naming every cause.

    Note: the timings observed here wrap trace + dispatch of the collective
    from the host's perspective, which is what a host-side watchdog can see;
    device-accurate straggler attribution comes from the benchmark harness
    feeding :meth:`Watchdog.observe` with measured times.
    """
    import time as _time

    from .faults import FallbackExhaustedError, FaultError
    from .resilience import FallbackEvent, FallbackPolicy

    policy = policy or FallbackPolicy()
    causes: list[str] = []
    for stage in policy.chain:
        delay = policy.backoff_s
        for attempt in range(policy.max_retries + 1):
            t0 = _time.perf_counter()
            try:
                if stage == "xla":
                    out = _one_shot_fallback(plan, x, axis_name)
                else:
                    # pin the executor to exactly this stage: inkernel=True
                    # for the head, inkernel=False + explicit compiled flag
                    # below it (a tuned exec_path must not re-route a
                    # degraded stage back onto the executor that just failed)
                    out = apply_plan(
                        plan, x, axis_name, fused=fused,
                        compiled=(None if stage == "inkernel"
                                  else stage == "compiled"),
                        inkernel=(stage == "inkernel"),
                    )
            except FaultError:
                raise
            except Exception as e:  # noqa: BLE001 — the chain is the handler
                dt = _time.perf_counter() - t0
                causes.append(f"{stage}[{attempt}]: {type(e).__name__}: {e}")
                if on_event is not None:
                    on_event(FallbackEvent(stage, attempt, "error", dt, repr(e)))
                if attempt < policy.max_retries:
                    _time.sleep(delay)
                    delay *= policy.backoff_mult
                continue
            dt = _time.perf_counter() - t0
            straggled = policy.timeout_s is not None and dt > policy.timeout_s
            if on_event is not None:
                on_event(FallbackEvent(stage, attempt, "straggler" if straggled else "ok", dt))
            if watchdog is not None:
                watchdog.observe(plan, dt)
            return out
    raise FallbackExhaustedError(
        f"every fallback stage failed for {plan.op}/{plan.algo} "
        f"(M={plan.M}, n={plan.n}): " + "; ".join(causes)
    )


# ---------------------------------------------------------------------------
# bcast / reduce (the paper's ops, now plan-driven)
# ---------------------------------------------------------------------------


def pbcast(
    x: jax.Array,
    axis_name,
    *,
    root: int = 0,
    algo: str = "auto",
    num_chunks: int | None = None,
    tuner: Tuner | None = None,
    inter_pod: bool = False,
    fused: bool = True,
    compiled: bool | None = None,
    inkernel: bool | None = None,
    wire_format: str | None = None,
) -> jax.Array:
    """Broadcast ``x`` from ``root`` over the named mesh axis (must be called
    inside ``shard_map``; every rank passes a same-shape buffer and receives
    the root's).

    ``wire_format`` ('bf16'|'fp8'|'int8', default full-precision passthrough)
    compresses every hop at the ppermute seam; compressed payloads travel in
    the f32 wire domain (``M`` counts 4 bytes/element before compression) and
    the result comes back in ``x``'s dtype.
    """
    x = jnp.asarray(x)
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    fmt = normalize_wire_format(wire_format)
    if algo in ("xla_psum", "xla_allgather"):
        if fmt.compressed:
            raise ValueError(
                f"wire_format={fmt.value!r} requires a schedule-backed algo; "
                f"the one-shot {algo!r} has no compression seam"
            )
        if algo == "xla_psum":
            return algorithms.xla_psum_bcast(x, axis_name, root=root)
        return algorithms.xla_allgather_bcast(x, axis_name, root=root)
    _flat_x, M = _flat(x.astype(jnp.float32) if fmt.compressed else x)
    plan = plan_cached(
        "bcast", M, n, root=root, algo=algo, num_chunks=num_chunks,
        tuner=tuner, inter_pod=inter_pod, wire_format=wire_format,
    )
    return apply_plan(plan, x, axis_name, fused=fused, compiled=compiled,
                      inkernel=inkernel)


def preduce(
    x: jax.Array,
    axis_name,
    *,
    root: int = 0,
    algo: str = "auto",
    num_chunks: int | None = None,
    tuner: Tuner | None = None,
    inter_pod: bool = False,
    combiner: str = "sum",
    compiled: bool | None = None,
    inkernel: bool | None = None,
    wire_format: str | None = None,
) -> jax.Array:
    """Reduce-to-root (``combiner``: sum by default). Non-root ranks return
    garbage partial sums by design (MPI_Reduce semantics) — only the root's
    output is meaningful. Non-sum combiners route through the XLA one-shot
    collectives (the schedule executors combine by sum, and zero pad tails
    are only the identity for sum)."""
    _check_combiner(combiner, "preduce")
    x = jnp.asarray(x)  # n == 1 must return the communicating path's
    # dtype/shape contract (a committed jnp array), not the caller's object
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    fmt = normalize_wire_format(wire_format)
    if combiner != "sum":
        if fmt.compressed:
            raise ValueError(
                f"wire_format={fmt.value!r} supports the 'sum' combiner only "
                f"(non-sum combiners route through the XLA one-shots)"
            )
        if algo != "auto":
            raise ValueError(f"combiner {combiner!r} supports algo='auto' only")
        return _ONE_SHOT_REDUCERS[combiner](x, axis_name)
    _flat_x, M = _flat(x.astype(jnp.float32) if fmt.compressed else x)
    plan = plan_cached(
        "reduce", M, n, root=root, algo=algo, num_chunks=num_chunks,
        tuner=tuner, inter_pod=inter_pod, wire_format=wire_format,
    )
    return apply_plan(plan, x, axis_name, compiled=compiled, inkernel=inkernel)


# ---------------------------------------------------------------------------
# allreduce / allgather / reduce_scatter (beyond-paper ops, Sec. VII)
# ---------------------------------------------------------------------------


def pallreduce(
    x: jax.Array,
    axis_name,
    *,
    algo: str = "auto",
    num_chunks: int | None = None,
    tuner: Tuner | None = None,
    inter_pod: bool = False,
    fused: bool = True,
    combiner: str = "sum",
    compiled: bool | None = None,
    inkernel: bool | None = None,
    wire_format: str | None = None,
) -> jax.Array:
    """All-reduce (``combiner``: sum by default) over the named axis through
    the tuned plan layer.

    ``algo``: 'auto', 'reduce_then_bcast', 'fused_rsb', 'ring_allreduce', or
    the one-shot baseline 'xla_psum'. Non-sum combiners (max/min) route to
    the XLA one-shots — the schedule executors combine by sum only.
    ``wire_format`` ('bf16'|'fp8'|'int8') compresses every hop at the
    ppermute seam (combine arithmetic stays full precision); compressed
    payloads travel in the f32 wire domain.
    """
    _check_combiner(combiner, "pallreduce")
    x = jnp.asarray(x)
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    fmt = normalize_wire_format(wire_format)
    if combiner != "sum":
        if fmt.compressed:
            raise ValueError(
                f"wire_format={fmt.value!r} supports the 'sum' combiner only "
                f"(non-sum combiners route through the XLA one-shots)"
            )
        if algo not in ("auto", "xla_psum"):
            raise ValueError(
                f"combiner {combiner!r} supports algo='auto' or 'xla_psum' only"
            )
        return _ONE_SHOT_REDUCERS[combiner](x, axis_name)
    if algo == "xla_psum":
        if fmt.compressed:
            raise ValueError(
                f"wire_format={fmt.value!r} requires a schedule-backed algo; "
                "the one-shot 'xla_psum' has no compression seam"
            )
        return lax.psum(x, axis_name)
    _flat_x, M = _flat(x.astype(jnp.float32) if fmt.compressed else x)
    plan = plan_cached(
        "allreduce", M, n, algo=algo, num_chunks=num_chunks,
        tuner=tuner, inter_pod=inter_pod, wire_format=wire_format,
    )
    return apply_plan(plan, x, axis_name, fused=fused, compiled=compiled,
                      inkernel=inkernel)


def pallgather(
    x: jax.Array,
    axis_name,
    *,
    algo: str = "auto",
    tuner: Tuner | None = None,
    inter_pod: bool = False,
    compiled: bool | None = None,
    inkernel: bool | None = None,
    wire_format: str | None = None,
) -> jax.Array:
    """All-gather the per-rank shard ``x`` into a stacked ``(n, *x.shape)``
    array (the ``lax.all_gather(axis=0)`` convention).

    ``algo``: 'auto', 'ring_allgather', 'doubling_allgather' (power-of-two
    n), or the one-shot baseline 'xla_allgather'.
    """
    x = jnp.asarray(x)
    n = lax.axis_size(axis_name)
    if n == 1:
        return x[None]
    fmt = normalize_wire_format(wire_format)
    if algo == "xla_allgather":
        if fmt.compressed:
            raise ValueError(
                f"wire_format={fmt.value!r} requires a schedule-backed algo; "
                "the one-shot 'xla_allgather' has no compression seam"
            )
        return lax.all_gather(x, axis_name, axis=0)
    # full gathered payload; compressed wires ship in the f32 wire domain
    M = n * x.size * (4 if fmt.compressed else x.dtype.itemsize)
    plan = plan_cached(
        "allgather", M, n, algo=algo, tuner=tuner, inter_pod=inter_pod,
        wire_format=wire_format,
    )
    return apply_plan(plan, x, axis_name, compiled=compiled, inkernel=inkernel)


def preduce_scatter(
    x: jax.Array,
    axis_name,
    *,
    algo: str = "auto",
    tuner: Tuner | None = None,
    inter_pod: bool = False,
    combiner: str = "sum",
    compiled: bool | None = None,
    inkernel: bool | None = None,
    wire_format: str | None = None,
) -> jax.Array:
    """Reduce-scatter (``combiner``: sum by default): every rank contributes
    the full flat buffer and receives its rank-indexed shard of the combined
    result — a flat array of ``ceil(x.size / n)`` elements (zero-padded tail
    on the last shard). Non-sum combiners combine FIRST through the XLA
    one-shot (pmax/pmin), then shard — the pad tail is appended after the
    combine, so the identity-element question never arises."""
    _check_combiner(combiner, "preduce_scatter")
    n = lax.axis_size(axis_name)
    flat = jnp.ravel(x)
    if n == 1:
        return flat
    fmt = normalize_wire_format(wire_format)
    if combiner != "sum":
        if fmt.compressed:
            raise ValueError(
                f"wire_format={fmt.value!r} supports the 'sum' combiner only "
                f"(non-sum combiners route through the XLA one-shots)"
            )
        if algo != "auto":
            raise ValueError(f"combiner {combiner!r} supports algo='auto' only")
        full = _ONE_SHOT_REDUCERS[combiner](flat, axis_name)
        buf, _pad = _chunked(full, n)
        return lax.dynamic_slice(buf, (lax.axis_index(axis_name), 0), (1, buf.shape[1]))[0]
    M = flat.size * (4 if fmt.compressed else flat.dtype.itemsize)
    plan = plan_cached(
        "reduce_scatter", M, n, algo=algo, tuner=tuner, inter_pod=inter_pod,
        wire_format=wire_format,
    )
    if plan.algo == "noop":
        return flat
    return apply_plan(plan, x, axis_name, compiled=compiled, inkernel=inkernel)


# ---------------------------------------------------------------------------
# ragged collectives (allgatherv / alltoallv — MPI_Allgatherv/MPI_Alltoallv
# analogues on the schedule IR; the MoE expert-dispatch transport)
# ---------------------------------------------------------------------------


def pallgatherv(
    x: jax.Array,
    axis_name,
    *,
    sizes: Sequence[int],
    algo: str = "auto",
    tuner: Tuner | None = None,
    inter_pod: bool = False,
    fused: bool = True,
    compiled: bool | None = None,
    inkernel: bool | None = None,
) -> jax.Array:
    """Ragged all-gather: rank ``r`` contributes the first ``sizes[r]`` rows
    of ``x`` (rows beyond the valid prefix are ignored) and every rank
    receives the ``(sum(sizes), *x.shape[1:])`` concatenation in rank order.

    ``x`` must have the same static shape on every rank with leading dim
    >= ``max(sizes)`` (SPMD). Zero-sized ranks are fine — they contribute
    nothing but still receive the full result. ``algo``: 'auto',
    'ring_allgatherv', or 'doubling_allgatherv' (power-of-two n); 'auto'
    routes through the skew-aware tuner (``Tuner.select(..., sizes=)``).
    """
    x = jnp.asarray(x)
    n = lax.axis_size(axis_name)
    sz = tuple(int(s) for s in sizes)
    if len(sz) != n:
        raise ValueError(f"allgatherv sizes has {len(sz)} entries for axis size {n}")
    if any(s < 0 for s in sz) or sum(sz) == 0:
        raise ValueError(f"allgatherv sizes must be non-negative and non-empty: {sz}")
    if x.ndim < 1 or x.shape[0] < max(sz):
        raise ValueError(
            f"allgatherv input has {x.shape[0] if x.ndim else 0} rows, "
            f"size vector needs max(sizes)={max(sz)}")
    total = sum(sz)
    if n == 1:
        return x[: sz[0]]
    elems = math.prod(x.shape[1:]) if x.ndim > 1 else 1
    if elems == 0:
        return jnp.zeros((total,) + x.shape[1:], x.dtype)
    M = total * elems * x.dtype.itemsize
    plan = plan_cached(
        "allgatherv", M, n, algo=algo, tuner=tuner, inter_pod=inter_pod,
        sizes=sz,
    )
    return apply_plan(plan, x, axis_name, fused=fused, compiled=compiled,
                      inkernel=inkernel)


def palltoallv(
    x: jax.Array,
    axis_name,
    *,
    sizes,
    algo: str = "auto",
    tuner: Tuner | None = None,
    inter_pod: bool = False,
    in_padded: bool = False,
    out_padded: bool = False,
    fused: bool = True,
    compiled: bool | None = None,
    inkernel: bool | None = None,
) -> jax.Array:
    """Ragged all-to-all: ``sizes`` gives the block matrix ``m[s][d]`` (rows
    rank ``s`` sends to rank ``d``) as an n x n nested sequence, a flat
    row-major n^2 vector, or a length-n per-destination vector (every source
    sends the same counts). Rank ``r`` sends block ``m[r][d]`` to each
    ``d`` and receives block ``m[s][r]`` from each ``s``.

    Layouts (``elem = x.shape[1:]`` compact, ``x.shape[2:]`` padded):

    - compact in (default): ``x`` is the destination-major concatenation —
      the first ``sum_d m[r][d]`` rows are blocks for d=0..n-1 back-to-back;
      leading dim >= ``max_r sum_d m[r][d]`` (static, shared by all ranks).
    - padded in (``in_padded=True``): ``x`` is ``(n, bmax_in, *elem)`` with
      the block for destination ``d`` at ``x[d, :m[r][d]]``.
    - compact out (default): source-major concatenation, shape
      ``(max_r sum_s m[s][r], *elem)``, zero beyond the rank's valid prefix.
    - padded out (``out_padded=True``): ``(n, max(m), *elem)`` with the
      block from source ``s`` at ``out[s, :m[s][r]]``, zeros elsewhere.

    The padded layouts keep per-rank shapes static when block heights vary
    per rank — the MoE expert-dispatch contract. ``algo``: 'auto',
    'pairwise_alltoallv', or 'ring_alltoallv' (store-and-forward).
    """
    x = jnp.asarray(x)
    n = lax.axis_size(axis_name)
    m = alltoallv_matrix(sizes, n)
    flat = tuple(v for row in m for v in row)
    total = sum(flat)
    if total == 0:
        raise ValueError("alltoallv size matrix is all zeros")
    elem = x.shape[2:] if in_padded else x.shape[1:]
    elems = math.prod(elem) if elem else 1
    if n == 1:
        c = m[0][0]
        if in_padded:
            return x[:, :c] if out_padded else x[0, :c]
        return x[:c][None] if out_padded else x[:c]
    if elems == 0:
        bmax = max(flat)
        rmax = max(sum(m[s][r] for s in range(n)) for r in range(n))
        shape = ((n, bmax) + elem) if out_padded else ((rmax,) + elem)
        return jnp.zeros(shape, x.dtype)
    M = total * elems * x.dtype.itemsize
    plan = plan_cached(
        "alltoallv", M, n, algo=algo, tuner=tuner, inter_pod=inter_pod,
        sizes=flat,
    )
    run = _EXECUTORS[
        _resolve_exec_path(plan, fused=fused, compiled=compiled, inkernel=inkernel)
    ]
    return _run_alltoallv(plan, x, axis_name, run,
                          in_padded=in_padded, out_padded=out_padded)


# ---------------------------------------------------------------------------
# pytree variants (bucketed; the application regime of paper Sec. V-D)
# ---------------------------------------------------------------------------


def _tree_collective(op_fn, tree, axis_name, *, bucket_bytes, stage, stage_chunk, **kw):
    spec = bucketing.plan_buckets(tree, bucket_bytes)
    buckets = bucketing.pack_buckets(tree, spec)
    out = []
    for b in buckets:
        if not b.size:
            out.append(b)
            continue
        if stage:
            from ..kernels.chunked_copy import chunked_copy

            b = chunked_copy(b, chunk_elems=stage_chunk)
        out.append(op_fn(b, axis_name, **kw))
    return bucketing.unpack_buckets(out, spec)


def pbcast_tree(
    tree: Any,
    axis_name,
    *,
    root: int = 0,
    algo: str = "auto",
    tuner: Tuner | None = None,
    bucket_bytes: int = 4 << 20,
    inter_pod: bool = False,
    stage: bool = False,
    stage_chunk: int = 64 * 1024,
) -> Any:
    """Broadcast a pytree via same-dtype buckets, each tuned independently.

    The bucket mix reproduces the application regime of the paper (Sec.
    V-D): a few large buckets (pipelined-chain territory) plus a tail of
    small ones (k-nomial territory). ``stage=True`` routes each packed
    bucket through the ``chunked_copy`` Pallas staging pipeline first.
    """
    return _tree_collective(
        pbcast, tree, axis_name, bucket_bytes=bucket_bytes, stage=stage,
        stage_chunk=stage_chunk, root=root, algo=algo, tuner=tuner,
        inter_pod=inter_pod,
    )


def pallreduce_tree(
    tree: Any,
    axes: Sequence,
    *,
    algo: str = "auto",
    tuner: Tuner | None = None,
    bucket_bytes: int = 4 << 20,
    inter_pod_axes: Sequence = (),
    stage: bool = False,
    stage_chunk: int = 64 * 1024,
    compiled: bool | None = None,
    wire_format: str | None = None,
) -> Any:
    """Hierarchical bucketed all-reduce over one or more mesh axes.

    Axes run in the given order (use :func:`hierarchical_allreduce_axes` for
    the intra-pod-first convention); axes named in ``inter_pod_axes`` are
    priced with the tuner's inter-pod constants, so the pod level can pick a
    different algorithm than the fast intra-pod level. The tree is packed
    into buckets ONCE; all hierarchy levels run over the packed buffers.
    ``wire_format`` applies to every bucket at every level (see
    :func:`pallreduce`).
    """
    spec = bucketing.plan_buckets(tree, bucket_bytes)
    buckets = bucketing.pack_buckets(tree, spec)
    inter = tuple(inter_pod_axes)
    out = []
    for b in buckets:
        if not b.size:
            out.append(b)
            continue
        if stage:
            from ..kernels.chunked_copy import chunked_copy

            b = chunked_copy(b, chunk_elems=stage_chunk)
        for ax in axes:
            b = pallreduce(b, ax, algo=algo, tuner=tuner, inter_pod=(ax in inter),
                           compiled=compiled, wire_format=wire_format)
        out.append(b)
    return bucketing.unpack_buckets(out, spec)


def hierarchical_allreduce_axes(mesh) -> tuple:
    """Axis order for hierarchical allreduce: intra-pod data axes first,
    then the inter-pod level (the reverse of ``topology.bcast_axes`` —
    reduce locally before touching the slow fabric)."""
    from ..dist import topology

    return tuple(reversed(topology.bcast_axes(mesh)))
