"""Multi-stream link scheduler: collectives as DAG-embedded stream entries
(DESIGN.md Sec. 13).

The PR 4 overlap engine schedules ONE stream of buckets against compute.
Production runs several concurrent comm streams — gradient sync, next-step
weight prefetch, checkpoint drain, weight distribution — contending for the
same links. Following the MXNet DAG-embedding design (arXiv:1802.06949),
this module represents every in-flight collective as a dependency-tracked
entry in a global link scheduler:

* :class:`StreamEntry` — a named stream carrying an ordered list of
  per-bucket :class:`~repro.comm.plan.CollectivePlan`s, a priority, a link
  class, and DAG edges (``after``) to entries it must follow. Exactly the
  payload of a PR 4 ``OverlapPlan`` plus the arbitration metadata.
* :class:`StreamGraph` — the validated set of entries (unique names,
  resolvable acyclic ``after`` edges) plus the scheduler's starvation
  bound and the spec-level fingerprint ``plan_cached`` keys on.
* :func:`plan_streams` — host-side planning: one :class:`StreamSpec` per
  stream resolves to per-(axis, bucket) plans through the SAME
  ``plan_cached`` path the single-stream planner uses, with per-stream
  depth/priority pulled from the tuner's ``stream:*`` entries when not
  explicit.
* :func:`simulate_streams` — discrete-round replay of the contended
  timeline through :func:`cost_model.multi_stream_finish_times`, with
  per-stream idle-round, wire-byte, and finish-time accounting plus the
  fairness (no stream starves beyond the graph's bound) and no-idle (a
  ready transfer never waits behind an empty link) properties, and the
  naive-serialization baseline span the table gate compares against.
  ``faults=`` composes under the PR 7 contract: every bucket's clock runs
  through the degraded ``timed_rounds`` and dead ranks raise the typed
  ``DeadRankError`` — never a silent wrong answer.
* :func:`execute_streams` / :func:`execute_stream_entry` — traced
  execution. A 1-entry graph replays BIT-IDENTICALLY to the PR 4
  ``execute_overlap`` loop (same plans, same ``apply_plan`` lanes, same
  staging windows); multi-entry graphs interleave bucket dispatches in
  the arbiter's commit order.

The arbitration rule (one serial resource per link class): a transfer may
dispatch at ``max(link_free, min(ready))`` — the link never idles while
any transfer is ready. Highest priority wins the contended slot, except a
stream already passed over ``starvation_bound`` times is forced through
(skip-counter aging). Preemption points sit at round boundaries: a bucket
occupies its link one round-quantum at a time, so a high-priority stream
waits at most one round, never a whole bucket.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Mapping, Sequence

from ..core import bucketing, cost_model
from ..core.bucketing import BucketSpec
from ..core.tuner import Tuner, default_tuner
from .plan import CollectivePlan, plan_cached

__all__ = [
    "StreamSpec",
    "StreamEntry",
    "StreamGraph",
    "graph_key",
    "plan_streams",
    "simulate_streams",
    "dispatch_schedule",
    "execute_streams",
    "execute_stream_entry",
]

# analytic depth sweep ceiling — every extra slot is a live staged bucket
# buffer in device memory (shared with the single-stream planner)
_MAX_DEPTH = 8

# scheduler default: a contended stream is never passed over more than this
# many times (plus S-2 for S-way contention) before it is forced through
_DEFAULT_STARVATION_BOUND = 4


def graph_key(payload: Any) -> str:
    """Stable fingerprint of a stream-graph SPEC (names, ops, priorities,
    DAG edges, bucket mixes, axes, depth requests). Computable BEFORE any
    plan resolves — this is the ``stream=`` component of the
    ``plan_cached`` key, so two different graph shapes can never share a
    cached per-bucket plan even when the (op, M, n) point coincides."""
    blob = json.dumps(payload, sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """Planning request for one stream (input to :func:`plan_streams`).

    ``tree`` may be abstract (``ShapeDtypeStruct`` leaves) — nothing is
    traced at plan time. ``after`` names streams that must fully finish
    before this one's first bucket stages; ``link`` names the serial
    resource the stream occupies (streams on different links never
    contend). ``priority``/``overlap_depth`` left ``None`` fall back to
    the tuner's ``stream:<name>`` entry, then (depth) to the per-op
    empirical/analytic tiers of the single-stream planner."""

    name: str
    tree: Any
    axes: tuple
    op: str = "allreduce"
    root: int = 0
    algo: str = "auto"
    priority: int | None = None
    after: tuple = ()
    overlap_depth: int | None = None
    compute_s: float = 0.0
    link: str = "ici"
    bucket_bytes: int = 4 << 20
    inter_pod_axes: tuple = ()
    reverse: bool = False
    spec: BucketSpec | None = None


@dataclasses.dataclass(frozen=True)
class StreamEntry:
    """A fully-resolved stream: bucket mix + per-(axis, bucket) plans +
    dispatch order + in-flight window + arbitration metadata."""

    name: str
    op: str
    spec: BucketSpec
    axes: tuple[str, ...]                         # sync order (hierarchy levels)
    plans: dict[str, tuple[CollectivePlan, ...]]  # per axis, one plan per bucket
    order: tuple[int, ...]                        # bucket dispatch order
    overlap_depth: int
    compute_s: float = 0.0
    depth_source: str = "manual"   # 'manual' | 'stream' | 'empirical' | 'analytic'
    priority: int = 0
    after: tuple[str, ...] = ()
    link: str = "ici"

    @property
    def num_buckets(self) -> int:
        return self.spec.num_buckets

    def bucket_comm_s(self) -> list[float]:
        """Per-bucket predicted collective time, summed over hierarchy
        levels, in DISPATCH order."""
        return [
            sum(self.plans[ax][k].predicted_s for ax in self.axes)
            for k in self.order
        ]

    def bucket_stage_s(self, hw: cost_model.Hardware | None = None) -> list[float]:
        """Per-bucket staging (pack / ``chunked_copy``) time in dispatch
        order: one HBM read + one HBM write of the bucket."""
        hw = hw or cost_model.TPU_V5E
        sizes = self.spec.bucket_bytes()
        return [2.0 * sizes[k] / hw.hbm_bw for k in self.order]

    def bucket_rounds(self) -> list[int]:
        """Per-bucket network-round counts in dispatch order (summed over
        hierarchy levels; one-shot baselines count 1, noops 0; floored at
        1 so every bucket occupies its link for at least one quantum)."""
        out = []
        for k in self.order:
            r = 0
            for ax in self.axes:
                p = self.plans[ax][k]
                r += p.schedule.num_rounds if p.schedule is not None else (
                    0 if p.algo == "noop" else 1
                )
            out.append(max(r, 1))
        return out

    def bucket_times_s(self, hw: cost_model.Hardware | None = None,
                       faults=None) -> tuple[list[float], list[float]]:
        """Per-bucket (healthy, clocked) schedule replay times in dispatch
        order. With ``faults`` the clocked column runs the degraded
        ``timed_rounds`` (PR 7 contract — dead ranks raise from the first
        bucket's replay); without, the two columns are identical."""
        hw = hw or cost_model.TPU_V5E
        healthy, clocked = [], []
        for k in self.order:
            t0 = 0.0
            t = 0.0
            for ax in self.axes:
                p = self.plans[ax][k]
                if p.schedule is not None:
                    t0 += p.timed_rounds_s(hw)
                    t += p.timed_rounds_s(hw, faults=faults) if faults is not None else 0.0
            healthy.append(t0)
            clocked.append(t if faults is not None else t0)
        return healthy, clocked

    def wire_bytes(self) -> int:
        """Total bytes on the wire — exactly the sum of the per-bucket plan
        accounting (arbitration reorders transfers, it never adds any)."""
        return sum(p.wire_bytes() for ax in self.axes for p in self.plans[ax])


class StreamGraphError(ValueError):
    """Malformed stream graph: duplicate names, dangling or cyclic edges."""


@dataclasses.dataclass(frozen=True)
class StreamGraph:
    """A validated DAG of :class:`StreamEntry`s sharing the link scheduler.

    ``starvation_bound`` is the scheduler's aging threshold: a contended
    stream passed over that many times is forced through regardless of
    priority. ``key`` is the spec-level fingerprint from
    :func:`plan_streams` (``plan_cached`` keyed on it); content-derived
    when entries are constructed by hand."""

    entries: tuple[StreamEntry, ...]
    starvation_bound: int = _DEFAULT_STARVATION_BOUND
    key: str | None = None

    def __post_init__(self) -> None:
        names = [e.name for e in self.entries]
        if len(set(names)) != len(names):
            raise StreamGraphError(f"duplicate stream names: {names}")
        if int(self.starvation_bound) < 1:
            raise StreamGraphError("starvation_bound must be >= 1")
        known = set(names)
        for e in self.entries:
            for dep in e.after:
                if dep == e.name:
                    raise StreamGraphError(f"stream {e.name!r} is after itself")
                if dep not in known:
                    raise StreamGraphError(
                        f"stream {e.name!r} is after unknown stream {dep!r}"
                    )
        self.topo_order()  # raises on cycles

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(e.name for e in self.entries)

    def entry(self, name: str) -> StreamEntry:
        for e in self.entries:
            if e.name == name:
                return e
        raise KeyError(name)

    def topo_order(self) -> tuple[int, ...]:
        """Entry indices in a dependency-respecting order (stable: ties
        keep declaration order). Raises :class:`StreamGraphError` on a
        cycle — this is the validation pass."""
        idx = {e.name: i for i, e in enumerate(self.entries)}
        deps = {i: {idx[d] for d in e.after} for i, e in enumerate(self.entries)}
        out: list[int] = []
        done: set[int] = set()
        while len(out) < len(self.entries):
            progressed = False
            for i in range(len(self.entries)):
                if i in done or deps[i] - done:
                    continue
                out.append(i)
                done.add(i)
                progressed = True
            if not progressed:
                cyc = [self.entries[i].name for i in range(len(self.entries))
                       if i not in done]
                raise StreamGraphError(f"cycle in 'after' edges through {cyc}")
        return tuple(out)

    def fairness_bound(self) -> int:
        """The scheduler's hard starvation guarantee: no stream is passed
        over more than this many consecutive contended dispatches (the
        configured bound, plus S-2 when S starved streams must drain one
        at a time — exact for pairwise contention)."""
        return int(self.starvation_bound) + max(0, len(self.entries) - 2)

    def wire_bytes(self) -> int:
        return sum(e.wire_bytes() for e in self.entries)

    def fingerprint(self) -> str:
        if self.key is not None:
            return self.key
        payload = {
            "starvation_bound": int(self.starvation_bound),
            "entries": [
                {
                    "name": e.name, "op": e.op, "axes": list(e.axes),
                    "order": list(e.order), "depth": e.overlap_depth,
                    "priority": e.priority, "after": list(e.after),
                    "link": e.link, "compute_s": e.compute_s,
                    "plans": {
                        ax: [(p.decision.algo, p.decision.num_chunks, p.M,
                              p.n, p.root, p.inter_pod) for p in ps]
                        for ax, ps in sorted(e.plans.items())
                    },
                }
                for e in self.entries
            ],
        }
        return graph_key(payload)


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------


def _resolve_depth(spec: StreamSpec, entry_plans: Mapping, bspec: BucketSpec,
                   order: tuple[int, ...], axes: Sequence, tuner: Tuner,
                   compute_s: float) -> tuple[int, str]:
    """Depth precedence: explicit > tuner ``stream:<name>`` entry > tuned
    per-op depth at the largest bucket > analytic sweep (the PR 4 tiers
    with the stream tier spliced in)."""
    if spec.overlap_depth is not None:
        return max(1, int(spec.overlap_depth)), "manual"
    tuned = tuner.stream_decision(spec.name).get("overlap_depth")
    if tuned is not None:
        return max(1, int(tuned)), "stream"
    sizes = bspec.bucket_bytes()
    if sizes:
        k_big = max(range(len(sizes)), key=lambda k: sizes[k])
        for ax, _n in axes:
            d = entry_plans[ax][k_big].decision.overlap_depth
            if d is not None:
                return d, "empirical"
    probe = StreamEntry(
        spec.name, spec.op, bspec, tuple(a for a, _ in axes), dict(entry_plans),
        order, 1, compute_s, "analytic",
    )
    depth = cost_model.optimal_overlap_depth(
        probe.bucket_comm_s(), compute_s,
        stage_s=probe.bucket_stage_s(), max_depth=_MAX_DEPTH,
    )
    return depth, "analytic"


def plan_streams(
    specs: Sequence[StreamSpec],
    *,
    tuner: Tuner | None = None,
    starvation_bound: int = _DEFAULT_STARVATION_BOUND,
) -> StreamGraph:
    """Resolve a :class:`StreamGraph` from per-stream :class:`StreamSpec`s.

    Every stream's per-bucket plans go through the SAME ``plan_cached``
    path the single-stream planner uses — keyed additionally on the
    graph's spec-level fingerprint, so plans resolved for one graph shape
    never leak into another. Priorities fall back to the tuner's
    ``stream:<name>`` entries (see :meth:`Tuner.record_stream`), depth to
    the stream > empirical > analytic tiers."""
    t = tuner or default_tuner()
    specs = tuple(specs)
    bspecs = [
        s.spec if s.spec is not None else bucketing.plan_buckets(s.tree, s.bucket_bytes)
        for s in specs
    ]
    gkey = graph_key({
        "starvation_bound": int(starvation_bound),
        "streams": [
            {
                "name": s.name, "op": s.op, "root": s.root, "algo": s.algo,
                "priority": s.priority, "after": list(s.after),
                "overlap_depth": s.overlap_depth, "compute_s": s.compute_s,
                "link": s.link, "axes": [[a, int(n)] for a, n in s.axes],
                "inter_pod_axes": sorted(str(a) for a in s.inter_pod_axes),
                "reverse": bool(s.reverse),
                "buckets": list(b.bucket_bytes()),
            }
            for s, b in zip(specs, bspecs)
        ],
    })
    entries = []
    for s, bspec in zip(specs, bspecs):
        inter = tuple(s.inter_pod_axes)
        plans: dict[str, tuple[CollectivePlan, ...]] = {}
        for ax, n in s.axes:
            plans[ax] = tuple(
                plan_cached(
                    s.op, max(M, 1), n, root=s.root, algo=s.algo, tuner=t,
                    inter_pod=(ax in inter), stream=gkey,
                )
                for M in bspec.bucket_bytes()
            )
        idx = range(bspec.num_buckets)
        order = tuple(reversed(idx)) if s.reverse else tuple(idx)
        depth, source = _resolve_depth(s, plans, bspec, order, s.axes, t, s.compute_s)
        priority = s.priority
        if priority is None:
            priority = t.stream_decision(s.name).get("priority", 0)
        entries.append(StreamEntry(
            name=s.name, op=s.op, spec=bspec,
            axes=tuple(a for a, _ in s.axes), plans=plans, order=order,
            overlap_depth=depth, compute_s=s.compute_s, depth_source=source,
            priority=int(priority), after=tuple(s.after), link=s.link,
        ))
    return StreamGraph(tuple(entries), starvation_bound=int(starvation_bound),
                       key=gkey)


# ---------------------------------------------------------------------------
# round-accurate contention simulator
# ---------------------------------------------------------------------------


def _discretize(graph: StreamGraph, hw: cost_model.Hardware,
                faults=None) -> tuple[list[dict], dict]:
    """Shared discretization for the simulator and the dispatch schedule:
    one GLOBAL mean round duration (all streams share the links, so rounds
    must be commensurable), per-stream staging/compute round counts, comm
    expanded into unit round-quanta (the preemption points)."""
    idx = {e.name: i for i, e in enumerate(graph.entries)}
    rounds: list[list[int]] = []
    healthy: list[list[float]] = []
    clocked: list[list[float]] = []
    for e in graph.entries:
        rounds.append(e.bucket_rounds())
        h, c = e.bucket_times_s(hw, faults=faults)
        healthy.append(h)
        clocked.append(c)
    total_rounds = sum(sum(r) for r in rounds)
    total_time = sum(sum(c) for c in clocked)
    mean_round_s = (total_time / total_rounds) if total_rounds else hw.ts
    mean_round_s = max(mean_round_s, hw.ts)
    demands = []
    info = {"mean_round_s": mean_round_s, "rounds": rounds,
            "healthy_s": sum(sum(h) for h in healthy),
            "clocked_s": total_time,
            "stage_rounds": [], "per_bucket_compute": []}
    for i, e in enumerate(graph.entries):
        K = len(rounds[i])
        stage_rounds = [int(round(s / mean_round_s)) for s in e.bucket_stage_s(hw)]
        per_bucket_compute = max(
            1, int(round(e.compute_s / max(K, 1) / mean_round_s))
        ) if K else 0
        info["stage_rounds"].append(stage_rounds)
        info["per_bucket_compute"].append(per_bucket_compute)
        demands.append({
            "avail": [(k + 1) * per_bucket_compute for k in range(K)],
            "stage": stage_rounds,
            "comm": [[1] * r for r in rounds[i]],
            "depth": e.overlap_depth,
            "priority": e.priority,
            "link": e.link,
            "after": tuple(idx[d] for d in e.after),
        })
    return demands, info


def _chained(demands: list[dict], graph: StreamGraph) -> list[dict]:
    """The naive-serialization baseline: the SAME demands with chain
    ``after`` edges along a topological order — stream i+1 may not start
    until stream i fully drains. Running it through the same scheduler
    (rather than summing spans by hand) keeps the two numbers exactly
    comparable."""
    topo = graph.topo_order()
    out = [dict(d) for d in demands]
    for pos in range(1, len(topo)):
        prev, cur = topo[pos - 1], topo[pos]
        out[cur]["after"] = tuple(set(out[cur]["after"]) | {prev})
    return out


def simulate_streams(
    graph: StreamGraph,
    hw: cost_model.Hardware | None = None,
    faults=None,
) -> dict:
    """Discrete-round replay of the contended multi-stream timeline.

    Time is discretized into network rounds (one global mean round
    duration — all streams share the links). Every bucket occupies its
    stream's link for its schedule's round count, one unit quantum at a
    time (round-boundary preemption points); staging and compute gate
    availability exactly as in the single-stream simulator, and ``after``
    edges hold a stream back until its upstream fully drains.

    Returns span/idle/wire accounting for the arbitrated schedule AND for
    naive serialization of the same entries (chain edges, same
    scheduler), plus the two scheduler properties in checkable form:

    * fairness — ``max_skips`` never exceeds :meth:`StreamGraph.fairness_bound`;
    * no-idle — ``idle_while_ready_rounds`` is 0: every dispatch starts at
      ``max(link_free, min_ready)``, recomputed here from the trace.

    With ``faults`` (PR 7 :class:`~repro.comm.faults.FaultSpec`), every
    bucket's clock runs the degraded ``timed_rounds`` — round structure
    untouched, ``comm_s_healthy``/``comm_s_faulty``/``fault_slowdown``
    quantify the degradation, dead ranks raise ``DeadRankError``."""
    hw = hw or cost_model.TPU_V5E
    demands, info = _discretize(graph, hw, faults=faults)
    trace: list[dict] = []
    ends = cost_model.multi_stream_finish_times(
        demands, starvation_bound=graph.starvation_bound, trace=trace)
    naive_ends = cost_model.multi_stream_finish_times(
        _chained(demands, graph), starvation_bound=graph.starvation_bound)
    multi_span = max((e[-1] for e in ends if e), default=0)
    naive_span = max((e[-1] for e in naive_ends if e), default=0)

    idle_while_ready = 0
    max_skips = 0
    link_busy: dict[str, int] = {}
    link_span: dict[str, int] = {}
    waits = [0] * len(graph.entries)
    for rec in trace:
        idle_while_ready += max(0, rec["start"] - max(rec["link_free"], rec["min_ready"]))
        max_skips = max(max_skips, rec["skips"])
        link_busy[rec["link"]] = link_busy.get(rec["link"], 0) + (rec["end"] - rec["start"])
        link_span[rec["link"]] = max(link_span.get(rec["link"], 0), rec["end"])
        if rec["quantum"] == 0:
            waits[rec["stream"]] += rec["start"] - rec["ready"]

    streams_out = {}
    for i, e in enumerate(graph.entries):
        comm_rounds = sum(info["rounds"][i])
        finish = ends[i][-1] if ends[i] else 0
        streams_out[e.name] = {
            "num_buckets": len(info["rounds"][i]),
            "priority": e.priority,
            "depth": e.overlap_depth,
            "link": e.link,
            "after": list(e.after),
            "comm_rounds": comm_rounds,
            "stage_rounds": sum(info["stage_rounds"][i]),
            "compute_rounds": len(info["rounds"][i]) * info["per_bucket_compute"][i],
            "finish_round": finish,
            "naive_finish_round": naive_ends[i][-1] if naive_ends[i] else 0,
            "wait_rounds": waits[i],
            "idle_rounds": finish - comm_rounds,
            "wire_bytes": e.wire_bytes(),
        }

    out = {
        "num_streams": len(graph.entries),
        "starvation_bound": int(graph.starvation_bound),
        "fairness_bound": graph.fairness_bound(),
        "mean_round_s": info["mean_round_s"],
        "multi_span_rounds": multi_span,
        "naive_span_rounds": naive_span,
        "comm_rounds": sum(sum(r) for r in info["rounds"]),
        "wire_bytes": graph.wire_bytes(),
        "max_skips": max_skips,
        "idle_while_ready_rounds": idle_while_ready,
        "links": {
            ln: {
                "busy_rounds": link_busy[ln],
                "span_rounds": link_span[ln],
                "idle_rounds": link_span[ln] - link_busy[ln],
            }
            for ln in sorted(link_busy)
        },
        "streams": streams_out,
    }
    if faults is not None:
        healthy = info["healthy_s"]
        faulty = info["clocked_s"]
        out["comm_s_healthy"] = healthy
        out["comm_s_faulty"] = faulty
        out["fault_slowdown"] = faulty / healthy if healthy > 0 else 1.0
        out["fault_fingerprint"] = faults.fingerprint()
    return out


def dispatch_schedule(
    graph: StreamGraph, hw: cost_model.Hardware | None = None
) -> list[tuple[str, int]]:
    """Bucket-level dispatch order: ``(stream name, bucket index)`` pairs
    in the arbiter's commit order (the first round-quantum of each
    bucket). This is the interleave :func:`execute_streams` replays —
    per stream, buckets appear exactly in that stream's ``order``."""
    hw = hw or cost_model.TPU_V5E
    demands, _ = _discretize(graph, hw)
    trace: list[dict] = []
    cost_model.multi_stream_finish_times(
        demands, starvation_bound=graph.starvation_bound, trace=trace)
    sched = []
    for rec in trace:
        if rec["quantum"] == 0:
            e = graph.entries[rec["stream"]]
            sched.append((e.name, e.order[rec["bucket"]]))
    return sched


# ---------------------------------------------------------------------------
# traced execution (inside shard_map)
# ---------------------------------------------------------------------------


def _apply_plan(plan, b, ax, *, fused, compiled):
    """Per-bucket replay, resolved through the ``repro.comm`` facade at
    call time — fault-injection seams that monkeypatch
    ``repro.comm.apply_plan`` (the robustness tests' mid-broadcast failure
    hook) must see stream execution too."""
    from .. import comm as _pkg

    return _pkg.apply_plan(plan, b, ax, fused=fused, compiled=compiled)


def _run_entry(entry: StreamEntry, tree: Any, dispatch: Sequence[int], *,
               stage: bool, stage_chunk: int, fused: bool,
               compiled: bool | None) -> Any:
    """Replay ``entry`` over ``tree`` issuing buckets in ``dispatch``
    order with the entry's staging window kept ahead — the PR 4
    ``execute_overlap`` loop, parameterized by dispatch order so the
    multi-entry interleave can drive it too."""
    buckets = bucketing.pack_buckets(tree, entry.spec)
    order = [k for k in dispatch if buckets[k].size]
    out: list = list(buckets)  # empty buckets pass through untouched

    staged: dict[int, Any] = {}

    def _stage(k: int) -> None:
        b = buckets[k]
        if stage:
            from ..kernels.chunked_copy import chunked_copy

            b = chunked_copy(b, chunk_elems=stage_chunk)
        staged[k] = b

    depth = max(1, entry.overlap_depth)
    for i, k in enumerate(order):
        for j in order[i : i + depth]:   # keep the window staged ahead
            if j not in staged:
                _stage(j)
        b = staged.pop(k)
        for ax in entry.axes:
            b = _apply_plan(
                entry.plans[ax][k], b, ax, fused=fused, compiled=compiled
            )
        out[k] = b
    return bucketing.unpack_buckets(out, entry.spec)


def execute_stream_entry(
    entry: StreamEntry,
    tree: Any,
    *,
    stage: bool = False,
    stage_chunk: int = 64 * 1024,
    fused: bool = True,
    compiled: bool | None = None,
) -> Any:
    """Replay ONE stream entry on concrete values inside ``shard_map`` —
    bit-identical to the PR 4 ``execute_overlap`` path for the same
    plans/order/depth. Consumers whose streams run at different points of
    the traced program (e.g. grad sync inside the step, weight prefetch
    after the update — the DAG edge realized by program order) call this
    per entry instead of :func:`execute_streams`."""
    return _run_entry(entry, tree, entry.order, stage=stage,
                      stage_chunk=stage_chunk, fused=fused, compiled=compiled)


def execute_streams(
    graph: StreamGraph,
    trees: Mapping[str, Any],
    *,
    hw: cost_model.Hardware | None = None,
    stage: bool = False,
    stage_chunk: int = 64 * 1024,
    fused: bool = True,
    compiled: bool | None = None,
) -> dict[str, Any]:
    """Replay every stream of ``graph`` over its tree (``trees`` maps
    stream name -> pytree), interleaving bucket dispatches in the
    arbiter's commit order (:func:`dispatch_schedule`). Per-bucket math
    is identical to the per-entry path — only the cross-stream interleave
    differs, which is exactly what lets the XLA scheduler overlap one
    stream's staging with another's in-flight collective."""
    missing = set(graph.names) - set(trees)
    if missing:
        raise KeyError(f"execute_streams: no tree for streams {sorted(missing)}")
    if len(graph.entries) == 1:
        e = graph.entries[0]
        return {e.name: execute_stream_entry(
            e, trees[e.name], stage=stage, stage_chunk=stage_chunk,
            fused=fused, compiled=compiled)}

    sched = dispatch_schedule(graph, hw)
    buckets = {e.name: bucketing.pack_buckets(trees[e.name], e.spec)
               for e in graph.entries}
    out = {name: list(bs) for name, bs in buckets.items()}
    staged: dict[str, dict[int, Any]] = {e.name: {} for e in graph.entries}
    nonempty = {
        e.name: [k for k in e.order if buckets[e.name][k].size]
        for e in graph.entries
    }
    pos = {e.name: 0 for e in graph.entries}

    def _stage(name: str, k: int) -> None:
        b = buckets[name][k]
        if stage:
            from ..kernels.chunked_copy import chunked_copy

            b = chunked_copy(b, chunk_elems=stage_chunk)
        staged[name][k] = b

    for name, k in sched:
        e = graph.entry(name)
        if not buckets[name][k].size:
            continue
        order = nonempty[name]
        i = pos[name]
        assert order[i] == k, (name, k, order, i)
        for j in order[i : i + max(1, e.overlap_depth)]:
            if j not in staged[name]:
                _stage(name, j)
        b = staged[name].pop(k)
        for ax in e.axes:
            b = _apply_plan(
                e.plans[ax][k], b, ax, fused=fused, compiled=compiled
            )
        out[name][k] = b
        pos[name] += 1
    return {
        e.name: bucketing.unpack_buckets(out[e.name], e.spec)
        for e in graph.entries
    }
