"""Resilient execution policy: fallback chains, retries, and a straggler
watchdog.

The execution side of the fault subsystem (`comm.faults` is the *model*
side): :func:`comm.api.apply_plan_resilient` walks a typed fallback chain —
in-kernel executor -> compiled executor -> unrolled executor -> XLA
one-shot — under the
retry/timeout/backoff policy defined here, and a :class:`Watchdog` compares
observed timings against the plan's cost-model expectation to flag
stragglers into ``Tuner.record`` (which bumps the tuner fingerprint and so
invalidates cached plans, closing the observe -> retune loop).

Semantics worth stating precisely:

  * only *unexpected* exceptions advance the chain (a trace failure, a
    Pallas lowering bug, an executor assertion). A typed
    :class:`~.faults.FaultError` propagates immediately — it already names
    the recovery action (replan / restore / widen the budget) and retrying
    the same plan would reproduce it.
  * a stage that *completes* but blows the policy timeout still returns its
    (correct) result; it is recorded as a straggler, not a failure —
    discarding a correct collective because it was slow would turn a
    performance fault into a data loss.
  * when every stage fails, :class:`~.faults.FallbackExhaustedError` carries
    the per-stage causes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

from ..core.tuner import Tuner
from .faults import FallbackExhaustedError  # noqa: F401  (re-export for callers)
from .plan import CollectivePlan

__all__ = [
    "FallbackPolicy",
    "FallbackEvent",
    "StragglerReport",
    "Watchdog",
]

# fallback stages, strongest first: the in-kernel executor (one persistent
# Pallas launch per schedule), the compiled executor (fused Pallas combine,
# O(lane classes) HLO), the unrolled schedule executor, then the native XLA
# one-shot collective for the op
DEFAULT_CHAIN = ("inkernel", "compiled", "unrolled", "xla")


@dataclasses.dataclass(frozen=True)
class FallbackPolicy:
    """Retry/timeout/backoff policy driving the fallback chain.

    ``max_retries`` retries *per stage* (so a transient trace failure gets a
    second chance before the chain degrades), with ``backoff_s`` sleep
    growing by ``backoff_mult`` between attempts. ``timeout_s`` is the
    straggler threshold for a completed attempt (None = use only the
    watchdog's relative threshold)."""

    chain: tuple[str, ...] = DEFAULT_CHAIN
    max_retries: int = 1
    timeout_s: float | None = None
    backoff_s: float = 0.05
    backoff_mult: float = 2.0

    def __post_init__(self):
        unknown = set(self.chain) - set(DEFAULT_CHAIN)
        if unknown:
            raise ValueError(f"unknown fallback stages {sorted(unknown)}; have {DEFAULT_CHAIN}")
        if not self.chain:
            raise ValueError("fallback chain must name at least one stage")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")


@dataclasses.dataclass
class FallbackEvent:
    """One attempt in the chain, for logs and tests."""

    stage: str
    attempt: int
    outcome: str  # 'ok' | 'error' | 'straggler'
    elapsed_s: float
    error: str | None = None


@dataclasses.dataclass(frozen=True)
class StragglerReport:
    op: str
    algo: str
    M: int
    n: int
    measured_s: float
    expected_s: float

    @property
    def factor(self) -> float:
        return self.measured_s / self.expected_s if self.expected_s > 0 else math.inf


class Watchdog:
    """Compares observed collective timings against cost-model expectations.

    A measurement slower than ``straggler_factor`` x the plan's expectation
    (``decision.predicted_s``, falling back to the round-accurate simulator
    clock when the prediction is NaN — one-shot baselines) is flagged: the
    report is kept on :attr:`reports` and, when a tuner is attached, the
    observation lands via ``Tuner.record`` so the next planning pass sees
    the real link behavior and ``plan_cached`` keys move off the stale
    fingerprint.
    """

    def __init__(self, tuner: Optional[Tuner] = None, *, straggler_factor: float = 3.0,
                 on_straggler: Optional[Callable[[StragglerReport], None]] = None):
        if straggler_factor <= 1.0:
            raise ValueError("straggler_factor must be > 1")
        self.tuner = tuner
        self.straggler_factor = float(straggler_factor)
        self.on_straggler = on_straggler
        self.reports: list[StragglerReport] = []

    def expected_s(self, plan: CollectivePlan) -> float:
        exp = plan.predicted_s
        if not math.isfinite(exp) or exp <= 0.0:
            exp = plan.timed_rounds_s()
        return exp

    def observe(self, plan: CollectivePlan, measured_s: float) -> StragglerReport | None:
        """Feed one measurement; returns the report if it was a straggler."""
        exp = self.expected_s(plan)
        if exp <= 0.0 or measured_s <= self.straggler_factor * exp:
            return None
        rep = StragglerReport(
            op=plan.op, algo=plan.algo, M=plan.M, n=plan.n,
            measured_s=float(measured_s), expected_s=exp,
        )
        self.reports.append(rep)
        if self.tuner is not None:
            self.tuner.record(
                plan.M, plan.n, plan.algo, plan.num_chunks, float(measured_s),
                op=plan.op, inter_pod=plan.inter_pod, sizes=plan.sizes,
            )
        if self.on_straggler is not None:
            self.on_straggler(rep)
        return rep
