"""Overlap engine: tuned *schedules of* collectives (DESIGN.md Sec. 8).

The paper's end-to-end result (7% CNTK speedup at 128 GPUs, Sec. V-D) does
not come from any single collective — it comes from *pipelining*: the
chunked chain overlaps the stages of one broadcast, and the application win
comes from hiding communication behind training compute. Awan et al.
(1810.11112) show the same structure — bucketed collectives streamed
against backprop — is what makes CUDA-Aware MPI competitive for TF
training. This module is that layer for the ``repro.comm`` plan stack: it
turns a :class:`~repro.core.bucketing.BucketSpec` plus per-bucket
:class:`~repro.comm.plan.CollectivePlan`s into an *interleaved* execution.

Three pieces:

* :func:`plan_overlap` / :class:`OverlapPlan` — host-side planning: buckets
  are dispatched in REVERSE tree-flatten order (backward-order streaming,
  the DDP/Horovod pattern — gradients of late layers materialize first),
  and the in-flight window (``overlap_depth``) is chosen by
  :func:`repro.core.cost_model.t_overlapped` unless a tuner table carries a
  tuned depth for the bucket (``Decision.overlap_depth``).
* :func:`simulate_overlap` — a round-accurate discrete simulator that
  prices the overlapped timeline against the barrier schedule
  (``pallreduce_tree``'s all-compute-then-all-comm lowering) and accounts
  network idle rounds and wire bytes.
* :func:`execute_overlap` / :func:`overlap_allreduce_tree` — the traced
  execution: per-bucket collectives are IDENTICAL to the barrier path
  (same ``CollectivePlan``, same ``apply_plan`` lanes, bit-for-summation-
  order equal results); only the dispatch order and the ``chunked_copy``
  staging interleave differ, which is exactly what lets the XLA scheduler
  overlap bucket k+1's staging DMA with bucket k's in-flight collective.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from jax import lax

from ..core import bucketing, cost_model
from ..core.bucketing import BucketSpec
from ..core.tuner import Tuner, default_tuner
from . import api as comm_api
from .plan import CollectivePlan, plan_cached

__all__ = [
    "OverlapPlan",
    "plan_overlap",
    "simulate_overlap",
    "execute_overlap",
    "overlap_allreduce_tree",
]

# analytic depth sweep ceiling: every extra slot is a live staged bucket
# buffer in device memory, and t_overlapped flattens past a handful
_MAX_DEPTH = 8


@dataclasses.dataclass(frozen=True)
class OverlapPlan:
    """A fully-resolved schedule-of-collectives: bucket mix + per-(axis,
    bucket) plans + dispatch order + in-flight window."""

    op: str
    spec: BucketSpec
    axes: tuple[str, ...]                        # sync order (hierarchy levels)
    plans: dict[str, tuple[CollectivePlan, ...]]  # per axis, one plan per bucket
    order: tuple[int, ...]                       # bucket dispatch order
    overlap_depth: int
    compute_s: float                             # hidden-compute budget (s)
    depth_source: str                            # 'manual' | 'empirical' | 'analytic'

    @property
    def num_buckets(self) -> int:
        return self.spec.num_buckets

    def bucket_comm_s(self) -> list[float]:
        """Per-bucket predicted collective time, summed over hierarchy
        levels, in DISPATCH order."""
        return [
            sum(self.plans[ax][k].predicted_s for ax in self.axes)
            for k in self.order
        ]

    def bucket_stage_s(self, hw: cost_model.Hardware | None = None) -> list[float]:
        """Per-bucket staging (pack / ``chunked_copy``) time in dispatch
        order: one HBM read + one HBM write of the bucket."""
        hw = hw or cost_model.TPU_V5E
        sizes = self.spec.bucket_bytes()
        return [2.0 * sizes[k] / hw.hbm_bw for k in self.order]

    def wire_bytes(self) -> int:
        """Total bytes on the wire — exactly the sum of the per-bucket plan
        accounting (overlap reorders transfers, it never adds any)."""
        return sum(p.wire_bytes() for ax in self.axes for p in self.plans[ax])

    def barrier_s(self, hw: cost_model.Hardware | None = None) -> float:
        return cost_model.t_bucketed_barrier(
            self.bucket_comm_s(), self.compute_s, self.bucket_stage_s(hw)
        )

    def overlapped_s(self, hw: cost_model.Hardware | None = None) -> float:
        return cost_model.t_overlapped(
            self.bucket_comm_s(),
            self.compute_s,
            depth=self.overlap_depth,
            stage_s=self.bucket_stage_s(hw),
        )

    def efficiency(self, hw: cost_model.Hardware | None = None) -> float:
        """Fraction of the barrier schedule's span the overlap removes."""
        barrier = self.barrier_s(hw)
        if barrier <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.overlapped_s(hw) / barrier)


def plan_overlap(
    tree: Any,
    axes: Sequence[tuple[str, int]],
    *,
    op: str = "allreduce",
    root: int = 0,
    algo: str = "auto",
    tuner: Tuner | None = None,
    bucket_bytes: int = 4 << 20,
    inter_pod_axes: Sequence = (),
    compute_s: float = 0.0,
    overlap_depth: int | None = None,
    reverse: bool = True,
    spec: BucketSpec | None = None,
) -> OverlapPlan:
    """Resolve a schedule-of-collectives for ``tree`` over the mesh
    ``axes`` (name, size) pairs, hierarchy levels in the given order.

    Works on abstract leaves (``ShapeDtypeStruct``) — nothing is traced.
    ``reverse=True`` dispatches buckets in reverse tree-flatten order
    (gradient availability order during backprop); weight distribution
    passes ``reverse=False`` (buckets stream in load order).

    Depth resolution order: explicit ``overlap_depth`` > a tuned
    ``overlap_depth`` in the tuner's per-op table (largest bucket's entry)
    > the analytic :func:`cost_model.optimal_overlap_depth` sweep.
    """
    t = tuner or default_tuner()
    spec = spec if spec is not None else bucketing.plan_buckets(tree, bucket_bytes)
    inter = tuple(inter_pod_axes)
    plans: dict[str, tuple[CollectivePlan, ...]] = {}
    for ax, n in axes:
        plans[ax] = tuple(
            plan_cached(
                op, max(M, 1), n, root=root, algo=algo, tuner=t,
                inter_pod=(ax in inter),
            )
            for M in spec.bucket_bytes()
        )
    idx = range(spec.num_buckets)
    order = tuple(reversed(idx)) if reverse else tuple(idx)

    if overlap_depth is not None:
        depth, source = max(1, int(overlap_depth)), "manual"
    else:
        depth, source = None, "analytic"
        # consult the tuner table at the largest bucket (the depth that
        # matters — small tail buckets drain inside any window)
        sizes = spec.bucket_bytes()
        if sizes:
            k_big = max(range(len(sizes)), key=lambda k: sizes[k])
            for ax, _n in axes:
                d = plans[ax][k_big].decision.overlap_depth
                if d is not None:
                    depth, source = d, "empirical"
                    break
        if depth is None:
            oplan0 = OverlapPlan(op, spec, tuple(a for a, _ in axes), plans,
                                 order, 1, compute_s, "analytic")
            depth = cost_model.optimal_overlap_depth(
                oplan0.bucket_comm_s(), compute_s,
                stage_s=oplan0.bucket_stage_s(), max_depth=_MAX_DEPTH,
            )
    return OverlapPlan(
        op, spec, tuple(a for a, _ in axes), plans, order, depth, compute_s, source
    )


# ---------------------------------------------------------------------------
# round-accurate overlap simulator
# ---------------------------------------------------------------------------


def simulate_overlap(
    oplan: OverlapPlan, hw: cost_model.Hardware | None = None, faults=None
) -> dict:
    """Discrete-round replay of the overlapped timeline vs the barrier one.

    Time is discretized into network rounds: bucket b costs its schedules'
    round counts (summed over hierarchy levels; one-shot baselines count 1)
    plus its staging rounds (``bucket_stage_s`` over the mean round
    duration — this is what makes ``overlap_depth`` bind: staging of bucket
    k needs a free slot in the window, exactly as in
    :func:`cost_model.t_overlapped`). The backward pass produces one bucket
    (in dispatch order) every ``compute_rounds_per_bucket`` rounds —
    derived from ``compute_s`` and the mean round duration, floored at 1
    (even free compute produces buckets sequentially, never all at once).

    Returns idle-round and span accounting for both schedules. The
    guaranteed invariant (tested): for >= 2 non-empty buckets the overlapped
    schedule has STRICTLY fewer network-idle rounds than the barrier one —
    the network starts on bucket 0 while later buckets are still computing.

    With ``faults`` (a :class:`comm.faults.FaultSpec`), every bucket's clock
    runs through the degraded ``timed_rounds`` (slow links, retransmit
    inflation, stalls) — the round *structure* is untouched, so the idle
    accounting stays comparable and the extra keys (``comm_s_healthy`` /
    ``comm_s_faulty`` / ``fault_slowdown``) quantify the degradation. Dead
    ranks raise ``DeadRankError`` from the first bucket's replay.
    """
    hw = hw or cost_model.TPU_V5E
    rounds = []
    times = []
    healthy_times = []
    for k in oplan.order:
        r = 0
        t = 0.0
        t0 = 0.0
        for ax in oplan.axes:
            p = oplan.plans[ax][k]
            r += p.schedule.num_rounds if p.schedule is not None else (
                0 if p.algo == "noop" else 1
            )
            if p.schedule is not None:
                t0 += p.timed_rounds_s(hw)
                t += p.timed_rounds_s(hw, faults=faults) if faults is not None else 0.0
        rounds.append(max(r, 1))
        times.append(t if faults is not None else t0)
        healthy_times.append(t0)
    K = len(rounds)
    total_comm_rounds = sum(rounds)
    mean_round_s = (sum(times) / total_comm_rounds) if total_comm_rounds else hw.ts
    mean_round_s = max(mean_round_s, hw.ts)
    stage_rounds = [
        int(round(s / mean_round_s)) for s in oplan.bucket_stage_s(hw)
    ]
    total_stage_rounds = sum(stage_rounds)
    per_bucket_compute = max(
        1, int(round(oplan.compute_s / max(K, 1) / mean_round_s))
    ) if K else 0

    # barrier: all compute, then all staging, then every transfer
    barrier_span = K * per_bucket_compute + total_stage_rounds + total_comm_rounds
    barrier_idle = K * per_bucket_compute + total_stage_rounds

    # overlapped: the SAME greedy window recurrence the analytic depth
    # tuner prices (cost_model.window_finish_times), in integer rounds —
    # staging bucket k needs a free slot in the depth-deep window
    depth = max(1, min(oplan.overlap_depth, max(K, 1)))
    comm_end = cost_model.window_finish_times(
        [(k + 1) * per_bucket_compute for k in range(K)],
        stage_rounds,
        rounds,
        depth,
    )
    overlap_span = comm_end[-1] if K else 0
    overlap_idle = overlap_span - total_comm_rounds

    out = {
        "num_buckets": K,
        "overlap_depth": depth,
        "comm_rounds": total_comm_rounds,
        "compute_rounds": K * per_bucket_compute,
        "barrier_span_rounds": barrier_span,
        "overlap_span_rounds": overlap_span,
        "idle_rounds_barrier": barrier_idle,
        "idle_rounds_overlap": overlap_idle,
        "barrier_s": oplan.barrier_s(hw),
        "overlapped_s": oplan.overlapped_s(hw),
        "efficiency": oplan.efficiency(hw),
        "wire_bytes": oplan.wire_bytes(),
    }
    if faults is not None:
        healthy = sum(healthy_times)
        faulty = sum(times)
        out["comm_s_healthy"] = healthy
        out["comm_s_faulty"] = faulty
        out["fault_slowdown"] = faulty / healthy if healthy > 0 else 1.0
        out["fault_fingerprint"] = faults.fingerprint()
    return out


# ---------------------------------------------------------------------------
# traced execution (inside shard_map)
# ---------------------------------------------------------------------------


def execute_overlap(
    oplan: OverlapPlan,
    tree: Any,
    *,
    stage: bool = False,
    stage_chunk: int = 64 * 1024,
    fused: bool = True,
    compiled: bool | None = None,
) -> Any:
    """Replay an :class:`OverlapPlan` on concrete values inside
    ``shard_map``: buckets issue in dispatch order, and the next
    ``overlap_depth - 1`` buckets are staged (``chunked_copy`` when
    ``stage=True``) *before* the current bucket's collectives — the
    double-buffer interleave that lets the scheduler run staging DMA
    concurrently with the in-flight collective.

    Per-bucket math is identical to the barrier ``*_tree`` path (same
    plans, same executors), so results match it to float summation order.
    """
    buckets = bucketing.pack_buckets(tree, oplan.spec)
    order = [k for k in oplan.order if buckets[k].size]
    out: list = list(buckets)  # empty buckets pass through untouched

    staged: dict[int, Any] = {}

    def _stage(k: int) -> None:
        b = buckets[k]
        if stage:
            from ..kernels.chunked_copy import chunked_copy

            b = chunked_copy(b, chunk_elems=stage_chunk)
        staged[k] = b

    depth = max(1, oplan.overlap_depth)
    for i, k in enumerate(order):
        for j in order[i : i + depth]:   # keep the window staged ahead
            if j not in staged:
                _stage(j)
        b = staged.pop(k)
        for ax in oplan.axes:
            b = comm_api.apply_plan(
                oplan.plans[ax][k], b, ax, fused=fused, compiled=compiled
            )
        out[k] = b
    return bucketing.unpack_buckets(out, oplan.spec)


def overlap_allreduce_tree(
    tree: Any,
    axes: Sequence,
    *,
    algo: str = "auto",
    tuner: Tuner | None = None,
    bucket_bytes: int = 4 << 20,
    inter_pod_axes: Sequence = (),
    overlap_depth: int | None = None,
    compute_s: float = 0.0,
    stage: bool = False,
    stage_chunk: int = 64 * 1024,
    compiled: bool | None = None,
) -> Any:
    """Bucket-streamed hierarchical all-reduce: the overlap-engine analogue
    of :func:`repro.comm.api.pallreduce_tree` (same bucketing, same
    hierarchy levels, same per-bucket plans — results equal to summation
    order), with buckets dispatched in backward-streaming order inside the
    tuned in-flight window. Must be called inside ``shard_map`` with every
    axis in ``axes`` bound."""
    spec = bucketing.plan_buckets(tree, bucket_bytes)
    sized_axes = [(ax, lax.axis_size(ax)) for ax in axes]
    oplan = plan_overlap(
        tree,
        sized_axes,
        op="allreduce",
        algo=algo,
        tuner=tuner,
        bucket_bytes=bucket_bytes,
        inter_pod_axes=inter_pod_axes,
        compute_s=compute_s,
        overlap_depth=overlap_depth,
        reverse=True,
        spec=spec,
    )
    return execute_overlap(
        oplan, tree, stage=stage, stage_chunk=stage_chunk, compiled=compiled
    )
