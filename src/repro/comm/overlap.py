"""Overlap engine: tuned *schedules of* collectives (DESIGN.md Sec. 8).

The paper's end-to-end result (7% CNTK speedup at 128 GPUs, Sec. V-D) does
not come from any single collective — it comes from *pipelining*: the
chunked chain overlaps the stages of one broadcast, and the application win
comes from hiding communication behind training compute. Awan et al.
(1810.11112) show the same structure — bucketed collectives streamed
against backprop — is what makes CUDA-Aware MPI competitive for TF
training.

Since the multi-stream refactor (DESIGN.md Sec. 13) this module is the
SINGLE-STREAM special case of :mod:`repro.comm.streams`: an
:class:`OverlapPlan` is exactly a 1-entry :class:`~repro.comm.streams.StreamGraph`,
and every function here is a thin wrapper —

* :func:`plan_overlap` delegates to :func:`streams.plan_streams` with one
  :class:`~repro.comm.streams.StreamSpec` (same depth-resolution tiers,
  same ``plan_cached`` path keyed on the graph fingerprint);
* :func:`simulate_overlap` replays the 1-entry graph through
  :func:`streams.simulate_streams` (the multi-stream arbiter reduces
  bit-exactly to ``cost_model.window_finish_times`` for one stream) and
  re-shapes the accounting into the PR 4 keys;
* :func:`execute_overlap` / :func:`overlap_allreduce_tree` replay through
  :func:`streams.execute_stream_entry` — the identical staging-window
  loop, so traced programs are unchanged.

The wrappers are kept as the named entry points because every
single-stream consumer (trainer grad sync, bench_overlap, the overlap
table) speaks this vocabulary; multi-stream consumers use
``comm.streams`` directly.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

from jax import lax

from ..core import bucketing, cost_model
from ..core.bucketing import BucketSpec
from ..core.tuner import Tuner
from . import streams
from .plan import CollectivePlan

__all__ = [
    "OverlapPlan",
    "plan_overlap",
    "simulate_overlap",
    "execute_overlap",
    "overlap_allreduce_tree",
]

# analytic depth sweep ceiling (shared with the multi-stream planner)
_MAX_DEPTH = streams._MAX_DEPTH

# the canonical entry name a 1-stream graph carries
_ENTRY = "overlap"


@dataclasses.dataclass(frozen=True)
class OverlapPlan:
    """A fully-resolved schedule-of-collectives: bucket mix + per-(axis,
    bucket) plans + dispatch order + in-flight window. Exactly the payload
    of one :class:`~repro.comm.streams.StreamEntry` minus the arbitration
    metadata (a single stream has nothing to contend with)."""

    op: str
    spec: BucketSpec
    axes: tuple[str, ...]                        # sync order (hierarchy levels)
    plans: dict[str, tuple[CollectivePlan, ...]]  # per axis, one plan per bucket
    order: tuple[int, ...]                       # bucket dispatch order
    overlap_depth: int
    compute_s: float                             # hidden-compute budget (s)
    depth_source: str            # 'manual' | 'stream' | 'empirical' | 'analytic'

    def as_entry(self, name: str = _ENTRY, *, priority: int = 0,
                 link: str = "ici", after: tuple[str, ...] = ()) -> streams.StreamEntry:
        """This plan as a stream entry — the bridge every wrapper rides."""
        return streams.StreamEntry(
            name=name, op=self.op, spec=self.spec, axes=self.axes,
            plans=self.plans, order=self.order,
            overlap_depth=self.overlap_depth, compute_s=self.compute_s,
            depth_source=self.depth_source, priority=priority, after=after,
            link=link,
        )

    def as_graph(self) -> streams.StreamGraph:
        """This plan as a 1-entry stream graph (the backward-compat
        contract: its replay is bit-identical to this plan's)."""
        return streams.StreamGraph((self.as_entry(),))

    @property
    def num_buckets(self) -> int:
        return self.spec.num_buckets

    def bucket_comm_s(self) -> list[float]:
        """Per-bucket predicted collective time, summed over hierarchy
        levels, in DISPATCH order."""
        return self.as_entry().bucket_comm_s()

    def bucket_stage_s(self, hw: cost_model.Hardware | None = None) -> list[float]:
        """Per-bucket staging (pack / ``chunked_copy``) time in dispatch
        order: one HBM read + one HBM write of the bucket."""
        return self.as_entry().bucket_stage_s(hw)

    def wire_bytes(self) -> int:
        """Total bytes on the wire — exactly the sum of the per-bucket plan
        accounting (overlap reorders transfers, it never adds any)."""
        return self.as_entry().wire_bytes()

    def barrier_s(self, hw: cost_model.Hardware | None = None) -> float:
        return cost_model.t_bucketed_barrier(
            self.bucket_comm_s(), self.compute_s, self.bucket_stage_s(hw)
        )

    def overlapped_s(self, hw: cost_model.Hardware | None = None) -> float:
        return cost_model.t_overlapped(
            self.bucket_comm_s(),
            self.compute_s,
            depth=self.overlap_depth,
            stage_s=self.bucket_stage_s(hw),
        )

    def efficiency(self, hw: cost_model.Hardware | None = None) -> float:
        """Fraction of the barrier schedule's span the overlap removes."""
        barrier = self.barrier_s(hw)
        if barrier <= 0.0:
            return 0.0
        return max(0.0, 1.0 - self.overlapped_s(hw) / barrier)


def plan_overlap(
    tree: Any,
    axes: Sequence[tuple[str, int]],
    *,
    op: str = "allreduce",
    root: int = 0,
    algo: str = "auto",
    tuner: Tuner | None = None,
    bucket_bytes: int = 4 << 20,
    inter_pod_axes: Sequence = (),
    compute_s: float = 0.0,
    overlap_depth: int | None = None,
    reverse: bool = True,
    spec: BucketSpec | None = None,
) -> OverlapPlan:
    """Resolve a schedule-of-collectives for ``tree`` over the mesh
    ``axes`` (name, size) pairs, hierarchy levels in the given order.

    Works on abstract leaves (``ShapeDtypeStruct``) — nothing is traced.
    ``reverse=True`` dispatches buckets in reverse tree-flatten order
    (gradient availability order during backprop); weight distribution
    passes ``reverse=False`` (buckets stream in load order).

    Depth resolution order (the multi-stream planner's tiers): explicit
    ``overlap_depth`` > a ``stream:overlap`` tuner entry > a tuned
    ``overlap_depth`` in the tuner's per-op table (largest bucket's entry)
    > the analytic :func:`cost_model.optimal_overlap_depth` sweep.
    """
    graph = streams.plan_streams(
        [
            streams.StreamSpec(
                name=_ENTRY, tree=tree, axes=tuple(tuple(a) for a in axes),
                op=op, root=root, algo=algo, priority=0,
                overlap_depth=overlap_depth, compute_s=compute_s,
                bucket_bytes=bucket_bytes,
                inter_pod_axes=tuple(inter_pod_axes), reverse=reverse,
                spec=spec,
            )
        ],
        tuner=tuner,
    )
    e = graph.entries[0]
    return OverlapPlan(
        e.op, e.spec, e.axes, e.plans, e.order, e.overlap_depth, e.compute_s,
        e.depth_source,
    )


# ---------------------------------------------------------------------------
# round-accurate overlap simulator (1-entry graph replay)
# ---------------------------------------------------------------------------


def simulate_overlap(
    oplan: OverlapPlan, hw: cost_model.Hardware | None = None, faults=None
) -> dict:
    """Discrete-round replay of the overlapped timeline vs the barrier one.

    Delegates to :func:`streams.simulate_streams` on the 1-entry graph —
    for one stream the link arbiter IS the PR 4 greedy window recurrence
    (``cost_model.window_finish_times``), so every round number is
    identical to the pre-refactor simulator — and re-shapes the
    multi-stream accounting into the historical keys. The guaranteed
    invariant (tested): for >= 2 non-empty buckets the overlapped schedule
    has STRICTLY fewer network-idle rounds than the barrier one.

    With ``faults`` (a :class:`comm.faults.FaultSpec`), every bucket's clock
    runs through the degraded ``timed_rounds`` (slow links, retransmit
    inflation, stalls) — the round *structure* is untouched, so the idle
    accounting stays comparable and the extra keys (``comm_s_healthy`` /
    ``comm_s_faulty`` / ``fault_slowdown``) quantify the degradation. Dead
    ranks raise ``DeadRankError`` from the first bucket's replay.
    """
    hw = hw or cost_model.TPU_V5E
    sim = streams.simulate_streams(oplan.as_graph(), hw, faults=faults)
    s = sim["streams"][_ENTRY]
    K = s["num_buckets"]
    # barrier: all compute, then all staging, then every transfer
    barrier_idle = s["compute_rounds"] + s["stage_rounds"]
    out = {
        "num_buckets": K,
        "overlap_depth": max(1, min(oplan.overlap_depth, max(K, 1))),
        "comm_rounds": s["comm_rounds"],
        "compute_rounds": s["compute_rounds"],
        "barrier_span_rounds": barrier_idle + s["comm_rounds"],
        "overlap_span_rounds": s["finish_round"],
        "idle_rounds_barrier": barrier_idle,
        "idle_rounds_overlap": s["idle_rounds"],
        "barrier_s": oplan.barrier_s(hw),
        "overlapped_s": oplan.overlapped_s(hw),
        "efficiency": oplan.efficiency(hw),
        "wire_bytes": oplan.wire_bytes(),
    }
    if faults is not None:
        for key in ("comm_s_healthy", "comm_s_faulty", "fault_slowdown",
                    "fault_fingerprint"):
            out[key] = sim[key]
    return out


# ---------------------------------------------------------------------------
# traced execution (inside shard_map)
# ---------------------------------------------------------------------------


def execute_overlap(
    oplan: OverlapPlan,
    tree: Any,
    *,
    stage: bool = False,
    stage_chunk: int = 64 * 1024,
    fused: bool = True,
    compiled: bool | None = None,
) -> Any:
    """Replay an :class:`OverlapPlan` on concrete values inside
    ``shard_map``: buckets issue in dispatch order, and the next
    ``overlap_depth - 1`` buckets are staged (``chunked_copy`` when
    ``stage=True``) *before* the current bucket's collectives — the
    double-buffer interleave that lets the scheduler run staging DMA
    concurrently with the in-flight collective.

    Delegates to :func:`streams.execute_stream_entry` on the 1-entry
    graph: per-bucket math is identical to the barrier ``*_tree`` path
    (same plans, same executors), so results match it to float
    summation order.
    """
    return streams.execute_stream_entry(
        oplan.as_entry(), tree, stage=stage, stage_chunk=stage_chunk,
        fused=fused, compiled=compiled,
    )


def overlap_allreduce_tree(
    tree: Any,
    axes: Sequence,
    *,
    algo: str = "auto",
    tuner: Tuner | None = None,
    bucket_bytes: int = 4 << 20,
    inter_pod_axes: Sequence = (),
    overlap_depth: int | None = None,
    compute_s: float = 0.0,
    stage: bool = False,
    stage_chunk: int = 64 * 1024,
    compiled: bool | None = None,
) -> Any:
    """Bucket-streamed hierarchical all-reduce: the overlap-engine analogue
    of :func:`repro.comm.api.pallreduce_tree` (same bucketing, same
    hierarchy levels, same per-bucket plans — results equal to summation
    order), with buckets dispatched in backward-streaming order inside the
    tuned in-flight window. Must be called inside ``shard_map`` with every
    axis in ``axes`` bound."""
    spec = bucketing.plan_buckets(tree, bucket_bytes)
    sized_axes = [(ax, lax.axis_size(ax)) for ax in axes]
    oplan = plan_overlap(
        tree,
        sized_axes,
        op="allreduce",
        algo=algo,
        tuner=tuner,
        bucket_bytes=bucket_bytes,
        inter_pod_axes=inter_pod_axes,
        compute_s=compute_s,
        overlap_depth=overlap_depth,
        reverse=True,
        spec=spec,
    )
    return execute_overlap(
        oplan, tree, stage=stage, stage_chunk=stage_chunk, compiled=compiled
    )
