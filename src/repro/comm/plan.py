"""CollectivePlan: one tuned, inspectable decision + schedule per collective.

A plan is the host-side artifact the consumers (trainer sync, serving weight
distribution, hillclimb, benchmarks) share: which algorithm, how many chunks,
the predicted time, and the concrete schedule — all decided BEFORE tracing,
so the same object can be logged, costed, and executed. This is the "tuned
tables decide every collective" layer of DESIGN.md Sec. 3.
"""
from __future__ import annotations

import dataclasses
import math
from collections import OrderedDict

from ..core import cost_model
from ..core.schedules import ALGORITHMS, LoweredSchedule, Schedule, build, lower_schedule
from ..core.tuner import OPS, RAGGED_OPS, Decision, Tuner, default_tuner
from . import schedules as comm_schedules
from .compress import WireFormat, normalize_wire_format, wire_chunk_bytes

__all__ = [
    "CollectivePlan",
    "plan_collective",
    "plan_degraded",
    "plan_cached",
    "plan_cache_info",
    "plan_cache_clear",
    "cache_stats",
    "decide",
    "expected_wire_bytes",
]

# one-shot XLA baselines (no schedule; lowered to a native collective),
# and the ops each can legally implement — an op/one-shot mismatch must
# raise like a schedule-based mismatch does (build_op KeyError), not
# silently run the wrong collective
ONE_SHOT = {"xla_psum", "xla_allgather"}
_ONE_SHOT_OPS = {
    "xla_psum": ("bcast", "reduce", "allreduce"),
    "xla_allgather": ("bcast", "allgather"),
}

# ops whose schedules are pinned to num_chunks == n
_N_CHUNK_ALGOS = {
    "scatter_allgather",
    "ring_allreduce",
    "ring_allgather",
    "doubling_allgather",
    "ring_reduce_scatter",
}

_CHAIN_ALGOS = {"pipelined_chain", "bidir_chain", "pipelined_reduce_chain", "fused_rsb"}

# ragged algos: chunking is pinned by the size vector, not swept
_RAGGED_ALGOS = {
    "ring_allgatherv", "doubling_allgatherv", "pairwise_alltoallv", "ring_alltoallv",
}


def _norm_sizes(op: str, sizes, n: int) -> tuple[int, ...] | None:
    """Canonical size vector for cache keys and tuner pricing: a flat tuple
    of non-negative ints (alltoallv matrices flatten row-major)."""
    if sizes is None:
        return None
    if op not in RAGGED_OPS:
        raise ValueError(f"sizes= is only meaningful for {RAGGED_OPS}, not {op!r}")
    if op == "alltoallv":
        m = comm_schedules.alltoallv_matrix(sizes, n)
        return tuple(v for row in m for v in row)
    flat = tuple(int(s) for s in sizes)
    if len(flat) != n:
        raise ValueError(f"allgatherv sizes must have n={n} entries, got {len(flat)}")
    if any(s < 0 for s in flat):
        raise ValueError(f"sizes must be non-negative: {flat}")
    return flat


@dataclasses.dataclass(frozen=True)
class CollectivePlan:
    """A fully-resolved collective: op + decision + executable schedule."""

    op: str
    M: int                      # full logical payload (bytes)
    n: int
    root: int
    inter_pod: bool
    decision: Decision
    schedule: Schedule | None   # None for noop and the one-shot baselines
    # ragged ops: the canonical row-count vector (per rank for allgatherv,
    # per (src, dst) block row-major for alltoallv); None for uniform ops.
    # M == sum(sizes) * row_bytes, so wire accounting stays exact.
    sizes: tuple[int, ...] | None = None
    # degraded-mesh plans: survivors[i] is the PHYSICAL rank that plays
    # logical rank i of this plan's shrunk schedule (n == len(survivors)).
    # None for plans built on the full mesh.
    survivors: tuple[int, ...] | None = None

    @property
    def algo(self) -> str:
        return self.decision.algo

    @property
    def num_chunks(self) -> int:
        return self.decision.num_chunks

    @property
    def predicted_s(self) -> float:
        return self.decision.predicted_s

    @property
    def wire_format(self) -> WireFormat:
        return normalize_wire_format(self.decision.wire_format)

    def wire_bytes(self) -> int:
        """Total bytes on the wire across all links (schedule accounting:
        chunk-transfers x actual per-transfer wire size, which under a
        compressed format is the block-padded payload + scale sidecar —
        see :func:`repro.comm.compress.wire_chunk_bytes`). One-shot
        baselines are priced at their HLO equivalents: psum-bcast =
        2M(n-1)/n-ish ring, gather = n*M; noop = 0. One-shots never
        compress (``decide`` rejects the combination)."""
        if self.schedule is not None:
            chunk_bytes = math.ceil(self.M / max(self.schedule.num_chunks, 1))
            return self.schedule.wire_chunks() * wire_chunk_bytes(
                self.wire_format, chunk_bytes
            )
        if self.algo == "xla_psum":
            return 2 * self.M * (self.n - 1)  # mask + all-reduce (ring both phases)
        if self.algo == "xla_allgather":
            return self.n * self.M
        return 0

    def lowered(self) -> LoweredSchedule | None:
        """Dense round tables for the compiled executor (host-side, cached
        per schedule in ``core.schedules.lower_schedule``)."""
        return None if self.schedule is None else lower_schedule(self.schedule)

    def timed_rounds_s(self, hw: cost_model.Hardware | None = None, faults=None) -> float:
        """Round-accurate simulator clock for this plan's schedule; with a
        :class:`comm.faults.FaultSpec` the clock degrades (slow links, retry
        inflation, stalls) exactly as ``core.simulator.timed_rounds`` does."""
        from ..core.simulator import timed_rounds

        if self.schedule is None:
            return 0.0
        hw = hw or cost_model.TPU_V5E
        chunk_bytes = math.ceil(self.M / max(self.schedule.num_chunks, 1))
        return timed_rounds(
            self.schedule, chunk_bytes, hw.ts, hw.path_bw(self.inter_pod), faults=faults
        )


def decide(
    op: str,
    M: int,
    n: int,
    *,
    algo: str = "auto",
    num_chunks: int | None = None,
    tuner: Tuner | None = None,
    inter_pod: bool = False,
    sizes=None,
    exec_path: str | None = None,
    wire_format: str | None = None,
) -> Decision:
    """Resolve (op, M, n) to a Decision. ``algo='auto'`` consults the tuner;
    a manual algo gets analytic chunking AND an analytic ``predicted_s`` (so
    manual and auto decisions are comparable in reports — the old bcast path
    returned NaN here). Ragged ops take their row-count vector via
    ``sizes`` (see :meth:`Tuner.select`). An explicit ``exec_path``
    ('inkernel'|'compiled'|'unrolled') pins the executor tier on the
    Decision, overriding whatever the tuner's table carries; an explicit
    ``wire_format`` ('bf16'|'fp8'|'int8') likewise pins what the chunks
    look like on the wire. Compressed formats are scoped to the dense
    schedule-based ops — ragged ops and the XLA one-shots (whose transfers
    we don't own) reject them."""
    if op not in OPS:
        raise ValueError(f"unknown collective op {op!r}; have {OPS}")
    if exec_path is not None and exec_path not in ("inkernel", "compiled", "unrolled"):
        raise ValueError(
            f"exec_path must be 'inkernel'|'compiled'|'unrolled', got {exec_path!r}"
        )
    fmt = normalize_wire_format(wire_format)
    if fmt.compressed:
        if op in RAGGED_OPS:
            raise ValueError(
                f"compressed wire format {fmt.value!r} is not supported for "
                f"ragged op {op!r} (per-rank chunk sizes break the uniform "
                "block accounting)"
            )
        if algo in ONE_SHOT:
            raise ValueError(
                f"one-shot {algo!r} lowers to a native XLA collective — its "
                f"transfers cannot carry wire format {fmt.value!r}"
            )
    if algo in ONE_SHOT and op not in _ONE_SHOT_OPS[algo]:
        raise ValueError(
            f"one-shot {algo!r} cannot implement op {op!r} (valid for {_ONE_SHOT_OPS[algo]})"
        )
    t = tuner or default_tuner()
    sizes = _norm_sizes(op, sizes, n)
    if n <= 1:
        return Decision("noop", 1, max(M, 1), 0.0, "analytic")
    if algo == "auto":
        dec = t.select(M, n, op=op, inter_pod=inter_pod, sizes=sizes)
        if exec_path is not None and dec.algo != "noop":
            dec = dataclasses.replace(dec, exec_path=exec_path)
        if wire_format is not None and dec.algo != "noop":
            if fmt.compressed and dec.algo in ONE_SHOT:
                raise ValueError(
                    f"tuner selected one-shot {dec.algo!r} which cannot carry "
                    f"wire format {fmt.value!r}; pin a schedule-based algo"
                )
            dec = dataclasses.replace(dec, wire_format=fmt.value)
        return dec
    B = t.hw.path_bw(inter_pod)
    if num_chunks is None:
        if algo in _RAGGED_ALGOS:
            num_chunks = max(sum(sizes), 1) if sizes else n
        elif algo in ("pipelined_chain", "bidir_chain", "pipelined_reduce_chain"):
            # per-algorithm analytic chunking (a generic fallback of 8 chunks
            # made a 64-rank chain carry 5x extra fill/drain garbage —
            # EXPERIMENTS.md §Perf pair 3)
            hops = ((n - 1 + 1) // 2 + 1) if algo == "bidir_chain" else n
            c_star = cost_model.optimal_chunk_bytes(M, hops, t.hw, B)
            num_chunks = max(1, min(t.max_chunks, math.ceil(M / c_star)))
        elif algo == "fused_rsb":
            c_star = cost_model.optimal_chunk_bytes_fused(M, n, t.hw, B)
            num_chunks = max(1, min(t.max_chunks, math.ceil(M / c_star)))
        elif algo in _N_CHUNK_ALGOS:
            num_chunks = n
        elif algo == "reduce_then_bcast":
            num_chunks = t.select(M, n, op="bcast", inter_pod=inter_pod).num_chunks
        else:
            num_chunks = 1
    num_chunks = int(num_chunks)
    chunk = math.ceil(M / max(1, num_chunks))
    if algo in cost_model.ALGO_COSTS:
        kw = {"C": float(chunk)} if algo in _CHAIN_ALGOS else {}
        if algo == "reduce_then_bcast":
            inner = t.select(M, n, op="bcast", inter_pod=inter_pod)
            kw = {"t_bcast": inner.predicted_s}
        elif algo in _RAGGED_ALGOS and sizes is not None and sum(sizes) > 0:
            row_bytes = M / sum(sizes)
            kw = {"sizes": [s * row_bytes for s in sizes]}
        predicted = cost_model.cost(algo, M, n, t.hw, inter_pod=inter_pod, **kw)
    else:
        predicted = float("nan")  # one-shot baselines have no Eq. 1-6 model
    return Decision(algo, num_chunks, chunk, predicted, "manual",
                    exec_path=exec_path,
                    wire_format=None if wire_format is None else fmt.value)


def plan_collective(
    op: str,
    M: int,
    n: int,
    *,
    root: int = 0,
    algo: str = "auto",
    num_chunks: int | None = None,
    tuner: Tuner | None = None,
    inter_pod: bool = False,
    sizes=None,
    exec_path: str | None = None,
    wire_format: str | None = None,
) -> CollectivePlan:
    """Decide + build the executable schedule for one collective."""
    sizes = _norm_sizes(op, sizes, n)
    dec = decide(op, M, n, algo=algo, num_chunks=num_chunks, tuner=tuner,
                 inter_pod=inter_pod, sizes=sizes, exec_path=exec_path,
                 wire_format=wire_format)
    t = tuner or default_tuner()
    if dec.algo == "noop" or dec.algo in ONE_SHOT:
        return CollectivePlan(op, M, n, root, inter_pod, dec, None, sizes)
    if op == "bcast":
        kw = {}
        if dec.algo in ("pipelined_chain", "bidir_chain"):
            kw["num_chunks"] = dec.num_chunks
        elif dec.algo == "knomial":
            kw["k"] = t.knomial_k
        sched = build(dec.algo, n, root, **kw)
    elif dec.algo == "reduce_then_bcast":
        inner = decide("bcast", M, n, tuner=tuner, inter_pod=inter_pod)
        if inner.algo in ONE_SHOT or inner.algo == "noop":
            inner = dataclasses.replace(inner, algo="binomial", num_chunks=1)
        kw = {}
        if inner.algo in ("pipelined_chain", "bidir_chain"):
            kw["num_chunks"] = inner.num_chunks
        elif inner.algo == "knomial":
            kw["k"] = t.knomial_k
        bcast_sched = build(inner.algo, n, root, **kw)
        sched = comm_schedules.reduce_then_bcast(n, root, bcast_sched)
        dec = dataclasses.replace(dec, num_chunks=sched.num_chunks,
                                  chunk_bytes=math.ceil(M / max(1, sched.num_chunks)))
    else:
        sched = comm_schedules.build_op(op, dec.algo, n, root,
                                        num_chunks=dec.num_chunks, sizes=sizes)
        if sched.num_chunks != dec.num_chunks:
            dec = dataclasses.replace(dec, num_chunks=sched.num_chunks,
                                      chunk_bytes=math.ceil(M / max(1, sched.num_chunks)))
        if op in RAGGED_OPS:
            sizes = sched.sizes  # the builder's canonical (flattened) vector
    return CollectivePlan(op, M, n, root, inter_pod, dec, sched, sizes)


def _reprice_degraded(dec, op, M, n, t, inter_pod, sizes, slow_links):
    """Re-price a resolved decision under a degraded-link report via
    ``cost_model.cost_degraded`` — the same kw construction as the manual
    branch of :func:`decide`, evaluated at the degraded bandwidth."""
    algo = dec.algo
    if not slow_links or algo not in cost_model.ALGO_COSTS:
        return dec
    kw = {"C": float(dec.chunk_bytes)} if algo in _CHAIN_ALGOS else {}
    if algo == "reduce_then_bcast":
        inner = t.select(M, n, op="bcast", inter_pod=inter_pod)
        # conservative: scale the whole inner bcast by the worst factor
        # (the closed form would only scale its bandwidth term)
        kw = {"t_bcast": inner.predicted_s * cost_model.worst_link_factor(slow_links)}
    elif algo in _RAGGED_ALGOS and sizes is not None and sum(sizes) > 0:
        row_bytes = M / sum(sizes)
        kw = {"sizes": [s * row_bytes for s in sizes]}
    predicted = cost_model.cost_degraded(
        algo, M, n, t.hw, inter_pod=inter_pod, slow_links=slow_links, **kw
    )
    return dataclasses.replace(dec, predicted_s=predicted, source=dec.source + "+degraded")


def plan_degraded(
    op: str,
    M: int,
    n: int,
    health,
    *,
    root: int = 0,
    algo: str = "auto",
    num_chunks: int | None = None,
    tuner: Tuner | None = None,
    inter_pod: bool = False,
    sizes=None,
    exec_path: str | None = None,
    wire_format: str | None = None,
) -> CollectivePlan:
    """Replan one collective for a degraded mesh (:class:`comm.faults.MeshHealth`).

    Dead ranks shrink the mesh: the schedule is rebuilt from scratch on the
    ``n' = len(survivors)`` surviving ranks (rings/chains/trees simply omit
    the dead rank — the builders know nothing about the old mesh), the
    global row frame is remapped (allgather shards and ragged size vectors
    drop the dead ranks' segments), and ``plan.survivors`` records the
    logical-to-physical rank map. Slow links leave the schedule alone but
    re-price the decision through ``cost_model.cost_degraded``, so reports
    and the overlap tuner see the degraded clock.

    Typed failures: a dead root on bcast/reduce raises
    :class:`~..comm.faults.DeadRankError` (the data source is gone — only a
    checkpoint restore can recover), as does an empty survivor set.
    """
    from .faults import DeadRankError

    if health.n != n:
        raise ValueError(f"health report is for n={health.n}, plan asked n={n}")
    if health.healthy:
        return plan_collective(op, M, n, root=root, algo=algo, num_chunks=num_chunks,
                               tuner=tuner, inter_pod=inter_pod, sizes=sizes,
                               exec_path=exec_path, wire_format=wire_format)
    t = tuner or default_tuner()
    sizes = _norm_sizes(op, sizes, n)
    survivors = health.survivors()
    slow = health.surviving_slow_links()
    if not health.dead_ranks:
        # slow links only: same mesh, same schedule, degraded pricing
        plan = plan_collective(op, M, n, root=root, algo=algo, num_chunks=num_chunks,
                               tuner=t, inter_pod=inter_pod, sizes=sizes,
                               exec_path=exec_path, wire_format=wire_format)
        dec = _reprice_degraded(plan.decision, op, M, n, t, inter_pod, sizes, slow)
        return dataclasses.replace(plan, decision=dec)
    if len(survivors) == 0:
        raise DeadRankError(f"no surviving ranks in health report for n={n}")
    dead = set(health.dead_ranks)
    if root in dead:
        if op in ("bcast", "reduce"):
            raise DeadRankError(
                f"{op} root {root} is dead; its payload is unrecoverable from the "
                f"mesh — restore from checkpoint and replan with a live root"
            )
        new_root = 0
    else:
        new_root = survivors.index(root)
    n2 = len(survivors)
    # remap the global frame onto the survivor mesh
    sizes2 = None
    if op in RAGGED_OPS:
        sizes2 = comm_schedules.shrink_sizes(op, sizes, survivors)
        M2 = int(round(M / max(sum(sizes), 1) * sum(sizes2))) if sum(sizes) else 0
    elif op == "allgather":
        M2 = (M // n) * n2  # the dead ranks' shards leave the gathered frame
    else:
        M2 = M  # bcast/reduce/allreduce/reduce_scatter keep the full payload
    # remap surviving slow links into the survivor index space so degraded
    # pricing and any fault replay on the shrunk schedule line up
    pos = {r: i for i, r in enumerate(survivors)}
    slow2 = tuple(((pos[s], pos[d]), f) for (s, d), f in slow)
    plan = plan_collective(op, M2, n2, root=new_root, algo=algo, num_chunks=num_chunks,
                           tuner=t, inter_pod=inter_pod, sizes=sizes2,
                           exec_path=exec_path, wire_format=wire_format)
    dec = _reprice_degraded(plan.decision, op, M2, n2, t, inter_pod, plan.sizes, slow2)
    return dataclasses.replace(plan, decision=dec, survivors=survivors)


# ---------------------------------------------------------------------------
# host-side plan cache
#
# Trainers and serving engines resolve the SAME (op, M, n) points every step
# — re-pricing the tuner and re-building (and re-lowering) an identical
# schedule each call is pure host overhead at trace time. The cache key
# carries the tuner's content fingerprint, so any `Tuner.record` /
# `record_overlap` / `calibrate` (a new empirical row, a tuned depth)
# changes the key and stale plans are never replayed after calibration.
# ---------------------------------------------------------------------------

_PLAN_CACHE: "OrderedDict[tuple, CollectivePlan]" = OrderedDict()
_PLAN_CACHE_MAX = 512
_PLAN_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def plan_cached(
    op: str,
    M: int,
    n: int,
    *,
    root: int = 0,
    algo: str = "auto",
    num_chunks: int | None = None,
    tuner: Tuner | None = None,
    inter_pod: bool = False,
    sizes=None,
    health=None,
    exec_path: str | None = None,
    stream: str | None = None,
    wire_format: str | None = None,
) -> CollectivePlan:
    """LRU-cached :func:`plan_collective`. Key: (op, M, n, root, algo,
    num_chunks, inter_pod, sizes vector, exec_path, wire_format,
    stream-graph fingerprint, tuner fingerprint, health fingerprint). The
    buffer dtype
    is already folded into ``M`` (a byte count), so same-point calls from
    different dtypes correctly share one plan; ragged plans for different
    size vectors never collide (the canonical flat vector is in the key).
    Plans are frozen and their schedules immutable, so sharing the object
    across callers (and across traced programs) is safe; the pre-lowered
    round tables ride along via ``CollectivePlan.lowered()``'s own cache.

    ``health`` (a :class:`comm.faults.MeshHealth`) routes degraded meshes
    through :func:`plan_degraded`; its content fingerprint sits in the key
    beside the tuner fingerprint, so a health transition (a rank dying, a
    link degrading or recovering) can never serve a plan built for the
    pre-fault mesh. ``exec_path`` pins the executor tier on the Decision
    (see :func:`decide`); it is a key component so callers pinning
    different tiers never share a plan object. ``stream`` is the opaque
    stream-graph fingerprint from :func:`repro.comm.streams.plan_streams`
    — plans resolved inside one graph shape never leak into another (or
    into the stream-less single-collective path, which keys ``None``).

    Hit/miss/eviction counters are observable via :func:`cache_stats`."""
    if exec_path is not None and exec_path not in ("inkernel", "compiled", "unrolled"):
        raise ValueError(
            f"exec_path must be 'inkernel'|'compiled'|'unrolled', got {exec_path!r}"
        )
    t = tuner or default_tuner()
    sizes = _norm_sizes(op, sizes, n)
    key = (
        op,
        int(M),
        int(n),
        int(root),
        algo,
        None if num_chunks is None else int(num_chunks),
        bool(inter_pod),
        sizes,
        exec_path,
        None if wire_format is None else normalize_wire_format(wire_format).value,
        None if stream is None else str(stream),
        t.fingerprint(),
        None if health is None else health.fingerprint(),
    )
    plan = _PLAN_CACHE.get(key)
    if plan is not None:
        _PLAN_CACHE.move_to_end(key)
        _PLAN_CACHE_STATS["hits"] += 1
        return plan
    _PLAN_CACHE_STATS["misses"] += 1
    if health is not None and not health.healthy:
        plan = plan_degraded(
            op, M, n, health, root=root, algo=algo, num_chunks=num_chunks,
            tuner=t, inter_pod=inter_pod, sizes=sizes, exec_path=exec_path,
            wire_format=wire_format,
        )
    else:
        plan = plan_collective(
            op, M, n, root=root, algo=algo, num_chunks=num_chunks, tuner=t,
            inter_pod=inter_pod, sizes=sizes, exec_path=exec_path,
            wire_format=wire_format,
        )
    _PLAN_CACHE[key] = plan
    while len(_PLAN_CACHE) > _PLAN_CACHE_MAX:
        _PLAN_CACHE.popitem(last=False)
        _PLAN_CACHE_STATS["evictions"] += 1
    return plan


def cache_stats() -> dict:
    """Snapshot of the plan cache's observability counters: cumulative
    ``hits``/``misses``/``evictions`` since the last
    :func:`plan_cache_clear`, plus current ``size`` and ``maxsize``."""
    return dict(_PLAN_CACHE_STATS, size=len(_PLAN_CACHE), maxsize=_PLAN_CACHE_MAX)


# historical name — same snapshot
plan_cache_info = cache_stats


def plan_cache_clear() -> None:
    _PLAN_CACHE.clear()
    _PLAN_CACHE_STATS.update(hits=0, misses=0, evictions=0)


def expected_wire_bytes(op: str, algo: str, M: int, n: int, num_chunks: int = 1,
                        sizes=None, wire_format: str | None = None) -> float:
    """Closed-form bytes-on-wire accounting the property tests check the
    schedule-level accounting (``CollectivePlan.wire_bytes``) against.
    Ragged algos need the row-count vector: wire bytes depend on WHICH ranks
    (blocks) hold the rows, not just the total.

    ``wire_format`` applies :func:`repro.comm.compress.wire_chunk_bytes`
    to every dense transfer: each closed form below is (transfer count) x
    (per-transfer bytes), and compression acts on the per-transfer chunk —
    so the compress-table gate can demand EXACT equality between this form
    and the measured plan accounting. Ragged algos reject compressed
    formats (same scope rule as :func:`decide`)."""
    fmt = normalize_wire_format(wire_format)
    if n <= 1 or algo == "noop":
        return 0.0
    if algo in _RAGGED_ALGOS:
        if fmt.compressed:
            raise ValueError(
                f"compressed wire format {fmt.value!r} is not supported for "
                f"ragged algo {algo!r}"
            )
        sizes = _norm_sizes(op, sizes, n) if sizes is not None else None
        if sizes is None or sum(sizes) == 0:
            return 0.0
        row = M / sum(sizes)
        if algo == "ring_allgatherv":
            # every segment crosses n-1 ring edges
            return (n - 1) * sum(sizes) * row
        if algo == "doubling_allgatherv":
            # round t: each of the 2^t ranks holding a contiguous group of
            # 2^t segments sends it to its partner
            total, span = 0, 1
            while span < n:
                for base in range(0, n, span):
                    total += span * sum(sizes[base:min(base + span, n)])
                span *= 2
            return total * row
        m = comm_schedules.alltoallv_matrix(
            tuple(sizes[r * n:(r + 1) * n] for r in range(n))
            if len(sizes) == n * n else sizes, n)
        if algo == "pairwise_alltoallv":
            # every off-diagonal block crosses the wire exactly once
            return sum(m[s][d] for s in range(n) for d in range(n) if s != d) * row
        if algo == "ring_alltoallv":
            # store-and-forward: each block pays its hop count
            return sum(
                m[s][d] * ((d - s) % n) for s in range(n) for d in range(n)
            ) * row
    # every dense form is (transfer count) x (per-transfer chunk bytes);
    # the wire format transforms the per-transfer size, never the count
    chunk = math.ceil(M / max(1, num_chunks))
    share = math.ceil(M / n)
    if algo == "scatter_allgather":
        # (n/2)*log2(n) scatter chunk-sends + n*(n-1) ring chunk-sends
        count, per = (n // 2) * int(math.log2(n)) + n * (n - 1), share
    elif algo in ("ring_allgather", "ring_reduce_scatter"):
        count, per = n * (n - 1), share
    elif algo == "doubling_allgather":
        count, per = n * (n - 1), share  # sum_t n * 2^t = n (n - 1)
    elif algo == "ring_allreduce":
        count, per = 2 * n * (n - 1), share
    elif algo == "fused_rsb":
        count, per = 2 * (n - 1) * num_chunks, chunk
    elif algo == "reduce_then_bcast":
        raise ValueError("composite: account the two phases separately")
    else:
        # every tree/chain bcast (and its reduce mirror) moves the full
        # message over exactly n-1 edges
        count, per = (n - 1) * num_chunks, chunk
    return count * wire_chunk_bytes(fmt, per)
