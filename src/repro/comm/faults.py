"""Deterministic, seeded fault-injection layer for the collective runtime.

Everything here is host-side and pure: a :class:`FaultSpec` describes which
faults a replay should experience (slow links, stalled rounds, transient
drops, dead ranks) and every consequence of it is a deterministic function of
``(spec, schedule)`` — the same spec replayed twice produces the same
retries, the same timings, and the same typed errors.

The correctness contract of the whole fault subsystem lives in one sentence:
under every injected fault class, a replay either converges bit-identically
to the fault-free oracle or raises a typed :class:`FaultError` naming the
failure and the recovery action — never a silent wrong answer.

  * slow links / stalled rounds only stretch the simulated clock
    (``timed_rounds``); values are untouched;
  * transient drops are link-layer retransmits *within* the round — the
    payload that finally lands is the round-start snapshot, so values are
    bit-identical, and a drop streak exceeding the retry budget raises
    :class:`TransientDropError`;
  * a dead rank can neither send nor receive: any schedule that routes a
    transfer through it raises :class:`DeadRankError` pointing at
    degraded-mesh replanning (``comm.plan.plan_degraded``).

This module is a leaf: it imports only the stdlib and numpy, so
``core.simulator`` can consume specs by duck-typing (the spec raises its own
typed errors) without a core -> comm import cycle.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json

import numpy as np

__all__ = [
    "FaultError",
    "DeadRankError",
    "TransientDropError",
    "FallbackExhaustedError",
    "WeightSyncError",
    "FaultSpec",
    "MeshHealth",
]


class FaultError(RuntimeError):
    """Base of the typed fault taxonomy.

    Deliberately NOT retryable by the fallback chain: a FaultError carries a
    diagnosis and a recovery action (replan, restore, widen the retry
    budget), so retrying the same plan would just reproduce it.
    """


class DeadRankError(FaultError):
    """A schedule routes traffic through a rank reported dead."""


class TransientDropError(FaultError):
    """A link dropped the same transfer more times than the retry budget."""


class FallbackExhaustedError(FaultError):
    """Every stage of the resilient fallback chain failed."""


class WeightSyncError(FaultError):
    """Serving weight distribution failed; weights were drained to disk."""


def _norm_links(links) -> tuple[tuple[tuple[int, int], float], ...]:
    """Normalize a {(src, dst): factor} mapping or pair-iterable into a
    sorted, hashable tuple of ((src, dst), factor)."""
    items = links.items() if isinstance(links, dict) else links
    out = []
    for (src, dst), factor in items:
        factor = float(factor)
        if factor < 1.0:
            raise ValueError(f"link slowdown factor must be >= 1, got {factor} for {(src, dst)}")
        out.append(((int(src), int(dst)), factor))
    return tuple(sorted(out))


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """A deterministic fault scenario.

    ``link_slowdown``
        ((src, dst), factor) pairs; the link's effective bandwidth is
        divided by ``factor`` (>= 1) in ``timed_rounds``.
    ``stalled_rounds`` / ``stall_s``
        round indices that pause the whole mesh for ``stall_s`` seconds
        (e.g. a host preemption between rounds).
    ``drop_prob`` / ``max_drop_retries``
        per-transfer probability that a send is dropped and retransmitted;
        retransmit streaks are drawn from a generator seeded by
        ``(seed, round, src, dst)`` so they are independent of replay
        order. A streak longer than ``max_drop_retries`` raises
        :class:`TransientDropError`.
    ``dead_ranks``
        ranks that are gone; touching one raises :class:`DeadRankError`.
    """

    seed: int = 0
    link_slowdown: tuple[tuple[tuple[int, int], float], ...] = ()
    stalled_rounds: tuple[int, ...] = ()
    stall_s: float = 1e-3
    drop_prob: float = 0.0
    max_drop_retries: int = 3
    dead_ranks: tuple[int, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "link_slowdown", _norm_links(self.link_slowdown))
        object.__setattr__(
            self, "stalled_rounds", tuple(sorted({int(r) for r in self.stalled_rounds}))
        )
        object.__setattr__(
            self, "dead_ranks", tuple(sorted({int(r) for r in self.dead_ranks}))
        )
        if not 0.0 <= self.drop_prob < 1.0:
            raise ValueError(f"drop_prob must be in [0, 1), got {self.drop_prob}")
        if self.max_drop_retries < 0:
            raise ValueError("max_drop_retries must be >= 0")

    # -- clock effects ----------------------------------------------------
    def slowdown(self, src: int, dst: int) -> float:
        """Bandwidth-division factor for one directed link (1.0 = healthy)."""
        for (s, d), factor in self.link_slowdown:
            if (s, d) == (src, dst):
                return factor
        return 1.0

    @property
    def retry_factor(self) -> float:
        """Expected wire-traffic inflation from retransmits: a transfer is
        sent 1/(1-p) times in expectation under per-send drop prob p."""
        return 1.0 / (1.0 - self.drop_prob) if self.drop_prob > 0.0 else 1.0

    # -- value effects ----------------------------------------------------
    def check_alive(self, schedule) -> None:
        """Raise :class:`DeadRankError` if the schedule routes any transfer
        through a dead rank. Called by the simulator before replay."""
        dead = set(self.dead_ranks)
        if not dead:
            return
        for ridx, rnd in enumerate(schedule.rounds):
            for t in rnd.transfers:
                for r in (t.src, t.dst):
                    if r in dead:
                        raise DeadRankError(
                            f"{schedule.name}: round {ridx} routes {t.src}->{t.dst} "
                            f"through dead rank {r}; rebuild the schedule on the "
                            f"surviving ranks (comm.plan.plan_degraded) or restore "
                            f"from checkpoint if rank {r} held unreplicated state"
                        )

    def check_alive_pairs(self, pairs, context: str = "lowered schedule") -> None:
        """Dead-rank check over raw (src, dst) pairs (lowered-schedule path,
        where the round structure has been compiled away)."""
        dead = set(self.dead_ranks)
        if not dead:
            return
        for src, dst in pairs:
            for r in (src, dst):
                if r in dead:
                    raise DeadRankError(
                        f"{context}: lane routes {src}->{dst} through dead rank {r}; "
                        f"rebuild the schedule on the surviving ranks "
                        f"(comm.plan.plan_degraded)"
                    )

    def retries(self, round_idx: int, src: int, dst: int, tag: int = 0) -> int:
        """Number of retransmits the (round, link) transfer suffers before
        landing. Deterministic in (seed, round, src, dst, tag); raises
        :class:`TransientDropError` when the streak exceeds the budget."""
        if self.drop_prob <= 0.0:
            return 0
        rng = np.random.default_rng((self.seed, 0xFA17, round_idx, src, dst, tag))
        k = 0
        while rng.random() < self.drop_prob:
            k += 1
            if k > self.max_drop_retries:
                raise TransientDropError(
                    f"round {round_idx}: link {src}->{dst} dropped the same transfer "
                    f"{k} times (budget {self.max_drop_retries}); treat the link as "
                    f"down and replan with a slow-link/dead-rank health report"
                )
        return k

    # -- identity ---------------------------------------------------------
    @property
    def healthy(self) -> bool:
        return (
            not self.link_slowdown
            and not self.stalled_rounds
            and self.drop_prob == 0.0
            and not self.dead_ranks
        )

    def fingerprint(self) -> str:
        """Stable content hash — composes into plan-cache keys."""
        payload = json.dumps(dataclasses.astuple(self), sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class MeshHealth:
    """What the runtime currently believes about an n-rank mesh.

    This is the *report* side of the fault model: a FaultSpec injects faults
    into a replay, a MeshHealth summarizes observed faults for the planner.
    ``plan_cached`` keys on :meth:`fingerprint` so a health transition can
    never serve a plan built for the pre-fault mesh.
    """

    n: int
    dead_ranks: tuple[int, ...] = ()
    slow_links: tuple[tuple[tuple[int, int], float], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "dead_ranks", tuple(sorted({int(r) for r in self.dead_ranks})))
        object.__setattr__(self, "slow_links", _norm_links(self.slow_links))
        for r in self.dead_ranks:
            if not 0 <= r < self.n:
                raise ValueError(f"dead rank {r} outside mesh of {self.n}")

    @classmethod
    def from_fault_spec(cls, n: int, spec: FaultSpec) -> "MeshHealth":
        return cls(n=n, dead_ranks=spec.dead_ranks, slow_links=spec.link_slowdown)

    @property
    def healthy(self) -> bool:
        return not self.dead_ranks and not self.slow_links

    def survivors(self) -> tuple[int, ...]:
        dead = set(self.dead_ranks)
        return tuple(r for r in range(self.n) if r not in dead)

    def surviving_slow_links(self) -> tuple[tuple[tuple[int, int], float], ...]:
        """Slow links whose both endpoints survive — the ones that still
        price into a degraded plan after dead ranks are dropped."""
        dead = set(self.dead_ranks)
        return tuple(
            ((s, d), f) for (s, d), f in self.slow_links if s not in dead and d not in dead
        )

    def fingerprint(self) -> str:
        payload = json.dumps([self.n, self.dead_ranks, self.slow_links], sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:16]
