"""Rule-based layout: PartitionSpecs for params, batches, and KV caches.

One source of truth for how every tensor in the system lands on a
``(data, model)`` or ``(pod, data, model)`` mesh.  The serving engine, the
trainer, and the dry-run all consume these specs; ``core.bcast`` derives
its hierarchical axes from the same mesh metadata (``dist.topology``), so
collective tuning and tensor layout stay co-designed.

Layout rules (the fallback policy is per-dim: any dim not divisible by the
product of its mesh-axis sizes is replicated instead):

parameters (``param_specs``)
  * attention: the heads dim shards on ``model`` (q-heads for wq/wo,
    kv-heads for wk/wv).  Non-divisible head counts (hymba's 25, MQA's 1)
    fall back per ``attn_fallback``: ``"replicate"`` (train/prefill — a
    head_dim shard would all-reduce score blocks every layer) or
    ``"head_dim"`` (decode — serving memory wins).
  * MoE: the expert dim shards on ``model`` when divisible (qwen3's 128
    experts), else the expert FFN width does (mixtral's 8 < 16); shared
    experts follow the dense-MLP rule.
  * dense matmuls: the FFN-width / output-feature dim shards on ``model``.
  * FSDP (``fsdp=True``, the training default) additionally shards the
    d_model-side dim over the data axes — ('pod','data') jointly when
    divisible, else 'data' alone, else replicated.  ``fsdp=False``
    (serving) never places a data axis: weights are broadcast, not
    gathered per step.
  * norm scales, 1-D biases, and scalars replicate.

batches (``batch_specs``)
  * dim 0 (global batch) shards over the joint data axes, falling back to
    'data' alone, then replication (long-context batch=1).

KV caches (``cache_specs``)
  * k/v ``(B, S, KV, hd)``: batch over the data axes; kv-heads on
    ``model`` when divisible, else the sequence dim takes ``model``
    (flash-decoding split).  When the batch cannot shard (long_500k's
    B=1), the sequence dim also takes 'data'.
  * recurrent state (mamba/mLSTM/sLSTM): batch over data axes; the widest
    trailing state dim on ``model``.
  * position rings replicate.

Specs are always full-rank: ``len(spec) == leaf.ndim``.  Scan-stacked
block leaves (under a ``'blocks'`` key) get a leading ``None`` for the
superblock dim.  Functions only read ``mesh.axis_names`` /
``mesh.devices.shape``, so they run on abstract stand-ins with no devices.
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

from .topology import DP_AXES, TP_AXIS, axis_sizes

__all__ = ["param_specs", "batch_specs", "cache_specs"]

_ATTN_PROJ = {"wq", "wk", "wv", "wo", "bq", "bk", "bv"}


def _key_names(path) -> list:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(f"[{k.idx}]")
        elif hasattr(k, "name"):
            names.append(str(k.name))
        else:  # pragma: no cover - unknown path entry kinds
            names.append(str(k))
    return names


class _Axes:
    """Divisibility-checked axis assignment for one mesh. Also reused by
    ``dist.hints`` so the activation fallback policy cannot drift from the
    tensor-layout one (``dp``/``tp`` override the topology defaults)."""

    def __init__(self, mesh, *, dp=None, tp=None):
        self.sizes = axis_sizes(mesh)
        tp = TP_AXIS if tp is None else tp
        self.tp = tp if tp in self.sizes else None
        self.dp = tuple(a for a in (DP_AXES if dp is None else dp) if a in self.sizes)

    def fits(self, dim: int, axes) -> bool:
        if not axes:
            return False
        axes = axes if isinstance(axes, tuple) else (axes,)
        return dim % math.prod(self.sizes[a] for a in axes) == 0

    def tp_if_divisible(self, dim: int):
        return self.tp if (self.tp and self.fits(dim, self.tp)) else None

    def dp_if_divisible(self, dim: int):
        """Joint data axes when divisible, else the innermost data axis
        alone, else None."""
        if self.dp and self.fits(dim, self.dp):
            return self.dp
        if len(self.dp) > 1 and self.fits(dim, self.dp[-1]):
            return self.dp[-1:]
        return None


def _stacked(names) -> int:
    """Leaves under a 'blocks' key carry a leading scan-stacked dim."""
    return 1 if "blocks" in names else 0


def param_specs(shapes: Any, mesh, *, fsdp: bool = True,
                attn_fallback: str = "replicate") -> Any:
    """PartitionSpec tree for a parameter tree (see module layout rules).

    ``shapes``: pytree of arrays or ShapeDtypeStructs (``Model.param_shapes``).
    ``fsdp``: additionally shard the d_model-side dim over the data axes.
    ``attn_fallback``: 'replicate' | 'head_dim' — what to do with attention
    projections whose head count does not divide the ``model`` axis.
    """
    if attn_fallback not in ("replicate", "head_dim"):
        raise ValueError(f"attn_fallback must be 'replicate' or 'head_dim', got {attn_fallback!r}")
    ax = _Axes(mesh)

    def one(path, leaf):
        names = _key_names(path)
        stacked = _stacked(names)
        dims = list(leaf.shape[stacked:])
        ent = [None] * len(dims)
        leaf_key = names[-1] if names else ""
        in_attn = ("attn" in names or "cross" in names) and leaf_key in _ATTN_PROJ
        in_moe = "moe" in names and "shared" not in names

        def fsdp_put(i):
            if fsdp and ent[i] is None:
                ent[i] = ax.dp_if_divisible(dims[i])

        def head_rule(i_heads, i_hd):
            got = ax.tp_if_divisible(dims[i_heads])
            if got is not None:
                ent[i_heads] = got
            elif attn_fallback == "head_dim":
                ent[i_hd] = ax.tp_if_divisible(dims[i_hd])

        if len(dims) <= 1:
            pass  # scalars, norm scales, 1-D biases: replicate
        elif in_attn:
            if leaf_key in ("wq", "wk", "wv"):      # (d, H|KV, hd)
                head_rule(-2, -1)
                fsdp_put(-3)
            elif leaf_key == "wo":                  # (H, hd, d)
                head_rule(-3, -2)
                fsdp_put(-1)
            else:                                   # bq/bk/bv (H|KV, hd)
                head_rule(-2, -1)
        elif in_moe and leaf_key == "router":       # (d, E)
            ent[-1] = ax.tp_if_divisible(dims[-1])
            fsdp_put(-2)
        elif in_moe and leaf_key in ("w_gate", "w_up", "w_down"):
            # w_gate/w_up: (E, d, f); w_down: (E, f, d)
            i_ff = -1 if leaf_key != "w_down" else -2
            i_dm = -2 if leaf_key != "w_down" else -1
            got = ax.tp_if_divisible(dims[-3])
            if got is not None:
                ent[-3] = got                        # expert parallelism
            else:
                ent[i_ff] = ax.tp_if_divisible(dims[i_ff])  # expert-FFN shard
            fsdp_put(i_dm)
        elif "embed" in names and leaf_key in ("tokens", "unembed"):  # (V, D)
            ent[-2] = ax.tp_if_divisible(dims[-2])
            fsdp_put(-1)
        elif leaf_key in ("w_up", "w_gate", "w_down"):  # dense / shared MLP
            i_ff = -1 if leaf_key != "w_down" else -2
            i_dm = -2 if leaf_key != "w_down" else -1
            ent[i_ff] = ax.tp_if_divisible(dims[i_ff])
            fsdp_put(i_dm)
        else:
            # generic matmul-ish leaf (SSM projections, gates, recurrent
            # kernels): output-feature dim on `model`, FSDP on the input dim
            ent[-1] = ax.tp_if_divisible(dims[-1])
            if len(dims) >= 2 and ent[0] is None:
                fsdp_put(0)
        return P(*([None] * stacked + ent))

    return jax.tree_util.tree_map_with_path(one, shapes)


def batch_specs(tree: Any, mesh) -> Any:
    """PartitionSpecs for model inputs: dim 0 (global batch) over the joint
    data axes when divisible, else 'data', else replicated."""
    ax = _Axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        return P(ax.dp_if_divisible(leaf.shape[0]), *([None] * (leaf.ndim - 1)))

    return jax.tree.map(one, tree)


def cache_specs(tree: Any, mesh, cfg) -> Any:
    """PartitionSpecs for a decode/prefill cache tree (see layout rules).

    ``cfg`` is accepted for rule symmetry with the engine call sites; the
    rules themselves are shape-driven so they hold for windowed ring
    buffers, cross caches, and recurrent state alike.
    """
    del cfg  # shape-driven; see docstring
    ax = _Axes(mesh)

    def one(path, leaf):
        names = _key_names(path)
        stacked = _stacked(names)
        dims = list(leaf.shape[stacked:])
        ent = [None] * len(dims)
        leaf_key = names[-1] if names else ""

        if leaf_key in ("k", "v") and len(dims) == 4:   # (B, S, KV, hd)
            B, S, KV, _hd = dims
            b_ax = ax.dp_if_divisible(B)
            ent[0] = b_ax
            seq = []
            if ax.tp_if_divisible(KV) is not None:
                ent[2] = ax.tp                      # kv-head sharding
            elif ax.tp_if_divisible(S) is not None:
                seq.append(ax.tp)                   # flash-decoding: seq on model
            if b_ax is None and "data" in ax.sizes and ax.fits(S, "data"):
                seq.insert(0, "data")               # long-context: seq on data
            if seq:
                ent[1] = tuple(seq) if len(seq) > 1 else seq[0]
        elif leaf_key == "pos" or len(dims) <= 1:
            pass                                    # position rings replicate
        else:
            # recurrent state (B, ...): batch over data axes; the widest
            # trailing divisible dim takes `model`.
            ent[0] = ax.dp_if_divisible(dims[0])
            trailing = sorted(range(1, len(dims)), key=lambda i: -dims[i])
            for i in trailing:
                if ax.tp_if_divisible(dims[i]) is not None:
                    ent[i] = ax.tp
                    break
        return P(*([None] * stacked + ent))

    return jax.tree_util.tree_map_with_path(one, tree)
