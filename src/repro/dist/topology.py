"""Mesh metadata: the single source of truth for axis roles.

Every layer that needs to know "which axes are data-parallel", "which axis
is tensor-parallel", or "which axes cross the slow inter-pod fabric" reads
it from here, keyed off the mesh itself — mirroring how MVAPICH2-GDR's
hierarchical designs key their intra/inter-node split off the node
topology.  Consumers: ``repro.dist.sharding`` (placement rules),
``core.bcast.hierarchical_bcast`` (per-level broadcast axes and inter-pod
pricing), ``serve.engine.distribute_weights`` and the trainer.

Helpers take any mesh-like object exposing ``axis_names`` and
``devices.shape`` (a real ``jax.sharding.Mesh`` or a test stand-in); none
of them touch jax device state.
"""
from __future__ import annotations

import math

__all__ = [
    "DP_AXES",
    "TP_AXIS",
    "INTER_POD_AXES",
    "axis_sizes",
    "dp_axes",
    "dp_size",
    "tp_axis",
    "tp_size",
    "inter_pod_axes",
    "is_inter_pod",
    "bcast_axes",
]

# Conventional axis roles; meshes use a subset of these names.
DP_AXES = ("pod", "data")     # batch / FSDP axes (outer-to-inner order)
TP_AXIS = "model"             # tensor-parallel axis
INTER_POD_AXES = ("pod",)     # axes priced with inter-pod constants


def axis_sizes(mesh) -> dict:
    """``{axis_name: size}`` for any mesh-like object."""
    return dict(zip(tuple(mesh.axis_names), tuple(mesh.devices.shape)))


def dp_axes(mesh) -> tuple:
    """Data-parallel axes present on ``mesh``: ('pod','data') on a 3-axis
    mesh, ('data',) on a 2-axis one."""
    return tuple(a for a in mesh.axis_names if a in DP_AXES)


def dp_size(mesh) -> int:
    sizes = axis_sizes(mesh)
    return math.prod(sizes[a] for a in dp_axes(mesh)) if dp_axes(mesh) else 1


def tp_axis(mesh):
    """The tensor-parallel axis name, or None if the mesh has none."""
    return TP_AXIS if TP_AXIS in tuple(mesh.axis_names) else None


def tp_size(mesh) -> int:
    ax = tp_axis(mesh)
    return axis_sizes(mesh)[ax] if ax else 1


def inter_pod_axes(mesh) -> tuple:
    """Axes of ``mesh`` that cross the slow inter-pod fabric (the tuner's
    ``inter_pod`` path class prices broadcasts over these)."""
    return tuple(a for a in mesh.axis_names if a in INTER_POD_AXES)


def is_inter_pod(axis) -> bool:
    return axis in INTER_POD_AXES


def bcast_axes(mesh) -> tuple:
    """Per-level axis order for hierarchical broadcast: the inter-pod level
    first (pod leaders exchange), then the intra-pod data axes fan out."""
    dp = dp_axes(mesh)
    return tuple(a for a in dp if a in INTER_POD_AXES) + tuple(
        a for a in dp if a not in INTER_POD_AXES
    )
