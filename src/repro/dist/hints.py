"""Activation sharding hints.

The model code marks activation cut-points with ``hint(x, kind)``; with no
ambient context the call is the identity, so smoke tests and single-device
runs never touch sharding machinery.  The dry-run (and any production
launcher) wraps lowering in ``activation_hints(mesh, ...)``, which turns
each marked point into a ``with_sharding_constraint`` against specs derived
from the same mesh metadata as ``dist.sharding``.

Kinds:
  * ``"btd"``     — (B, T, D) residual-stream entry: batch over data axes.
  * ``"btd_res"`` — per-block residual: same, plus sequence over ``model``
    when ``seq_shard=True`` (sequence-parallel residuals).
  * ``"btv"``     — (B, T, V) logits: batch over data axes, vocab over
    ``model``.

Per-dim divisibility fallback matches ``dist.sharding``: a dim that does
not divide its axis product is left unconstrained.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from .sharding import _Axes

__all__ = ["hint", "activation_hints"]

_STACK: list = []


class _HintCtx:
    """Axis assignment delegates to ``sharding._Axes`` so the divisibility
    fallback (joint data axes -> innermost data axis -> replicate) is the
    same policy the tensor layouts use."""

    def __init__(self, mesh, dp: Optional[tuple], tp: Optional[str], seq_shard: bool):
        self.mesh = mesh
        self.ax = _Axes(mesh, dp=dp, tp=tp)
        self.seq_shard = seq_shard

    def spec_for(self, kind: str, shape) -> Optional[P]:
        if len(shape) != 3:
            return None
        ax = self.ax
        B, T, V = shape
        b_ax = ax.dp_if_divisible(B)
        if kind in ("btd", "btd_res"):
            t_ax = None
            if kind == "btd_res" and self.seq_shard:
                t_ax = ax.tp_if_divisible(T)
            return P(b_ax, t_ax, None)
        if kind == "btv":
            return P(b_ax, None, ax.tp_if_divisible(V))
        raise ValueError(f"unknown hint kind {kind!r}")


@contextlib.contextmanager
def activation_hints(mesh, *, dp=None, tp=None, seq_shard=False):
    """Activate activation-sharding hints for tracing under ``mesh``.

    ``dp``/``tp`` default to the topology role constants (DP_AXES /
    TP_AXIS) via ``_Axes``; pass explicit names only to override them."""
    _STACK.append(_HintCtx(mesh, dp if dp is None else tuple(dp), tp, seq_shard))
    try:
        yield
    finally:
        _STACK.pop()


def hint(x, kind: str):
    """Constrain ``x``'s sharding at a named cut-point (identity when no
    ``activation_hints`` context is active)."""
    if not _STACK:
        return x
    ctx = _STACK[-1]
    spec = ctx.spec_for(kind, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
