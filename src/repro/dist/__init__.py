"""repro.dist — sharding subsystem: mesh topology roles, layout rules, and
activation hints. One source of truth for how tensors land on the mesh."""
from . import topology
from .hints import activation_hints, hint
from .sharding import batch_specs, cache_specs, param_specs
from .topology import (
    axis_sizes,
    bcast_axes,
    dp_axes,
    dp_size,
    inter_pod_axes,
    is_inter_pod,
    tp_axis,
    tp_size,
)

__all__ = [
    "topology",
    "param_specs",
    "batch_specs",
    "cache_specs",
    "hint",
    "activation_hints",
    "axis_sizes",
    "dp_axes",
    "dp_size",
    "tp_axis",
    "tp_size",
    "inter_pod_axes",
    "is_inter_pod",
    "bcast_axes",
]
