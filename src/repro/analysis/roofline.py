"""Three-term roofline from a compiled dry-run artifact.

    compute    = FLOPs_global   / (chips * peak_FLOP/s)
    memory     = bytes_global   / (chips * HBM_bw)
    collective = wire_bytes_global / (chips * link_bw)

Per-device quantities come from the parsed post-SPMD HLO (trip-count
corrected — see analysis.hlo); global = per-device * chips. We report the
raw ``cost_analysis()`` numbers alongside for comparison (they undercount
loop bodies). MODEL_FLOPS = 6*N*D (N = active params for MoE) gives the
"useful fraction" ratio that catches remat/redundancy waste.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..core.cost_model import TPU_V5E, Hardware
from .hlo import parse_hlo

__all__ = ["RooflineReport", "analyze_compiled", "model_flops"]


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device, trip-corrected
    dot_flops_dev: float
    dot_bytes_dev: float
    wire_bytes_dev: float
    wire_by_family: dict
    collective_counts: dict
    # raw cost_analysis (per device, loop bodies counted once)
    xla_flops_dev: float
    xla_bytes_dev: float
    # memory analysis
    bytes_per_device: float
    # model-level
    model_flops_total: float
    unknown_trips: int

    hw: Hardware = TPU_V5E

    # ---- terms (seconds) ----
    @property
    def t_compute(self) -> float:
        return self.dot_flops_dev / self.hw.peak_flops

    @property
    def t_memory(self) -> float:
        return self.dot_bytes_dev / self.hw.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_dev / self.hw.link_bw

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.dot_flops_dev * self.chips
        return self.model_flops_total / total if total else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops_total,
            "hlo_flops_global": self.dot_flops_dev * self.chips,
            "useful_flops_ratio": self.useful_flops_ratio,
            "hbm_bytes_global": self.dot_bytes_dev * self.chips,
            "wire_bytes_global": self.wire_bytes_dev * self.chips,
            "wire_by_family": self.wire_by_family,
            "collective_counts": self.collective_counts,
            "bytes_per_device": self.bytes_per_device,
            "xla_flops_dev": self.xla_flops_dev,
            "xla_bytes_dev": self.xla_bytes_dev,
            "unknown_trips": self.unknown_trips,
        }


def model_flops(cfg, shape, run_cfg=None) -> float:
    """6*N*D model FLOPs for the step being lowered."""
    n_active = cfg.param_count(active_only=True)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def analyze_compiled(
    compiled,
    *,
    arch: str,
    shape,
    mesh_name: str,
    chips: int,
    cfg=None,
    hw: Hardware = TPU_V5E,
) -> RooflineReport:
    txt = compiled.as_text()
    mod = parse_hlo(txt)
    cost = {}
    try:
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jax wraps the dict
            cost = cost[0] if cost else {}
    except Exception:
        pass
    mem_bytes = 0.0
    try:
        ma = compiled.memory_analysis()
        mem_bytes = float(
            ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes
        )
    except Exception:
        pass
    wire = mod.collective_wire_bytes()
    return RooflineReport(
        arch=arch,
        shape=shape.name,
        mesh=mesh_name,
        chips=chips,
        dot_flops_dev=mod.dot_flops(),
        dot_bytes_dev=mod.dot_bytes(),
        wire_bytes_dev=sum(wire.values()),
        wire_by_family=wire,
        collective_counts=mod.collective_count(),
        xla_flops_dev=float(cost.get("flops", 0.0)),
        xla_bytes_dev=float(cost.get("bytes accessed", 0.0)),
        bytes_per_device=mem_bytes,
        model_flops_total=model_flops(cfg, shape) if cfg else 0.0,
        unknown_trips=len(mod.unknown_trip),
        hw=hw,
    )
