"""Aggregate dry-run JSON artifacts into the roofline table (EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
import os


def load_rows(path: str = "experiments/dryrun") -> list[dict]:
    rows = []
    for p in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(p) as f:
            rows.append(json.load(f))
    return rows


def fmt_ms(s: float) -> str:
    return f"{s*1e3:9.2f}"


def roofline_table(rows: list[dict], mesh: str = "pod16x16") -> str:
    hdr = (
        "| arch | shape | compute ms | memory ms | collective ms | bound | "
        "model TFLOPs | useful | peak GiB/dev | top collective |\n"
        "|---|---|---:|---:|---:|---|---:|---:|---:|---|\n"
    )
    lines = []
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    for r in sorted(
        (r for r in rows if r["mesh"] == mesh),
        key=lambda r: (r["arch"], order.get(r["shape"], 9)),
    ):
        fam = r.get("wire_by_family", {})
        top = max(fam, key=fam.get) if fam else "-"
        peak = r["memory_analysis"]["peak_per_device_gb"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_ms(r['t_compute_s'])} | "
            f"{fmt_ms(r['t_memory_s'])} | {fmt_ms(r['t_collective_s'])} | "
            f"{r['bottleneck']} | {r['model_flops']/1e12:10.1f} | "
            f"{r['useful_flops_ratio']:.2f} | {peak:6.2f} | {top} |"
        )
    return hdr + "\n".join(lines)


def summarize(rows: list[dict]) -> dict:
    pod = [r for r in rows if r["mesh"] == "pod16x16"]

    def frac(r):
        tot = r["t_compute_s"] + r["t_memory_s"] + r["t_collective_s"]
        return r["t_compute_s"] / tot if tot else 0.0

    return {
        "n_pairs_pod": len(pod),
        "n_pairs_multipod": len([r for r in rows if r["mesh"] == "pod2x16x16"]),
        "worst_compute_fraction": min(pod, key=frac)["arch" ] + "/" + min(pod, key=frac)["shape"],
        "most_collective_bound": max(pod, key=lambda r: r["t_collective_s"])["arch"]
        + "/"
        + max(pod, key=lambda r: r["t_collective_s"])["shape"],
        "bottleneck_counts": {
            b: len([r for r in pod if r["bottleneck"] == b])
            for b in ("compute", "memory", "collective")
        },
    }


if __name__ == "__main__":
    rows = load_rows()
    print(roofline_table(rows))
    print()
    print(json.dumps(summarize(rows), indent=1))
