"""Optimized-HLO text parser for roofline accounting.

Why: ``compiled.cost_analysis()`` visits each while-loop body ONCE — a
scan-over-layers model therefore under-reports FLOPs/bytes by the layer
count, and collective ops inside the loop are likewise under-counted. XLA
records ``known_trip_count`` on while ops, so we parse the module text,
build the computation call graph, and multiply every instruction by the
product of trip counts on its call path.

Extracted quantities (all PER DEVICE — the post-SPMD module is the
per-device program):
  * ``dot_flops``           — 2 * prod(out) * contracted, trip-multiplied
  * ``dot_bytes``           — operand+output bytes of dots (HBM floor)
  * ``collective_wire_bytes`` — bytes on the wire per collective family,
    using standard ring accounting: all-gather (g-1)/g * out, all-reduce
    2*(g-1)/g * bytes, reduce-scatter (g-1)/g * in, all-to-all (g-1)/g,
    collective-permute = full operand.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

__all__ = ["HloModule", "parse_hlo", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# One operand inside an op's argument list. Depending on XLA version the
# text is either untyped ("dot(%x, %y)") or typed
# ("dot(f32[8,64]{1,0} %x, ...)") — capture the optional inline type so
# shapes never have to round-trip through the symbol table.
_OPERAND_RE = re.compile(r"(?:(\w+\[[\d,]*\](?:\{[\d,\s]*\})?)\s+)?%([\w.\-]+)")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_CALLED_RE = re.compile(r"(?:calls|to_apply|body|condition)=%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    """Sum bytes over all array shapes appearing in a type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None, ()
    dt, dims = m.groups()
    shape = tuple(int(d) for d in dims.split(",")) if dims else ()
    return dt, shape


@dataclasses.dataclass
class Instr:
    name: str
    defn: str          # full rhs text

    @property
    def op(self) -> str:
        # rhs looks like: "f32[32,256]{1,0} all-gather(%copy), ..." — the op
        # token is the word right before '('
        m = re.search(r"([\w\-]+)\(", self.defn)
        return m.group(1) if m else ""


@dataclasses.dataclass
class HloModule:
    computations: dict            # name -> list[Instr]
    entry: str
    multipliers: dict             # name -> float (sum over call paths)
    unknown_trip: list            # while ops we could not bound
    num_partitions: int = 1

    # ---------------- metrics ----------------

    def _iter_weighted(self):
        for comp, instrs in self.computations.items():
            w = self.multipliers.get(comp, 0.0)
            if w <= 0:
                continue
            for ins in instrs:
                yield w, ins

    def dot_flops(self) -> float:
        total = 0.0
        for w, ins in self._iter_weighted():
            if ins.op not in ("dot", "convolution"):
                continue
            dt, out_shape = _first_shape(ins.defn)
            out = 1
            for d in out_shape:
                out *= d
            contracted = self._contracted_size(ins)
            total += w * 2.0 * out * contracted
        return total

    def _operands(self, ins: Instr) -> list:
        """(dtype, shape) per operand: inline type when printed, else the
        symbol table. Anchored at the op token so tuple-typed OUTPUTS
        (async '-start' ops print '(f32[...], f32[...]) all-gather-start(...)')
        are never mistaken for the argument list."""
        m = re.search(r"[\w\-]+\(([^)]*)\)", ins.defn)
        if not m:
            return []
        out = []
        for typ, name in _OPERAND_RE.findall(m.group(1)):
            if typ:
                out.append(_first_shape(typ))
            else:
                out.append(self._symbols_dt.get(name, (None, ())))
        return out

    def _contracted_size(self, ins: Instr) -> int:
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.defn)
        if not m:
            return 1
        dims = [int(d) for d in m.group(1).split(",") if d]
        operands = self._operands(ins)
        if not operands:
            return 1
        _, shape = operands[0]
        n = 1
        for d in dims:
            if d < len(shape):
                n *= shape[d]
        return n

    def dot_bytes(self) -> float:
        total = 0.0
        for w, ins in self._iter_weighted():
            if ins.op not in ("dot", "convolution"):
                continue
            total += w * _shape_bytes(ins.defn.split(" ", 1)[0])
            for dt, shape in self._operands(ins):
                if dt is None:
                    continue
                n = 1
                for d in shape:
                    n *= d
                total += w * n * DTYPE_BYTES.get(dt, 4)
        return total

    def collective_wire_bytes(self) -> dict:
        """Per-family wire bytes (per device), trip-count weighted."""
        out: dict[str, float] = defaultdict(float)
        for w, ins in self._iter_weighted():
            op = ins.op
            if op not in (
                "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start",
            ):
                continue
            fam = op.replace("-start", "")
            g = self._group_size(ins)
            # Output type is everything before the op token. Sync variadic
            # (combined) collectives return a tuple of RESULTS — sum them.
            # Async '-start' ops return (operand aliases..., results...) —
            # count only the result half, not the aliased inputs.
            m = re.search(r"[\w\-]+\(", ins.defn)
            out_text = ins.defn[: m.start()] if m else ins.defn
            shapes = [_shape_bytes(f"{dt}[{dims}]") for dt, dims in _SHAPE_RE.findall(out_text)]
            if op.endswith("-start") and len(shapes) >= 2:
                half = sorted(shapes)[len(shapes) // 2:]
                out_bytes = sum(half) if len(shapes) % 2 == 0 else max(shapes)
            else:
                out_bytes = sum(shapes)
            in_bytes = 0
            for dt, shape in self._operands(ins):
                if dt is None:
                    continue
                n = 1
                for d in shape:
                    n *= d
                in_bytes += n * DTYPE_BYTES.get(dt, 4)
            if fam == "all-gather":
                wire = out_bytes * (g - 1) / max(g, 1)
            elif fam == "all-reduce":
                wire = 2.0 * max(in_bytes, out_bytes) * (g - 1) / max(g, 1)
            elif fam == "reduce-scatter":
                wire = in_bytes * (g - 1) / max(g, 1)
            elif fam == "all-to-all":
                wire = max(in_bytes, out_bytes) * (g - 1) / max(g, 1)
            else:
                # collective-permute: only the listed (src,dst) pairs
                # transmit. Per-device average wire = operand * pairs/N —
                # charging every device the full operand over-counted a
                # binomial bcast ~6x (EXPERIMENTS.md §Perf pair 3).
                n_pairs = ins.defn.count("},{") + 1 if "source_target_pairs" in ins.defn else 1
                frac = n_pairs / max(self.num_partitions, 1)
                wire = max(in_bytes, out_bytes) * min(frac, 1.0)
            out[fam] += w * wire
        return dict(out)

    def _group_size(self, ins: Instr) -> int:
        m = _GROUPS_RE.search(ins.defn)
        if m:
            return int(m.group(2))  # [n_groups, group_size]<=[N]
        m = _GROUPS_LIST_RE.search(ins.defn)
        if m:
            return len([x for x in m.group(1).split(",") if x.strip() != ""])
        return 2

    def collective_count(self) -> dict:
        out: dict[str, float] = defaultdict(float)
        for w, ins in self._iter_weighted():
            if ins.op in ("all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute"):
                out[ins.op] += w
        return dict(out)


def parse_hlo(txt: str) -> HloModule:
    m = re.search(r"num_partitions=(\d+)", txt)
    num_partitions = int(m.group(1)) if m else 1
    computations: dict[str, list[Instr]] = {}
    entry = None
    cur: Optional[str] = None
    symbols: dict[str, tuple] = {}
    symbols_dt: dict[str, tuple] = {}
    for line in txt.splitlines():
        if not line.startswith(" "):
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                computations[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
                continue
        if line.strip() == "}":
            continue
        m = _INSTR_RE.match(line)
        if m and cur is not None:
            name, defn = m.groups()
            computations[cur].append(Instr(name, defn))
            dt, shape = _first_shape(defn)
            if dt:
                symbols[name] = shape
                symbols_dt[name] = (dt, shape)

    if entry is None and computations:
        entry = list(computations)[-1]

    # ---- call graph with trip-count multipliers ----
    mult: dict[str, float] = defaultdict(float)
    unknown: list[str] = []

    def visit(comp: str, w: float, depth=0):
        if comp not in computations or depth > 50:
            return
        mult[comp] += w
        for ins in computations[comp]:
            called = _CALLED_RE.findall(ins.defn)
            if not called:
                continue
            if ins.op == "while" or "while(" in ins.defn:
                t = _TRIP_RE.search(ins.defn)
                trip = float(t.group(1)) if t else 1.0
                if not t:
                    unknown.append(f"{comp}:{ins.name}")
                body = re.search(r"body=%([\w.\-]+)", ins.defn)
                cond = re.search(r"condition=%([\w.\-]+)", ins.defn)
                if body:
                    visit(body.group(1), w * trip, depth + 1)
                if cond:
                    visit(cond.group(1), w * (trip + 1), depth + 1)
            else:
                for c in called:
                    visit(c, w, depth + 1)

    if entry:
        visit(entry, 1.0)

    mod = HloModule(computations, entry or "", dict(mult), unknown, num_partitions)
    mod._symbols = symbols
    mod._symbols_dt = symbols_dt
    return mod
