"""Train-step factories.

Three data-parallel synchronization modes (DESIGN.md Sec. 4):

* ``grad_allreduce`` — the modern baseline: pjit/GSPMD inserts the gradient
  all-reduce (and FSDP all-gathers/reduce-scatters) automatically. This is
  the "vendor collective" path, analogous to NCCL allreduce.

* ``param_bcast`` — the paper's CA-CNTK pattern as an explicit shard_map
  program over the data-parallel axis: per-rank gradients are reduced to the
  root with the reversed-binomial schedule, and the synchronized buffers are
  then *broadcast* with the tuned algorithm library (pipelined chain et al.)
  via ``core.bcast.pbcast_tree``. SPMD note recorded in DESIGN.md: we
  broadcast the root's reduced gradient rather than the updated parameters —
  byte-identical traffic and the same collective, but every rank can then
  apply the optimizer deterministically, keeping per-rank optimizer state
  coherent (CNTK keeps the optimizer on the root instead).

* ``tuned_allreduce`` — the follow-up-work pattern (Awan et al. 1810.11112,
  Mamidala 1802.06949): gradients sync through the ``repro.comm`` allreduce
  plan layer — bucketed (``core.bucketing``), hierarchical over the
  ``dist.topology`` data axes (intra-pod level first, the pod level priced
  with inter-pod constants), per-bucket algorithm selected by the per-op
  tuner (reduce_then_bcast / fused_rsb / ring_allreduce windows).

Per-bucket plans resolve through the host-side plan cache
(``comm.plan.plan_cached``) — identical (op, M, n) points across steps and
buckets share one ``CollectivePlan`` and its pre-lowered round tables — and
``run_cfg.compiled_collectives`` routes the replay between the exact
unrolled executor and the O(1)-HLO compiled fori_loop executor (DESIGN.md
Sec. 9). The step is jitted with params/opt-state donated (see
``train.trainer``), so the compiled replay updates gradient buckets in
place.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..comm import hierarchical_allreduce_axes, overlap_allreduce_tree, pallreduce_tree
from ..comm.streams import StreamSpec, execute_stream_entry, plan_streams
from ..configs.base import RunConfig
from ..core.algorithms import ring_allreduce
from ..core.bcast import pbcast_tree, preduce_sum
from ..core.tuner import Tuner
from ..launch.mesh import dp_axes
from ..optim.optimizers import Optimizer, clip_by_global_norm

__all__ = [
    "make_train_step",
    "make_bcast_train_step",
    "make_tuned_allreduce_train_step",
    "make_overlap_allreduce_train_step",
    "make_compressed_allreduce_train_step",
    "make_degraded_psum_train_step",
    "with_error_feedback",
]


def _microbatch(batch, k: int):
    return jax.tree.map(lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)


def _grad_fn(model, run_cfg: RunConfig, grad_specs=None):
    def loss_fn(params, mb):
        return model.loss(params, mb, remat=run_cfg.remat)

    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain(tree):
        if grad_specs is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, grad_specs)

    def compute(params, batch):
        k = run_cfg.num_microbatches
        if k == 1:
            (loss, metrics), grads = vg(params, batch)
            return loss, metrics, grads

        def body(acc, mb):
            (loss, metrics), grads = vg(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / k, acc, constrain(grads)
            )
            return constrain(acc), (loss, metrics)

        zeros = constrain(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        )
        grads, (losses, metricss) = jax.lax.scan(body, zeros, _microbatch(batch, k))
        metrics = jax.tree.map(jnp.mean, metricss)
        return jnp.mean(losses), metrics, grads

    return compute


def make_train_step(model, run_cfg: RunConfig, optimizer: Optimizer, lr_fn: Callable, grad_specs=None):
    """pjit path: sharding comes from in/out shardings; collectives are
    GSPMD-inserted (the baseline the paper's mode is compared against).
    ``grad_specs``: optional NamedSharding tree pinning the f32 grad
    accumulator to the parameter sharding (prevents a replicated buffer)."""
    compute = _grad_fn(model, run_cfg, grad_specs)

    def train_step(params, opt_state, batch):
        loss, metrics, grads = compute(params, batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = lr_fn(opt_state["step"])
        params, opt_state = optimizer.update(grads, opt_state, params, lr)
        out = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out.update(metrics)
        return params, opt_state, out

    return train_step


def make_bcast_train_step(
    model,
    run_cfg: RunConfig,
    optimizer: Optimizer,
    lr_fn: Callable,
    mesh,
    *,
    tuner: Tuner | None = None,
    root: int = 0,
):
    """The paper's sync mode: explicit reduce-to-root + tuned broadcast over
    the data axis. Requires a pure data-parallel mesh (model axis size 1) —
    the setting of the paper (n GPUs, replicated model)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    assert axis_sizes.get("model", 1) == 1, "param_bcast mode is pure-DP (paper setting)"
    dp = dp_axes(mesh)
    assert len(dp) >= 1
    compute = _grad_fn(model, run_cfg)
    n_dp = 1
    for a in dp:
        n_dp *= axis_sizes[a]

    def local_step(params, opt_state, batch):
        # per-rank grads on the local shard of the batch
        loss, metrics, grads = compute(params, batch)
        if run_cfg.bcast_algo == "ring_allreduce":
            # paper Sec. VII future work: the explicit bandwidth-optimal
            # ring allreduce from the same ppermute substrate
            for ax in dp:
                grads = jax.tree.map(lambda g: ring_allreduce(g, ax), grads)
            grads = jax.tree.map(lambda g: g / n_dp, grads)
        else:
            # --- the paper's collective sequence, bucketed & tuned ---
            for ax in dp:
                grads = jax.tree.map(lambda g: preduce_sum(g, ax, root=root), grads)
            grads = jax.tree.map(lambda g: g / n_dp, grads)
            for ax in reversed(dp):
                grads = pbcast_tree(
                    grads,
                    ax,
                    root=root,
                    algo=run_cfg.bcast_algo,
                    tuner=tuner,
                    bucket_bytes=run_cfg.bcast_bucket_bytes,
                    inter_pod=(ax == "pod"),
                )
        # deterministic, identical update on every rank
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = lr_fn(opt_state["step"])
        params, opt_state = optimizer.update(grads, opt_state, params, lr)
        loss = jax.lax.pmean(loss, dp)
        out = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out.update({k: jax.lax.pmean(v, dp) for k, v in metrics.items()})
        return params, opt_state, out

    return _wrap_dp_step(local_step, mesh, dp)


def _wrap_dp_step(local_step, mesh, dp):
    """shard_map wrapper shared by the explicit-sync modes: params/opt state
    replicated, batch sharded over the data axes, outputs replicated."""
    replicated = P()

    def batch_spec(x):
        return P(dp, *([None] * (x.ndim - 1)))

    def train_step(params, opt_state, batch):
        in_specs = (
            jax.tree.map(lambda _: replicated, params),
            jax.tree.map(lambda _: replicated, opt_state),
            jax.tree.map(batch_spec, batch),
        )
        out_specs = (
            jax.tree.map(lambda _: replicated, params),
            jax.tree.map(lambda _: replicated, opt_state),
            replicated,
        )
        fn = jax.shard_map(
            local_step, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
        return fn(params, opt_state, batch)

    return train_step


def make_tuned_allreduce_train_step(
    model,
    run_cfg: RunConfig,
    optimizer: Optimizer,
    lr_fn: Callable,
    mesh,
    *,
    tuner: Tuner | None = None,
):
    """Gradient sync through the ``repro.comm`` collective-plan subsystem.

    Per-rank gradients are packed into same-dtype buckets and all-reduced
    hierarchically: intra-pod data axes first, then the pod level with
    inter-pod pricing (``comm.hierarchical_allreduce_axes``). Each bucket's
    algorithm/chunking is a per-op ``CollectivePlan`` decision — set
    ``run_cfg.allreduce_algo`` to pin one. Pure-DP like ``param_bcast``
    (model axis size 1), and produces the same update as ``grad_allreduce``
    up to float summation order.
    """
    def sync(grads, axes, inter_pod_axes):
        return pallreduce_tree(
            grads,
            axes,
            algo=run_cfg.allreduce_algo,
            tuner=tuner,
            bucket_bytes=run_cfg.bcast_bucket_bytes,
            inter_pod_axes=inter_pod_axes,
            compiled=run_cfg.compiled_collectives,
        )

    return _make_comm_sync_step(
        model, run_cfg, mesh, sync, optimizer, lr_fn, mode="tuned_allreduce"
    )


def make_overlap_allreduce_train_step(
    model,
    run_cfg: RunConfig,
    optimizer: Optimizer,
    lr_fn: Callable,
    mesh,
    *,
    tuner: Tuner | None = None,
):
    """Gradient sync through the overlap engine (``repro.comm.overlap``).

    Same bucketing, hierarchy levels, and per-bucket ``CollectivePlan``s as
    ``tuned_allreduce`` — so parameters match it (and the GSPMD psum
    baseline) up to float summation order — but buckets stream in
    backward-dispatch order inside the tuned in-flight window
    (``run_cfg.overlap_depth``; ``None`` = tuned), letting the scheduler
    hide collectives behind the rest of the step (the CNTK end-to-end
    pattern, paper Sec. V-D; Awan et al. 1810.11112).

    With ``run_cfg.prefetch_stream`` the step carries a SECOND comm stream:
    right after ``optimizer.update`` the updated (replicated) parameters are
    re-broadcast as a lower-priority ``weight_prefetch`` entry of a 2-entry
    :class:`~repro.comm.streams.StreamGraph`, DAG-ordered ``after`` the
    ``grad_sync`` entry. The bcast is value-identical (every rank already
    holds the same params), so results are bit-unchanged — what it buys is
    the wire schedule: next step's weights are pre-staged on the link the
    arbiter grants between gradient buckets. Both entries resolve through
    ``plan_streams`` (shared ``plan_cached`` path keyed on the graph
    fingerprint), and the DAG edge is realized by program order — grad sync
    executes inside the step, the prefetch entry after the update.
    """
    if not run_cfg.prefetch_stream:

        def sync(grads, axes, inter_pod_axes):
            return overlap_allreduce_tree(
                grads,
                axes,
                algo=run_cfg.allreduce_algo,
                tuner=tuner,
                bucket_bytes=run_cfg.bcast_bucket_bytes,
                inter_pod_axes=inter_pod_axes,
                overlap_depth=run_cfg.overlap_depth,
                compute_s=run_cfg.overlap_compute_s,
                compiled=run_cfg.compiled_collectives,
            )

        return _make_comm_sync_step(
            model, run_cfg, mesh, sync, optimizer, lr_fn, mode="overlap_allreduce"
        )

    from ..dist import topology

    if tuner is not None:
        # surface the stream decisions in the tuner table (stream:* entries
        # survive save/load, so a calibrated table pins them for later runs)
        tuner.record_stream(
            "grad_sync", priority=1, overlap_depth=run_cfg.overlap_depth
        )
        tuner.record_stream("weight_prefetch", priority=0)

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    sized_axes = tuple(
        (a, axis_sizes[a])
        for a in hierarchical_allreduce_axes(mesh)
        if axis_sizes.get(a, 1) > 1
    )
    inter = tuple(topology.inter_pod_axes(mesh))
    pshapes = model.param_shapes()
    # grads share the params' treedef/shapes; the microbatch accumulator
    # holds them in f32 (see _grad_fn), so the grad_sync bucket mix must be
    # planned at that dtype
    gshapes = pshapes
    if run_cfg.num_microbatches > 1:
        gshapes = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), pshapes
        )
    graph = plan_streams(
        [
            StreamSpec(
                name="grad_sync", tree=gshapes, axes=sized_axes,
                op="allreduce", algo=run_cfg.allreduce_algo, priority=1,
                overlap_depth=run_cfg.overlap_depth,
                compute_s=run_cfg.overlap_compute_s,
                bucket_bytes=run_cfg.bcast_bucket_bytes,
                inter_pod_axes=inter, reverse=True,
            ),
            StreamSpec(
                name="weight_prefetch", tree=pshapes, axes=sized_axes,
                op="bcast", algo=run_cfg.bcast_algo, priority=0,
                after=("grad_sync",),
                bucket_bytes=run_cfg.bcast_bucket_bytes,
                inter_pod_axes=inter, reverse=False,
            ),
        ],
        tuner=tuner,
    )
    grad_entry = graph.entry("grad_sync")
    prefetch_entry = graph.entry("weight_prefetch")

    def sync(grads, axes, inter_pod_axes):
        return execute_stream_entry(
            grad_entry, grads, compiled=run_cfg.compiled_collectives
        )

    def post_update(params, axes, inter_pod_axes):
        return execute_stream_entry(
            prefetch_entry, params, compiled=run_cfg.compiled_collectives
        )

    return _make_comm_sync_step(
        model, run_cfg, mesh, sync, optimizer, lr_fn,
        mode="overlap_allreduce", post_update=post_update,
    )


def with_error_feedback(optimizer: Optimizer) -> Optimizer:
    """Wrap an :class:`Optimizer` so its state carries the error-feedback
    residual tree at ``state['ef']`` (f32 zeros like params at init).

    ``update`` passes the residual through unchanged — the compressed train
    step owns the residual's read-modify-write (it must see the residual
    BEFORE the optimizer step and store the new one after). Wrapping here
    (rather than ad-hoc state surgery in the step) keeps ``init``,
    ``jax.eval_shape(optimizer.init, ...)`` for checkpoint restore, and the
    donation contract all consistent with one state treedef."""
    from ..comm.compress import CompressionState

    def init(params):
        state = dict(optimizer.init(params))
        state["ef"] = CompressionState.init(params)
        return state

    def update(grads, state, params, lr):
        inner = {k: v for k, v in state.items() if k != "ef"}
        new_params, new_inner = optimizer.update(grads, inner, params, lr)
        new_state = dict(new_inner)
        new_state["ef"] = state["ef"]
        return new_params, new_state

    return Optimizer(optimizer.name + "+ef", init, update)


def make_compressed_allreduce_train_step(
    model,
    run_cfg: RunConfig,
    optimizer: Optimizer,
    lr_fn: Callable,
    mesh,
    *,
    tuner: Tuner | None = None,
):
    """Gradient sync over a compressed wire with error feedback.

    Same bucketing, hierarchy, and per-bucket ``CollectivePlan``s as
    ``tuned_allreduce``, but every hop ships ``run_cfg.wire_format``
    ('bf16'|'fp8'|'int8'): compressed formats quantize each chunk to 1
    byte/element plus per-256-element-block f32 scales at the ppermute seam
    (combine arithmetic stays f32). The quantization error is not discarded
    — each step's residual ``e`` is carried in ``opt_state['ef']`` (the
    optimizer must be wrapped with :func:`with_error_feedback`) and
    re-injected into the next step's gradient (EF-SGD, Karimireddy et al.):

        c_t = g_t + e_t            # compensate
        sync = allreduce(Q(c_t))   # compressed wire
        e_{t+1} = c_t - Q(c_t)     # this rank's quantization error

    The residual models the rank's OWN first-hop quantization error;
    multi-hop recompression error inside the schedule is not re-captured
    (standard EF approximation — the residual still bounds the bias, which
    is what makes the trajectory track the full-precision baseline).

    With ``wire_format='bf16'`` the wire is the bit-identical passthrough:
    the step skips compensation entirely (the residual is identically zero,
    and even a value-preserving ``g.astype(f32)`` would change the sync's
    bucket dtype and summation precision), so it syncs exactly the buffers
    ``tuned_allreduce`` syncs and produces bit-identical parameters.
    """
    from ..comm.compress import CompressionState, normalize_wire_format
    from ..dist import topology

    fmt = normalize_wire_format(run_cfg.wire_format)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    assert axis_sizes.get("model", 1) == 1, "compressed_allreduce mode is pure-DP"
    dp = dp_axes(mesh)
    assert len(dp) >= 1
    compute = _grad_fn(model, run_cfg)
    n_dp = 1
    for a in dp:
        n_dp *= axis_sizes[a]
    axes = [a for a in hierarchical_allreduce_axes(mesh) if axis_sizes.get(a, 1) > 1]
    inter_pod_axes = topology.inter_pod_axes(mesh)

    def local_step(params, opt_state, batch):
        loss, metrics, grads = compute(params, batch)
        comp = (
            CompressionState.compensate(grads, opt_state["ef"])
            if fmt.compressed
            else grads
        )
        synced = pallreduce_tree(
            comp,
            axes,
            algo=run_cfg.allreduce_algo,
            tuner=tuner,
            bucket_bytes=run_cfg.bcast_bucket_bytes,
            inter_pod_axes=inter_pod_axes,
            compiled=run_cfg.compiled_collectives,
            wire_format=fmt.value,
        )
        new_ef = (
            CompressionState.update(comp, fmt.value)
            if fmt.compressed
            else opt_state["ef"]
        )
        grads = jax.tree.map(lambda g: g / n_dp, synced)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = lr_fn(opt_state["step"])
        params, opt_state = optimizer.update(grads, opt_state, params, lr)
        opt_state = dict(opt_state, ef=new_ef)
        loss = jax.lax.pmean(loss, dp)
        out = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out.update({k: jax.lax.pmean(v, dp) for k, v in metrics.items()})
        return params, opt_state, out

    return _wrap_dp_step(local_step, mesh, dp)


def make_degraded_psum_train_step(
    model,
    run_cfg: RunConfig,
    optimizer: Optimizer,
    lr_fn: Callable,
    mesh,
    *,
    health,
):
    """Graceful-degradation sync: psum over SURVIVORS with corrected mean
    normalization (``comm.faults.MeshHealth``).

    When ranks die mid-run the tuned schedules are unusable until a replan,
    but training can limp on: every rank's gradient is masked by its
    liveness bit before the psum and the mean divides by the survivor count
    — so the surviving ranks compute exactly the ``n_surv``-way
    data-parallel update (dividing by the full ``n_dp`` would silently
    shrink the effective learning rate by ``n_surv / n_dp``; that silent
    skew is the bug this factory exists to prevent). Ranks are linearized
    over the data axes in mesh order, matching ``MeshHealth`` rank ids.

    The dead ranks' processes (when still running — e.g. a degraded link
    rather than a lost host) contribute zeros and receive the same
    replicated update, so the mesh stays parameter-coherent for a later
    recovery replan."""
    from ..comm.faults import DeadRankError

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    assert axis_sizes.get("model", 1) == 1, "degraded_psum mode is pure-DP"
    dp = dp_axes(mesh)
    assert len(dp) >= 1
    compute = _grad_fn(model, run_cfg)
    n_dp = 1
    for a in dp:
        n_dp *= axis_sizes[a]
    if health.n != n_dp:
        raise ValueError(f"health report is for n={health.n}, mesh has n_dp={n_dp}")
    survivors = health.survivors()
    n_surv = len(survivors)
    if n_surv == 0:
        raise DeadRankError("no surviving data-parallel ranks; restore from checkpoint")
    alive = np.zeros((n_dp,), np.float32)
    alive[list(survivors)] = 1.0

    def local_step(params, opt_state, batch):
        loss, metrics, grads = compute(params, batch)
        r = jnp.zeros((), jnp.int32)
        for a in dp:
            r = r * axis_sizes[a] + jax.lax.axis_index(a)
        m = jnp.asarray(alive)[r]

        def survivor_mean(v):
            v = v * m.astype(v.dtype)
            for ax in dp:
                v = jax.lax.psum(v, ax)
            return v / n_surv

        grads = jax.tree.map(survivor_mean, grads)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = lr_fn(opt_state["step"])
        params, opt_state = optimizer.update(grads, opt_state, params, lr)
        loss = survivor_mean(loss)
        out = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out.update({k: survivor_mean(v) for k, v in metrics.items()})
        return params, opt_state, out

    return _wrap_dp_step(local_step, mesh, dp)


def _make_comm_sync_step(model, run_cfg, mesh, sync, optimizer, lr_fn, *, mode,
                         post_update=None):
    """Shared body of the repro.comm gradient-sync modes: pure-DP shard_map
    step whose gradient all-reduce is ``sync(grads, axes, inter_pod_axes)``.
    ``post_update(params, axes, inter_pod_axes)`` runs right after the
    optimizer step — the hook the weight-prefetch stream entry rides
    (value-preserving: it must return params unchanged up to layout)."""
    from ..dist import topology

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    assert axis_sizes.get("model", 1) == 1, f"{mode} mode is pure-DP"
    dp = dp_axes(mesh)
    assert len(dp) >= 1
    compute = _grad_fn(model, run_cfg)
    n_dp = 1
    for a in dp:
        n_dp *= axis_sizes[a]
    axes = [a for a in hierarchical_allreduce_axes(mesh) if axis_sizes.get(a, 1) > 1]
    inter_pod_axes = topology.inter_pod_axes(mesh)

    def local_step(params, opt_state, batch):
        loss, metrics, grads = compute(params, batch)
        grads = sync(grads, axes, inter_pod_axes)
        grads = jax.tree.map(lambda g: g / n_dp, grads)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = lr_fn(opt_state["step"])
        params, opt_state = optimizer.update(grads, opt_state, params, lr)
        if post_update is not None:
            params = post_update(params, axes, inter_pod_axes)
        loss = jax.lax.pmean(loss, dp)
        out = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out.update({k: jax.lax.pmean(v, dp) for k, v in metrics.items()})
        return params, opt_state, out

    return _wrap_dp_step(local_step, mesh, dp)
