"""Trainer: wires model + data + optimizer + sync mode + checkpointing."""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, RunConfig
from ..data.pipeline import batches, make_source
from ..dist.sharding import batch_specs, param_specs
from ..launch.mesh import dp_axes, make_local_mesh
from ..models import Model
from ..optim.optimizers import get_optimizer
from ..optim.schedules import warmup_cosine
from . import checkpoint as ckpt_lib
from .train_step import (
    make_bcast_train_step,
    make_compressed_allreduce_train_step,
    make_degraded_psum_train_step,
    make_overlap_allreduce_train_step,
    make_train_step,
    make_tuned_allreduce_train_step,
    with_error_feedback,
)

__all__ = ["Trainer"]


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        run: RunConfig,
        *,
        mesh=None,
        data_path: Optional[str] = None,
        ckpt_dir: Optional[str] = None,
        health=None,
    ):
        self.cfg = cfg
        self.run = run
        self.model = Model(cfg)
        self.mesh = mesh if mesh is not None else make_local_mesh(1)
        self.optimizer = get_optimizer(run.optimizer, run.weight_decay)
        if run.sync_mode == "compressed_allreduce":
            # the EF residual rides in opt_state['ef'] so it checkpoints,
            # restores, and donates with the rest of the optimizer state
            self.optimizer = with_error_feedback(self.optimizer)
        self.lr_fn = warmup_cosine(run.learning_rate, run.warmup_steps, run.total_steps)
        self.source = make_source(cfg, path=data_path, seed=run.seed)
        self.ckpt_dir = ckpt_dir
        # comm.faults.MeshHealth for the data-parallel world; a degraded
        # report overrides sync_mode with the psum-over-survivors fallback
        self.health = health
        self._build()

    def _build(self):
        mesh = self.mesh
        explicit_sync = {
            "param_bcast": make_bcast_train_step,
            "tuned_allreduce": make_tuned_allreduce_train_step,
            "overlap_allreduce": make_overlap_allreduce_train_step,
            "compressed_allreduce": make_compressed_allreduce_train_step,
        }
        if self.health is not None and not self.health.healthy and self.health.dead_ranks:
            # graceful degradation: the tuned schedules assume every rank is
            # reachable, so a dead-rank report routes gradient sync to the
            # masked psum with survivor-count normalization until a replan
            print(
                f"trainer: mesh degraded (dead ranks {self.health.dead_ranks}); "
                f"sync_mode {self.run.sync_mode!r} falls back to psum-over-survivors",
                flush=True,
            )
            step_fn = make_degraded_psum_train_step(
                self.model, self.run, self.optimizer, self.lr_fn, mesh,
                health=self.health,
            )
            self._pspecs = jax.tree.map(lambda _: P(), self.model.param_shapes())
        elif self.run.sync_mode in explicit_sync:
            # calibrated empirical decisions (Tuner.save format) when the
            # run points at a table; analytic otherwise
            from ..core.tuner import Tuner

            tuner = Tuner.load(self.run.tuner_table) if self.run.tuner_table else None
            step_fn = explicit_sync[self.run.sync_mode](
                self.model, self.run, self.optimizer, self.lr_fn, mesh, tuner=tuner
            )
            self._pspecs = jax.tree.map(
                lambda _: P(), self.model.param_shapes()
            )
        else:
            step_fn = make_train_step(self.model, self.run, self.optimizer, self.lr_fn)
            self._pspecs = param_specs(self.model.param_shapes(), mesh)
        self._step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    def init_state(self, seed: Optional[int] = None):
        seed = self.run.seed if seed is None else seed
        with jax.set_mesh(self.mesh) if hasattr(jax, "set_mesh") else self.mesh:
            params = jax.jit(
                self.model.init,
                out_shardings=jax.tree.map(lambda s: NamedSharding(self.mesh, s), self._pspecs),
            )(jax.random.PRNGKey(seed))
            opt_state = jax.jit(
                self.optimizer.init,
            )(params)
        return params, opt_state

    def restore_or_init(self):
        if self.ckpt_dir:
            step = ckpt_lib.latest_step(self.ckpt_dir)
            if step is not None:
                params_like = self.model.param_shapes()
                params = ckpt_lib.restore_checkpoint(self.ckpt_dir, step, params_like)
                opt_like = jax.eval_shape(self.optimizer.init, params_like)
                opt = ckpt_lib.restore_checkpoint(
                    self.ckpt_dir + "/opt", step, opt_like
                )
                return params, opt, step
        params, opt = self.init_state()
        return params, opt, 0

    def train(self, *, batch: int, seq: int, steps: int, log_every: int = 10, ckpt_every: int = 0):
        params, opt_state, start = self.restore_or_init()
        it = batches(self.source, self.cfg, batch=batch, seq=seq, start_step=start)
        bspecs = None
        history = []
        t0 = time.time()
        with self.mesh:
            for step in range(start, start + steps):
                b = next(it)
                if bspecs is None:
                    bspecs = batch_specs(b, self.mesh)
                b = jax.tree.map(
                    lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)), b, bspecs
                )
                params, opt_state, metrics = self._step_fn(params, opt_state, b)
                if log_every and (step % log_every == 0 or step == start + steps - 1):
                    m = {k: float(v) for k, v in metrics.items()}
                    dt = time.time() - t0
                    history.append({"step": step, "time_s": dt, **m})
                    print(
                        f"step {step:6d} loss {m['loss']:.4f} nll {m.get('nll', 0.0):.4f} "
                        f"gnorm {m['grad_norm']:.2f} lr {m['lr']:.2e} ({dt:.1f}s)",
                        flush=True,
                    )
                if ckpt_every and self.ckpt_dir and (step + 1) % ckpt_every == 0:
                    ckpt_lib.save_checkpoint(self.ckpt_dir, step + 1, params)
                    ckpt_lib.save_checkpoint(self.ckpt_dir + "/opt", step + 1, opt_state)
        return params, opt_state, history
