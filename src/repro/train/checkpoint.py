"""Sharding-aware npz checkpointing (no external deps).

Leaves are gathered to host, keyed by their flattened tree path; restore
re-places them with the provided shardings. bf16 round-trips via a uint16
view (npz has no native bfloat16).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_BF16_TAG = "__bf16__"


def _key(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save_checkpoint(path: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    os.makedirs(path, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    for p, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        k = _key(p)
        if arr.dtype == jnp.bfloat16:
            arrays[k + _BF16_TAG] = arr.view(np.uint16)
        else:
            arrays[k] = arr
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    np.savez(fname, **arrays)
    with open(os.path.join(path, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump({"step": step, **(extra or {})}, f)
    return fname


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [
        int(f[len("ckpt_") : -len(".npz")])
        for f in os.listdir(path)
        if f.startswith("ckpt_") and f.endswith(".npz")
    ]
    return max(steps) if steps else None


def restore_checkpoint(path: str, step: int, like: Any, shardings: Any = None) -> Any:
    """``like``: a tree (concrete or ShapeDtypeStruct) defining the structure.
    ``shardings``: optional matching tree of NamedSharding for placement."""
    data = np.load(os.path.join(path, f"ckpt_{step:08d}.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(flat)
    )
    leaves = []
    for (p, leaf), sh in zip(flat, shard_flat):
        k = _key(p)
        if k + _BF16_TAG in data:
            arr = jnp.asarray(data[k + _BF16_TAG].view(jnp.bfloat16))
        else:
            arr = jnp.asarray(data[k])
        assert arr.shape == leaf.shape, (k, arr.shape, leaf.shape)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])
