"""Sharding-aware npz checkpointing (no external deps).

Leaves are gathered to host, keyed by their flattened tree path; restore
re-places them with the provided shardings. bf16 round-trips via a uint16
view (npz has no native bfloat16).
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import numpy as np

import jax
import jax.numpy as jnp

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_BF16_TAG = "__bf16__"


def _key(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _atomic_write(final: str, write_fn) -> None:
    """Write-temp + fsync + rename: the final path either doesn't exist or
    holds a complete file — a crash mid-write leaves only a ``.tmp``."""
    tmp = final + ".tmp"
    with open(tmp, "wb") as f:
        write_fn(f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)


def save_checkpoint(path: str, step: int, tree: Any, extra: Optional[dict] = None) -> str:
    """Atomic checkpoint save. The npz lands first (write-temp + rename),
    the json sidecar last — it is the commit marker: :func:`latest_step`
    only counts steps with BOTH files, so a crash at any point mid-save
    resumes from the previous complete checkpoint instead of a torn one."""
    os.makedirs(path, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {}
    for p, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        k = _key(p)
        if arr.dtype == jnp.bfloat16:
            arrays[k + _BF16_TAG] = arr.view(np.uint16)
        else:
            arrays[k] = arr
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    _atomic_write(fname, lambda f: np.savez(f, **arrays))
    meta = json.dumps({"step": step, **(extra or {})}).encode()
    _atomic_write(os.path.join(path, f"ckpt_{step:08d}.json"), lambda f: f.write(meta))
    return fname


def latest_step(path: str) -> Optional[int]:
    """Latest COMPLETE checkpoint step: an npz without its json commit
    marker is a torn save (crash between the two writes) and is skipped."""
    if not os.path.isdir(path):
        return None
    files = set(os.listdir(path))
    steps = [
        int(f[len("ckpt_") : -len(".npz")])
        for f in files
        if f.startswith("ckpt_") and f.endswith(".npz")
        and f[: -len(".npz")] + ".json" in files
    ]
    return max(steps) if steps else None


def restore_checkpoint(path: str, step: int, like: Any, shardings: Any = None) -> Any:
    """``like``: a tree (concrete or ShapeDtypeStruct) defining the structure.
    ``shardings``: optional matching tree of NamedSharding for placement."""
    data = np.load(os.path.join(path, f"ckpt_{step:08d}.npz"))
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None else [None] * len(flat)
    )
    leaves = []
    for (p, leaf), sh in zip(flat, shard_flat):
        k = _key(p)
        if k + _BF16_TAG in data:
            arr = jnp.asarray(data[k + _BF16_TAG].view(jnp.bfloat16))
        else:
            arr = jnp.asarray(data[k])
        assert arr.shape == leaf.shape, (k, arr.shape, leaf.shape)
        if sh is not None:
            arr = jax.device_put(arr, sh)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])
