"""Gate the repo's jax API surface onto older jax releases.

The code (and the test snippets) target the current public names —
``jax.shard_map``, ``jax.sharding.AxisType``, ``jax.make_mesh(...,
axis_types=...)`` and ``shard_map(check_vma=...)``.  Older 0.4.x installs
ship the same functionality under ``jax.experimental.shard_map`` /
``check_rep`` and without axis types, so this module installs thin
forwarding shims when (and only when) a name is missing.  On a current jax
everything here is a no-op.  Imported for its side effects from
``repro/__init__.py`` so any ``import repro.*`` activates it.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax


def _install() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if not hasattr(jax, "make_mesh"):
        def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
            from jax.experimental import mesh_utils

            if devices is None:
                devices = mesh_utils.create_device_mesh(tuple(axis_shapes))
            return jax.sharding.Mesh(devices, tuple(axis_names))

        jax.make_mesh = make_mesh
    elif "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
            # axis_types only selects Auto/Explicit sharding-in-types mode;
            # pre-AxisType releases are implicitly all-Auto, so drop it.
            return _make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    if not hasattr(jax.lax, "axis_size"):
        from jax._src import core as _core

        def axis_size(axis_name):
            # 0.4.x keeps the static size in the axis env; axis_frame
            # returns the bare int there (newer frames carry .size).
            frame = _core.axis_frame(axis_name)
            return getattr(frame, "size", frame)

        jax.lax.axis_size = axis_size

    if not hasattr(jax.lax, "pvary"):
        # pvary is the varying-manual-axes annotation of the newer VMA
        # system; pre-VMA releases treat everything as potentially varying,
        # so the identity is semantically exact.
        jax.lax.pvary = lambda x, axis_name=None: x

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None,
                      check_rep=None, **kwargs):
            if check_rep is None:
                check_rep = True if check_vma is None else check_vma
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_rep,
                              **kwargs)

        jax.shard_map = shard_map


_install()
