"""Mixture-of-Experts FFN: GShard-style top-k dispatch with capacity.

Formulation: tokens are grouped (B, nG, S); the router's top-k choices are
turned into a (B, nG, S, E, C) combine tensor; expert inputs/outputs move
through einsums so GSPMD shards experts on the `model` mesh axis (the
all-to-all appears in the lowered HLO). Tokens overflowing an expert's
capacity are dropped (residual passes through), as in GShard/Switch.

Two dispatch transports (``cfg.moe_dispatch``):

- ``"einsum"`` (default): the dense one-hot einsum formulation above. GSPMD
  infers the all-to-all; it is also the single-host oracle the explicit
  path is tested against.
- ``"alltoallv"``: explicit expert parallelism over a named mesh axis via
  :func:`repro.comm.palltoallv`. Tokens stay batch-sharded; experts are
  contiguously partitioned across ranks (E need not divide n — the ragged
  block sizes are exactly the ``sizes`` matrix of the schedule-IR
  alltoallv). Routing/combine math is identical to the einsum path, so the
  two agree to summation order.

Shared experts (DeepSeek/Moonlight style) run densely for every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .layers import _norm_init, down_proj

__all__ = ["init_moe", "moe_ffn", "expert_partition"]


def init_moe(key, cfg, dtype=jnp.bfloat16):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _norm_init(ks[0], (d, E), d**-0.5, jnp.float32),
        "w_gate": _norm_init(ks[1], (E, d, f), d**-0.5, dtype),
        "w_up": _norm_init(ks[2], (E, d, f), d**-0.5, dtype),
        "w_down": _norm_init(ks[3], (E, f, d), f**-0.5, dtype),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _norm_init(kss[0], (d, fs), d**-0.5, dtype),
            "w_up": _norm_init(kss[1], (d, fs), d**-0.5, dtype),
            "w_down": _norm_init(kss[2], (fs, d), fs**-0.5, dtype),
        }
    return p


def _capacity(S: int, k: int, E: int, cf: float) -> int:
    c = int(S * k * cf / E) + 1
    # the floor of 4 keeps tiny groups from thrashing drops, but it must
    # never exceed the S*k slot supply (S=2, k=1 has only 2 slots total)
    return max(min(4, S * k), min(c, S * k)) if S > 1 else max(1, k)


def _group_size(T: int, cfg) -> int:
    """Dispatch group length: ``cfg.moe_group_size`` when it divides T,
    else the largest divisor of T that fits (T=520, group 512 -> 260;
    prime T degrades to 1 rather than asserting)."""
    S = min(cfg.moe_group_size, T)
    if T % S:
        S = max(d for d in range(1, S + 1) if T % d == 0)
    return S


def _route(p, xg, cfg):
    """Router + capacity bookkeeping on grouped tokens (B, nG, S, D).

    Returns (combine, dispatch, me, ce): the (B, nG, S, E, C) combine /
    dispatch tensors and the load-balancing statistics — ``me`` the mean
    router probability and ``ce`` the fraction of tokens routed per expert
    (normalized by k so it sums to ~1 regardless of top-k width).
    """
    B, nG, S, D = xg.shape
    E, k = cfg.num_experts, cfg.experts_per_token

    logits = jnp.einsum("bgsd,de->bgse", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # (B,nG,S,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )  # renormalize over the selected experts

    C = _capacity(S, k, E, cfg.capacity_factor)
    onehot_e = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (B,nG,S,k,E)
    # position-in-expert: cumulative count over the flattened (S, k) order
    flat = onehot_e.reshape(B, nG, S * k, E)
    pos_in_e = (jnp.cumsum(flat, axis=2) - flat).reshape(B, nG, S, k, E)
    pos_in_e = jnp.sum(pos_in_e * onehot_e, axis=-1)             # (B,nG,S,k)
    keep = pos_in_e < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)
    onehot_c = jax.nn.one_hot(pos_in_e.astype(jnp.int32), C, dtype=jnp.float32)

    combine = jnp.einsum("bgske,bgsk,bgskc->bgsec", onehot_e, gate_vals, onehot_c)
    dispatch = (combine > 0).astype(xg.dtype)                    # (B,nG,S,E,C)
    combine = combine.astype(xg.dtype)

    # GShard load-balancing statistics (each a length-E batch mean)
    me = jnp.mean(probs, axis=(0, 1, 2))
    ce = jnp.mean(onehot_e.sum(axis=3), axis=(0, 1, 2)) / max(k, 1)
    return combine, dispatch, me, ce


def _shared_out(p, x):
    sp = p["shared"]
    hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
    return down_proj(hs, sp["w_down"])


def expert_partition(E: int, n: int) -> tuple[int, ...]:
    """Contiguous expert counts per rank: the first ``E % n`` ranks take one
    extra (E=6, n=4 -> (2, 2, 1, 1)). Ranks beyond E hold zero experts."""
    base, rem = divmod(E, n)
    return tuple(base + (1 if r < rem else 0) for r in range(n))


def moe_ffn(p, x, cfg, *, axis_name=None):
    """x: (B, T, D) -> (out, aux_loss).

    With ``axis_name`` set and ``cfg.moe_dispatch == "alltoallv"``, runs the
    explicit expert-parallel transport over that mesh axis (call inside
    ``shard_map`` with the batch sharded on the axis); otherwise the dense
    einsum formulation.
    """
    if axis_name is not None and getattr(cfg, "moe_dispatch", "einsum") == "alltoallv":
        return _moe_ffn_alltoallv(p, x, cfg, axis_name)
    B, T, D = x.shape
    E = cfg.num_experts
    S = _group_size(T, cfg)
    nG = T // S
    xg = x.reshape(B, nG, S, D)

    combine, dispatch, me, ce = _route(p, xg, cfg)

    expert_in = jnp.einsum("bgsec,bgsd->ebgcd", dispatch, xg)
    h = jax.nn.silu(jnp.einsum("ebgcd,edf->ebgcf", expert_in, p["w_gate"]))
    h = h * jnp.einsum("ebgcd,edf->ebgcf", expert_in, p["w_up"])
    expert_out = jnp.einsum(
        "ebgcf,efd->ebgcd", h, p["w_down"], preferred_element_type=h.dtype
    )
    y = jnp.einsum("bgsec,ebgcd->bgsd", combine, expert_out).reshape(B, T, D)

    if "shared" in p:
        y = y + _shared_out(p, x)

    aux = E * jnp.sum(me * ce) * cfg.router_aux_coef
    return y, aux


def _moe_ffn_alltoallv(p, x, cfg, axis_name):
    """Expert-parallel MoE over ``axis_name`` via the ragged alltoallv.

    Contract: ``x`` is the rank's batch shard (B_loc, T, D); expert weights
    are replicated. Experts partition contiguously across the n ranks
    (:func:`expert_partition` — ragged when n does not divide E). Per
    expert the dispatch tensor supplies R = B_loc * nG * C capacity rows,
    so the forward block matrix is m[s][d] = cnt[d] * R (uniform per
    destination) and the return matrix its transpose — exactly the ragged
    ``sizes`` the schedule-IR alltoallv consumes. The returned aux loss is
    the global-batch value (me/ce are pmean'd before combining), matching
    the einsum oracle run on the unsharded batch.
    """
    from ..comm.api import palltoallv

    B, T, D = x.shape
    E = cfg.num_experts
    n = lax.axis_size(axis_name)
    S = _group_size(T, cfg)
    nG = T // S
    xg = x.reshape(B, nG, S, D)

    combine, dispatch, me, ce = _route(p, xg, cfg)
    C = combine.shape[-1]
    R = B * nG * C                         # capacity rows per expert
    cnt = expert_partition(E, n)
    cnt_max = max(cnt)

    # ---- forward transport: (E, B, nG, C, D) flattened expert-major is
    # already the destination-major compact layout (experts contiguous per
    # rank). Out as padded (n, cnt_max*R, D) blocks: source s's tokens for
    # my cnt[r] local experts live in out[s]'s valid prefix.
    expert_in = jnp.einsum("bgsec,bgsd->ebgcd", dispatch, xg)
    fwd = palltoallv(
        expert_in.reshape(E * R, D), axis_name,
        sizes=[c * R for c in cnt], out_padded=True,
    )
    din = fwd.reshape(n, cnt_max, B, nG, C, D)

    # ---- local experts, padded to cnt_max with zero-masked weights: slot
    # j >= cnt[rank] computes silu(0)*0 = 0, so garbage slots are inert
    widx = np.zeros((n, cnt_max), np.int32)
    wvalid = np.zeros((n, cnt_max), bool)
    e0 = 0
    for r in range(n):
        widx[r, : cnt[r]] = np.arange(e0, e0 + cnt[r])
        wvalid[r, : cnt[r]] = True
        e0 += cnt[r]
    rank = lax.axis_index(axis_name)
    idx = jnp.asarray(widx)[rank]
    mask = jnp.asarray(wvalid)[rank][:, None, None]
    w_gate = p["w_gate"][idx] * mask
    w_up = p["w_up"][idx] * mask
    w_down = p["w_down"][idx] * mask

    h = jax.nn.silu(jnp.einsum("sjbgcd,jdf->sjbgcf", din, w_gate))
    h = h * jnp.einsum("sjbgcd,jdf->sjbgcf", din, w_up)
    eo = jnp.einsum(
        "sjbgcf,jfd->sjbgcd", h, w_down, preferred_element_type=h.dtype
    )

    # ---- return transport: block to source d is eo[d]'s valid prefix
    # (cnt[rank] local experts) — the transposed matrix, padded input
    back = palltoallv(
        eo.reshape(n, cnt_max * R, D), axis_name,
        sizes=[[c * R] * n for c in cnt], in_padded=True,
    )
    expert_out = back.reshape(E, B, nG, C, D)   # global expert order

    y = jnp.einsum("bgsec,ebgcd->bgsd", combine, expert_out).reshape(B, T, D)
    if "shared" in p:
        y = y + _shared_out(p, x)

    me_g = lax.pmean(me, axis_name)
    ce_g = lax.pmean(ce, axis_name)
    aux = E * jnp.sum(me_g * ce_g) * cfg.router_aux_coef
    return y, aux
