"""Mixture-of-Experts FFN: GShard-style top-k dispatch with capacity.

Formulation: tokens are grouped (B, nG, S); the router's top-k choices are
turned into a (B, nG, S, E, C) combine tensor; expert inputs/outputs move
through einsums so GSPMD shards experts on the `model` mesh axis (the
all-to-all appears in the lowered HLO). Tokens overflowing an expert's
capacity are dropped (residual passes through), as in GShard/Switch.

Shared experts (DeepSeek/Moonlight style) run densely for every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _norm_init, down_proj

__all__ = ["init_moe", "moe_ffn"]


def init_moe(key, cfg, dtype=jnp.bfloat16):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _norm_init(ks[0], (d, E), d**-0.5, jnp.float32),
        "w_gate": _norm_init(ks[1], (E, d, f), d**-0.5, dtype),
        "w_up": _norm_init(ks[2], (E, d, f), d**-0.5, dtype),
        "w_down": _norm_init(ks[3], (E, f, d), f**-0.5, dtype),
    }
    if cfg.num_shared_experts:
        fs = f * cfg.num_shared_experts
        kss = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _norm_init(kss[0], (d, fs), d**-0.5, dtype),
            "w_up": _norm_init(kss[1], (d, fs), d**-0.5, dtype),
            "w_down": _norm_init(kss[2], (fs, d), fs**-0.5, dtype),
        }
    return p


def _capacity(S: int, k: int, E: int, cf: float) -> int:
    c = int(S * k * cf / E) + 1
    return max(4, min(c, S * k)) if S > 1 else max(1, k)


def moe_ffn(p, x, cfg):
    """x: (B, T, D) -> (out, aux_loss)."""
    B, T, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    S = min(cfg.moe_group_size, T)
    assert T % S == 0, f"seq {T} not divisible by moe group {S}"
    nG = T // S
    xg = x.reshape(B, nG, S, D)

    logits = jnp.einsum("bgsd,de->bgse", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)            # (B,nG,S,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )  # renormalize over the selected experts

    C = _capacity(S, k, E, cfg.capacity_factor)
    onehot_e = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)  # (B,nG,S,k,E)
    # position-in-expert: cumulative count over the flattened (S, k) order
    flat = onehot_e.reshape(B, nG, S * k, E)
    pos_in_e = (jnp.cumsum(flat, axis=2) - flat).reshape(B, nG, S, k, E)
    pos_in_e = jnp.sum(pos_in_e * onehot_e, axis=-1)             # (B,nG,S,k)
    keep = pos_in_e < C
    gate_vals = gate_vals * keep.astype(gate_vals.dtype)
    onehot_c = jax.nn.one_hot(pos_in_e.astype(jnp.int32), C, dtype=jnp.float32)

    combine = jnp.einsum("bgske,bgsk,bgskc->bgsec", onehot_e, gate_vals, onehot_c)
    dispatch = (combine > 0).astype(x.dtype)                     # (B,nG,S,E,C)
    combine = combine.astype(x.dtype)

    expert_in = jnp.einsum("bgsec,bgsd->ebgcd", dispatch, xg)
    h = jax.nn.silu(jnp.einsum("ebgcd,edf->ebgcf", expert_in, p["w_gate"]))
    h = h * jnp.einsum("ebgcd,edf->ebgcf", expert_in, p["w_up"])
    expert_out = jnp.einsum(
        "ebgcf,efd->ebgcd", h, p["w_down"], preferred_element_type=h.dtype
    )
    y = jnp.einsum("bgsec,ebgcd->bgsd", combine, expert_out).reshape(B, T, D)

    if "shared" in p:
        sp = p["shared"]
        hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        y = y + down_proj(hs, sp["w_down"])

    # GShard load-balancing auxiliary loss
    me = jnp.mean(probs, axis=(0, 1, 2))                         # (E,)
    ce = jnp.mean(onehot_e.sum(axis=3), axis=(0, 1, 2))          # fraction routed
    aux = E * jnp.sum(me * ce) * cfg.router_aux_coef
    return y, aux
