"""Shared model layers: norms, RoPE, GQA attention (windowed / prefix-LM /
cross), SwiGLU MLP, embeddings. Pure functions over param dicts.

Conventions:
  * activations ``(B, T, D)``; attention heads ``(B, T, H, hd)``.
  * params are plain dict pytrees; every init_* takes a PRNGKey.
  * caches: dict with 'k','v' of shape (B, S_cache, KV, hd) plus 'pos'
    (stored absolute positions (S_cache,) int32, -1 = empty slot). Windowed
    layers use S_cache == window (ring buffer), global layers S_cache == max.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rms_norm",
    "init_rms_norm",
    "rope",
    "init_dense",
    "dense",
    "init_attention",
    "attention",
    "init_attn_cache",
    "init_mlp",
    "mlp",
    "init_embedding",
    "embed_tokens",
    "unembed",
    "cross_entropy_loss",
]


def _norm_init(key, shape, scale=1.0, dtype=jnp.float32):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norm / rope
# ---------------------------------------------------------------------------


def init_rms_norm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(p, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    # normalize in f32, but apply the scale in the COMPUTE dtype: an f32
    # scale promotes every backward cotangent of the residual stream to f32,
    # doubling the bytes of each TP activation collective (measured on
    # gemma3 train_4k: 459 GiB/device of f32 all-gathers — §Perf pair 2).
    out = (xf * jax.lax.rsqrt(var + eps)).astype(dt)
    return out * p["scale"].astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(-np.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., T, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., T, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------


def init_dense(key, d_in: int, d_out: int, bias: bool = False, dtype=jnp.bfloat16):
    p = {"w": _norm_init(key, (d_in, d_out), scale=d_in**-0.5, dtype=dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    window: Optional[int] = None     # sliding window (None = global)
    causal: bool = True              # False for encoder / cross attention
    use_rope: bool = True


def init_attention(key, d: int, spec: AttnSpec, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    H, KV, hd = spec.num_heads, spec.num_kv_heads, spec.head_dim
    s = d**-0.5
    p = {
        "wq": _norm_init(ks[0], (d, H, hd), s, dtype),
        "wk": _norm_init(ks[1], (d, KV, hd), s, dtype),
        "wv": _norm_init(ks[2], (d, KV, hd), s, dtype),
        "wo": _norm_init(ks[3], (H, hd, d), (H * hd) ** -0.5, dtype),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    return p


def init_attn_cache(batch: int, max_len: int, spec: AttnSpec, dtype=jnp.bfloat16):
    """Cache for one attention layer. Windowed layers keep a ring buffer."""
    S = min(max_len, spec.window) if spec.window else max_len
    KV, hd = spec.num_kv_heads, spec.head_dim
    return {
        "k": jnp.zeros((batch, S, KV, hd), dtype),
        "v": jnp.zeros((batch, S, KV, hd), dtype),
        "pos": jnp.full((S,), -1, jnp.int32),  # absolute position per slot
    }


def _qkv(p, spec: AttnSpec, x, kv_input=None):
    kv_input = x if kv_input is None else kv_input
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_input, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_input, p["wv"])
    if spec.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def _out_proj(out, wo):
    """(B,T,H,hd) x (H,hd,d) -> (B,T,d) with compute-dtype accumulation
    declaration (see down_proj)."""
    B, T, H, hd = out.shape
    return down_proj(out.reshape(B, T, H * hd), wo.reshape(H * hd, -1))


def _sdpa(q, k, v, mask, spec: AttnSpec):
    """q: (B,T,H,hd); k,v: (B,S,KV,hd); mask broadcastable to (B,T,S)."""
    B, T, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, T, KV, G, hd)
    k = k.astype(q.dtype)
    v = v.astype(q.dtype)
    scores = jnp.einsum("btkgh,bskh->bkgts", qg, k).astype(jnp.float32)
    scores = scores * (hd**-0.5)
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", w.astype(v.dtype), v)
    return out.reshape(B, T, H, hd)


# S at which train/prefill attention switches to the memory-efficient
# KV-block-scanned softmax (full T x S scores never materialize). The TPU
# production path is the Pallas flash kernel (repro.kernels.flash_attention);
# this is the XLA-portable equivalent with identical math.
CHUNKED_ATTN_MIN_S = 4096
_CHUNK_BLOCK = 1024


def _mask_block(spec: AttnSpec, prefix_len: int, i, j):
    """Boolean mask for query positions i (T,) x key positions j (block,)."""
    ii, jj = i[:, None], j[None, :]
    if spec.causal:
        m = jj <= ii
        if prefix_len:
            m = m | (jj < prefix_len)
    else:
        m = jnp.ones((ii.shape[0], jj.shape[1]), bool)
    if spec.window is not None:
        m = m & (jj > ii - spec.window)
        if prefix_len:
            m = m | ((jj < prefix_len) & (ii < prefix_len))
    return m


def _chunked_sdpa(q, k, v, spec: AttnSpec, prefix_len: int, block: int = _CHUNK_BLOCK):
    """Flash-style attention: scan over KV blocks with running (max, sum).

    Peak memory is O(B*T*H*block) instead of O(B*T*H*S).
    """
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    block = min(block, S)
    assert S % block == 0, (S, block)
    nb = S // block
    qg = (q.reshape(B, T, KV, G, hd).astype(jnp.float32)) * (hd**-0.5)
    kb = jnp.moveaxis(k.reshape(B, nb, block, KV, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nb, block, KV, hd), 1, 0)
    i = jnp.arange(T)

    def body(carry, xs):
        o, m, l = carry
        kblk, vblk, j0 = xs
        j = j0 + jnp.arange(block)
        mask = _mask_block(spec, prefix_len, i, j)  # (T, block)
        s = jnp.einsum("btkgh,bskh->bkgts", qg, kblk.astype(jnp.float32))
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        o = o * corr[..., None] + jnp.einsum(
            "bkgts,bskh->bkgth", p, vblk.astype(jnp.float32)
        )
        return (o, m_new, l), None

    o0 = jnp.zeros((B, KV, G, T, hd), jnp.float32)
    m0 = jnp.full((B, KV, G, T), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, KV, G, T), jnp.float32)
    (o, m, l), _ = jax.lax.scan(
        body, (o0, m0, l0), (kb, vb, jnp.arange(nb) * block)
    )
    out = o / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1).reshape(B, T, H, hd)
    return out.astype(q.dtype)


def full_mask(T: int, spec: AttnSpec, prefix_len: int = 0):
    """(1, T, S=T) mask for train/prefill."""
    i = jnp.arange(T)[:, None]
    j = jnp.arange(T)[None, :]
    if spec.causal:
        m = j <= i
        if prefix_len:
            m = m | (j < prefix_len)
    else:
        m = jnp.ones((T, T), bool)
    if spec.window is not None:
        m = m & (j > i - spec.window)
        if prefix_len:
            m = m | ((j < prefix_len) & (i < prefix_len))
    return m[None]


def attention(
    p,
    x,
    spec: AttnSpec,
    *,
    mode: str = "train",           # train | prefill | decode
    positions: jax.Array | None = None,
    prefix_len: int = 0,
    cache: dict | None = None,
    cur_pos: jax.Array | None = None,   # scalar int32: index of the new token
    cross_kv: tuple | None = None,      # (k, v, valid_len) for cross attention
):
    """Returns (out, new_cache). new_cache is None unless prefill/decode."""
    B, T, D = x.shape

    # ---- cross attention (whisper decoder): kv precomputed, no cache update
    if cross_kv is not None:
        k, v = cross_kv
        q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
        if spec.qkv_bias:
            q = q + p["bq"]
        mask = jnp.ones((1, T, k.shape[1]), bool)
        out = _sdpa(q, k, v, mask, spec)
        return _out_proj(out, p["wo"]), None

    if mode in ("train", "prefill"):
        if positions is None:
            positions = jnp.arange(T)[None, :]
        q, k, v = _qkv(p, spec, x)
        if spec.use_rope:
            q = rope(q, positions, spec.rope_theta)
            k = rope(k, positions, spec.rope_theta)
        if k.shape[1] >= CHUNKED_ATTN_MIN_S:
            out = _chunked_sdpa(q, k, v, spec, prefix_len)
        else:
            mask = full_mask(T, spec, prefix_len)
            out = _sdpa(q, k, v, mask, spec)
        y = _out_proj(out, p["wo"])
        new_cache = None
        if mode == "prefill":
            new_cache = _fill_cache(k, v, spec, T)
        return y, new_cache

    # ---- decode: T == 1, append to cache ----
    assert mode == "decode" and cache is not None and cur_pos is not None
    q, k_new, v_new = _qkv(p, spec, x)
    pos_b = jnp.broadcast_to(cur_pos, (B, 1))
    if spec.use_rope:
        q = rope(q, pos_b, spec.rope_theta)
        k_new = rope(k_new, pos_b, spec.rope_theta)
    S = cache["k"].shape[1]
    slot = (cur_pos % S).astype(jnp.int32)
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype), (0, slot, 0, 0))
    pos = jax.lax.dynamic_update_slice(cache["pos"], cur_pos[None].astype(jnp.int32), (slot,))
    valid = pos >= 0
    if spec.window is not None:
        valid = valid & (pos > cur_pos - spec.window)
    mask = valid[None, None, :]  # (1, 1, S)
    if k.dtype != q.dtype and S >= 8192:
        # Quantized (f8) cache: _sdpa's cast would materialize a full
        # compute-dtype shadow of the cache (qwen1.5-32b: +20 GiB/device).
        # Heads are independent, so process KV-head blocks in sequence —
        # the KV dim is unsharded for these archs (the cache seq dim holds
        # the 'model' axis), so slicing it inserts NO collectives. (A seq-dim
        # blocked scan all-gathered the sharded cache: 0.85 ms -> 1.3 s on
        # minitron — see EXPERIMENTS.md §Perf.)
        out = _decode_sdpa_headblocked(q, k, v, mask, spec)
    else:
        out = _sdpa(q, k, v, mask, spec)
    y = _out_proj(out, p["wo"])
    return y, {"k": k, "v": v, "pos": pos}


def _decode_sdpa_headblocked(q, k, v, mask, spec: AttnSpec, heads_per_block: int = 8):
    """q: (B,1,H,hd); k/v: (B,S,KV,hd) in a narrower cache dtype.

    Static loop over KV-head blocks: heads are independent under softmax,
    so each block runs a full (small) _sdpa; only one block of the cache is
    ever cast to the compute dtype."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    hb = min(heads_per_block, KV)
    while KV % hb:
        hb -= 1
    qg = q.reshape(B, T, KV, G, hd)
    outs = []
    for k0 in range(0, KV, hb):
        qb = qg[:, :, k0 : k0 + hb].reshape(B, T, hb * G, hd)
        kb = k[:, :, k0 : k0 + hb].astype(q.dtype)
        vb = v[:, :, k0 : k0 + hb].astype(q.dtype)
        outs.append(_sdpa(qb, kb, vb, mask, spec).reshape(B, T, hb, G, hd))
    return jnp.concatenate(outs, axis=2).reshape(B, T, H, hd)


def _fill_cache(k, v, spec: AttnSpec, T: int):
    """Build a decode cache from prefill K/V (keep last `window` for SWA)."""
    if spec.window is not None and T > spec.window:
        W = spec.window
        start = T - W
        k = k[:, start:]
        v = v[:, start:]
        # ring-buffer layout: slot = pos % W
        pos_abs = jnp.arange(start, T)
        slots = pos_abs % W
        order = jnp.argsort(slots)
        k, v = k[:, order], v[:, order]
        pos = jnp.zeros((W,), jnp.int32).at[slots[order]].set(pos_abs[order])
        return {"k": k, "v": v, "pos": pos}
    pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    return {"k": k, "v": v, "pos": pos}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, act: str = "silu", dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    p = {
        "w_up": _norm_init(ks[0], (d, f), d**-0.5, dtype),
        "w_down": _norm_init(ks[1], (f, d), f**-0.5, dtype),
    }
    if act in ("silu", "geglu"):
        p["w_gate"] = _norm_init(ks[2], (d, f), d**-0.5, dtype)
    return p


def down_proj(h, w):
    """Contraction-sharded (TP) projection with COMPUTE-dtype output: jax
    emits f32-accumulating dots by default and GSPMD all-reduces the f32
    partials BEFORE the downcast — 2x the wire bytes of every TP psum
    (measured on gemma3 train_4k; EXPERIMENTS.md §Perf pair 2). Declaring
    the output dtype moves the rounding before the collective; the MXU
    still accumulates in f32 on TPU."""
    return jax.lax.dot_general(
        h, w, (((h.ndim - 1,), (0,)), ((), ())), preferred_element_type=h.dtype
    )


def mlp(p, x, act: str = "silu"):
    up = x @ p["w_up"]
    if act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    elif act == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * up
    else:
        h = jax.nn.gelu(up)
    return down_proj(h, p["w_down"])


# ---------------------------------------------------------------------------
# embedding / unembedding / loss
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, tie: bool = True, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 2)
    p = {"tokens": _norm_init(ks[0], (vocab, d), d**-0.5, dtype)}
    if not tie:
        p["unembed"] = _norm_init(ks[1], (vocab, d), d**-0.5, dtype)
    return p


def embed_tokens(p, tokens):
    return jnp.take(p["tokens"], tokens, axis=0)


def unembed(p, x):
    table = p.get("unembed", p["tokens"])
    return jnp.einsum("btd,vd->btv", x, table).astype(jnp.float32)


def cross_entropy_loss(logits, labels, mask=None):
    """logits (B,T,V) f32, labels (B,T) int32. Returns mean nll."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(nll.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
