"""Stack assembly: scan-over-superblocks decoder (+ optional encoder).

Layers are grouped into *superblocks* of length P = lcm(|block_pattern|,
|attn_pattern|): a single traced scan body contains one block per pattern
slot, and ``lax.scan`` iterates over ``num_layers // P`` superblocks with
stacked parameters. Heterogeneous stacks (xLSTM's 7:1 mLSTM:sLSTM, gemma3's
5:1 local:global) therefore compile to ONE body — HLO size and compile time
are depth-independent. ``num_layers % P`` leftover layers run unscanned.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..dist.hints import hint
from .blocks import apply_block, init_block, init_block_cache
from .layers import embed_tokens, init_embedding, init_rms_norm, rms_norm, unembed

__all__ = ["StackLayout", "init_lm", "apply_lm", "init_decode_cache"]


class StackLayout:
    """Derived layer layout for a config."""

    def __init__(self, cfg, *, encoder: bool = False):
        self.cfg = cfg
        if encoder:
            self.kinds = ["attn"] * cfg.encoder_layers
            self.windows = [None] * cfg.encoder_layers
            self.period = 1
            self.num_layers = cfg.encoder_layers
        else:
            bp, ap = cfg.block_pattern, cfg.attn_pattern
            self.period = math.lcm(len(bp), len(ap))
            self.num_layers = cfg.num_layers
            self.kinds = cfg.layer_kinds()
            self.windows = cfg.layer_windows()
        self.num_super = self.num_layers // self.period
        self.tail = self.num_layers % self.period

    def slot_kind(self, i: int) -> str:
        return self.kinds[i]

    def slot_window(self, i: int):
        return self.windows[i]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _init_stack(key, cfg, layout: StackLayout, *, cross: bool, causal: bool):
    dt = _dtype(cfg)
    blocks = []
    for i in range(layout.period):
        kind, win = layout.kinds[i], layout.windows[i]
        keys = jax.random.split(jax.random.fold_in(key, i), max(layout.num_super, 1))
        init_one = partial(init_block, cfg=cfg, kind=kind, window=win, cross=cross, causal=causal, dtype=dt)
        if layout.num_super:
            blocks.append(jax.vmap(lambda k: init_one(k))(keys))
        else:
            blocks.append(None)
    tail = []
    for j in range(layout.tail):
        i = layout.num_super * layout.period + j
        tail.append(
            init_block(
                jax.random.fold_in(key, 10_000 + j),
                cfg,
                layout.kinds[i % layout.period],
                layout.windows[i % layout.period],
                cross=cross,
                causal=causal,
                dtype=dt,
            )
        )
    return {"blocks": blocks, "tail": tail}


def init_lm(key, cfg):
    """Full parameter tree for a config."""
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    layout = StackLayout(cfg)
    params = {
        "embed": init_embedding(ks[0], cfg.padded_vocab, cfg.d_model, cfg.tie_embeddings, dt),
        "decoder": _init_stack(ks[1], cfg, layout, cross=(cfg.arch_type == "encdec"), causal=True),
        "final_norm": init_rms_norm(cfg.d_model),
    }
    if cfg.arch_type == "encdec":
        enc_layout = StackLayout(cfg, encoder=True)
        params["encoder"] = _init_stack(ks[2], cfg, enc_layout, cross=False, causal=False)
        params["enc_norm"] = init_rms_norm(cfg.d_model)
    return params


def _apply_stack(
    stack_params,
    x,
    cfg,
    layout: StackLayout,
    *,
    mode: str,
    caches=None,
    cur_pos=None,
    max_len: int = 0,
    prefix_len: int = 0,
    causal: bool = True,
    cross_inputs=None,
    remat: bool = False,
    axis_name=None,
):
    """Returns (x, new_caches, aux). Caches: {'blocks': [...], 'tail': [...]}
    ``axis_name`` routes MoE expert dispatch over that mesh axis (see
    ``apply_block``)."""
    P = layout.period
    kinds, wins = layout.kinds, layout.windows
    run_block = partial(
        apply_block,
        cfg=cfg,
        mode=mode,
        cur_pos=cur_pos,
        max_len=max_len,
        prefix_len=prefix_len,
        causal=causal,
        cross_inputs=cross_inputs,
        axis_name=axis_name,
    )

    def body(x, xs):
        bs, cs = xs
        aux = jnp.zeros((), jnp.float32)
        new_cs = []
        for i in range(P):
            x, nc, a = run_block(bs[i], x, kind=kinds[i], window=wins[i], cache=None if cs is None else cs[i])
            x = hint(x, "btd_res")  # optional sequence-parallel residual
            aux = aux + a
            new_cs.append(nc)
        if mode == "train":
            return x, aux
        return x, (new_cs, aux)

    if remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)

    aux_total = jnp.zeros((), jnp.float32)
    new_caches = {"blocks": None, "tail": []}
    if layout.num_super:
        xs = (stack_params["blocks"], caches["blocks"] if caches else None)
        if mode == "train":
            x, auxs = lax.scan(body, x, xs)
        else:
            x, (blk_caches, auxs) = lax.scan(body, x, xs)
            new_caches["blocks"] = blk_caches
        aux_total = aux_total + jnp.sum(auxs)
    for j, tp in enumerate(stack_params["tail"]):
        i = layout.num_super * P + j
        tc = caches["tail"][j] if caches else None
        x, nc, a = run_block(tp, x, kind=kinds[i % P], window=wins[i % P], cache=tc)
        aux_total = aux_total + a
        new_caches["tail"].append(nc)
    return x, (new_caches if mode != "train" else None), aux_total


def apply_lm(
    params,
    cfg,
    *,
    tokens=None,
    embeds=None,
    mode: str = "train",
    caches=None,
    cur_pos=None,
    max_len: int = 0,
    remat: bool = False,
):
    """Unified forward.

    train/prefill: ``tokens`` (B, T_text); VLM prepends ``embeds``
    (B, prefix, D); audio encdec consumes ``embeds`` (B, frames, D) through
    the encoder. decode: ``tokens`` (B, 1) + ``caches`` + scalar ``cur_pos``.

    Returns (logits_f32, new_caches, aux).
    """
    layout = StackLayout(cfg)
    dt = _dtype(cfg)
    prefix_len = 0
    cross_inputs = None
    enc_caches_out = None

    if cfg.arch_type == "encdec":
        if mode == "decode":
            cross_inputs = None  # cross K/V live in the per-layer cache
        else:
            assert embeds is not None, "encdec needs frontend embeddings"
            enc_layout = StackLayout(cfg, encoder=True)
            h = embeds.astype(dt)
            h, _, _ = _apply_stack(
                params["encoder"], h, cfg, enc_layout, mode="train", causal=False, remat=remat
            )
            cross_inputs = rms_norm(params["enc_norm"], h, cfg.norm_eps)
        x = embed_tokens(params["embed"], tokens) * jnp.asarray(cfg.d_model**0.5, dt)
    elif cfg.frontend == "vision":
        x = embed_tokens(params["embed"], tokens) * jnp.asarray(cfg.d_model**0.5, dt)
        if mode in ("train", "prefill"):
            assert embeds is not None, "vlm needs patch embeddings"
            x = jnp.concatenate([embeds.astype(dt), x], axis=1)
            prefix_len = embeds.shape[1]
        else:
            prefix_len = cfg.prefix_len
    else:
        x = embed_tokens(params["embed"], tokens) * jnp.asarray(cfg.d_model**0.5, dt)

    x = hint(x, "btd")
    x, new_caches, aux = _apply_stack(
        params["decoder"],
        x,
        cfg,
        layout,
        mode=mode,
        caches=caches,
        cur_pos=cur_pos,
        max_len=max_len,
        prefix_len=prefix_len,
        causal=True,
        cross_inputs=cross_inputs,
        remat=remat,
    )
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    if mode in ("train", "prefill") and prefix_len and cfg.frontend == "vision":
        x = x[:, prefix_len:]
    logits = hint(unembed(params["embed"], x), "btv")
    return logits, new_caches, aux


def init_decode_cache(cfg, batch: int, max_len: int):
    """Zero decode cache matching apply_lm's cache structure (also used to
    build ShapeDtypeStruct specs for the decode dry-run)."""
    layout = StackLayout(cfg)
    dt = _dtype(cfg)
    P = layout.period
    blocks = None
    if layout.num_super:
        blocks = []
        for i in range(P):
            one = init_block_cache(cfg, layout.kinds[i], layout.windows[i], batch, max_len, dt)
            if cfg.arch_type == "encdec":
                one["cross"] = _zero_cross(cfg, batch, dt)
            stacked = jax.tree.map(
                lambda l: jnp.broadcast_to(l, (layout.num_super,) + l.shape), one
            )
            blocks.append(stacked)
    tail = []
    for j in range(layout.tail):
        i = layout.num_super * P + j
        one = init_block_cache(cfg, layout.kinds[i % P], layout.windows[i % P], batch, max_len, dt)
        if cfg.arch_type == "encdec":
            one["cross"] = _zero_cross(cfg, batch, dt)
        tail.append(one)
    return {"blocks": blocks, "tail": tail}


def _zero_cross(cfg, batch: int, dt):
    return {
        "k": jnp.zeros((batch, cfg.frontend_len, cfg.num_kv_heads, cfg.head_dim), dt),
        "v": jnp.zeros((batch, cfg.frontend_len, cfg.num_kv_heads, cfg.head_dim), dt),
    }
