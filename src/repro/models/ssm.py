"""Recurrent / state-space mixers: mLSTM & sLSTM (xLSTM) and Mamba (S6).

TPU adaptation notes (DESIGN.md Sec. 2): the GPU reference implementations
use fused CUDA scans; here the sequence dimension is processed *chunkwise* —
an outer ``lax.scan`` carries the recurrent state across chunks while each
chunk is computed in parallel (matmuls for mLSTM, ``associative_scan`` for
the diagonal Mamba recurrence). This keeps the MXU busy and the working set
in VMEM-sized tiles, which is the TPU-native shape of these operators.

Simplification recorded in DESIGN.md: xLSTM's stabilized exponential gating
is replaced by log-sigmoid gating (decay factors <= 1, unconditionally
stable). The matrix-memory structure, state shapes, and compute/collective
footprint — what the systems reproduction measures — are unchanged.

All mixers expose:
    init_*(key, cfg)        -> params
    *_seq(p, x, cfg)        -> (y, final_state)   # train / prefill
    *_step(p, x1, state, cfg) -> (y1, new_state)  # single-token decode
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .layers import _norm_init, down_proj

__all__ = [
    "chunked_diag_scan",
    "init_mlstm",
    "mlstm_seq",
    "mlstm_step",
    "init_slstm",
    "slstm_seq",
    "slstm_step",
    "init_mamba",
    "mamba_seq",
    "mamba_step",
]


# ---------------------------------------------------------------------------
# generic chunked diagonal-linear scan: h_t = exp(log_a_t) * h_{t-1} + b_t
# ---------------------------------------------------------------------------


def _pick_chunk(T: int, chunk: int) -> int:
    """Largest divisor of T that is <= chunk (production Ts are powers of
    two, so this returns `chunk`; odd smoke lengths degrade gracefully)."""
    L = min(chunk, T)
    while T % L:
        L -= 1
    return L


def chunked_diag_scan(log_a, b, h0, chunk: int):
    """log_a, b: (B, T, *S); h0: (B, *S). Returns (h (B,T,*S), h_last)."""
    B, T = b.shape[:2]
    L = _pick_chunk(T, chunk)
    nc = T // L
    rest = b.shape[2:]
    la = log_a.reshape(B, nc, L, *rest)
    bb = b.reshape(B, nc, L, *rest)

    def op(x, y):
        la1, h1 = x
        la2, h2 = y
        return (la1 + la2, jnp.exp(la2) * h1 + h2)

    # intra-chunk inclusive scan (zero incoming state)
    la_cum, h_intra = lax.associative_scan(op, (la, bb), axis=2)

    # cross-chunk carry
    def step(H, xs):
        la_c, h_c = xs  # (B, L, *S)
        h = h_c + jnp.exp(la_c) * H[:, None]
        return h[:, -1], h

    xs = (jnp.moveaxis(la_cum, 1, 0), jnp.moveaxis(h_intra, 1, 0))
    h_last, h_chunks = lax.scan(step, h0, xs)
    h = jnp.moveaxis(h_chunks, 0, 1).reshape(B, T, *rest)
    return h, h_last


# ---------------------------------------------------------------------------
# mLSTM (matrix-memory LSTM, chunkwise linear attention with decay)
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ks = jax.random.split(key, 7)
    s = d**-0.5
    return {
        "wq": _norm_init(ks[0], (d, di), s, dtype),
        "wk": _norm_init(ks[1], (d, di), s, dtype),
        "wv": _norm_init(ks[2], (d, di), s, dtype),
        "wg": _norm_init(ks[3], (d, di), s, dtype),
        "wi": _norm_init(ks[4], (d, cfg.num_heads), s, jnp.float32),
        "wf": _norm_init(ks[5], (d, cfg.num_heads), s, jnp.float32),
        "bf": jnp.full((cfg.num_heads,), 2.0, jnp.float32),  # open forget gates
        "wo": _norm_init(ks[6], (di, d), di**-0.5, dtype),
    }


def _mlstm_qkvg(p, x, cfg):
    B, T, d = x.shape
    H = cfg.num_heads
    di = cfg.ssm_expand * d
    hd = di // H
    q = (x @ p["wq"]).reshape(B, T, H, hd) * hd**-0.5
    k = (x @ p["wk"]).reshape(B, T, H, hd) * hd**-0.5
    v = (x @ p["wv"]).reshape(B, T, H, hd)
    g = jax.nn.sigmoid(x @ p["wg"])
    lf = jax.nn.log_sigmoid((x.astype(jnp.float32) @ p["wf"]) + p["bf"])  # (B,T,H)
    li = jax.nn.log_sigmoid(x.astype(jnp.float32) @ p["wi"])
    return q, k, v, g, lf, li


def mlstm_seq(p, x, cfg, state=None):
    """Chunkwise mLSTM. Returns (y, (C, n)) with C (B,H,hd,hd), n (B,H,hd)."""
    B, T, d = x.shape
    H = cfg.num_heads
    di = cfg.ssm_expand * d
    hd = di // H
    L = _pick_chunk(T, cfg.ssm_chunk)
    nc = T // L
    q, k, v, g, lf, li = _mlstm_qkvg(p, x, cfg)

    def rs(a):  # (B,T,H,...) -> (nc, B, H, L, ...)
        a = a.reshape(B, nc, L, *a.shape[2:])
        a = jnp.moveaxis(a, 1, 0)          # (nc, B, L, ...)
        return jnp.moveaxis(a, 3, 2) if a.ndim >= 4 else a  # heads before L

    qc, kc, vc = rs(q), rs(k), rs(v)       # (nc,B,H,L,hd)? check below
    lfc = jnp.moveaxis(lf.reshape(B, nc, L, H), 1, 0).transpose(0, 1, 3, 2)  # (nc,B,H,L)
    lic = jnp.moveaxis(li.reshape(B, nc, L, H), 1, 0).transpose(0, 1, 3, 2)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
    else:
        C0, n0 = state

    idx = jnp.arange(L)
    causal = idx[:, None] >= idx[None, :]

    def step(carry, xs):
        C, n = carry
        qq, kk, vv, lff, lii = xs           # (B,H,L,hd), (B,H,L)
        qf, kf, vf = (a.astype(jnp.float32) for a in (qq, kk, vv))
        F = jnp.cumsum(lff, axis=-1)        # (B,H,L) inclusive decay sums
        # intra-chunk: scores_ts = (q_t.k_s) exp(F_t - F_s + li_s), s <= t
        dec = F[..., :, None] - F[..., None, :] + lii[..., None, :]
        dec = jnp.where(causal, dec, -jnp.inf)
        scores = jnp.einsum("bhtd,bhsd->bhts", qf, kf) * jnp.exp(dec)
        num = jnp.einsum("bhts,bhsd->bhtd", scores, vf)
        # inter-chunk: exp(F_t) * (C q_t, n q_t)
        ef = jnp.exp(F)[..., None]
        num = num + jnp.einsum("bhtd,bhde->bhte", qf * ef, C)
        nq = jnp.einsum("bhtd,bhd->bht", qf * ef, n)
        # intra normalizer: sum_s exp(F_t - F_s + li_s) (k_s . q_t)
        nq = nq + jnp.einsum("bhts,bhsd,bhtd->bht", jnp.exp(dec), kf, qf)
        h = num / (jnp.abs(nq)[..., None] + 1.0)
        # carry updates
        eL = jnp.exp(F[..., -1])[..., None]                 # (B,H,1)
        w_s = jnp.exp(F[..., -1:] - F + lii)                # (B,H,L)
        C_new = C * eL[..., None] + jnp.einsum("bhs,bhsd,bhse->bhde", w_s, kf, vf)
        n_new = n * eL + jnp.einsum("bhs,bhsd->bhd", w_s, kf)
        return (C_new, n_new), h

    (C_f, n_f), hs = lax.scan(step, (C0, n0), (qc, kc, vc, lfc, lic))
    # hs: (nc, B, H, L, hd) -> (B, T, di)
    h = jnp.moveaxis(hs, 0, 1)              # (B, nc, H, L, hd)
    h = jnp.moveaxis(h, 2, 3).reshape(B, T, di).astype(x.dtype)
    y = down_proj(g * h, p["wo"])
    return y, (C_f, n_f)


def mlstm_step(p, x, state, cfg):
    """Single-token decode. x: (B, 1, d); state (C, n)."""
    B = x.shape[0]
    H = cfg.num_heads
    di = cfg.ssm_expand * cfg.d_model
    hd = di // H
    q, k, v, g, lf, li = _mlstm_qkvg(p, x, cfg)
    qf = q[:, 0].reshape(B, H, hd).astype(jnp.float32)
    kf = k[:, 0].reshape(B, H, hd).astype(jnp.float32)
    vf = v[:, 0].reshape(B, H, hd).astype(jnp.float32)
    f = jnp.exp(lf[:, 0])[..., None]        # (B,H,1)
    i = jnp.exp(li[:, 0])[..., None]
    C, n = state
    C = C * f[..., None] + i[..., None] * kf[..., :, None] * vf[..., None, :]
    n = n * f + i * kf
    num = jnp.einsum("bhd,bhde->bhe", qf, C)
    nq = jnp.einsum("bhd,bhd->bh", qf, n)
    h = (num / (jnp.abs(nq)[..., None] + 1.0)).reshape(B, 1, di).astype(x.dtype)
    y = down_proj(g * h, p["wo"])
    return y, (C, n)


# ---------------------------------------------------------------------------
# sLSTM (scalar-memory LSTM with head-wise recurrent mixing) — sequential
# ---------------------------------------------------------------------------


def init_slstm(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    ks = jax.random.split(key, 3)
    return {
        "w": _norm_init(ks[0], (d, 4 * d), d**-0.5, jnp.float32),
        "r": _norm_init(ks[1], (H, hd, 4 * hd), hd**-0.5, jnp.float32),
        "b": jnp.concatenate(
            [jnp.zeros((2 * d,)), jnp.full((d,), 2.0), jnp.zeros((d,))]
        ).astype(jnp.float32),
        "wo_r": _norm_init(ks[2], (d, d), d**-0.5, dtype),
    }


def _slstm_cell(p, xt, carry, cfg):
    """xt: (B, 4d) pre-projected input; carry: (c, n, h) each (B, d)."""
    d = cfg.d_model
    H = cfg.num_heads
    hd = d // H
    c, n, h = carry
    hr = h.reshape(-1, H, hd)
    rec = jnp.einsum("bhk,hkm->bhm", hr, p["r"]).reshape(-1, 4 * d)
    z, i, f, o = jnp.split(xt + rec + p["b"], 4, axis=-1)
    z = jnp.tanh(z)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    o = jax.nn.sigmoid(o)
    c = f * c + i * z
    n = f * n + i
    h = o * c / (jnp.abs(n) + 1.0)
    return (c, n, h)


def slstm_seq(p, x, cfg, state=None):
    B, T, d = x.shape
    xp = (x.astype(jnp.float32) @ p["w"])   # (B,T,4d)
    if state is None:
        z = jnp.zeros((B, d), jnp.float32)
        state = (z, z, z)

    def step(carry, xt):
        carry = _slstm_cell(p, xt, carry, cfg)
        return carry, carry[2]

    state, hs = lax.scan(step, state, jnp.moveaxis(xp, 0, 1))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype) @ p["wo_r"]
    return y, state


def slstm_step(p, x, state, cfg):
    xt = (x[:, 0].astype(jnp.float32) @ p["w"])
    state = _slstm_cell(p, xt, state, cfg)
    y = state[2][:, None].astype(x.dtype) @ p["wo_r"]
    return y, state


# ---------------------------------------------------------------------------
# Mamba (S6 selective scan, diagonal state) — chunked associative scan
# ---------------------------------------------------------------------------


def init_mamba(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    return {
        "w_in": _norm_init(ks[0], (d, 2 * di), d**-0.5, dtype),
        "conv": _norm_init(ks[1], (cfg.ssm_conv, di), 0.5, jnp.float32),
        "w_bc": _norm_init(ks[2], (di, 2 * N), di**-0.5, jnp.float32),
        "w_dt": _norm_init(ks[3], (di, di), di**-0.5, jnp.float32),
        "b_dt": jnp.full((di,), -4.0, jnp.float32),  # softplus ~= 0.018
        "a_log": jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32), (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "w_out": _norm_init(ks[4], (di, d), di**-0.5, dtype),
    }


def _mamba_conv(p, xb, conv_state=None):
    """Depthwise causal conv, width W. xb: (B,T,di) f32.
    conv_state: (B, W-1, di) previous inputs (or None -> zeros)."""
    W = p["conv"].shape[0]
    B, T, di = xb.shape
    if conv_state is None:
        conv_state = jnp.zeros((B, W - 1, di), xb.dtype)
    xp = jnp.concatenate([conv_state, xb], axis=1)       # (B, T+W-1, di)
    out = sum(xp[:, i : i + T] * p["conv"][i] for i in range(W))
    new_state = xp[:, -(W - 1) :]
    return jax.nn.silu(out), new_state


def mamba_seq(p, x, cfg, state=None):
    """Returns (y, (ssm_state (B,di,N), conv_state (B,W-1,di)))."""
    B, T, d = x.shape
    di = cfg.ssm_expand * d
    N = cfg.ssm_state
    xz = x @ p["w_in"]
    xb, z = jnp.split(xz, 2, axis=-1)
    xb = xb.astype(jnp.float32)
    conv_in = None if state is None else state[1]
    xc, conv_state = _mamba_conv(p, xb, conv_in)
    dt = jax.nn.softplus(xc @ p["w_dt"] + p["b_dt"])     # (B,T,di)
    BC = xc @ p["w_bc"]
    Bm, Cm = jnp.split(BC, 2, axis=-1)                   # (B,T,N)
    A = -jnp.exp(p["a_log"])                             # (di,N)
    h0 = jnp.zeros((B, di, N), jnp.float32) if state is None else state[0]

    # Fused chunkwise scan: the (B, T, di, N) state sequence NEVER
    # materializes — each chunk's intra-chunk associative scan and the
    # C-projection happen inside one sequential step (peak state memory is
    # O(B * chunk * di * N); the unfused version materialized the full T and
    # pushed hymba train_4k to 27.7 GiB/device — EXPERIMENTS.md §Perf).
    L = _pick_chunk(T, cfg.ssm_chunk)
    nc = T // L
    N = cfg.ssm_state

    def rs(a):  # (B,T,...) -> (nc,B,L,...)
        return jnp.moveaxis(a.reshape(B, nc, L, *a.shape[2:]), 1, 0)

    def op(u, w):
        la1, h1 = u
        la2, h2 = w
        return (la1 + la2, jnp.exp(la2) * h1 + h2)

    def step(h_in, xs):
        dt_c, xc_c, b_c, c_c = xs            # (B,L,di) / (B,L,N)
        log_a = dt_c[..., None] * A          # (B,L,di,N)
        bu = (dt_c * xc_c)[..., None] * b_c[..., None, :]
        la_cum, h_intra = lax.associative_scan(op, (log_a, bu), axis=1)
        h = h_intra + jnp.exp(la_cum) * h_in[:, None]
        y_c = jnp.einsum("bldn,bln->bld", h, c_c)
        return h[:, -1], y_c

    h_last, y_chunks = lax.scan(step, h0, (rs(dt), rs(xc), rs(Bm), rs(Cm)))
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(B, T, di) + p["d_skip"] * xc
    y = down_proj(y.astype(x.dtype) * jax.nn.silu(z), p["w_out"])
    return y, (h_last, conv_state)


def mamba_step(p, x, state, cfg):
    """x: (B,1,d); state: (ssm_state, conv_state)."""
    B = x.shape[0]
    di = cfg.ssm_expand * cfg.d_model
    xz = x @ p["w_in"]
    xb, z = jnp.split(xz, 2, axis=-1)
    xb = xb.astype(jnp.float32)
    h0, conv_state = state
    xc, conv_state = _mamba_conv(p, xb, conv_state)
    dt = jax.nn.softplus(xc @ p["w_dt"] + p["b_dt"])
    Bm, Cm = jnp.split(xc @ p["w_bc"], 2, axis=-1)
    A = -jnp.exp(p["a_log"])
    a = jnp.exp(dt[:, 0, :, None] * A)                   # (B,di,N)
    h = h0 * a + (dt[:, 0] * xc[:, 0])[..., None] * Bm[:, 0, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm[:, 0]) + p["d_skip"] * xc[:, 0]
    y = down_proj(y[:, None].astype(x.dtype) * jax.nn.silu(z), p["w_out"])
    return y, (h, conv_state)
