"""Composable model zoo: dense / MoE / SSM / hybrid / enc-dec / VLM stacks."""
from .model import Model
from .transformer import StackLayout, apply_lm, init_decode_cache, init_lm

__all__ = ["Model", "StackLayout", "apply_lm", "init_decode_cache", "init_lm"]
