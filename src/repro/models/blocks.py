"""Block assembly: one residual block per `kind`, with a uniform
(init, apply) interface so the transformer stack can scan over
heterogeneous layer patterns (see transformer.py).

Kinds:
  attn    pre-norm GQA attention + MLP            (dense archs)
  moe     pre-norm GQA attention + MoE FFN        (mixtral / qwen3 / moonshot)
  mlstm   matrix-LSTM mixer                       (xLSTM)
  slstm   scalar-LSTM mixer                       (xLSTM)
  hybrid  parallel attention + mamba heads + MLP  (hymba)

Caches (prefill/decode) are dict pytrees whose structure depends on kind.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import moe as moe_lib
from . import ssm
from .layers import (
    AttnSpec,
    attention,
    init_attention,
    init_mlp,
    init_rms_norm,
    mlp,
    rms_norm,
)

__all__ = ["attn_spec_for", "init_block", "apply_block", "init_block_cache"]


def attn_spec_for(cfg, window: Optional[int], causal: bool = True) -> AttnSpec:
    return AttnSpec(
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim,
        qkv_bias=cfg.qkv_bias,
        rope_theta=cfg.rope_theta,
        window=window,
        causal=causal,
    )


def init_block(key, cfg, kind: str, window: Optional[int], *, cross: bool = False, causal: bool = True, dtype=jnp.bfloat16):
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    spec = attn_spec_for(cfg, window, causal)
    p = {"norm1": init_rms_norm(d)}
    if kind in ("attn", "moe", "hybrid"):
        p["attn"] = init_attention(ks[0], d, spec, dtype)
    if kind == "hybrid":
        p["ssm"] = ssm.init_mamba(ks[1], cfg, dtype)
        p["mix_a"] = jnp.ones((), jnp.float32)
        p["mix_m"] = jnp.ones((), jnp.float32)
    if kind == "mlstm":
        p["ssm"] = ssm.init_mlstm(ks[1], cfg, dtype)
    if kind == "slstm":
        p["ssm"] = ssm.init_slstm(ks[1], cfg, dtype)
    if kind in ("attn", "moe", "hybrid") and cfg.d_ff:
        p["norm2"] = init_rms_norm(d)
        if kind == "moe":
            p["moe"] = moe_lib.init_moe(ks[2], cfg, dtype)
        else:
            p["mlp"] = init_mlp(ks[2], d, cfg.d_ff, cfg.act, dtype)
    if kind == "moe" and not cfg.d_ff:
        raise ValueError("moe blocks need d_ff (expert width)")
    if cross:
        p["norm_x"] = init_rms_norm(d)
        p["cross"] = init_attention(ks[3], d, spec, dtype)
    return p


def init_block_cache(cfg, kind: str, window: Optional[int], batch: int, max_len: int, dtype=jnp.bfloat16):
    """Zero cache for one block (used by serving and by decode input_specs)."""
    from .layers import init_attn_cache

    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = cfg.num_heads
    cache = {}
    if kind in ("attn", "moe", "hybrid"):
        import jax.numpy as _jnp

        kv_dt = _jnp.dtype(cfg.kv_cache_dtype)
        cache["attn"] = init_attn_cache(batch, max_len, attn_spec_for(cfg, window), kv_dt)
    if kind == "hybrid":
        N = cfg.ssm_state
        cache["ssm"] = {
            "h": jnp.zeros((batch, di, N), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di), jnp.float32),
        }
    if kind == "mlstm":
        hd = di // H
        cache["ssm"] = {
            "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
        }
    if kind == "slstm":
        z = jnp.zeros((batch, d), jnp.float32)
        cache["ssm"] = {"c": z, "n": z, "h": z}
    return cache


def apply_block(
    p,
    x,
    cfg,
    kind: str,
    window: Optional[int],
    *,
    mode: str = "train",
    cache: dict | None = None,
    cur_pos=None,
    max_len: int = 0,
    prefix_len: int = 0,
    positions=None,
    causal: bool = True,
    cross_inputs=None,
    axis_name=None,
):
    """Returns (x, new_cache, aux_loss). ``axis_name`` names the mesh axis
    for explicit MoE expert dispatch (``cfg.moe_dispatch='alltoallv'``);
    None keeps the dense einsum formulation."""
    spec = attn_spec_for(cfg, window, causal)
    aux = jnp.zeros((), jnp.float32)
    new_cache = {}
    h = rms_norm(p["norm1"], x, cfg.norm_eps)

    if kind in ("attn", "moe", "hybrid"):
        attn_cache = cache.get("attn") if cache else None
        y, ac = attention(
            p["attn"],
            h,
            spec,
            mode=mode,
            positions=positions,
            prefix_len=prefix_len,
            cache=attn_cache,
            cur_pos=cur_pos,
        )
        if mode == "prefill" and max_len:
            ac = _grow_cache(ac, max_len, spec)
        if ac is not None:
            kv_dt = jnp.dtype(cfg.kv_cache_dtype)
            ac = {**ac, "k": ac["k"].astype(kv_dt), "v": ac["v"].astype(kv_dt)}
            new_cache["attn"] = ac
        if kind == "hybrid":
            if mode in ("train", "prefill"):
                m, ms = ssm.mamba_seq(p["ssm"], h, cfg, state=None)
            else:
                st = (cache["ssm"]["h"], cache["ssm"]["conv"])
                m, ms = ssm.mamba_step(p["ssm"], h, st, cfg)
            if mode in ("prefill", "decode"):
                new_cache["ssm"] = {"h": ms[0], "conv": ms[1]}
            y = p["mix_a"].astype(x.dtype) * y + p["mix_m"].astype(x.dtype) * m
        x = x + y
    elif kind in ("mlstm", "slstm"):
        fn_seq = ssm.mlstm_seq if kind == "mlstm" else ssm.slstm_seq
        fn_step = ssm.mlstm_step if kind == "mlstm" else ssm.slstm_step
        if mode in ("train", "prefill"):
            y, st = fn_seq(p["ssm"], h, cfg)
        else:
            c = cache["ssm"]
            st_in = (c["C"], c["n"]) if kind == "mlstm" else (c["c"], c["n"], c["h"])
            y, st = fn_step(p["ssm"], h, st_in, cfg)
        if mode in ("prefill", "decode"):
            if kind == "mlstm":
                new_cache["ssm"] = {"C": st[0], "n": st[1]}
            else:
                new_cache["ssm"] = {"c": st[0], "n": st[1], "h": st[2]}
        x = x + y
    else:
        raise ValueError(f"unknown block kind {kind}")

    if "cross" in p:
        hx = rms_norm(p["norm_x"], x, cfg.norm_eps)
        if mode == "decode":
            ck, cv = cache["cross"]["k"], cache["cross"]["v"]
            new_cache["cross"] = cache["cross"]  # carry through
        else:
            cp = p["cross"]
            ck = jnp.einsum("bsd,dhk->bshk", cross_inputs, cp["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", cross_inputs, cp["wv"])
            if spec.qkv_bias:
                ck, cv = ck + cp["bk"], cv + cp["bv"]
            if mode == "prefill":
                new_cache["cross"] = {"k": ck, "v": cv}
        y, _ = attention(p["cross"], hx, spec, cross_kv=(ck, cv))
        x = x + y

    if "mlp" in p:
        x = x + mlp(p["mlp"], rms_norm(p["norm2"], x, cfg.norm_eps), cfg.act)
    elif "moe" in p:
        y, a = moe_lib.moe_ffn(p["moe"], rms_norm(p["norm2"], x, cfg.norm_eps), cfg,
                               axis_name=axis_name)
        x = x + y
        aux = aux + a

    return x, (new_cache if new_cache else None), aux


def _grow_cache(cache: dict, max_len: int, spec: AttnSpec) -> dict:
    """Extend a prefill-built cache to decode capacity ``max_len``."""
    S_tgt = min(max_len, spec.window) if spec.window else max_len
    S = cache["k"].shape[1]
    if S >= S_tgt:
        return cache
    pad = S_tgt - S
    k = jnp.pad(cache["k"], ((0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(cache["v"], ((0, 0), (0, pad), (0, 0), (0, 0)))
    pos = jnp.pad(cache["pos"], (0, pad), constant_values=-1)
    return {"k": k, "v": v, "pos": pos}
