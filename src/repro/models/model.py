"""Model facade: init / loss / prefill / decode + dry-run input specs."""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeSpec
from .layers import cross_entropy_loss
from .transformer import apply_lm, init_decode_cache, init_lm

__all__ = ["Model"]


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---- params -----------------------------------------------------------

    def init(self, key) -> Any:
        return init_lm(key, self.cfg)

    def param_shapes(self) -> Any:
        """Abstract parameter tree (no allocation) — dry-run / sharding."""
        return jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), self.cfg))

    # ---- forward ----------------------------------------------------------

    def forward(self, params, batch, *, remat: bool = False):
        logits, _, aux = apply_lm(
            params,
            self.cfg,
            tokens=batch["tokens"],
            embeds=batch.get("embeds"),
            mode="train",
            remat=remat,
        )
        return logits, aux

    def loss(self, params, batch, *, remat: bool = False):
        logits, aux = self.forward(params, batch, remat=remat)
        labels = jnp.minimum(batch["labels"], self.cfg.padded_vocab - 1)
        nll = cross_entropy_loss(logits, labels, batch.get("loss_mask"))
        return nll + aux, {"nll": nll, "aux": aux}

    # ---- serving ----------------------------------------------------------

    def prefill(self, params, batch, *, max_len: int):
        if self.cfg.frontend == "vision":
            max_len = max_len + self.cfg.prefix_len  # cache holds the prefix too
        logits, caches, _ = apply_lm(
            params,
            self.cfg,
            tokens=batch["tokens"],
            embeds=batch.get("embeds"),
            mode="prefill",
            max_len=max_len,
        )
        return logits, caches

    def decode_step(self, params, tokens, caches, cur_pos):
        """tokens (B,1) int32; cur_pos scalar int32 (absolute position of the
        new token). Returns (logits (B,1,V), new_caches)."""
        logits, caches, _ = apply_lm(
            params,
            self.cfg,
            tokens=tokens,
            mode="decode",
            caches=caches,
            cur_pos=jnp.asarray(cur_pos, jnp.int32),
        )
        return logits, caches

    def init_cache(self, batch: int, max_len: int):
        return init_decode_cache(self.cfg, batch, max_len)

    def cache_specs(self, batch: int, max_len: int):
        """ShapeDtypeStructs for the decode cache (no allocation)."""
        return jax.eval_shape(partial(init_decode_cache, self.cfg, batch, max_len))

    # ---- dry-run input specs (ShapeDtypeStruct stand-ins) -------------------

    def input_specs(self, shape: ShapeSpec) -> dict:
        """Abstract inputs for a given assigned input shape.

        The modality frontends are STUBS per the assignment: for VLM/audio
        archs the specs contain precomputed patch/frame embeddings of the
        right shape instead of pixels/waveforms.
        """
        cfg = self.cfg
        B, T = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        dt = jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct

        if shape.mode in ("train", "prefill"):
            specs: dict[str, Any] = {}
            if cfg.frontend == "vision":
                t_text = T - cfg.prefix_len
                specs["tokens"] = sds((B, t_text), i32)
                specs["embeds"] = sds((B, cfg.prefix_len, cfg.d_model), dt)
                if shape.mode == "train":
                    specs["labels"] = sds((B, t_text), i32)
            elif cfg.arch_type == "encdec":
                specs["tokens"] = sds((B, T), i32)
                specs["embeds"] = sds((B, cfg.frontend_len, cfg.d_model), dt)
                if shape.mode == "train":
                    specs["labels"] = sds((B, T), i32)
            else:
                specs["tokens"] = sds((B, T), i32)
                if shape.mode == "train":
                    specs["labels"] = sds((B, T), i32)
            return specs

        # decode: one new token against a seq_len-deep cache
        return {
            "tokens": sds((B, 1), i32),
            "caches": self.cache_specs(B, T),
            "cur_pos": sds((), i32),
        }

    # ---- sample concrete batch (smoke tests / examples) ---------------------

    def sample_batch(self, shape: ShapeSpec, seed: int = 0) -> dict:
        rng = np.random.RandomState(seed)
        specs = self.input_specs(shape)

        def make(s):
            if np.issubdtype(s.dtype, np.integer):
                return jnp.asarray(
                    rng.randint(0, max(self.cfg.vocab_size - 1, 2), size=s.shape), s.dtype
                )
            return jnp.asarray(rng.randn(*s.shape).astype(np.float32), s.dtype)

        out = {}
        for k, v in specs.items():
            if k == "caches":
                out[k] = self.init_cache(shape.global_batch, shape.seq_len)
            elif k == "cur_pos":
                out[k] = jnp.asarray(shape.seq_len - 1, jnp.int32)
            else:
                out[k] = jax.tree.map(make, v)
        return out
