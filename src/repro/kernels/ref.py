"""Pure-jnp oracles for every kernel (the tests' ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "chunked_copy_ref",
    "fused_combine_ref",
    "mix_ref",
    "scaled_add_ref",
    "flash_attention_ref",
]


def chunked_copy_ref(x: jax.Array) -> jax.Array:
    return jnp.array(x, copy=True)


def fused_combine_ref(cur, recv, row_mode):
    """Row-mode merge: per row, mode 2 accumulates recv, mode 1 selects it,
    mode 0 passes cur through bit-identically."""
    return jnp.where(row_mode == 2, cur + recv, jnp.where(row_mode == 1, recv, cur))


def mix_ref(w, u, a):
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)
    return ((1.0 - a) * wf + a * uf).astype(w.dtype)


def scaled_add_ref(w, u, a):
    return (w.astype(jnp.float32) - a * u.astype(jnp.float32)).astype(w.dtype)


def flash_attention_ref(
    q, k, v, *, causal: bool = True, window: Optional[int] = None, prefix: int = 0
):
    """Unblocked softmax attention with the same mask semantics."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, T, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("btkgh,bskh->bkgts", qg, k.astype(jnp.float32)) * hd**-0.5
    i = jnp.arange(T)[:, None]
    j = jnp.arange(S)[None, :]
    if causal:
        mask = j <= i
        if prefix:
            mask = mask | (j < prefix)
    else:
        mask = jnp.ones((T, S), bool)
    if window is not None:
        w_ok = j > i - window
        if prefix:
            w_ok = w_ok | ((j < prefix) & (i < prefix))
        mask = mask & w_ok
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", p, v.astype(jnp.float32))
    return out.reshape(B, T, H, hd).astype(q.dtype)
