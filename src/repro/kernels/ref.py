"""Pure-jnp oracles for every kernel (the tests' ground truth)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "chunked_copy_ref",
    "fused_combine_ref",
    "inkernel_shared_ref",
    "mix_ref",
    "scaled_add_ref",
    "flash_attention_ref",
]


def chunked_copy_ref(x: jax.Array) -> jax.Array:
    return jnp.array(x, copy=True)


def fused_combine_ref(cur, recv, row_mode):
    """Row-mode merge: per row, mode 2 accumulates recv, mode 1 selects it,
    mode 0 passes cur through bit-identically."""
    return jnp.where(row_mode == 2, cur + recv, jnp.where(row_mode == 1, recv, cur))


def inkernel_shared_ref(tables, shared):
    """Numpy oracle for the in-kernel schedule replay over the SHARED
    ``(n, num_chunks, chunk)`` buffer (row r = rank r's local buffer).

    Identical control flow to ``core.simulator.simulate_lowered``: per round,
    classes apply sequentially; within a class every source block is
    snapshotted BEFORE any destination writes (a rank may be src of one pair
    and dst of another in the same class); a destination whose window is
    empty (``hi <= lo``) keeps its rows bit-identically. ``tables`` is a
    :class:`repro.core.schedules.KernelTables`.
    """
    out = np.array(shared, copy=True)
    for s in range(tables.num_rounds):
        for c in range(tables.num_classes):
            perm, block = tables.perms[c], tables.blocks[c]
            if block == 0 or not perm:
                continue
            snap = {
                dst: out[src, tables.send_start[c, s, src]:
                         tables.send_start[c, s, src] + block].copy()
                for src, dst in perm
            }
            for _src, dst in perm:
                lo, hi = tables.lo[c, s, dst], tables.hi[c, s, dst]
                if hi <= lo:
                    continue
                r0 = tables.recv_start[c, s, dst]
                if tables.combine[c, s]:
                    out[dst, r0 + lo:r0 + hi] += snap[dst][lo:hi]
                else:
                    out[dst, r0 + lo:r0 + hi] = snap[dst][lo:hi]
    return out


def mix_ref(w, u, a):
    wf = w.astype(jnp.float32)
    uf = u.astype(jnp.float32)
    return ((1.0 - a) * wf + a * uf).astype(w.dtype)


def scaled_add_ref(w, u, a):
    return (w.astype(jnp.float32) - a * u.astype(jnp.float32)).astype(w.dtype)


def flash_attention_ref(
    q, k, v, *, causal: bool = True, window: Optional[int] = None, prefix: int = 0
):
    """Unblocked softmax attention with the same mask semantics."""
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, T, KV, G, hd).astype(jnp.float32)
    s = jnp.einsum("btkgh,bskh->bkgts", qg, k.astype(jnp.float32)) * hd**-0.5
    i = jnp.arange(T)[:, None]
    j = jnp.arange(S)[None, :]
    if causal:
        mask = j <= i
        if prefix:
            mask = mask | (j < prefix)
    else:
        mask = jnp.ones((T, S), bool)
    if window is not None:
        w_ok = j > i - window
        if prefix:
            w_ok = w_ok | ((j < prefix) & (i < prefix))
        mask = mask & w_ok
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", p, v.astype(jnp.float32))
    return out.reshape(B, T, H, hd).astype(q.dtype)
