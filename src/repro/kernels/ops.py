"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernels execute through the
Pallas interpreter for correctness) and False on TPU (real Mosaic lowering).
"""
from __future__ import annotations

from typing import Optional

import jax

from .chunked_copy import chunked_copy as _chunked_copy
from .combine_update import fused_combine as _fused_combine
from .flash_attention import flash_attention as _flash
from .param_update import mix as _mix, scaled_add as _scaled_add
from .quantize import (
    BLOCK_ELEMS,
    QUANT_DTYPES,
    dequantize_blocks as _dequantize_blocks,
    quantize_blocks as _quantize_blocks,
)

__all__ = [
    "on_tpu",
    "resolve_interpret",
    "chunked_copy",
    "fused_combine",
    "mix",
    "scaled_add",
    "flash_attention",
    "quantize_blocks",
    "dequantize_blocks",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Single source of truth for the Pallas ``interpret`` flag.

    ``None`` means "whatever the backend needs": the interpreter off-TPU,
    real Mosaic lowering on TPU. Every kernel call site must resolve through
    here — a CPU-backend trace must never embed a literal ``interpret=False``
    (it would try to Mosaic-lower on a backend that can't).
    """
    return (not on_tpu()) if interpret is None else bool(interpret)


def chunked_copy(x, *, chunk_elems: int = 64 * 1024, interpret: Optional[bool] = None):
    return _chunked_copy(x, chunk_elems=chunk_elems, interpret=resolve_interpret(interpret))


def fused_combine(cur, recv, row_mode, *, interpret: Optional[bool] = None):
    return _fused_combine(cur, recv, row_mode, interpret=resolve_interpret(interpret))


def mix(w, u, a, *, interpret: Optional[bool] = None):
    return _mix(w, u, a, interpret=resolve_interpret(interpret))


def scaled_add(w, u, a, *, interpret: Optional[bool] = None):
    return _scaled_add(w, u, a, interpret=resolve_interpret(interpret))


def quantize_blocks(x, fmt: str, *, interpret: Optional[bool] = None):
    """Quantize (B, C) f32 ``x`` to ``(values, scales)`` under wire format
    ``fmt`` ('int8' | 'fp8'). Ragged column tails are zero-padded to the
    256-element scale block (the padding IS shipped on the wire, and
    :func:`repro.comm.compress.wire_chunk_bytes` counts it); a zero-sized
    input short-circuits to empty outputs without launching a kernel.
    Returns values of shape (B, Cp) and scales (B, Cp // 256) where Cp is C
    rounded up to a multiple of 256.
    """
    import jax.numpy as jnp

    if fmt not in QUANT_DTYPES:
        raise ValueError(f"unknown quantize format {fmt!r}; expected one of "
                         f"{sorted(QUANT_DTYPES)}")
    B, C = x.shape
    blocks = -(-max(C, 1) // BLOCK_ELEMS)
    Cp = blocks * BLOCK_ELEMS
    if B == 0:
        dtype, _ = QUANT_DTYPES[fmt]
        return (jnp.zeros((0, Cp), dtype), jnp.zeros((0, blocks), jnp.float32))
    x = x.astype(jnp.float32)
    if Cp != C:
        x = jnp.pad(x, ((0, 0), (0, Cp - C)))
    return _quantize_blocks(x, fmt, interpret=resolve_interpret(interpret))


def dequantize_blocks(values, scales, *, out_cols: Optional[int] = None,
                      interpret: Optional[bool] = None):
    """Inverse of :func:`quantize_blocks`; ``out_cols`` slices off the
    block padding to recover the original column count."""
    if values.shape[0] == 0:
        import jax.numpy as jnp

        cols = values.shape[1] if out_cols is None else out_cols
        return jnp.zeros((0, cols), jnp.float32)
    out = _dequantize_blocks(values, scales, interpret=resolve_interpret(interpret))
    if out_cols is not None and out_cols != out.shape[1]:
        out = out[:, :out_cols]
    return out


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: Optional[bool] = None,
):
    interpret = resolve_interpret(interpret)
    return _flash(
        q, k, v, causal=causal, window=window, prefix=prefix, bq=bq, bk=bk, interpret=interpret
    )
