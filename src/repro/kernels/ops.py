"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernels execute through the
Pallas interpreter for correctness) and False on TPU (real Mosaic lowering).
"""
from __future__ import annotations

from typing import Optional

import jax

from .chunked_copy import chunked_copy as _chunked_copy
from .combine_update import fused_combine as _fused_combine
from .flash_attention import flash_attention as _flash
from .param_update import mix as _mix, scaled_add as _scaled_add

__all__ = [
    "on_tpu",
    "chunked_copy",
    "fused_combine",
    "mix",
    "scaled_add",
    "flash_attention",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def chunked_copy(x, *, chunk_elems: int = 64 * 1024, interpret: Optional[bool] = None):
    interpret = (not on_tpu()) if interpret is None else interpret
    return _chunked_copy(x, chunk_elems=chunk_elems, interpret=interpret)


def fused_combine(cur, recv, row_mode, *, interpret: Optional[bool] = None):
    interpret = (not on_tpu()) if interpret is None else interpret
    return _fused_combine(cur, recv, row_mode, interpret=interpret)


def mix(w, u, a, *, interpret: Optional[bool] = None):
    interpret = (not on_tpu()) if interpret is None else interpret
    return _mix(w, u, a, interpret=interpret)


def scaled_add(w, u, a, *, interpret: Optional[bool] = None):
    interpret = (not on_tpu()) if interpret is None else interpret
    return _scaled_add(w, u, a, interpret=interpret)


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: Optional[bool] = None,
):
    interpret = (not on_tpu()) if interpret is None else interpret
    return _flash(
        q, k, v, causal=causal, window=window, prefix=prefix, bq=bq, bk=bk, interpret=interpret
    )
