"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernels execute through the
Pallas interpreter for correctness) and False on TPU (real Mosaic lowering).
"""
from __future__ import annotations

from typing import Optional

import jax

from .chunked_copy import chunked_copy as _chunked_copy
from .combine_update import fused_combine as _fused_combine
from .flash_attention import flash_attention as _flash
from .param_update import mix as _mix, scaled_add as _scaled_add

__all__ = [
    "on_tpu",
    "resolve_interpret",
    "chunked_copy",
    "fused_combine",
    "mix",
    "scaled_add",
    "flash_attention",
]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: Optional[bool] = None) -> bool:
    """Single source of truth for the Pallas ``interpret`` flag.

    ``None`` means "whatever the backend needs": the interpreter off-TPU,
    real Mosaic lowering on TPU. Every kernel call site must resolve through
    here — a CPU-backend trace must never embed a literal ``interpret=False``
    (it would try to Mosaic-lower on a backend that can't).
    """
    return (not on_tpu()) if interpret is None else bool(interpret)


def chunked_copy(x, *, chunk_elems: int = 64 * 1024, interpret: Optional[bool] = None):
    return _chunked_copy(x, chunk_elems=chunk_elems, interpret=resolve_interpret(interpret))


def fused_combine(cur, recv, row_mode, *, interpret: Optional[bool] = None):
    return _fused_combine(cur, recv, row_mode, interpret=resolve_interpret(interpret))


def mix(w, u, a, *, interpret: Optional[bool] = None):
    return _mix(w, u, a, interpret=resolve_interpret(interpret))


def scaled_add(w, u, a, *, interpret: Optional[bool] = None):
    return _scaled_add(w, u, a, interpret=resolve_interpret(interpret))


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: Optional[bool] = None,
):
    interpret = resolve_interpret(interpret)
    return _flash(
        q, k, v, causal=causal, window=window, prefix=prefix, bq=bq, bk=bk, interpret=interpret
    )
