"""Flash attention kernel (Pallas, TPU target).

The compute hot-spot of every assigned transformer. Blocked online-softmax
over (q-block, kv-block) grid tiles with VMEM scratch accumulators; causal /
sliding-window / prefix-LM masks are applied per tile, and tiles that are
fully masked are SKIPPED via ``pl.when`` (the block-level skipping our
XLA-portable fallback, models.layers._chunked_sdpa, cannot do — see
EXPERIMENTS.md §Perf).

Grid: (batch, q_heads, T/bq, S/bk); the innermost (kv) dim iterates
sequentially on TPU, so scratch (acc, m, l) carries across kv blocks.
GQA: kv-head index = q-head // (H // KV) via the k/v BlockSpec index maps.

Validated against ref.py with interpret=True (CPU); compiles to the real
Mosaic pipeline on TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific scratch spaces; interpret mode accepts them too
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, scale, causal, window, prefix, bq, bk):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q0 = qi * bq
    k0 = ki * bk
    relevant = True
    if causal:
        relevant = k0 <= q0 + bq - 1
    if window is not None:
        in_win = k0 + bk - 1 > q0 - window
        if prefix:
            in_win = in_win | (k0 < prefix)
        relevant = relevant & in_win

    @pl.when(relevant)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)
        k = k_ref[0, :, 0, :].astype(jnp.float32)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                               # (bq, bk)
        i = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        j = k0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        if causal:
            mask = j <= i
            if prefix:
                mask = mask | (j < prefix)
        else:
            mask = jnp.ones((bq, bk), bool)
        if window is not None:
            w_ok = j > i - window
            if prefix:
                w_ok = w_ok | ((j < prefix) & (i < prefix))
            mask = mask & w_ok
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "prefix", "bq", "bk", "interpret"),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    prefix: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, T, H, hd); k, v: (B, S, KV, hd) with H % KV == 0.

    Returns (B, T, H, hd). Set ``interpret=False`` on real TPUs.
    """
    B, T, H, hd = q.shape
    S, KV = k.shape[1], k.shape[2]
    assert H % KV == 0
    G = H // KV
    bq = min(bq, T)
    bk = min(bk, S)
    assert T % bq == 0 and S % bk == 0, (T, bq, S, bk)
    grid = (B, H, T // bq, S // bk)

    kernel = functools.partial(
        _kernel,
        scale=hd**-0.5,
        causal=causal,
        window=window,
        prefix=prefix,
        bq=bq,
        bk=bk,
    )
    scratch = [
        _VMEM((bq, hd), jnp.float32),
        _VMEM((bq,), jnp.float32),
        _VMEM((bq,), jnp.float32),
    ]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
            pl.BlockSpec((1, bk, 1, hd), lambda b, h, qi, ki: (b, ki, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, hd), lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, H, hd), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
