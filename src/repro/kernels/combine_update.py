"""Fused combine-update kernel (Pallas) for the compiled schedule executor.

One replay round of a lane class must merge the received block into the
buffer window it lands on: ``out = cur + recv`` on the rows the schedule
actually addressed this round when the round combines, ``out = recv`` when
it overwrites, ``out = cur`` everywhere else. The jnp spelling of that is a
``dynamic_slice`` -> ``jnp.where`` mask -> ``dynamic_update_slice`` triple
that materializes the zero-filled mask operand and a second merged block in
HBM every round. This kernel does the merge in ONE VMEM pass — read the
current rows and the received rows, add-or-select-or-keep under the per-row
mode, write back — with the current block aliased to the output
(``input_output_aliases``) so no extra block is materialized. Same
grid-over-chunks contract as :func:`repro.kernels.chunked_copy`: the Mosaic
pipeliner double-buffers row (k+1)'s HBM read under row k's write.

The per-row mode (0 = keep, 1 = overwrite, 2 = accumulate) is data, not
kernel structure, so one kernel serves combining AND overwriting rounds —
which is what lets a lane class carry a per-round combine flag (e.g.
ring_allreduce's reduce-scatter and allgather phases on one class).

Validated with ``interpret=True`` off-TPU (the executor parity sweeps);
on TPU the same code emits the real DMA pipeline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["fused_combine", "fused_combine_update"]

# column tile: VREG-lane aligned, small enough that three (1, _COL_BLOCK)
# buffers triple-buffer comfortably in VMEM at any dtype
_COL_BLOCK = 2048

# row modes
KEEP, OVERWRITE, ACCUMULATE = 0, 1, 2


def _merge_kernel(cur_ref, recv_ref, m_ref, out_ref):
    m = m_ref[0, 0]
    cur = cur_ref[...]
    rec = recv_ref[...]
    # where(mode, ..., cur) — NOT cur + where(mode, rec, 0): kept rows must
    # round-trip bit-identically (a -0.0 would flip under the add-zero
    # form), which is what makes compiled == unrolled exact
    out_ref[...] = jnp.where(m == ACCUMULATE, cur + rec,
                             jnp.where(m == OVERWRITE, rec, cur))


def fused_combine(cur: jax.Array, recv: jax.Array, row_mode: jax.Array, *,
                  interpret: bool | None = None) -> jax.Array:
    """Merge ``recv`` into ``cur`` row-wise under ``row_mode``.

    ``cur``/``recv``: (block, chunk_elems); ``row_mode``: (block, 1) int32
    of KEEP (0) / OVERWRITE (1) / ACCUMULATE (2). Must be called inside a
    trace (jit/shard_map) like the executors that own it.
    """
    # function-level import: ops imports this module at load time, so the
    # shared interpret resolver has to be pulled in lazily here
    from .ops import resolve_interpret

    interpret = resolve_interpret(interpret)
    B, C = cur.shape
    colb = min(C, _COL_BLOCK)
    return pl.pallas_call(
        _merge_kernel,
        grid=(B, pl.cdiv(C, colb)),
        in_specs=[
            pl.BlockSpec((1, colb), lambda i, j: (i, j)),
            pl.BlockSpec((1, colb), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, colb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, C), cur.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(cur, recv, row_mode)


def fused_combine_update(buf: jax.Array, recv: jax.Array, start, lo, hi, *,
                         combine, interpret: bool | None = None) -> jax.Array:
    """Apply one lane-class round to ``buf`` (num_chunks, chunk_elems):
    rows ``[start + lo, start + hi)`` merge the matching rows of ``recv``
    (add when ``combine`` is truthy, else overwrite); every other row of
    the ``[start, start + block)`` window writes back unchanged. ``start``,
    ``lo``, ``hi``, and ``combine`` (bool or 0/1 int) may be traced scalars
    from the lowered round tables.
    """
    B, _C = recv.shape
    cur = lax.dynamic_slice(buf, (start, 0), recv.shape)
    rows = jnp.arange(B, dtype=jnp.int32)
    valid = ((rows >= lo) & (rows < hi)).astype(jnp.int32)
    mode = (valid * (1 + jnp.asarray(combine, jnp.int32))).reshape(B, 1)
    merged = fused_combine(cur, recv, mode, interpret=interpret)
    return lax.dynamic_update_slice(buf, merged, (start, 0))
