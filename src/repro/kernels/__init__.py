"""Pallas TPU kernels for the perf-critical hot-spots:

  chunked_copy     — pipelined HBM->VMEM->HBM staging copy (the paper's
                     CUDA-kernel-copy analogue, used by the staged bcast path)
  combine_update   — fused add-or-select block merge for the compiled
                     schedule executor (one VMEM pass per replay round)
  param_update     — fused model-average / scaled-add epilogue for bcast sync
  flash_attention  — blocked online-softmax attention with block skipping

Each kernel ships ops.py (jit'd wrapper, interpret on CPU / Mosaic on TPU)
and ref.py (pure-jnp oracle used by the test sweeps).
"""
from . import ops, ref
from .combine_update import fused_combine, fused_combine_update
from .ops import chunked_copy, flash_attention, mix, scaled_add

__all__ = [
    "ops",
    "ref",
    "chunked_copy",
    "fused_combine",
    "fused_combine_update",
    "flash_attention",
    "mix",
    "scaled_add",
]
