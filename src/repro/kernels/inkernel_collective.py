"""Persistent in-kernel collective executor: one Pallas launch per schedule.

The compiled executor (``comm.executors.execute_compiled``) already collapsed
HLO size to O(lane classes), but each replay round still pays a
``lax.ppermute`` -> combine-kernel launch boundary and two HBM round-trips.
This module deletes that overhead: ONE Pallas kernel launch replays the whole
lowered schedule — the kernel itself moves each round's block (async remote
copy on TPU, a shared-buffer write in the interpret-mode emulation) and merges
it into the destination window in the same VMEM pass, using exactly the
where-chain of ``repro.kernels.combine_update`` so the result stays
bit-identical to the unrolled oracle.

The static metadata the kernel needs is the PR 5 lowering, stacked into the
kernel-resident layout of :class:`repro.core.schedules.KernelTables`:
``send_start``/``recv_start``/``lo``/``hi`` as dense int32
``(num_classes, num_rounds, n)`` operands (scalar-prefetch on TPU) and the
per-class permutations/block heights as kernel *structure* (static python
loops). ``grid=(num_rounds,)`` walks rounds; the buffer block is revisited
every step (constant index map + ``input_output_aliases``), which is what
keeps the whole replay inside one launch.

Two paths, one control flow:

* **Interpret / CPU CI** — the mesh is emulated through a shared
  ``(n, num_chunks, chunk)`` buffer (``lax.all_gather`` of the per-rank
  buffers); the kernel replays every rank's sends and merges directly on the
  shared buffer, then the caller slices its own row. This is the executable
  contract: parity suites compare it bit-for-bit against
  ``simulate_lowered`` and the unrolled executor.
* **TPU** — the same round/class loop issues
  ``pltpu.make_async_remote_copy`` RDMA per active pair, with a neighbor
  barrier per class so a sender never overwrites a landing slot its partner
  has not consumed. Exercised only on real hardware (the repo's CI is CPU);
  the interpret path above pins the semantics it must reproduce.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import pallas as pl

from ..core.schedules import KernelTables, LoweredSchedule, pack_tables
from .ops import on_tpu, resolve_interpret

__all__ = ["inkernel_replay", "inkernel_replay_shared"]


@functools.lru_cache(maxsize=256)
def _packed_planes(tables: KernelTables) -> np.ndarray:
    """Fold the round tables into the gather/merge planes the emulation
    kernel consumes: ONE int32 operand of shape
    ``(num_rounds, num_classes, 2, n, num_chunks)`` where

    * plane 0 (``idx``) — for receiver ``dst`` and row ``r`` of its buffer,
      the FLAT index (into the shared buffer viewed as ``(n*K, cols)``) of
      the source row that lands there this round:
      ``src*K + send_start[src] + clip(r - recv_start[dst], 0, block-1)``
      (identity ``dst*K + r`` for ranks that never receive in the class);
    * plane 1 (``mode``) — the KEEP/OVERWRITE/ACCUMULATE selector of
      ``combine_update._merge_kernel``: ``(1 + combine)`` inside the row
      window ``[recv_start+lo, recv_start+hi)``, else 0.

    ALL index arithmetic happens here, on the host, at pack time — the
    tables are static schedule metadata, so the kernel body needs exactly
    one gather and one where-chain per lane class. That is what keeps the
    interpret-mode program both tiny and flat: every dynamic-slice the
    interpreter lowers costs a fixed clamp chain of HLO, so the fewer
    in-kernel index computations, the smaller the emulated program."""
    C, T, n = tables.send_start.shape
    K = tables.num_chunks
    src_of = np.tile(np.arange(n, dtype=np.int32), (C, 1))
    active = np.zeros((C, n), np.int32)
    for c, perm in enumerate(tables.perms):
        for src, dst in perm:
            src_of[c, dst] = src
            active[c, dst] = 1
    rows = np.arange(K, dtype=np.int32)[None, :]                 # (1, K)
    planes = np.zeros((T, C, 2, n, K), np.int32)
    for c in range(C):
        block = max(tables.blocks[c], 1)
        for s in range(T):
            send = tables.send_start[c, s]
            rel = rows - tables.recv_start[c, s][:, None]        # (n, K)
            idx = (src_of[c] * K + send[src_of[c]])[:, None] + np.clip(
                rel, 0, block - 1
            )
            ident = np.arange(n, dtype=np.int32)[:, None] * K + rows
            act = active[c][:, None]
            planes[s, c, 0] = np.where(act == 1, idx, ident)
            inwin = (rel >= tables.lo[c, s][:, None]) & (
                rel < tables.hi[c, s][:, None]
            )
            planes[s, c, 1] = inwin * act * (1 + tables.combine[c, s])
    return np.ascontiguousarray(planes)


def _shared_kernel(tables: KernelTables, cols: int,
                   tab_ref, shared_ref, out_ref):
    """Replay ALL rounds over the shared (n, K, cols) buffer in one kernel
    body: a ``lax.fori_loop`` over rounds whose carry is the buffer value,
    so the whole schedule is one launch and the program size is independent
    of the round count.

    Classes apply sequentially inside a round (matching
    ``simulate_lowered``); within a class every source row is read BEFORE
    any destination write (the class snapshot is the carry value) — a rank
    can be src of one pair and dst of another in the same class. Per class
    the body is one precomputed gather (``_packed_planes`` plane 0) pulling
    every receiver's incoming rows out of the snapshot, then the
    KEEP/OVERWRITE/ACCUMULATE where-chain of ``combine_update._merge_kernel``
    under the precomputed mode plane — kept rows round-trip bit-identically.
    """
    n, K = tables.n, tables.num_chunks
    tab = tab_ref[...]

    def round_body(s, out):
        planes = tab[s]                              # (C, 2, n, K)
        for c, (perm, block) in enumerate(zip(tables.perms, tables.blocks)):
            if block == 0 or not perm:
                continue
            flat = out.reshape(n * K, cols)
            rec = flat[planes[c, 0]]                 # (n, K, cols) gather
            m = planes[c, 1][:, :, None]
            out = jnp.where(m == 2, out + rec,
                            jnp.where(m == 1, rec, out))
        return out

    out_ref[...] = lax.fori_loop(0, tables.num_rounds, round_body,
                                 shared_ref[...])


def inkernel_replay_shared(lowered: LoweredSchedule, shared: jax.Array, *,
                           interpret: bool | None = None) -> jax.Array:
    """Replay every round of ``lowered`` on the shared ``(n, K, cols)``
    buffer in ONE ``pallas_call`` (row r = rank r's local buffer)."""
    interpret = resolve_interpret(interpret)
    tables = pack_tables(lowered)
    T = tables.num_rounds
    if T == 0 or tables.num_classes == 0:
        return shared
    n, K, cols = shared.shape
    # gridless whole-array launch: the round loop lives INSIDE the kernel
    # (carry-valued fori_loop), so there is no per-round grid machinery at
    # all — the packed table plane rides along as the one extra operand
    return pl.pallas_call(
        functools.partial(_shared_kernel, tables, cols),
        out_shape=jax.ShapeDtypeStruct(shared.shape, shared.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(jnp.asarray(_packed_planes(tables)), shared)


# ---------------------------------------------------------------------------
# TPU RDMA path — kernel-initiated transfers (exercised on hardware only)
# ---------------------------------------------------------------------------


def _neighbor_tables(tables: KernelTables):
    """Per-class partner maps: ``dst_of[c, r]`` is where rank r sends this
    class (r itself when inactive), ``src_of[c, r]`` who sends to it."""
    C, n = tables.num_classes, tables.n
    dst_of = np.tile(np.arange(n, dtype=np.int32), (C, 1))
    src_of = dst_of.copy()
    for c, perm in enumerate(tables.perms):
        for src, dst in perm:
            dst_of[c, src] = dst
            src_of[c, dst] = src
    return dst_of, src_of


def _rdma_kernel(tables: KernelTables, axis_name: str, cols: int, *refs):
    from jax.experimental.pallas import tpu as pltpu

    C = tables.num_classes
    (send_t, recv_t, lo_t, hi_t, comb_t, dst_of_t, src_of_t,
     buf_ref, out_ref) = refs[:9]
    scratch = refs[9:]  # per class: send_scr, recv_scr, send_sem, recv_sem

    s = pl.program_id(0)
    me = lax.axis_index(axis_name)

    @pl.when(s == 0)
    def _init():
        out_ref[...] = buf_ref[...]

    barrier = pltpu.get_barrier_semaphore()
    for c in range(C):
        block = tables.blocks[c]
        send_scr, recv_scr, send_sem, recv_sem = scratch[4 * c:4 * c + 4]
        dst = dst_of_t[c, me]
        src = src_of_t[c, me]
        is_src = dst != me
        is_dst = src != me

        # neighbor barrier: both partners must have finished the previous
        # round's merge before anyone overwrites a landing slot
        @pl.when(is_src)
        def _sig_dst():
            pltpu.semaphore_signal(
                barrier, device_id=dst,
                device_id_type=pltpu.DeviceIdType.LOGICAL,
            )

        @pl.when(is_dst)
        def _sig_src():
            pltpu.semaphore_signal(
                barrier, device_id=src,
                device_id_type=pltpu.DeviceIdType.LOGICAL,
            )

        pltpu.semaphore_wait(
            barrier, is_src.astype(jnp.int32) + is_dst.astype(jnp.int32)
        )

        @pl.when(is_src)
        def _send():
            # stage the outgoing block, then kernel-initiated RDMA to the
            # partner's landing scratch — no host round-trip, no relaunch
            send_scr[...] = out_ref[pl.ds(send_t[c, s, me], block), :]
            rdma = pltpu.make_async_remote_copy(
                src_ref=send_scr, dst_ref=recv_scr,
                send_sem=send_sem, recv_sem=recv_sem,
                device_id=dst, device_id_type=pltpu.DeviceIdType.LOGICAL,
            )
            rdma.start()
            rdma.wait_send()

        @pl.when(is_dst)
        def _recv():
            pltpu.semaphore_wait(recv_sem, 1)
            r0 = recv_t[c, s, me]
            cur = out_ref[pl.ds(r0, block), :]
            rec = recv_scr[...]
            rows = lax.broadcasted_iota(jnp.int32, (block, cols), 0)
            mode = ((rows >= lo_t[c, s, me]) & (rows < hi_t[c, s, me])
                    ).astype(jnp.int32) * (1 + comb_t[c, s])
            out_ref[pl.ds(r0, block), :] = jnp.where(
                mode == 2, cur + rec, jnp.where(mode == 1, rec, cur)
            )


def _rdma_replay(tables: KernelTables, buf: jax.Array,
                 axis_name: str) -> jax.Array:
    from jax.experimental.pallas import tpu as pltpu

    T = tables.num_rounds
    _K, cols = buf.shape
    dst_of, src_of = _neighbor_tables(tables)
    scratch = []
    for block in tables.blocks:
        scratch += [
            pltpu.VMEM((block, cols), buf.dtype),   # send staging
            pltpu.VMEM((block, cols), buf.dtype),   # landing slot
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ]
    full = pl.BlockSpec(buf.shape, lambda s: (0,) * buf.ndim)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=7,
        grid=(T,),
        in_specs=[full],
        out_specs=full,
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        functools.partial(_rdma_kernel, tables, axis_name, cols),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(buf.shape, buf.dtype),
        input_output_aliases={7: 0},
        compiler_params=pltpu.CompilerParams(
            has_side_effects=True, collective_id=0
        ),
    )(
        jnp.asarray(tables.send_start), jnp.asarray(tables.recv_start),
        jnp.asarray(tables.lo), jnp.asarray(tables.hi),
        jnp.asarray(tables.combine), jnp.asarray(dst_of), jnp.asarray(src_of),
        buf,
    )


def inkernel_replay(lowered: LoweredSchedule, buf: jax.Array, axis_name: str,
                    *, interpret: bool | None = None) -> jax.Array:
    """Replay a lowered schedule on this rank's ``(K, cols)`` buffer with a
    single kernel launch. Must be called inside ``shard_map`` over
    ``axis_name``, like the other executors."""
    interpret = resolve_interpret(interpret)
    tables = pack_tables(lowered)
    if tables.num_rounds == 0 or tables.num_classes == 0:
        return buf
    if not interpret and on_tpu():
        return _rdma_replay(tables, buf, axis_name)
    shared = lax.all_gather(buf, axis_name, axis=0)
    out = inkernel_replay_shared(lowered, shared, interpret=interpret)
    return lax.dynamic_index_in_dim(
        out, lax.axis_index(axis_name), 0, keepdims=False
    )
