"""Fused parameter-update kernels (Pallas, TPU target).

The bcast-sync trainer's epilogue applies the synchronized update to every
parameter bucket; fusing the read-modify-write keeps each element's traffic
at one HBM read + one write:

  * ``mix``        — model averaging  out = (1-a)*w + a*u   (CNTK-style)
  * ``scaled_add`` — gradient step    out = w - a*u

Both tile flat buckets through VMEM on a 1-D grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["mix", "scaled_add"]

_TILE = 64 * 1024


def _mix_kernel(w_ref, u_ref, a_ref, o_ref):
    a = a_ref[0]
    w = w_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = ((1.0 - a) * w + a * u).astype(o_ref.dtype)


def _scaled_add_kernel(w_ref, u_ref, a_ref, o_ref):
    a = a_ref[0]
    w = w_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)
    o_ref[...] = (w - a * u).astype(o_ref.dtype)


def _run(kernel, w, u, a, tile: int, interpret: bool):
    assert w.shape == u.shape and w.ndim == 1
    n = w.size
    tile = max(128, min(tile, max(n, 128)))
    pad = (-n) % tile
    if pad:
        w = jnp.concatenate([w, jnp.zeros((pad,), w.dtype)])
        u = jnp.concatenate([u, jnp.zeros((pad,), u.dtype)])
    num = w.size // tile
    w2, u2 = w.reshape(num, tile), u.reshape(num, tile)
    a_arr = jnp.asarray([a], jnp.float32)
    out = pl.pallas_call(
        kernel,
        grid=(num,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pl.ANY),
        ],
        out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num, tile), w.dtype),
        interpret=interpret,
    )(w2, u2, a_arr)
    out = out.reshape(-1)
    return out[:n] if pad else out


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def mix(w: jax.Array, u: jax.Array, a, *, tile: int = _TILE, interpret: bool = True) -> jax.Array:
    """Model averaging: ``(1-a)*w + a*u`` over flat buffers."""
    return _run(_mix_kernel, w, u, a, tile, interpret)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def scaled_add(w: jax.Array, u: jax.Array, a, *, tile: int = _TILE, interpret: bool = True) -> jax.Array:
    """SGD-style step: ``w - a*u`` over flat buffers."""
    return _run(_scaled_add_kernel, w, u, a, tile, interpret)
