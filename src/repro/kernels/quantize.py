"""Per-block quantize/dequantize kernels (Pallas) for compressed wire
formats.

A compressed collective hop ships each chunk as a low-precision payload
(int8 or float8_e4m3fn) plus one f32 scale per 256-element block instead of
the full-precision values: 4x fewer payload bytes at a ~1.6% scale
overhead. The quantize kernel computes a symmetric abs-max scale per block
(``scale = max(|x|) / qmax``), divides, clips to the representable range,
and casts; the dequantize kernel multiplies back. Both run one
(1, _BLOCK_ELEMS) tile per grid step — the same grid-over-rows contract as
:func:`repro.kernels.fused_combine`, so the Mosaic pipeliner double-buffers
block (k+1)'s HBM read under block k's write.

The clip BEFORE the cast is load-bearing for fp8: ``float8_e4m3fn`` has no
inf, so an out-of-range cast produces NaN, not saturation. With the abs-max
scale the quotient is already in range; the clip pins the boundary case
(``|x| == amax`` maps exactly to ``qmax``) against rounding above qmax.

Zero blocks get ``scale = qmax_eps`` (a tiny positive floor) so dequantize
never divides-by-zero territory — a zero block round-trips to exact zeros
because the quantized payload is zero regardless of the scale.

Validated with ``interpret=True`` off-TPU (roundtrip property tests); on
TPU the same code emits the real tiled pipeline. Callers go through
:func:`repro.kernels.ops.quantize_blocks` / ``dequantize_blocks``, which
pad ragged tails to the block size and resolve interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "BLOCK_ELEMS",
    "QUANT_DTYPES",
    "quantize_blocks",
    "dequantize_blocks",
]

# elements per scale block; also the column tile (f32 min-tile friendly,
# and small enough that the int8/fp8 payload tile stays VREG-aligned)
BLOCK_ELEMS = 256

# wire dtype -> clipping range qmax (symmetric): int8 uses the symmetric
# [-127, 127] grid; float8_e4m3fn saturates at +-448 (no inf -> NaN past
# it, hence the pre-cast clip)
QUANT_DTYPES = {
    "int8": (jnp.int8, 127.0),
    "fp8": (jnp.float8_e4m3fn, 448.0),
}

# scale floor for all-zero blocks: keeps scale strictly positive without
# perturbing the roundtrip (payload is 0 -> dequant 0 * floor == 0)
_SCALE_FLOOR = 1e-30


def _quantize_kernel(x_ref, v_ref, s_ref, *, qmax, is_int):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, _SCALE_FLOOR) / qmax
    q = jnp.clip(x / scale, -qmax, qmax)
    if is_int:
        q = jnp.round(q)
    v_ref[...] = q.astype(v_ref.dtype)
    s_ref[...] = jnp.full_like(s_ref, scale)


def _dequantize_kernel(v_ref, s_ref, x_ref):
    x_ref[...] = v_ref[...].astype(jnp.float32) * s_ref[0, 0]


def quantize_blocks(x: jax.Array, fmt: str, *, interpret: bool) -> tuple[jax.Array, jax.Array]:
    """Quantize ``x`` (B, C) f32 with C a multiple of :data:`BLOCK_ELEMS`
    into ``(values (B, C) wire-dtype, scales (B, C // BLOCK_ELEMS) f32)``.
    Callers own padding; see :func:`repro.kernels.ops.quantize_blocks`.
    """
    dtype, qmax = QUANT_DTYPES[fmt]
    B, C = x.shape
    nblocks = C // BLOCK_ELEMS

    def kernel(x_ref, v_ref, s_ref):
        _quantize_kernel(x_ref, v_ref, s_ref, qmax=qmax, is_int=fmt == "int8")

    return pl.pallas_call(
        kernel,
        grid=(B, nblocks),
        in_specs=[pl.BlockSpec((1, BLOCK_ELEMS), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((1, BLOCK_ELEMS), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, C), dtype),
            jax.ShapeDtypeStruct((B, nblocks), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def dequantize_blocks(values: jax.Array, scales: jax.Array, *,
                      interpret: bool) -> jax.Array:
    """Inverse of :func:`quantize_blocks`: (B, C) wire-dtype + per-block f32
    scales back to (B, C) f32."""
    B, C = values.shape
    nblocks = C // BLOCK_ELEMS
    assert scales.shape == (B, nblocks), (values.shape, scales.shape)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=(B, nblocks),
        in_specs=[
            pl.BlockSpec((1, BLOCK_ELEMS), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_ELEMS), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(values, scales)
