"""Pipelined chunked copy kernel (Pallas, TPU target).

The paper's GPU implementation replaces ``cudaMemcpy`` with CUDA-kernel
copies so chunk k+1's HBM read overlaps chunk k's write (the pipelined CUDA
IPC path, Sec. IV-C). The TPU analogue: a grid-over-chunks ``pallas_call``
whose BlockSpec tiling makes the Mosaic pipeliner double-buffer
HBM -> VMEM -> HBM chunk traffic. This is the staging primitive the
host-staged broadcast path uses to move bucket chunks.

The ragged tail is handled by the grid's masked final block (Pallas pads
out-of-bounds reads and masks out-of-bounds writes), NOT by materializing a
zero pad with ``jnp.concatenate`` — that pad was a full extra HBM copy of
the buffer before the pipeline even started.

``interpret`` defaults to the backend: the Pallas interpreter off-TPU
(validated by the shape/dtype sweeps in tests), the real Mosaic DMA
pipeline on TPU.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

__all__ = ["chunked_copy"]

# 8 * 128 lanes * 4 sublanes: a full VREG-aligned tile row count
_LANE = 128


def _copy_kernel(src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk_elems", "interpret"))
def chunked_copy(x: jax.Array, *, chunk_elems: int = 64 * 1024, interpret: bool | None = None) -> jax.Array:
    """Copy a 1-D buffer through VMEM in ``chunk_elems``-sized chunks.

    The grid walks chunks and the pipeliner overlaps the k-th write with the
    (k+1)-th read; a non-divisible tail rides in the final block under the
    grid's implicit bounds mask (no pad copy is ever materialized).
    """
    assert x.ndim == 1, "chunked_copy operates on flat comm buffers"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n = x.size
    chunk_elems = max(_LANE, min(chunk_elems, max(n, _LANE)))
    num_chunks = pl.cdiv(n, chunk_elems)

    return pl.pallas_call(
        _copy_kernel,
        grid=(num_chunks,),
        in_specs=[pl.BlockSpec((chunk_elems,), lambda i: (i,))],
        out_specs=pl.BlockSpec((chunk_elems,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=interpret,
    )(x)
