"""Pipelined chunked copy kernel (Pallas, TPU target).

The paper's GPU implementation replaces ``cudaMemcpy`` with CUDA-kernel
copies so chunk k+1's HBM read overlaps chunk k's write (the pipelined CUDA
IPC path, Sec. IV-C). The TPU analogue: a grid-over-chunks ``pallas_call``
whose BlockSpec tiling makes the Mosaic pipeliner double-buffer
HBM -> VMEM -> HBM chunk traffic. This is the staging primitive the
host-staged broadcast path uses to move bucket chunks.

Validated with ``interpret=True`` on CPU (tests sweep shapes/dtypes against
ref.py); on TPU the same code emits the real DMA pipeline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["chunked_copy"]

# 8 * 128 lanes * 4 sublanes: a full VREG-aligned tile row count
_LANE = 128


def _copy_kernel(src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk_elems", "interpret"))
def chunked_copy(x: jax.Array, *, chunk_elems: int = 64 * 1024, interpret: bool = True) -> jax.Array:
    """Copy a 1-D buffer through VMEM in ``chunk_elems``-sized chunks.

    ``x`` is padded (virtually) to a whole number of chunks; the grid walks
    chunks and the pipeliner overlaps the k-th write with the (k+1)-th read.
    """
    assert x.ndim == 1, "chunked_copy operates on flat comm buffers"
    n = x.size
    chunk_elems = max(_LANE, min(chunk_elems, max(n, _LANE)))
    pad = (-n) % chunk_elems
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    num_chunks = x.size // chunk_elems
    x2 = x.reshape(num_chunks, chunk_elems)

    out = pl.pallas_call(
        _copy_kernel,
        grid=(num_chunks,),
        in_specs=[pl.BlockSpec((1, chunk_elems), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, chunk_elems), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_chunks, chunk_elems), x.dtype),
        interpret=interpret,
    )(x2)
    out = out.reshape(-1)
    return out[:n] if pad else out
