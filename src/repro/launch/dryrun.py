import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import (jax locks the device count on first
# init). Only the dry-run gets 512 placeholder devices; tests/benches see 1.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh, print memory/cost analysis, and
write the parsed roofline report JSON.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh pod|multipod|both]
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.roofline import analyze_compiled
from repro.configs import ARCHS, INPUT_SHAPES, get_config
from repro.configs.base import RunConfig
from repro.dist.hints import activation_hints
from repro.dist.sharding import batch_specs, cache_specs, param_specs
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.optim.optimizers import get_optimizer
from repro.optim.schedules import warmup_cosine
from repro.train.train_step import make_train_step

# Archs allowed to run long_500k (sub-quadratic stacks; see DESIGN.md Sec. 6)
LONG_OK = {"xlstm-350m", "hymba-1.5b", "gemma3-27b", "mixtral-8x7b"}

# Production overrides applied at lowering time (recorded in EXPERIMENTS.md):
#   qwen1.5-32b decode: MHA KV cache (40 heads x 64 layers) needs f8 to fit
#   a single v5e pod at 32k x 128.
DECODE_OVERRIDES = {
    "qwen1.5-32b": {"kv_cache_dtype": "float8_e5m2"},
}

# train_4k microbatching (grad accumulation) per arch size class, so
# activations fit HBM with remat (see DESIGN.md Sec. 7).
def microbatches(cfg) -> int:
    big = cfg.d_model >= 4096 or cfg.num_layers >= 48
    return 8 if big else 4


def applicable(arch: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch in LONG_OK
    return True


def _sds(tree, specs, mesh):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=NamedSharding(mesh, s)),
        tree,
        specs,
    )


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool, verbose: bool = True, seq_shard: bool = False, microbatch_override: int | None = None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.devices.size
    shape = INPUT_SHAPES[shape_name]
    cfg = get_config(arch)
    if shape.mode == "decode" and arch in DECODE_OVERRIDES:
        cfg = dataclasses.replace(cfg, **DECODE_OVERRIDES[arch])
    model = Model(cfg)

    params_shapes = model.param_shapes()
    # FSDP for training; TP-only for serving (no per-step weight all-gather).
    # Attention fallback for non-divisible heads: replicate for big-T steps,
    # head_dim for single-token decode (see dist.sharding).
    pspecs = param_specs(
        params_shapes,
        mesh,
        fsdp=(shape.mode == "train"),
        attn_fallback="head_dim" if shape.mode == "decode" else "replicate",
    )
    params_sds = _sds(params_shapes, pspecs, mesh)

    with mesh, activation_hints(mesh, dp=("pod", "data"), tp="model", seq_shard=seq_shard):
        if shape.mode == "train":
            run = RunConfig(num_microbatches=microbatch_override or microbatches(cfg), remat=True)
            opt = get_optimizer("adamw")
            gspecs = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
            step = make_train_step(model, run, opt, warmup_cosine(3e-4, 100, 1000), grad_specs=gspecs)
            opt_shapes = jax.eval_shape(opt.init, params_shapes)
            ospecs = {"m": pspecs, "v": pspecs, "step": P()}
            opt_sds = _sds(opt_shapes, ospecs, mesh)
            batch_shapes = model.input_specs(shape)
            bspecs = batch_specs(batch_shapes, mesh)
            batch_sds = _sds(batch_shapes, bspecs, mesh)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(params_sds, opt_sds, batch_sds)
        elif shape.mode == "prefill":
            batch_shapes = model.input_specs(shape)
            bspecs = batch_specs(batch_shapes, mesh)
            batch_sds = _sds(batch_shapes, bspecs, mesh)
            fn = partial(model.prefill, max_len=shape.seq_len)
            # output shardings matter: without them GSPMD replicates the
            # returned KV caches (measured: 82 GiB/device on qwen1.5-32b)
            out_shapes = jax.eval_shape(fn, params_shapes, batch_shapes)
            logits_spec = P(tuple(a for a in mesh.axis_names if a != "model"), None, "model")
            ospecs = (
                logits_spec if out_shapes[0].shape[2] % 16 == 0 else P(logits_spec[0], None, None),
                cache_specs(out_shapes[1], mesh, cfg),
            )
            out_shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                                         is_leaf=lambda x: isinstance(x, P))
            lowered = jax.jit(fn, out_shardings=out_shardings).lower(params_sds, batch_sds)
        else:  # decode — serve_step: ONE token against a seq_len KV cache
            specs = model.input_specs(shape)
            cspecs = cache_specs(specs["caches"], mesh, cfg)
            caches_sds = _sds(specs["caches"], cspecs, mesh)
            tok_sds = jax.ShapeDtypeStruct(
                specs["tokens"].shape,
                specs["tokens"].dtype,
                sharding=NamedSharding(mesh, batch_specs(specs["tokens"], mesh)),
            )
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
            # NOTE: decode outputs deliberately have NO pinned shardings —
            # pinning the output cache to the input specs forced GSPMD into
            # resharding copies (measured: minitron decode collective term
            # 0.85 ms -> 1289 ms). Donation still aliases the cache because
            # propagation keeps the natural (= input) layout.
            lowered = jax.jit(model.decode_step, donate_argnums=(2,)).lower(
                params_sds, tok_sds, caches_sds, pos_sds
            )

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    mem = compiled.memory_analysis()
    report = analyze_compiled(
        compiled, arch=arch, shape=shape, mesh_name=mesh_name, chips=chips, cfg=cfg
    )
    row = report.row()
    row["compile_s"] = compile_s
    row["memory_analysis"] = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "peak_per_device_gb": (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 2**30,
    }
    if verbose:
        print(f"== {arch} x {shape_name} x {mesh_name} ({chips} chips) ==")
        print(f"  compile: {compile_s:.1f}s")
        print(f"  memory_analysis: args {mem.argument_size_in_bytes/2**30:.2f} GiB  "
              f"temp {mem.temp_size_in_bytes/2**30:.2f} GiB  "
              f"out {mem.output_size_in_bytes/2**30:.2f} GiB / device")
        print(f"  cost_analysis(raw): flops/dev {report.xla_flops_dev:.3e} bytes/dev {report.xla_bytes_dev:.3e}")
        print(f"  trip-corrected: flops/dev {report.dot_flops_dev:.3e}  hbm/dev {report.dot_bytes_dev:.3e}  wire/dev {report.wire_bytes_dev:.3e}")
        print(f"  roofline: compute {report.t_compute*1e3:.2f}ms  memory {report.t_memory*1e3:.2f}ms  "
              f"collective {report.t_collective*1e3:.2f}ms  -> {report.bottleneck}-bound")
        print(f"  model_flops {report.model_flops_total:.3e}  useful_ratio {report.useful_flops_ratio:.3f}")
        print(f"  collectives: {report.collective_counts}")
        sys.stdout.flush()
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-parallel residual hints (S Perf pair 2)")
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else sorted(ARCHS)
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            if not applicable(arch, shape):
                print(f"-- skip {arch} x {shape} (long_500k: not sub-quadratic; see DESIGN.md)")
                continue
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multipod' if mp else 'pod'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"-- cached {tag}")
                    continue
                try:
                    row = lower_pair(arch, shape, multi_pod=mp, seq_shard=args.seq_shard, microbatch_override=args.microbatches)
                    with open(path, "w") as f:
                        json.dump(row, f, indent=1)
                except Exception as e:
                    failures.append((tag, repr(e)))
                    print(f"!! FAIL {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        sys.exit(1)
    print("\nALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
