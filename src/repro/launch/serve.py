"""Serving launcher: load (or init) weights, distribute them with the tuned
broadcast, and run batched greedy generation.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-350m-smoke \
        --batch 4 --prompt-len 16 --steps 16 [--ckpt-dir DIR]
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serve.engine import Engine
from repro.train import checkpoint as ck


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--temperature", type=float, default=0.0, help="0 = greedy")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    model = Model(cfg)
    if args.ckpt_dir:
        step = ck.latest_step(args.ckpt_dir)
        assert step is not None, f"no checkpoint under {args.ckpt_dir}"
        params = ck.restore_checkpoint(args.ckpt_dir, step, model.param_shapes())
        print(f"restored step {step} from {args.ckpt_dir}")
    else:
        params = model.init(jax.random.PRNGKey(args.seed))
        print("no checkpoint given; serving random-init weights")

    engine = Engine(cfg, params, max_len=args.prompt_len + args.steps)
    rng = np.random.RandomState(args.seed)
    batch = {
        "tokens": jnp.asarray(
            rng.randint(0, cfg.vocab_size - 1, (args.batch, args.prompt_len))
        )
    }
    if cfg.frontend == "vision":
        batch["embeds"] = jnp.asarray(
            rng.randn(args.batch, cfg.prefix_len, cfg.d_model), jnp.bfloat16
        )
    if cfg.arch_type == "encdec":
        batch["embeds"] = jnp.asarray(
            rng.randn(args.batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16
        )
    res = engine.generate(
        batch,
        steps=args.steps,
        greedy=(args.temperature == 0.0),
        temperature=max(args.temperature, 1e-6),
        seed=args.seed,
    )
    print(f"arch={cfg.name} batch={args.batch} prefill={args.prompt_len} decode={args.steps}")
    for b in range(args.batch):
        print(f"req{b}: {res.tokens[b].tolist()} (mean logprob {res.logprobs[b].mean():.3f})")


if __name__ == "__main__":
    main()
