import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ must precede jax import (see dryrun.py)

"""Hillclimb pair 3 — the paper's own technique, measured from lowered HLO.

Lowers the FULL explicit-sync train step (xlstm-350m, train_4k tokens) on a
pure data-parallel mesh for each collective configuration, and reports the
sync stage's collective footprint: wire bytes (bandwidth term) and
collective op count x t_s (the launch/latency term the paper's
small-message wins come from). 'xla_psum' is the one-shot NCCL-style
baseline; 'pipelined_chain' is the paper's contribution; 'bidir_chain' is
our beyond-paper variant; 'ar:<algo>' entries lower the
sync_mode='tuned_allreduce' step through the repro.comm plan layer
(ar:auto / ar:fused_rsb / ar:ring_allreduce / ...); 'ov:<algo>' entries
lower the overlap-engine sync_mode='overlap_allreduce' step (same plans,
bucket-streamed schedule). Each row also carries the PLANNED footprint
(CollectivePlan wire-bytes and predicted time for the same bucket mix,
plus the overlap engine's barrier-vs-streamed span and idle-round
accounting for ar:/ov: rows, plus the compiled-executor accounting —
planned_rounds / planned_lane_classes / compiled_buckets, the HLO-size
story of DESIGN.md Sec. 9) next to the measured-from-HLO numbers. All
rows lower with params/opt-state donated, so the schedule replays update
gradient buckets in place.

    PYTHONPATH=src python -m repro.launch.hillclimb_bcast [--ranks 64]
"""
import argparse
import json

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import comm
from repro.comm import api as comm_api
from repro.analysis.roofline import analyze_compiled
from repro.configs import INPUT_SHAPES, get_config
from repro.configs.base import RunConfig
from repro.core import bucketing
from repro.core.cost_model import TPU_V5E
from repro.models import Model
from repro.optim.optimizers import get_optimizer
from repro.optim.schedules import warmup_cosine
from repro.train.train_step import (
    make_bcast_train_step,
    make_overlap_allreduce_train_step,
    make_tuned_allreduce_train_step,
)


def planned_footprint(model, *, ranks: int, bucket_bytes: int, op: str, algo: str,
                      overlap: bool = False, overlap_depth: int | None = None):
    """Host-side CollectivePlan accounting for the gradient bucket mix —
    what the comm layer PLANS to put on the wire, next to what the lowered
    HLO actually contains. With ``overlap=True`` the row also carries the
    overlap engine's planned-vs-simulated schedule accounting (barrier vs
    bucket-streamed span, idle rounds, tuned depth)."""
    grads_like = model.param_shapes()
    spec = bucketing.plan_buckets(grads_like, bucket_bytes)
    plans = [
        comm.plan_cached(op, M, ranks, algo=algo)
        for M in spec.bucket_bytes()
        if M
    ]
    # compiled-executor accounting: rounds vs lane classes is the HLO-size
    # story (unrolled grows with rounds, compiled with classes), and
    # compiled_buckets counts how many buckets the tuned routing policy
    # sends through the fori_loop replay
    lowered = [p.lowered() for p in plans]
    out = {
        "planned_algos": sorted({p.algo for p in plans}),
        "planned_wire_bytes": sum(p.wire_bytes() for p in plans),
        "planned_time_ms": sum(p.predicted_s for p in plans) * 1e3,
        "num_buckets": len(plans),
        "planned_rounds": sum(lw.num_rounds for lw in lowered if lw is not None),
        "planned_lane_classes": sum(lw.num_classes for lw in lowered if lw is not None),
        "compiled_buckets": sum(
            comm_api._use_compiled(p, fused=True, compiled=None) for p in plans
        ),
    }
    if overlap:
        oplan = comm.plan_overlap(
            grads_like, [("data", ranks)], op=op, algo=algo,
            bucket_bytes=bucket_bytes, overlap_depth=overlap_depth,
        )
        sim = comm.simulate_overlap(oplan)
        out.update(
            overlap_depth=oplan.overlap_depth,
            overlap_depth_source=oplan.depth_source,
            planned_barrier_ms=oplan.barrier_s() * 1e3,
            planned_overlap_ms=oplan.overlapped_s() * 1e3,
            overlap_efficiency=oplan.efficiency(),
            sim_idle_rounds_barrier=sim["idle_rounds_barrier"],
            sim_idle_rounds_overlap=sim["idle_rounds_overlap"],
        )
    return out


def lower_algo(algo: str, *, ranks: int, seq: int, batch: int, bucket_mb: int):
    mesh = jax.make_mesh((ranks,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    cfg = get_config("xlstm-350m")
    model = Model(cfg)
    opt = get_optimizer("adamw")
    lr_fn = warmup_cosine(3e-4, 100, 1000)
    if algo.startswith("ar:") or algo.startswith("ov:"):
        # ar:<algo> lowers the barrier tuned_allreduce step; ov:<algo> the
        # overlap-engine (bucket-streamed) step — same plans, different
        # schedule-of-collectives, so the planned overlap accounting sits
        # next to the lowered-HLO footprint of each
        overlap = algo.startswith("ov:")
        run = RunConfig(
            sync_mode="overlap_allreduce" if overlap else "tuned_allreduce",
            allreduce_algo=algo[3:],
            bcast_bucket_bytes=bucket_mb << 20,
            num_microbatches=1,
            remat=True,
        )
        make = make_overlap_allreduce_train_step if overlap else make_tuned_allreduce_train_step
        step = make(model, run, opt, lr_fn, mesh)
        planned = planned_footprint(
            model, ranks=ranks, bucket_bytes=bucket_mb << 20,
            op="allreduce", algo=algo[3:], overlap=True,
        )
    else:
        run = RunConfig(
            sync_mode="param_bcast",
            bcast_algo=algo,
            bcast_bucket_bytes=bucket_mb << 20,
            num_microbatches=1,
            remat=True,
        )
        step = make_bcast_train_step(model, run, opt, lr_fn, mesh)
        planned = (
            planned_footprint(
                model, ranks=ranks, bucket_bytes=bucket_mb << 20,
                op="bcast", algo=algo,
            )
            if algo not in ("xla_psum", "xla_allgather", "ring_allreduce")
            else {}
        )

    params_shapes = model.param_shapes()
    opt_shapes = jax.eval_shape(opt.init, params_shapes)
    repl = lambda tree: jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=NamedSharding(mesh, P())), tree
    )
    import jax.numpy as jnp

    batch_sds = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32,
                                       sharding=NamedSharding(mesh, P("data", None))),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32,
                                       sharding=NamedSharding(mesh, P("data", None))),
    }
    with mesh:
        lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
            repl(params_shapes), repl(opt_shapes), batch_sds
        )
        compiled = lowered.compile()
    rep = analyze_compiled(
        compiled, arch="xlstm-350m", shape=INPUT_SHAPES["train_4k"], mesh_name=f"dp{ranks}",
        chips=ranks, cfg=cfg,
    )
    ops = sum(rep.collective_counts.values())
    mem = compiled.memory_analysis()
    return {
        "algo": algo,
        "wire_bytes_dev": rep.wire_bytes_dev,
        "t_bandwidth_ms": rep.t_collective * 1e3,
        "collective_ops": ops,
        "t_launch_ms": ops * TPU_V5E.ts * 1e3,
        "t_sync_total_ms": rep.t_collective * 1e3 + ops * TPU_V5E.ts * 1e3,
        "by_family": rep.wire_by_family,
        "counts": rep.collective_counts,
        "peak_gb": (mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 2**30,
        **planned,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=64)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--seq", type=int, default=4096)
    ap.add_argument("--bucket-mb", type=int, default=2048)
    ap.add_argument(
        "--algos",
        default="xla_psum,binomial,pipelined_chain,bidir_chain,scatter_allgather,auto,"
                "ar:auto,ar:fused_rsb,ar:ring_allreduce,ar:reduce_then_bcast,ov:auto",
    )
    ap.add_argument("--out", default="experiments/hillclimb_bcast.json")
    args = ap.parse_args()

    rows = []
    for algo in args.algos.split(","):
        try:
            row = lower_algo(algo, ranks=args.ranks, seq=args.seq, batch=args.batch,
                             bucket_mb=args.bucket_mb)
        except Exception as e:  # noqa: BLE001
            row = {"algo": algo, "error": repr(e)[:300]}
        rows.append(row)
        print(json.dumps(row), flush=True)
    with open(args.out, "w") as f:
        json.dump({"ranks": args.ranks, "batch": args.batch, "seq": args.seq,
                   "bucket_mb": args.bucket_mb, "rows": rows}, f, indent=1)


if __name__ == "__main__":
    main()
