"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls this.
"""
from __future__ import annotations

import jax

from ..dist.topology import DP_AXES, TP_AXIS, dp_axes

__all__ = ["make_production_mesh", "make_local_mesh", "dp_axes"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axis names follow ``repro.dist.topology``'s roles so the sharding rules,
    activation hints, and hierarchical broadcast all key off the same mesh.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = DP_AXES + (TP_AXIS,) if multi_pod else (DP_AXES[-1], TP_AXIS)
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_local_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (CPU smoke / small runs)."""
    n = len(jax.devices())
    assert n % model_parallel == 0, (n, model_parallel)
    shape = (n // model_parallel, model_parallel)
    return jax.make_mesh(
        shape, (DP_AXES[-1], TP_AXIS), axis_types=(jax.sharding.AxisType.Auto,) * 2
    )
