"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-8b-smoke \
        --steps 100 --batch 8 --seq 128 --sync-mode param_bcast

Any assigned architecture id (or its '-smoke' reduced variant) is accepted.
``--sync-mode param_bcast`` runs the paper's reduce-to-root + tuned-broadcast
data-parallel synchronization; ``grad_allreduce`` is the GSPMD baseline.
"""
from __future__ import annotations

import argparse

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.launch.mesh import make_local_mesh
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgdm", "lion"])
    ap.add_argument("--sync-mode", default="grad_allreduce",
                    choices=["grad_allreduce", "param_bcast"])
    ap.add_argument("--bcast-algo", default="auto")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--data", default=None, help="packed int32 token .npy file")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    run = RunConfig(
        learning_rate=args.lr,
        warmup_steps=args.warmup,
        total_steps=args.steps,
        optimizer=args.optimizer,
        sync_mode=args.sync_mode,
        bcast_algo=args.bcast_algo,
        num_microbatches=args.microbatches,
        seed=args.seed,
    )
    mesh = make_local_mesh(args.model_parallel)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} sync={run.sync_mode}")
    tr = Trainer(cfg, run, mesh=mesh, data_path=args.data, ckpt_dir=args.ckpt_dir)
    tr.train(
        batch=args.batch,
        seq=args.seq,
        steps=args.steps,
        log_every=args.log_every,
        ckpt_every=args.ckpt_every,
    )


if __name__ == "__main__":
    main()
