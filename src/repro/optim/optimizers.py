"""Optimizers (from scratch, pytree-based): AdamW, SGD-momentum, Lion."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "sgdm", "lion", "get_optimizer", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """init(params) -> state;  update(grads, state, params, lr) ->
    (new_params, new_state). All pure; state['step'] is a scalar."""

    name: str
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple]


def _zeros_like_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {"m": _zeros_like_f32(params), "v": _zeros_like_f32(params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * gf
            v = b2 * v + (1 - b2) * gf * gf
            mh = m / c1
            vh = v / c2
            delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}

    return Optimizer("adamw", init, update)


def sgdm(momentum: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"m": _zeros_like_f32(params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        def upd(g, m, p):
            gf = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            m = momentum * m + gf
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        return tdef.unflatten([o[0] for o in out]), {
            "m": tdef.unflatten([o[1] for o in out]),
            "step": state["step"] + 1,
        }

    return Optimizer("sgdm", init, update)


def lion(b1: float = 0.9, b2: float = 0.99, weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        return {"m": _zeros_like_f32(params), "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        def upd(g, m, p):
            gf = g.astype(jnp.float32)
            u = jnp.sign(b1 * m + (1 - b1) * gf) + weight_decay * p.astype(jnp.float32)
            m2 = b2 * m + (1 - b2) * gf
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m2

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        out = [upd(g, m, p) for g, m, p in zip(flat_g, flat_m, flat_p)]
        return tdef.unflatten([o[0] for o in out]), {
            "m": tdef.unflatten([o[1] for o in out]),
            "step": state["step"] + 1,
        }

    return Optimizer("lion", init, update)


def get_optimizer(name: str, weight_decay: float = 0.1) -> Optimizer:
    if name == "adamw":
        return adamw(weight_decay=weight_decay)
    if name == "sgdm":
        return sgdm(weight_decay=weight_decay)
    if name == "lion":
        return lion(weight_decay=weight_decay)
    raise KeyError(name)
