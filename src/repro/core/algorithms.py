"""Fused shard_map executors + XLA one-shot baselines for the bcast family.

Generic schedule replay lives in :mod:`repro.comm.executors`
(``execute_collective`` unrolled / ``execute_compiled`` fori_loop over the
host-side lowering — the production path, compact HLO independent of chunk
count for EVERY schedule); :func:`execute_schedule` /
:func:`execute_reduce_schedule` here are thin compatibility wrappers. The
hand-written :func:`pipelined_chain_fused` / :func:`ring_allreduce`
fori_loop executors remain as the original single-op references the generic
compiled executor is tested against.

All functions here run *inside* ``jax.shard_map`` over a named axis. The
buffer convention is ``(num_chunks, chunk_elems)``; every rank holds a buffer
of identical shape, only the root's content matters on entry, and on exit all
ranks hold the root's data.

Baselines ("the vendor library"): :func:`xla_psum_bcast` and
:func:`xla_allgather_bcast` use XLA's native one-shot collectives — the TPU
stand-ins for NCCL's broadcast (see DESIGN.md Sec. 2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .schedules import Schedule, build

__all__ = [
    "execute_schedule",
    "execute_reduce_schedule",
    "pipelined_chain_fused",
    "xla_psum_bcast",
    "xla_allgather_bcast",
    "schedule_bcast",
]


def _axis_size(axis_name) -> int:
    return lax.axis_size(axis_name)


def execute_schedule(schedule: Schedule, buf: jax.Array, axis_name) -> jax.Array:
    """Replay a bcast schedule. ``buf``: (num_chunks, chunk_elems).

    Thin wrapper over the ONE generalized executor
    (:func:`repro.comm.executors.execute_collective`) — kept for the
    original API surface.
    """
    if schedule.kind != "bcast":
        raise ValueError("use execute_reduce_schedule for reduce schedules")
    from ..comm.executors import execute_collective

    return execute_collective(schedule, buf, axis_name)


def execute_reduce_schedule(schedule: Schedule, buf: jax.Array, axis_name) -> jax.Array:
    """Replay a reduce-to-root schedule (sum combiner) over a whole buffer
    of any shape. Wrapper over the generalized executor."""
    if schedule.kind != "reduce":
        raise ValueError("not a reduce schedule")
    from ..comm.executors import execute_collective

    shape = buf.shape
    out = execute_collective(schedule, jnp.ravel(buf).reshape(1, -1), axis_name)
    return out.reshape(shape)


def pipelined_chain_fused(
    buf: jax.Array, axis_name, *, root: int = 0, unroll: int = 1
) -> jax.Array:
    """Fused executor for the paper's pipelined chain (Eq. 5).

    ``buf``: (num_chunks, chunk_elems). Emits ONE ppermute inside a
    ``fori_loop`` of ``num_chunks + n - 2`` rounds — HLO size is independent
    of the chunk count, unlike the generic unrolled executor.

    Round ``s``: the rank at logical chain position ``p`` sends chunk
    ``s - p`` (if valid) to position ``p + 1`` and accepts chunk
    ``s - p + 1`` from position ``p - 1``.
    """
    n = _axis_size(axis_name)
    if n == 1:
        return buf
    num_chunks, chunk = buf.shape
    perm = [((root + j) % n, (root + j + 1) % n) for j in range(n - 1)]
    pos = (lax.axis_index(axis_name) - root) % n

    def body(s, b):
        c_send = jnp.clip(s - pos, 0, num_chunks - 1)
        operand = lax.dynamic_slice(b, (c_send, 0), (1, chunk))
        received = lax.ppermute(operand, axis_name, perm)
        c_in = s - pos + 1
        valid = (pos >= 1) & (c_in >= 0) & (c_in < num_chunks)
        c_recv = jnp.clip(c_in, 0, num_chunks - 1)
        current = lax.dynamic_slice(b, (c_recv, 0), (1, chunk))
        merged = jnp.where(valid, received, current)
        return lax.dynamic_update_slice(b, merged, (c_recv, 0))

    return lax.fori_loop(0, num_chunks + n - 2, body, buf, unroll=unroll)


def ring_allreduce(x: jax.Array, axis_name, *, unroll: int = 1) -> jax.Array:
    """PAPER FUTURE-WORK (Sec. VII): explicit bandwidth-optimal ring
    allreduce — reduce-scatter phase (n-1 rounds, each rank accumulates one
    chunk) followed by an all-gather phase (n-1 rounds), built from the same
    ppermute substrate as the broadcast library. Total wire: 2M(n-1)/n per
    rank — matches the one-shot psum's bandwidth while staying inside the
    explicit-schedule framework (tunable, hierarchical-composable).
    """
    n = _axis_size(axis_name)
    if n == 1:
        return x
    shape, dtype = x.shape, x.dtype
    flat = jnp.ravel(x)
    chunk = -(-flat.size // n)
    pad = n * chunk - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), dtype)])
    buf = flat.reshape(n, chunk)
    rank = lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]

    # reduce-scatter: at step s, rank r sends chunk (r - s) mod n; after
    # n-1 steps rank r owns the full sum of chunk (r + 1) mod n.
    def rs_body(s, state):
        b, acc = state
        send_idx = (rank - s) % n
        operand = jnp.where(
            s == 0,
            lax.dynamic_slice(b, (send_idx, 0), (1, chunk))[0],
            acc,
        )
        received = lax.ppermute(operand, axis_name, perm)
        recv_idx = (rank - s - 1) % n
        acc = received + lax.dynamic_slice(b, (recv_idx, 0), (1, chunk))[0]
        return b, acc

    acc0 = lax.pvary(jnp.zeros((chunk,), dtype), axis_name)
    _, acc = lax.fori_loop(0, n - 1, rs_body, (buf, acc0), unroll=unroll)
    owned = (rank + 1) % n
    buf = lax.dynamic_update_slice(buf, acc[None], (owned, 0))

    # all-gather: circulate the reduced chunks for n-1 rounds.
    def ag_body(s, b):
        idx = (rank + 1 - s) % n
        operand = lax.dynamic_slice(b, (idx, 0), (1, chunk))
        received = lax.ppermute(operand, axis_name, perm)
        recv_idx = (rank - s) % n
        return lax.dynamic_update_slice(b, received, (recv_idx, 0))

    buf = lax.fori_loop(0, n - 1, ag_body, buf, unroll=unroll)
    out = buf.reshape(-1)
    if pad:
        out = out[: flat.size - pad]
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# XLA-native one-shot baselines (the "NCCL" of the TPU world)
# ---------------------------------------------------------------------------


def xla_psum_bcast(x: jax.Array, axis_name, *, root: int = 0) -> jax.Array:
    """Broadcast by masking non-root contributions and all-reducing."""
    keep = lax.axis_index(axis_name) == root
    return lax.psum(jnp.where(keep, x, jnp.zeros_like(x)), axis_name)


def xla_allgather_bcast(x: jax.Array, axis_name, *, root: int = 0) -> jax.Array:
    """Broadcast via all_gather + select of the root slice (n*M on the wire)."""
    gathered = lax.all_gather(x, axis_name, axis=0)
    return gathered[root]


# ---------------------------------------------------------------------------
# Convenience: build + execute for a named algorithm over a chunked buffer
# ---------------------------------------------------------------------------


def schedule_bcast(
    buf: jax.Array,
    axis_name,
    *,
    algo: str,
    root: int = 0,
    fused: bool = True,
    **algo_kw,
) -> jax.Array:
    """Broadcast a (num_chunks, chunk) buffer with the named algorithm."""
    n = _axis_size(axis_name)
    if n == 1:
        return buf
    num_chunks = buf.shape[0]
    # The compiled fori_loop executor emits one ppermute per lane class
    # regardless of chunk count, but its constant perms transmit garbage
    # during pipeline fill/drain ((K + n - 2)/K x the useful bytes). The
    # unrolled schedule executor sends EXACTLY the schedule's transfers.
    # Use the exact one while its HLO stays small; fall back to the generic
    # compiled replay for huge round counts (same policy as
    # comm.api.apply_plan).
    if algo in ("pipelined_chain", "bidir_chain") and fused and (num_chunks + n - 2) > 256:
        from ..comm.executors import execute_compiled

        sched = build(algo, n, root, num_chunks=num_chunks, **algo_kw)
        return execute_compiled(sched, buf, axis_name)
    if algo in ("pipelined_chain", "bidir_chain"):
        sched = build(algo, n, root, num_chunks=num_chunks, **algo_kw)
    elif algo == "scatter_allgather":
        if num_chunks != n:
            raise ValueError(f"scatter_allgather wants num_chunks == n ({n}), got {num_chunks}")
        sched = build(algo, n, root, **algo_kw)
    else:
        if num_chunks != 1:
            # whole-message algorithms view the buffer as one chunk
            buf2 = buf.reshape(1, -1)
            out = schedule_bcast(buf2, axis_name, algo=algo, root=root, fused=fused, **algo_kw)
            return out.reshape(buf.shape)
        sched = build(algo, n, root, **algo_kw)
    return execute_schedule(sched, buf, axis_name)
