"""shard_map executors for broadcast/reduce schedules.

The generic executor (:func:`execute_schedule`) replays any
:class:`core.schedules.Schedule` with one ``lax.ppermute`` per round. For the
paper's pipelined chain a fused ``lax.fori_loop`` executor
(:func:`pipelined_chain_fused`) emits a single ppermute in the loop body —
this is the production path (compact HLO independent of chunk count).

All functions here run *inside* ``jax.shard_map`` over a named axis. The
buffer convention is ``(num_chunks, chunk_elems)``; every rank holds a buffer
of identical shape, only the root's content matters on entry, and on exit all
ranks hold the root's data.

Baselines ("the vendor library"): :func:`xla_psum_bcast` and
:func:`xla_allgather_bcast` use XLA's native one-shot collectives — the TPU
stand-ins for NCCL's broadcast (see DESIGN.md Sec. 2).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .schedules import Schedule, build

__all__ = [
    "execute_schedule",
    "execute_reduce_schedule",
    "pipelined_chain_fused",
    "xla_psum_bcast",
    "xla_allgather_bcast",
    "schedule_bcast",
]


def _axis_size(axis_name) -> int:
    return lax.axis_size(axis_name)


def _per_rank(values: np.ndarray, axis_name):
    """Trace-time table lookup: values[axis_index]."""
    return jnp.asarray(values)[lax.axis_index(axis_name)]


def _lanes(transfers):
    """Partition a round's transfers into ppermute 'lanes': within one lane
    each rank is a source at most once (destinations are unique per round by
    construction). Multi-lane rounds (e.g. the bidirectional chain's root
    feeding both directions) issue one ppermute per lane; on TPU these run
    on disjoint full-duplex links concurrently."""
    lanes: list[list] = []
    for t in transfers:
        for lane in lanes:
            if all(t.src != u.src for u in lane):
                lane.append(t)
                break
        else:
            lanes.append([t])
    return lanes


def execute_schedule(schedule: Schedule, buf: jax.Array, axis_name) -> jax.Array:
    """Replay a bcast schedule. ``buf``: (num_chunks, chunk_elems)."""
    if schedule.kind != "bcast":
        raise ValueError("use execute_reduce_schedule for reduce schedules")
    n = schedule.n
    assert buf.ndim == 2 and buf.shape[0] == schedule.num_chunks, buf.shape
    for full_round in schedule.rounds:
        if not full_round.transfers:
            continue
        for lane in _lanes(full_round.transfers):
            buf = _execute_lane(lane, buf, axis_name, n)
    return buf


def _execute_lane(transfers, buf, axis_name, n):
    count = transfers[0].chunk_count
    send_start = np.zeros(n, np.int32)
    recv_start = np.zeros(n, np.int32)
    is_dst = np.zeros(n, bool)
    for t in transfers:
        send_start[t.src] = t.chunk_start
        recv_start[t.dst] = t.chunk_start
        is_dst[t.dst] = True
    perm = [(t.src, t.dst) for t in transfers]
    s0 = _per_rank(send_start, axis_name)
    operand = lax.dynamic_slice(buf, (s0, 0), (count, buf.shape[1]))
    received = lax.ppermute(operand, axis_name, perm)
    r0 = _per_rank(recv_start, axis_name)
    current = lax.dynamic_slice(buf, (r0, 0), (count, buf.shape[1]))
    received = jnp.where(_per_rank(is_dst, axis_name), received, current)
    return lax.dynamic_update_slice(buf, received, (r0, 0))


def execute_reduce_schedule(schedule: Schedule, buf: jax.Array, axis_name) -> jax.Array:
    """Replay a reduce-to-root schedule (sum combiner). Whole-buffer transfers."""
    if schedule.kind != "reduce":
        raise ValueError("not a reduce schedule")
    n = schedule.n
    for rnd in schedule.rounds:
        if not rnd.transfers:
            continue
        is_dst = np.zeros(n, bool)
        for t in rnd.transfers:
            is_dst[t.dst] = True
        perm = [(t.src, t.dst) for t in rnd.transfers]
        received = lax.ppermute(buf, axis_name, perm)
        add = jnp.where(_per_rank(is_dst, axis_name), received, jnp.zeros_like(buf))
        buf = buf + add
    return buf


def pipelined_chain_fused(
    buf: jax.Array, axis_name, *, root: int = 0, unroll: int = 1
) -> jax.Array:
    """Fused executor for the paper's pipelined chain (Eq. 5).

    ``buf``: (num_chunks, chunk_elems). Emits ONE ppermute inside a
    ``fori_loop`` of ``num_chunks + n - 2`` rounds — HLO size is independent
    of the chunk count, unlike the generic unrolled executor.

    Round ``s``: the rank at logical chain position ``p`` sends chunk
    ``s - p`` (if valid) to position ``p + 1`` and accepts chunk
    ``s - p + 1`` from position ``p - 1``.
    """
    n = _axis_size(axis_name)
    if n == 1:
        return buf
    num_chunks, chunk = buf.shape
    perm = [((root + j) % n, (root + j + 1) % n) for j in range(n - 1)]
    pos = (lax.axis_index(axis_name) - root) % n

    def body(s, b):
        c_send = jnp.clip(s - pos, 0, num_chunks - 1)
        operand = lax.dynamic_slice(b, (c_send, 0), (1, chunk))
        received = lax.ppermute(operand, axis_name, perm)
        c_in = s - pos + 1
        valid = (pos >= 1) & (c_in >= 0) & (c_in < num_chunks)
        c_recv = jnp.clip(c_in, 0, num_chunks - 1)
        current = lax.dynamic_slice(b, (c_recv, 0), (1, chunk))
        merged = jnp.where(valid, received, current)
        return lax.dynamic_update_slice(b, merged, (c_recv, 0))

    return lax.fori_loop(0, num_chunks + n - 2, body, buf, unroll=unroll)


def ring_allreduce(x: jax.Array, axis_name, *, unroll: int = 1) -> jax.Array:
    """PAPER FUTURE-WORK (Sec. VII): explicit bandwidth-optimal ring
    allreduce — reduce-scatter phase (n-1 rounds, each rank accumulates one
    chunk) followed by an all-gather phase (n-1 rounds), built from the same
    ppermute substrate as the broadcast library. Total wire: 2M(n-1)/n per
    rank — matches the one-shot psum's bandwidth while staying inside the
    explicit-schedule framework (tunable, hierarchical-composable).
    """
    n = _axis_size(axis_name)
    if n == 1:
        return x
    shape, dtype = x.shape, x.dtype
    flat = jnp.ravel(x)
    chunk = -(-flat.size // n)
    pad = n * chunk - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), dtype)])
    buf = flat.reshape(n, chunk)
    rank = lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]

    # reduce-scatter: at step s, rank r sends chunk (r - s) mod n; after
    # n-1 steps rank r owns the full sum of chunk (r + 1) mod n.
    def rs_body(s, state):
        b, acc = state
        send_idx = (rank - s) % n
        operand = jnp.where(
            s == 0,
            lax.dynamic_slice(b, (send_idx, 0), (1, chunk))[0],
            acc,
        )
        received = lax.ppermute(operand, axis_name, perm)
        recv_idx = (rank - s - 1) % n
        acc = received + lax.dynamic_slice(b, (recv_idx, 0), (1, chunk))[0]
        return b, acc

    acc0 = lax.pvary(jnp.zeros((chunk,), dtype), axis_name)
    _, acc = lax.fori_loop(0, n - 1, rs_body, (buf, acc0), unroll=unroll)
    owned = (rank + 1) % n
    buf = lax.dynamic_update_slice(buf, acc[None], (owned, 0))

    # all-gather: circulate the reduced chunks for n-1 rounds.
    def ag_body(s, b):
        idx = (rank + 1 - s) % n
        operand = lax.dynamic_slice(b, (idx, 0), (1, chunk))
        received = lax.ppermute(operand, axis_name, perm)
        recv_idx = (rank - s) % n
        return lax.dynamic_update_slice(b, received, (recv_idx, 0))

    buf = lax.fori_loop(0, n - 1, ag_body, buf, unroll=unroll)
    out = buf.reshape(-1)
    if pad:
        out = out[: flat.size - pad]
    return out.reshape(shape)


# ---------------------------------------------------------------------------
# XLA-native one-shot baselines (the "NCCL" of the TPU world)
# ---------------------------------------------------------------------------


def xla_psum_bcast(x: jax.Array, axis_name, *, root: int = 0) -> jax.Array:
    """Broadcast by masking non-root contributions and all-reducing."""
    keep = lax.axis_index(axis_name) == root
    return lax.psum(jnp.where(keep, x, jnp.zeros_like(x)), axis_name)


def xla_allgather_bcast(x: jax.Array, axis_name, *, root: int = 0) -> jax.Array:
    """Broadcast via all_gather + select of the root slice (n*M on the wire)."""
    gathered = lax.all_gather(x, axis_name, axis=0)
    return gathered[root]


# ---------------------------------------------------------------------------
# Convenience: build + execute for a named algorithm over a chunked buffer
# ---------------------------------------------------------------------------


def schedule_bcast(
    buf: jax.Array,
    axis_name,
    *,
    algo: str,
    root: int = 0,
    fused: bool = True,
    **algo_kw,
) -> jax.Array:
    """Broadcast a (num_chunks, chunk) buffer with the named algorithm."""
    n = _axis_size(axis_name)
    if n == 1:
        return buf
    num_chunks = buf.shape[0]
    # The fused fori_loop executor emits one ppermute regardless of chunk
    # count, but its constant ring perm transmits garbage during pipeline
    # fill/drain ((K + n - 2)/K x the useful bytes). The unrolled schedule
    # executor sends EXACTLY the schedule's transfers. Use the exact one
    # while its HLO stays small; fall back to fused for huge round counts.
    if algo == "pipelined_chain" and fused and (num_chunks + n - 2) > 256:
        return pipelined_chain_fused(buf, axis_name, root=root)
    if algo in ("pipelined_chain", "bidir_chain"):
        sched = build(algo, n, root, num_chunks=num_chunks, **algo_kw)
    elif algo == "scatter_allgather":
        if num_chunks != n:
            raise ValueError(f"scatter_allgather wants num_chunks == n ({n}), got {num_chunks}")
        sched = build(algo, n, root, **algo_kw)
    else:
        if num_chunks != 1:
            # whole-message algorithms view the buffer as one chunk
            buf2 = buf.reshape(1, -1)
            out = schedule_bcast(buf2, axis_name, algo=algo, root=root, fused=fused, **algo_kw)
            return out.reshape(buf.shape)
        sched = build(algo, n, root, **algo_kw)
    return execute_schedule(sched, buf, axis_name)
