"""Public broadcast API — the paper's contribution as a composable JAX module.

``pbcast`` is the collective itself (callable inside ``jax.shard_map``), with
``algo='auto'`` routing through the tuning framework exactly like
``MPI_Bcast`` routes through MVAPICH2-GDR's tuned tables. ``pbcast_tree``
broadcasts a whole parameter pytree through same-dtype buckets, which is how
the trainer's ``param_bcast`` sync mode uses it. ``preduce_sum`` is the
mirror-image reduce-to-root. ``hierarchical_bcast`` composes per-axis bcasts
(intra-pod then inter-pod), mirroring MVAPICH2's hierarchical designs.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import algorithms, bucketing, schedules
from .tuner import Decision, Tuner, default_tuner

__all__ = [
    "pbcast",
    "pbcast_tree",
    "preduce_sum",
    "hierarchical_bcast",
    "bcast_stacked",
]

_ONE_SHOT = {"xla_psum", "xla_allgather"}


def _decide(M: int, n: int, algo: str, num_chunks, tuner: Tuner | None, inter_pod: bool) -> Decision:
    if algo == "auto":
        return (tuner or default_tuner()).select(M, n, inter_pod=inter_pod)
    if num_chunks is None:
        t = tuner or default_tuner()
        if algo in ("pipelined_chain", "bidir_chain"):
            # per-algorithm analytic chunking (a generic fallback of 8 chunks
            # made a 64-rank chain carry 5x extra fill/drain garbage —
            # EXPERIMENTS.md §Perf pair 3)
            from . import cost_model as _cm

            hops = ((n - 1 + 1) // 2 + 1) if algo == "bidir_chain" else n
            c_star = _cm.optimal_chunk_bytes(M, hops, t.hw, t.hw.path_bw(inter_pod))
            num_chunks = max(1, min(t.max_chunks, math.ceil(M / c_star)))
        elif algo == "scatter_allgather":
            num_chunks = n
        else:
            num_chunks = 1
    return Decision(algo, int(num_chunks), math.ceil(M / max(1, int(num_chunks))), float("nan"), "manual")


def pbcast(
    x: jax.Array,
    axis_name,
    *,
    root: int = 0,
    algo: str = "auto",
    num_chunks: int | None = None,
    tuner: Tuner | None = None,
    inter_pod: bool = False,
    fused: bool = True,
) -> jax.Array:
    """Broadcast ``x`` from ``root`` over the named mesh axis.

    Must be called inside ``shard_map``. Every rank passes a buffer of the
    same shape/dtype; the return value equals the root's input on all ranks.
    ``algo``: 'auto' (tuned), one of core.schedules.ALGORITHMS, or the
    one-shot XLA baselines 'xla_psum' / 'xla_allgather'.
    """
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    if algo == "xla_psum":
        return algorithms.xla_psum_bcast(x, axis_name, root=root)
    if algo == "xla_allgather":
        return algorithms.xla_allgather_bcast(x, axis_name, root=root)

    shape, dtype = x.shape, x.dtype
    flat = jnp.ravel(x)
    M = flat.size * flat.dtype.itemsize
    dec = _decide(M, n, algo, num_chunks, tuner, inter_pod)
    if dec.algo == "noop":
        return x
    k = max(1, min(dec.num_chunks, flat.size))
    chunk_elems = -(-flat.size // k)  # ceil
    pad = k * chunk_elems - flat.size
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), dtype)])
    buf = flat.reshape(k, chunk_elems)
    out = algorithms.schedule_bcast(buf, axis_name, algo=dec.algo, root=root, fused=fused)
    out = out.reshape(-1)
    if pad:
        out = out[: flat.size - pad]
    return out.reshape(shape)


def pbcast_tree(
    tree: Any,
    axis_name,
    *,
    root: int = 0,
    algo: str = "auto",
    tuner: Tuner | None = None,
    bucket_bytes: int = 4 << 20,
    inter_pod: bool = False,
) -> Any:
    """Broadcast a pytree via same-dtype buckets, each tuned independently.

    The bucket mix reproduces the application regime of the paper (Sec. V-D):
    a few large buckets (pipelined-chain / scatter-allgather territory) plus
    a tail of small ones (k-nomial territory).
    """
    spec = bucketing.plan_buckets(tree, bucket_bytes)
    buckets = bucketing.pack_buckets(tree, spec)
    out = [
        pbcast(b, axis_name, root=root, algo=algo, tuner=tuner, inter_pod=inter_pod)
        if b.size
        else b
        for b in buckets
    ]
    return bucketing.unpack_buckets(out, spec)


def preduce_sum(x: jax.Array, axis_name, *, root: int = 0) -> jax.Array:
    """Reduce-to-root (sum) via the reversed binomial tree.

    Non-root ranks return garbage partial sums by design (MPI_Reduce
    semantics) — only the root's output is meaningful.
    """
    n = lax.axis_size(axis_name)
    if n == 1:
        return x
    sched = schedules.binomial_reduce(n, root)
    shape = x.shape
    flat = jnp.ravel(x)
    out = algorithms.execute_reduce_schedule(sched, flat.reshape(1, -1), axis_name)
    return out.reshape(shape)


def hierarchical_bcast(
    x: jax.Array,
    axes: Sequence | None = None,
    *,
    mesh=None,
    root: int = 0,
    algo: str = "auto",
    tuner: Tuner | None = None,
    inter_pod_axes: Sequence | None = None,
) -> jax.Array:
    """Broadcast over multiple mesh axes, one level at a time.

    Mirrors MVAPICH2's hierarchical collectives: the inter-pod level runs
    first (pod leaders), then each pod fans out internally. Axes whose name
    is in ``inter_pod_axes`` are priced with the slower inter-pod constants.

    Both the per-level axis order and the inter-pod classification come
    from ``repro.dist.topology`` — the same mesh metadata that drives the
    sharding rules — either explicitly (``axes=``) or derived from a mesh
    (``mesh=``): ``bcast_axes(mesh)`` yields pod leaders first, then the
    intra-pod data axes.
    """
    from ..dist import topology

    if axes is None:
        if mesh is None:
            raise ValueError("hierarchical_bcast needs `axes` or a `mesh` to derive them")
        axes = topology.bcast_axes(mesh)
    if inter_pod_axes is None:
        inter_pod_axes = topology.INTER_POD_AXES
    for ax in axes:
        x = pbcast(
            x,
            ax,
            root=root,
            algo=algo,
            tuner=tuner,
            inter_pod=(ax in inter_pod_axes),
        )
    return x


def bcast_stacked(
    xs: jax.Array,
    mesh: jax.sharding.Mesh,
    axis_name: str,
    *,
    root: int = 0,
    algo: str = "auto",
    tuner: Tuner | None = None,
) -> jax.Array:
    """Top-level helper: ``xs`` has leading dim == axis size (one slice per
    rank, sharded over ``axis_name``); returns the same stacked array where
    every slice equals the root's slice. Useful for tests and tools."""
    from jax.sharding import PartitionSpec as P

    spec = P(axis_name, *([None] * (xs.ndim - 1)))

    @partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(spec,),
        out_specs=spec,
    )
    def _run(block):
        sl = block[0]
        out = pbcast(sl, axis_name, root=root, algo=algo, tuner=tuner)
        return out[None]

    return _run(xs)
