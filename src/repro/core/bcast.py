"""Public broadcast API — compatibility facade over ``repro.comm``.

Historically this module WAS the collective library; the plan/executor logic
now lives in the :mod:`repro.comm` subsystem (see DESIGN.md Sec. 3) and
these wrappers keep the original entry points stable: ``pbcast`` routes
through the tuning framework exactly like ``MPI_Bcast`` routes through
MVAPICH2-GDR's tuned tables, ``pbcast_tree`` broadcasts a parameter pytree
through same-dtype buckets, ``preduce_sum`` is the mirror-image
reduce-to-root, and ``hierarchical_bcast`` composes per-axis bcasts
(intra-pod then inter-pod), mirroring MVAPICH2's hierarchical designs.

New code should import from ``repro.comm`` directly — it also exposes the
allreduce/allgather/reduce_scatter ops and the CollectivePlan layer.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Sequence

import jax

from ..comm import api as _api
from ..comm.plan import ONE_SHOT as _ONE_SHOT  # noqa: F401  (re-export for compat)
from ..comm.plan import decide as _comm_decide
from .tuner import Decision, Tuner

__all__ = [
    "pbcast",
    "pbcast_tree",
    "preduce_sum",
    "hierarchical_bcast",
    "bcast_stacked",
]

# direct delegations — signatures unchanged
pbcast = _api.pbcast
pbcast_tree = _api.pbcast_tree


def _decide(M: int, n: int, algo: str, num_chunks, tuner: Tuner | None, inter_pod: bool) -> Decision:
    """Legacy hook kept for callers/tests; manual decisions now carry an
    analytic ``predicted_s`` instead of NaN (comm.plan.decide)."""
    return _comm_decide(
        "bcast", M, n, algo=algo, num_chunks=num_chunks, tuner=tuner, inter_pod=inter_pod
    )


def preduce_sum(x: jax.Array, axis_name, *, root: int = 0) -> jax.Array:
    """Reduce-to-root (sum) via the reversed binomial tree.

    Non-root ranks return garbage partial sums by design (MPI_Reduce
    semantics) — only the root's output is meaningful.
    """
    return _api.preduce(x, axis_name, root=root, algo="binomial_reduce")


def hierarchical_bcast(
    x: jax.Array,
    axes: Sequence | None = None,
    *,
    mesh=None,
    root: int = 0,
    algo: str = "auto",
    tuner: Tuner | None = None,
    inter_pod_axes: Sequence | None = None,
) -> jax.Array:
    """Broadcast over multiple mesh axes, one level at a time.

    Mirrors MVAPICH2's hierarchical collectives: the inter-pod level runs
    first (pod leaders), then each pod fans out internally. Axes whose name
    is in ``inter_pod_axes`` are priced with the slower inter-pod constants.

    Both the per-level axis order and the inter-pod classification come
    from ``repro.dist.topology`` — the same mesh metadata that drives the
    sharding rules — either explicitly (``axes=``) or derived from a mesh
    (``mesh=``): ``bcast_axes(mesh)`` yields pod leaders first, then the
    intra-pod data axes.
    """
    from ..dist import topology

    if axes is None:
        if mesh is None:
            raise ValueError("hierarchical_bcast needs `axes` or a `mesh` to derive them")
        axes = topology.bcast_axes(mesh)
    if inter_pod_axes is None:
        inter_pod_axes = topology.INTER_POD_AXES
    for ax in axes:
        x = _api.pbcast(
            x,
            ax,
            root=root,
            algo=algo,
            tuner=tuner,
            inter_pod=(ax in inter_pod_axes),
        )
    return x


def bcast_stacked(
    xs: jax.Array,
    mesh: jax.sharding.Mesh,
    axis_name: str,
    *,
    root: int = 0,
    algo: str = "auto",
    tuner: Tuner | None = None,
) -> jax.Array:
    """Top-level helper: ``xs`` has leading dim == axis size (one slice per
    rank, sharded over ``axis_name``); returns the same stacked array where
    every slice equals the root's slice. Useful for tests and tools."""
    from jax.sharding import PartitionSpec as P

    spec = P(axis_name, *([None] * (xs.ndim - 1)))

    @partial(
        jax.shard_map,
        mesh=mesh,
        in_specs=(spec,),
        out_specs=spec,
    )
    def _run(block):
        sl = block[0]
        out = _api.pbcast(sl, axis_name, root=root, algo=algo, tuner=tuner)
        return out[None]

    return _run(xs)
