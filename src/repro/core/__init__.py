"""Core: the paper's contribution — tuned broadcast collectives for DL.

Pipelined-chain broadcast (Eq. 5) + the classical algorithm library
(Eqs. 1-4, 6), analytic cost models, a tuning framework, pytree bucketing,
and XLA-native one-shot baselines (the TPU stand-in for NCCL).
"""
from .algorithms import ring_allreduce
from .bcast import (
    bcast_stacked,
    hierarchical_bcast,
    pbcast,
    pbcast_tree,
    preduce_sum,
)
from .cost_model import CPU_SIM, TPU_V5E, Hardware, cost, optimal_chunk_bytes
from .schedules import ALGORITHMS, Schedule, build
from .tuner import Decision, Tuner, default_tuner

__all__ = [
    "ring_allreduce",
    "pbcast",
    "pbcast_tree",
    "preduce_sum",
    "hierarchical_bcast",
    "bcast_stacked",
    "Hardware",
    "TPU_V5E",
    "CPU_SIM",
    "cost",
    "optimal_chunk_bytes",
    "Schedule",
    "ALGORITHMS",
    "build",
    "Tuner",
    "Decision",
    "default_tuner",
]
