"""Pytree <-> communication-bucket packing.

DL frameworks never broadcast tensors one by one: parameters are flattened
and coalesced into fixed-budget, same-dtype buckets (CNTK "divides the
communication based on the process count", paper Sec. V-D). The bucket mix —
a few huge buffers plus a tail of small ones — is exactly the message-size
spectrum the tuning framework exists for.

Pure-jnp packing so it composes with jit/shard_map.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BucketSpec", "pack_buckets", "unpack_buckets"]


@dataclasses.dataclass(frozen=True)
class _LeafMeta:
    index: int          # position in tree_flatten order
    shape: tuple
    dtype: Any
    bucket: int         # which bucket it landed in
    offset: int         # element offset within the bucket


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    treedef: Any
    leaves: tuple  # of _LeafMeta
    bucket_sizes: tuple[int, ...]     # elements per bucket
    bucket_dtypes: tuple[Any, ...]

    @property
    def num_buckets(self) -> int:
        return len(self.bucket_sizes)

    def bucket_bytes(self) -> list[int]:
        return [
            int(s) * np.dtype(d).itemsize
            for s, d in zip(self.bucket_sizes, self.bucket_dtypes)
        ]


def plan_buckets(tree: Any, bucket_bytes: int = 4 << 20) -> BucketSpec:
    """Greedy same-dtype bucketing in tree_flatten order (deterministic)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    metas: list[_LeafMeta] = []
    sizes: list[int] = []
    dtypes: list[Any] = []
    open_bucket: dict[Any, int] = {}  # dtype -> bucket idx still below budget
    for i, leaf in enumerate(leaves):
        # shape/dtype only — works on abstract leaves (ShapeDtypeStruct)
        # so consumers can plan bucket mixes without materializing params
        dt = leaf.dtype if hasattr(leaf, "dtype") else jnp.asarray(leaf).dtype
        shape = tuple(getattr(leaf, "shape", ()))
        nelem = int(np.prod(shape)) if shape else 1
        itemsize = np.dtype(dt).itemsize
        b = open_bucket.get(dt)
        if b is None or (sizes[b] + nelem) * itemsize > bucket_bytes:
            b = len(sizes)
            sizes.append(0)
            dtypes.append(dt)
            open_bucket[dt] = b
        metas.append(_LeafMeta(i, shape, dt, b, sizes[b]))
        sizes[b] += nelem
    return BucketSpec(treedef, tuple(metas), tuple(sizes), tuple(dtypes))


def pack_buckets(tree: Any, spec: BucketSpec) -> list[jax.Array]:
    """Flatten + concatenate leaves into their buckets."""
    leaves = jax.tree_util.tree_leaves(tree)
    parts: list[list[jax.Array]] = [[] for _ in range(spec.num_buckets)]
    for meta in spec.leaves:
        parts[meta.bucket].append(jnp.ravel(leaves[meta.index]))
    out = []
    for b, chunks in enumerate(parts):
        if chunks:
            out.append(jnp.concatenate(chunks) if len(chunks) > 1 else chunks[0])
        else:
            out.append(jnp.zeros((0,), spec.bucket_dtypes[b]))
    return out


def unpack_buckets(buckets: list[jax.Array], spec: BucketSpec) -> Any:
    """Inverse of :func:`pack_buckets`."""
    leaves: list[Any] = [None] * len(spec.leaves)
    for meta in spec.leaves:
        nelem = int(np.prod(meta.shape)) if meta.shape else 1
        flat = jax.lax.dynamic_slice_in_dim(buckets[meta.bucket], meta.offset, nelem)
        leaves[meta.index] = flat.reshape(meta.shape)
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)
