"""Analytical cost models for broadcast algorithms (paper Sec. III, Eqs. 1-6).

Notation follows Table I of the paper:
    M   message size (bytes)
    C   chunk size (bytes)
    B   link bandwidth (bytes/s)
    n   number of ranks
    t_s startup time per transfer

Hardware constants are TPU-v5e flavoured (the adaptation target — see
DESIGN.md Sec. 2): ICI links inside a pod, a slower inter-pod path, and a
host-DMA path standing in for the paper's PCIe staging link.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

__all__ = [
    "Hardware",
    "TPU_V5E",
    "CPU_SIM",
    "calibrate_t_launch",
    "t_exec_path",
    "cost",
    "cost_wire",
    "LinkClass",
    "calibrate_link_classes",
    "cost_link_class",
    "WIRE_PAYLOAD_FRACTION",
    "optimal_chunk_bytes",
    "optimal_chunk_bytes_fused",
    "t_overlapped",
    "t_bucketed_barrier",
    "optimal_overlap_depth",
    "window_finish_times",
    "skew_ratio",
    "ALGO_COSTS",
]


@dataclasses.dataclass(frozen=True)
class Hardware:
    """Fabric constants used by the analytic model and the tuner."""

    name: str
    ts: float            # startup latency per transfer (s)
    link_bw: float       # per-link bandwidth, intra-pod ICI (bytes/s)
    interpod_bw: float   # per-link bandwidth across pods (bytes/s)
    host_bw: float       # host staging path ("B_PCIe" analogue, bytes/s)
    peak_flops: float    # per chip, bf16
    hbm_bw: float        # per chip
    # per kernel-launch overhead (s): what each round of a host-mediated
    # executor pays at the launch boundary and the in-kernel executor pays
    # once per schedule. Defaulted so keyword-constructed Hardware values
    # (and saved configs) stay valid; see calibrate_t_launch for deriving it
    # from a committed compile table.
    t_launch: float = 5e-6

    def path_bw(self, inter_pod: bool) -> float:
        return self.interpod_bw if inter_pod else self.link_bw


# TPU v5e: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (task constants).
# Inter-pod (DCN/ICI-over-optics) priced at a quarter of an ICI link; startup
# latency ~1.5us for a ppermute hop, 10us across pods.
TPU_V5E = Hardware(
    name="tpu_v5e",
    ts=1.5e-6,
    link_bw=50e9,
    interpod_bw=12.5e9,
    host_bw=16e9,
    peak_flops=197e12,
    hbm_bw=819e9,
    t_launch=8e-6,
)

# Constants for interpreting CPU microbenchmarks (used only to sanity-check
# measured-vs-model shape agreement in benchmarks; absolute values are
# calibrated at runtime).
CPU_SIM = Hardware(
    name="cpu_sim",
    ts=50e-6,
    link_bw=8e9,
    interpod_bw=2e9,
    host_bw=8e9,
    peak_flops=1e11,
    hbm_bw=2e10,
    t_launch=100e-6,
)


# ---------------------------------------------------------------------------
# Executor launch overhead (the term the in-kernel executor deletes)
# ---------------------------------------------------------------------------


def calibrate_t_launch(table: dict) -> float:
    """Per-round launch/lowering overhead (s/round) from a committed compile
    table (``experiments/compile_table.json``).

    Each ``n<r>/<op>/<algo>/K<k>`` group that sweeps several chunk counts
    gives (num_rounds, unrolled_lower_s) pairs; the unrolled executor's
    lower time grows linearly in the round count, so the least-squares slope
    of each multi-K group is that group's per-round boundary cost. The
    calibrated constant is the median across groups — robust to one
    pathological algorithm family.
    """
    groups: dict[tuple, list[tuple[float, float]]] = {}
    for key, e in table.items():
        parts = key.split("/")
        if len(parts) != 4:
            continue
        groups.setdefault(tuple(parts[:3]), []).append(
            (float(e["num_rounds"]), float(e["unrolled_lower_s"]))
        )
    slopes = []
    for pts in groups.values():
        if len(pts) < 2:
            continue
        xs, ys = zip(*pts)
        mx, my = sum(xs) / len(xs), sum(ys) / len(ys)
        den = sum((x - mx) ** 2 for x in xs)
        if den <= 0:
            continue
        slopes.append(sum((x - mx) * (y - my) for x, y in pts) / den)
    if not slopes:
        raise ValueError(
            "calibrate_t_launch: table has no multi-K group to fit a slope on"
        )
    slopes.sort()
    mid = len(slopes) // 2
    return slopes[mid] if len(slopes) % 2 else 0.5 * (slopes[mid - 1] + slopes[mid])


def t_exec_path(path: str, num_rounds: int, num_classes: int, hw: Hardware) -> float:
    """Launch-boundary overhead of one executor choice (s), additive on top
    of the wire-time closed forms — what lets the tuner price inkernel vs
    compiled vs unrolled honestly:

      * ``unrolled`` — every round re-emits one ppermute + one merge per
        lane class into the program (2 boundaries per class per round);
      * ``compiled`` — one fori_loop, but still a ppermute -> combine-kernel
        launch pair per round at runtime;
      * ``inkernel`` — a single persistent kernel launch for the whole
        schedule.
    """
    rounds = max(int(num_rounds), 0)
    classes = max(int(num_classes), 1)
    if path == "inkernel":
        return hw.t_launch
    if path == "compiled":
        return 2.0 * rounds * hw.t_launch
    if path == "unrolled":
        return 2.0 * rounds * classes * hw.t_launch
    raise ValueError(
        f"exec path must be 'inkernel'|'compiled'|'unrolled', got {path!r}"
    )


# ---------------------------------------------------------------------------
# Closed forms, Eqs. 1-6
# ---------------------------------------------------------------------------


def t_direct(M: float, n: int, hw: Hardware, B: float) -> float:
    """Eq. 1: T = n * (ts + M/B). (Paper keeps the n factor; the root's n-1
    serialized sends plus the initiation round-off.)"""
    return n * (hw.ts + M / B)


def t_chain(M: float, n: int, hw: Hardware, B: float) -> float:
    """Eq. 2: T = (n-1) * (ts + M/B)."""
    return (n - 1) * (hw.ts + M / B)


def t_knomial(M: float, n: int, hw: Hardware, B: float, k: int = 2, multiport: bool = False) -> float:
    """Eq. 3: T = ceil(log_k n) * (ts + M/B) (multiport idealization).

    Our executor serializes a parent's k-1 child sends (single egress port),
    so the default prices (k-1)*ceil(log_k n) rounds; for k=2 both agree.
    """
    if n <= 1:
        return 0.0
    steps = math.ceil(math.log(n, k))
    if not multiport:
        steps *= k - 1
    return steps * (hw.ts + M / B)


def t_scatter_allgather(M: float, n: int, hw: Hardware, B: float) -> float:
    """Eq. 4: (ceil(log2 n) + n - 1) * ts + 2*(n-1)/n * M/B."""
    if n <= 1:
        return 0.0
    return (math.ceil(math.log2(n)) + n - 1) * hw.ts + 2.0 * (n - 1) / n * M / B


def t_pipelined_chain(M: float, n: int, hw: Hardware, B: float, C: float | None = None) -> float:
    """Eq. 5: T = (M/C + n - 2) * (ts + C/B), the paper's proposed design."""
    if n <= 1:
        return 0.0
    if C is None:
        C = optimal_chunk_bytes(M, n, hw, B)
    C = min(max(C, 1.0), M)
    num_chunks = math.ceil(M / C)
    return (num_chunks + max(n - 2, 0)) * (hw.ts + C / B)


def t_bidir_chain(M: float, n: int, hw: Hardware, B: float, C: float | None = None) -> float:
    """BEYOND-PAPER: bidirectional pipelined chain over full-duplex links —
    both directions carry the full message concurrently, so the chunk
    pipeline only has to cover ceil((n-1)/2) hops:
        T = (M/C + ceil((n-1)/2) - 1) * (ts + C/B)."""
    if n <= 2:
        return t_pipelined_chain(M, n, hw, B, C=C)
    hops = (n - 1 + 1) // 2
    if C is None:
        C = optimal_chunk_bytes(M, hops + 1, hw, B)
    C = min(max(C, 1.0), M)
    num_chunks = math.ceil(M / C)
    return (num_chunks + max(hops - 1, 0)) * (hw.ts + C / B)


def t_knomial_staged(M: float, n: int, hw: Hardware, B: float, k: int = 2) -> float:
    """Eq. 6: host-staged k-nomial: M/B_host + ceil(log_k n) * (ts + M/B)."""
    return M / hw.host_bw + t_knomial(M, n, hw, B, k=k)


def optimal_chunk_bytes(M: float, n: int, hw: Hardware, B: float) -> float:
    """Analytic minimizer of Eq. 5 over C:

        d/dC [(M/C + n-2)(ts + C/B)] = -M*ts/C^2 + (n-2)/B = 0
        =>  C* = sqrt(M * ts * B / (n - 2))

    For n <= 2 the chain is a single hop and chunking only adds startup
    cost, so C* = M.
    """
    if n <= 2 or M <= 0:
        return float(max(M, 1))
    c = math.sqrt(M * hw.ts * B / (n - 2))
    return float(min(max(c, 1.0), M))


# ---------------------------------------------------------------------------
# Non-bcast collectives (repro.comm): closed forms for the per-op tuner.
# M is always the FULL logical buffer (the bcast payload, the allreduce
# gradient, the gathered allgather output) — shard sizes are M/n.
# ---------------------------------------------------------------------------


def t_fused_rsb(M: float, n: int, hw: Hardware, B: float, C: float | None = None) -> float:
    """Fused pipelined reduce-chain + bcast-chain allreduce ("fused_rsb").

    Chunk c is fully reduced at the chain head after n-1 hops and is
    immediately streamed back down while later chunks are still reducing, so
    the two phases overlap on the full-duplex links:

        T = (M/C + 2n - 3) * (ts + C/B)
    """
    if n <= 1:
        return 0.0
    if C is None:
        C = optimal_chunk_bytes_fused(M, n, hw, B)
    C = min(max(C, 1.0), M)
    num_chunks = math.ceil(M / C)
    return (num_chunks + max(2 * n - 3, 0)) * (hw.ts + C / B)


def optimal_chunk_bytes_fused(M: float, n: int, hw: Hardware, B: float) -> float:
    """Minimizer of t_fused_rsb over C: C* = sqrt(M * ts * B / (2n - 3))."""
    if n <= 1 or M <= 0:
        return float(max(M, 1))
    c = math.sqrt(M * hw.ts * B / max(2 * n - 3, 1))
    return float(min(max(c, 1.0), M))


def t_reduce_then_bcast(M: float, n: int, hw: Hardware, B: float, t_bcast: float | None = None) -> float:
    """Two-phase allreduce: reversed-binomial reduce-to-root, barrier, then
    the tuned broadcast (``t_bcast``; defaults to the binomial tree)."""
    if n <= 1:
        return 0.0
    t_reduce = t_knomial(M, n, hw, B, k=2)
    if t_bcast is None:
        t_bcast = t_knomial(M, n, hw, B, k=2)
    return t_reduce + t_bcast


def t_ring_allreduce(M: float, n: int, hw: Hardware, B: float) -> float:
    """Bandwidth-optimal ring: reduce-scatter (n-1 rounds) + allgather
    (n-1 rounds), each round moving one M/n chunk per rank."""
    if n <= 1:
        return 0.0
    return 2 * (n - 1) * (hw.ts + math.ceil(M / n) / B)


def t_ring_allgather(M: float, n: int, hw: Hardware, B: float) -> float:
    """Ring allgather: n-1 rounds of one M/n chunk per rank (any n)."""
    if n <= 1:
        return 0.0
    return (n - 1) * (hw.ts + math.ceil(M / n) / B)


def t_doubling_allgather(M: float, n: int, hw: Hardware, B: float) -> float:
    """Recursive-doubling allgather (power-of-two n): log2(n) rounds whose
    payload doubles each round — same bytes as the ring, log startups."""
    if n <= 1:
        return 0.0
    return math.ceil(math.log2(n)) * hw.ts + (n - 1) / n * M / B


def t_ring_reduce_scatter(M: float, n: int, hw: Hardware, B: float) -> float:
    """Ring reduce-scatter: n-1 combining rounds of one M/n chunk per rank."""
    if n <= 1:
        return 0.0
    return (n - 1) * (hw.ts + math.ceil(M / n) / B)


# ---------------------------------------------------------------------------
# Ragged collectives (allgatherv / alltoallv). ``sizes`` is the per-rank (or
# per-block) payload in BYTES; ``None`` prices the uniform M/n (M/n^2) split,
# which collapses every form below to its uniform counterpart. The skew term
# max(sizes) vs sum(sizes) is what inverts the ring/pairwise decision — the
# regime the Allgatherv study (arXiv:1812.05964) measures.
# ---------------------------------------------------------------------------


def skew_ratio(sizes: Sequence[float]) -> float:
    """max(sizes) / mean(sizes) — 1.0 for uniform, up to len(sizes) for a
    single hot rank. The tuner buckets empirical keys on log2 of this."""
    sizes = [float(s) for s in sizes]
    total = sum(sizes)
    if not sizes or total <= 0:
        return 1.0
    return max(sizes) * len(sizes) / total


def _gatherv_sizes(M: float, n: int, sizes: Sequence[float] | None) -> list[float]:
    if sizes is None:
        return [M / max(n, 1)] * n
    return [float(s) for s in sizes]


def _a2av_matrix(M: float, n: int, sizes: Sequence[float] | None) -> list[list[float]]:
    if sizes is None:
        b = M / max(n * n, 1)
        return [[b] * n for _ in range(n)]
    flat = [float(s) for s in sizes]
    if len(flat) == n:          # per-destination vector, uniform across sources
        return [list(flat) for _ in range(n)]
    if len(flat) == n * n:
        return [flat[r * n:(r + 1) * n] for r in range(n)]
    raise ValueError(f"alltoallv sizes must have n or n*n entries, got {len(flat)}")


def t_ring_allgatherv(M: float, n: int, hw: Hardware, B: float,
                      sizes: Sequence[float] | None = None) -> float:
    """Ring allgatherv: n-1 neighbor rounds, but EVERY round is gated by the
    largest segment in flight somewhere on the ring:

        T = (n - 1) * (ts + max(sizes)/B)

    Uniform sizes recover t_ring_allgather; under skew the cost is keyed on
    max(sizes) while the wire total is keyed on sum(sizes) — the ring's
    bandwidth optimality evaporates as skew grows."""
    if n <= 1:
        return 0.0
    sz = _gatherv_sizes(M, n, sizes)
    return (n - 1) * (hw.ts + max(sz) / B)


def t_doubling_allgatherv(M: float, n: int, hw: Hardware, B: float,
                          sizes: Sequence[float] | None = None) -> float:
    """Recursive-doubling allgatherv: log2(n) rounds, round t gated by the
    largest contiguous group of 2^t segments.

    Unlike the switch-fabric ``t_doubling_allgather`` (the paper's IB
    cluster, where any pair is one hop), the ragged variant prices the
    ring-embedded ICI fabric: a distance-2^t exchange occupies 2^t
    consecutive links, dividing per-link bandwidth by the hop count. Under
    uniform sizes the quadratic hop-weighted bytes lose to the ring; under
    skew the hot segment pays its (n-1) hop-bytes either way and doubling
    wins back (n-1) - log2(n) startups — the inversion the tuner keys on."""
    if n <= 1:
        return 0.0
    sz = _gatherv_sizes(M, n, sizes)
    t, span = 0.0, 1
    while span < n:
        worst = 0.0
        for base in range(0, n, span):
            worst = max(worst, sum(sz[base:min(base + span, n)]))
        if worst > 0:
            t += hw.ts + min(span, n - span) * worst / B
        span *= 2
    return t


def t_pairwise_alltoallv(M: float, n: int, hw: Hardware, B: float,
                         sizes: Sequence[float] | None = None) -> float:
    """Pairwise-exchange alltoallv: n-1 steps, step s gated by the largest
    (r -> r+s) block; every block crosses the wire once, but a step of ring
    distance d occupies d consecutive ICI links (hop-weighted bandwidth,
    as in :func:`t_doubling_allgatherv`). Hot-destination (incast) skew
    makes the far steps carry the hot block over their full distance —
    the regime where the store-and-forward ring wins."""
    if n <= 1:
        return 0.0
    m = _a2av_matrix(M, n, sizes)
    t = 0.0
    for s in range(1, n):
        worst = max(m[r][(r + s) % n] for r in range(n))
        if worst > 0:
            t += hw.ts + min(s, n - s) * worst / B
    return t


def t_ring_alltoallv(M: float, n: int, hw: Hardware, B: float,
                     sizes: Sequence[float] | None = None) -> float:
    """Store-and-forward ring alltoallv: n-1 neighbor rounds; round t is
    gated by the heaviest edge, which carries every not-yet-delivered block
    whose current holder feeds that edge. Each block pays its hop count in
    wire bytes, so hot blocks far from their destination hurt most."""
    if n <= 1:
        return 0.0
    m = _a2av_matrix(M, n, sizes)
    t = 0.0
    for step in range(n - 1):
        worst = 0.0
        for r in range(n):
            s = (r - step) % n
            load = sum(m[s][d] for d in range(n) if (d - s) % n > step)
            worst = max(worst, load)
        if worst > 0:
            t += hw.ts + worst / B
    return t


# ---------------------------------------------------------------------------
# Compute/communication overlap (the CNTK end-to-end regime, paper Sec. V-D):
# bucketed gradient sync pipelined against backward compute. These price
# *schedules of* collectives — the overlap engine (repro.comm.overlap) feeds
# them per-bucket times from CollectivePlans.
# ---------------------------------------------------------------------------


def t_bucketed_barrier(
    bucket_comm_s: Sequence[float],
    compute_s: float,
    stage_s: Sequence[float] | None = None,
) -> float:
    """Barrier schedule: ALL compute, then ALL staging, then every bucket's
    collective back-to-back (what ``pallreduce_tree`` lowers today). The
    network idles for the whole compute phase."""
    stage = sum(stage_s) if stage_s is not None else 0.0
    return float(compute_s) + stage + float(sum(bucket_comm_s))


def multi_stream_finish_times(
    streams: Sequence[dict],
    *,
    starvation_bound: int | None = None,
    trace: list | None = None,
) -> list:
    """THE link-scheduler recurrence — the multi-stream generalization of the
    PR 4 in-flight-window timeline. Every contending stream is a dict:

        avail     per-bucket earliest availability times (compute gating)
        stage     per-bucket staging costs (off-link; pack / chunked_copy)
        comm      per-bucket link occupancy — a scalar (the bucket is one
                  indivisible transfer) or a sequence of round quanta (the
                  scheduler may preempt the stream between quanta: 'priority
                  preemption points at round boundaries')
        depth     in-flight window depth (default 1): bucket k's staging
                  waits for comm_end[k - depth]
        priority  higher wins contended dispatches (default 0)
        link      name of the serial resource the stream occupies
                  (default "net"); different links never contend
        after     indices of streams that must FULLY finish before this
                  stream's first bucket may stage (DAG edges)

    Arbitration, per link: a transfer may dispatch at
    ``t = max(link_free, min(ready))`` over that link's pending quanta —
    the link never idles while any transfer is ready (no-idle property).
    Among the quanta ready by ``t``, the highest-priority stream wins
    (ties: latest-ready loses, then lower stream index wins) UNLESS some
    eligible stream has already been passed over ``starvation_bound``
    times — then the most-starved stream is forced (fairness property:
    with S contending streams no stream is passed over more than
    ``starvation_bound + S - 2`` consecutive times; exact bound for
    S == 2). ``starvation_bound=None`` disables aging (pure priority).

    Works on any numeric type (floats or integer rounds). Returns the
    per-stream per-bucket comm finish times. With ONE stream this reduces
    exactly to the PR 4 recurrence (:func:`window_finish_times`):

        stage_k starts at max(avail_k, comm_end_{k-depth})   (free slot)
        comm_k  starts at max(stage-end_k, comm_end_{k-1})   (serial net)

    If ``trace`` is a list, one record per dispatched quantum is appended
    (stream, bucket, quantum, start, end, link, link_free, min_ready,
    contenders) in commit order — the replay schedule consumers execute.
    """
    S = len(streams)
    quanta: list[list[list]] = []
    nbuckets: list[int] = []
    depth: list[int] = []
    prio: list = []
    link: list[str] = []
    after: list[tuple[int, ...]] = []
    for st in streams:
        qs = [list(c) if isinstance(c, (list, tuple)) else [c] for c in st["comm"]]
        if any(not q for q in qs):
            raise ValueError("every bucket needs >= 1 comm quantum")
        quanta.append(qs)
        nbuckets.append(len(qs))
        depth.append(max(1, min(int(st.get("depth", 1)), max(len(qs), 1))))
        prio.append(st.get("priority", 0))
        link.append(str(st.get("link", "net")))
        deps = tuple(int(d) for d in st.get("after", ()))
        if any(d < 0 or d >= S for d in deps):
            raise ValueError(f"'after' index out of range: {deps}")
        after.append(deps)
    comm_end: list[list] = [[0] * nbuckets[s] for s in range(S)]
    nk = [0] * S   # next bucket per stream
    nq = [0] * S   # next quantum within that bucket
    qend = [0] * S  # end time of the stream's previous quantum
    link_free: dict = {}
    skips = [0] * S
    while True:
        pend: dict[str, list] = {}
        active = False
        for s in range(S):
            if nk[s] >= nbuckets[s]:
                continue
            active = True
            if any(nk[d] < nbuckets[d] for d in after[s]):
                continue  # upstream stream still draining
            k = nk[s]
            if nq[s] == 0:
                dep_done = 0
                for d in after[s]:
                    if nbuckets[d]:
                        dep_done = max(dep_done, comm_end[d][-1])
                slot_free = comm_end[s][k - depth[s]] if k >= depth[s] else 0
                ready = max(streams[s]["avail"][k], slot_free, dep_done) + streams[s]["stage"][k]
            else:
                ready = qend[s]  # mid-bucket: back-to-back quanta
            pend.setdefault(link[s], []).append((ready, s))
        if not pend:
            if active:
                raise ValueError("stream deadlock: cycle in 'after' edges")
            break
        best = None
        for ln in sorted(pend):
            cands = pend[ln]
            lfree = link_free.get(ln, 0)
            t = max(lfree, min(r for r, _ in cands))
            elig = [s for r, s in cands if r <= t]
            ready_of = {s: r for r, s in cands}
            starved = [
                s for s in elig
                if starvation_bound is not None and skips[s] >= starvation_bound
            ]
            pool = starved or elig
            if starved:
                chosen = max(pool, key=lambda s: (skips[s], prio[s], -s))
            else:
                chosen = max(pool, key=lambda s: (prio[s], -ready_of[s], -s))
            if best is None or (t, ln) < (best[0], best[1]):
                best = (t, ln, lfree, ready_of, chosen, elig)
        t, ln, lfree, ready_of, s, elig = best
        end = t + quanta[s][nk[s]][nq[s]]
        link_free[ln] = end
        qend[s] = end
        for o in elig:
            skips[o] = 0 if o == s else skips[o] + 1
        if trace is not None:
            trace.append({
                "stream": s, "bucket": nk[s], "quantum": nq[s],
                "start": t, "end": end, "link": ln,
                "link_free": lfree, "min_ready": min(ready_of.values()),
                "ready": ready_of[s], "contenders": len(elig),
                "skips": max(skips) if skips else 0,
            })
        nq[s] += 1
        if nq[s] >= len(quanta[s][nk[s]]):
            comm_end[s][nk[s]] = end
            nk[s] += 1
            nq[s] = 0
    return comm_end


def window_finish_times(
    avail: Sequence,
    stage: Sequence,
    comm: Sequence,
    depth: int,
) -> list:
    """The greedy in-flight-window recurrence both :func:`t_overlapped`
    (seconds) and the round simulator (``repro.comm.streams``, integer
    rounds) drain through. Since the stream refactor this is literally the
    1-stream case of :func:`multi_stream_finish_times` — kept as the named
    entry point so the analytic depth tuner, the round accounting, and the
    multi-stream arbiter can never drift apart. Per bucket k (dispatch
    order):

        stage_k starts at max(avail_k, comm_end_{k-depth})   (free slot)
        comm_k  starts at max(stage-end_k, comm_end_{k-1})   (serial net)

    Works on any numeric type (floats or integer rounds). Returns the
    per-bucket comm finish times.
    """
    return multi_stream_finish_times(
        [{"avail": avail, "stage": stage, "comm": comm, "depth": depth}]
    )[0]


def t_overlapped(
    bucket_comm_s: Sequence[float],
    compute_s: float,
    *,
    depth: int = 2,
    stage_s: Sequence[float] | None = None,
) -> float:
    """Overlapped (bucket-streamed) schedule: greedy timeline estimate.

    Buckets are listed in DISPATCH order (backward-order streaming — the
    DDP/Horovod pattern). Bucket k's gradient becomes available a fraction
    (k+1)/K through the backward pass; staging (pack / ``chunked_copy``)
    needs a free slot in the ``depth``-deep in-flight window (the double/
    multi-buffer the consumer allocates), and the serialized network drains
    staged buckets in dispatch order (:func:`window_finish_times`).

    ``depth`` only buys time when staging is non-free: depth 1 serializes
    stage and comm, depth 2 is classic double buffering, deeper windows hide
    staging bursts at the cost of one live bucket buffer each. Returns the
    finish time of the last bucket's collective.
    """
    K = len(bucket_comm_s)
    if K == 0:
        return float(compute_s)
    avail = [compute_s * (k + 1) / K for k in range(K)]
    stage = list(stage_s) if stage_s is not None else [0.0] * K
    return float(window_finish_times(avail, stage, bucket_comm_s, depth)[-1])


def optimal_overlap_depth(
    bucket_comm_s: Sequence[float],
    compute_s: float,
    *,
    stage_s: Sequence[float] | None = None,
    max_depth: int = 8,
) -> int:
    """Smallest in-flight window minimizing :func:`t_overlapped` (ties go to
    the shallower window — each extra depth is a live staged bucket buffer)."""
    K = len(bucket_comm_s)
    if K <= 1:
        return 1
    best_d, best_t = 1, float("inf")
    for d in range(1, min(max_depth, K) + 1):
        t = t_overlapped(bucket_comm_s, compute_s, depth=d, stage_s=stage_s)
        if t < best_t * (1.0 - 1e-12):
            best_d, best_t = d, t
    return best_d


def t_nccl_ring(M: float, n: int, hw: Hardware, B: float, slice_bytes: float = 256 << 10) -> float:
    """The NCCL-stand-in baseline: a pipelined ring with a FIXED slice size
    and no algorithm switching (what NCCL 1.x broadcast does). At small M the
    (n-1) serial hops of ``t_s`` dominate — the regime where the paper
    reports 14x/16.6x wins for the tuned library."""
    if n <= 1:
        return 0.0
    C = min(max(slice_bytes, 1.0), M)
    num_chunks = math.ceil(M / C)
    return (num_chunks + max(n - 2, 0)) * (hw.ts + C / B)


ALGO_COSTS = {
    "nccl_ring": t_nccl_ring,
    "direct": t_direct,
    "chain": t_chain,
    "binomial": lambda M, n, hw, B: t_knomial(M, n, hw, B, k=2),
    "knomial": t_knomial,
    "knomial_staged": t_knomial_staged,
    "scatter_allgather": t_scatter_allgather,
    "pipelined_chain": t_pipelined_chain,
    "bidir_chain": t_bidir_chain,
    # reduce mirrors (same round structure, reversed)
    "binomial_reduce": lambda M, n, hw, B: t_knomial(M, n, hw, B, k=2),
    "pipelined_reduce_chain": t_pipelined_chain,
    # allreduce / allgather / reduce_scatter (repro.comm ops)
    "reduce_then_bcast": t_reduce_then_bcast,
    "fused_rsb": t_fused_rsb,
    "ring_allreduce": t_ring_allreduce,
    "ring_allgather": t_ring_allgather,
    "doubling_allgather": t_doubling_allgather,
    "ring_reduce_scatter": t_ring_reduce_scatter,
    # ragged ops (skew-aware; sizes in bytes via cost(..., sizes=...))
    "ring_allgatherv": t_ring_allgatherv,
    "doubling_allgatherv": t_doubling_allgatherv,
    "pairwise_alltoallv": t_pairwise_alltoallv,
    "ring_alltoallv": t_ring_alltoallv,
}


def cost(algo: str, M: float, n: int, hw: Hardware = TPU_V5E, *, inter_pod: bool = False, **kw) -> float:
    """Predicted latency (s) of ``algo`` for an M-byte bcast over n ranks."""
    B = hw.path_bw(inter_pod)
    return ALGO_COSTS[algo](M, n, hw, B, **kw)


def worst_link_factor(slow_links) -> float:
    """Worst per-link slowdown factor in a health report (>= 1.0).

    ``slow_links`` is a {(src, dst): factor} mapping or an iterable of
    ((src, dst), factor) pairs — the same shape ``comm.faults`` carries.
    Every schedule the planner emits serializes rounds, so the whole
    collective is gated by its slowest active link: the bandwidth term of a
    closed-form cost degrades by exactly this factor (startup terms are
    latency-bound and unaffected).
    """
    items = list(slow_links.values()) if isinstance(slow_links, dict) else [
        f for _pair, f in slow_links
    ]
    if not items:
        return 1.0
    return max(1.0, max(float(f) for f in items))


def degraded_bandwidth(B: float, slow_links) -> float:
    """Effective per-link bandwidth once the worst reported slowdown gates
    the round clock."""
    return B / worst_link_factor(slow_links)


def cost_degraded(
    algo: str,
    M: float,
    n: int,
    hw: Hardware = TPU_V5E,
    *,
    inter_pod: bool = False,
    slow_links=(),
    **kw,
) -> float:
    """:func:`cost` under a degraded-link health report: the same closed
    form, evaluated at :func:`degraded_bandwidth`. With an empty report this
    is exactly ``cost`` — the degraded path prices the healthy mesh
    identically, so replanning on a health transition can only re-rank
    algorithms for a reason."""
    B = degraded_bandwidth(hw.path_bw(inter_pod), slow_links)
    return ALGO_COSTS[algo](M, n, hw, B, **kw)


# ---------------------------------------------------------------------------
# compressed wire formats: bytes-vs-precision pricing
# ---------------------------------------------------------------------------

# wire payload per full-precision byte (f32 wire domain): compressed
# formats ship one byte per 4-byte element plus one f32 scale per
# 256-element block — 260 wire bytes per 1024 payload bytes (the physical
# form in repro.comm.compress.wire_chunk_bytes, before the block-padding
# ceil that only matters for ragged chunk tails)
WIRE_PAYLOAD_FRACTION = {
    "bf16": 1.0,
    "fp8": 260.0 / 1024.0,
    "int8": 260.0 / 1024.0,
}

# HBM passes each compressed hop adds on top of the transfer itself: the
# sender reads the block and writes the payload, the receiver reads the
# payload and writes the block back — ~2 full-size passes per hop, charged
# once against the whole message (hops pipeline the way transfers do)
_QUANTIZE_HBM_PASSES = 2.0


def cost_wire(
    algo: str,
    M: float,
    n: int,
    hw: Hardware = TPU_V5E,
    *,
    wire_format: str | None = None,
    inter_pod: bool = False,
    **kw,
) -> float:
    """:func:`cost` under a wire format: the closed form evaluated at the
    format's wire payload (bandwidth terms shrink by the compression
    fraction; startup/round terms are unchanged) plus the quantize/
    dequantize HBM toll. ``bf16``/``None`` is exactly ``cost``. This is
    the bytes-vs-precision trade the :class:`~repro.core.tuner.OnlineTuner`
    prices when it explores formats: compression wins where the bandwidth
    term dominates (large M) and loses to the HBM toll at small M."""
    fmt = wire_format or "bf16"
    if fmt not in WIRE_PAYLOAD_FRACTION:
        raise ValueError(
            f"unknown wire format {fmt!r}; have {sorted(WIRE_PAYLOAD_FRACTION)}"
        )
    frac = WIRE_PAYLOAD_FRACTION[fmt]
    if "C" in kw:
        kw = dict(kw, C=max(kw["C"] * frac, 1.0))
    t = ALGO_COSTS[algo](M * frac, n, hw, hw.path_bw(inter_pod), **kw)
    if frac < 1.0:
        t += _QUANTIZE_HBM_PASSES * M / hw.hbm_bw
    return t


@dataclasses.dataclass(frozen=True)
class LinkClass:
    """One calibrated link class: a (bandwidth, startup) pair for a set of
    physically-alike links. Asymmetric and multi-rail topologies are just
    distinct class names ('ici', 'host', 'rail0:up', 'rail0:down', ...) —
    per-direction links calibrate to different constants and price
    differently, nothing else is needed."""

    name: str
    bw: float  # bytes/s
    ts: float  # per-transfer startup (s)


def calibrate_link_classes(
    samples: dict[str, Sequence[tuple[float, float]]]
) -> dict[str, "LinkClass"]:
    """Fit per-class link constants from measured point-to-point transfers.

    ``samples[name]`` is a list of ``(bytes, seconds)`` pairs for one link
    class. Each class gets the least-squares line ``t = ts + bytes / bw``
    (the same slope fit :func:`calibrate_t_launch` uses per compile-table
    group): the slope is ``1/bw``, the intercept the startup. Needs >= 2
    distinct sizes per class and a positive slope — a flat or negative fit
    means the samples can't identify a bandwidth and raises instead of
    returning a nonsense constant.
    """
    classes: dict[str, LinkClass] = {}
    for name, pts in samples.items():
        pts = [(float(b), float(t)) for b, t in pts]
        if len(pts) < 2 or len({b for b, _ in pts}) < 2:
            raise ValueError(
                f"link class {name!r}: need >= 2 samples at distinct sizes "
                f"to fit (bw, ts), got {pts}"
            )
        xs, ys = zip(*pts)
        mx, my = sum(xs) / len(xs), sum(ys) / len(ys)
        den = sum((x - mx) ** 2 for x in xs)
        slope = sum((x - mx) * (y - my) for x, y in pts) / den
        if slope <= 0:
            raise ValueError(
                f"link class {name!r}: non-positive transfer-time slope "
                f"({slope:.3e} s/byte) — samples cannot identify a bandwidth"
            )
        classes[name] = LinkClass(name, bw=1.0 / slope,
                                  ts=max(my - slope * mx, 0.0))
    return classes


def cost_link_class(
    algo: str,
    M: float,
    n: int,
    link: "LinkClass",
    hw: Hardware = TPU_V5E,
    **kw,
) -> float:
    """Predicted latency of ``algo`` over links of one calibrated class:
    the closed form evaluated at the class's bandwidth with the hardware's
    startup replaced by the class's — how the planner prices a collective
    confined to one rail/direction of an asymmetric topology."""
    return ALGO_COSTS[algo](M, n, dataclasses.replace(hw, ts=link.ts),
                            link.bw, **kw)
