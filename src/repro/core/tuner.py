"""The collective tuning framework (paper Sec. IV-B/IV-C, "MV2-GDR-Opt").

Selects an algorithm and chunk size per (op, message size, rank count,
path class), the way MVAPICH2-GDR's tuning tables do — ``op`` covers the
whole ``repro.comm`` collective family (bcast/reduce/allreduce/allgather/
reduce_scatter), not just the paper's broadcast. Two sources combine:

  * the analytic cost models (Eqs. 1-6) with the target Hardware constants —
    always available;
  * an optional *empirical table*, keyed by (n, log2-size bucket), produced by
    the calibration benchmark on real devices and persisted as JSON. Empirical
    entries override the analytic choice inside their bucket (the paper
    "experimentally determines the optimal chunk size").
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import random
from typing import Callable, Iterable, Sequence

from . import cost_model
from .cost_model import Hardware, TPU_V5E

__all__ = ["Decision", "Tuner", "OnlineTuner", "TunerTableError", "default_tuner",
           "OPS", "RAGGED_OPS", "WIRE_FORMATS", "RECORD_DIMENSIONS"]


class TunerTableError(ValueError):
    """A persisted tuner table is unreadable or violates the schema.

    Subclasses ``ValueError`` so existing ``except ValueError`` callers keep
    working; the message always names the offending file (and entry key,
    when one exists) so a corrupt artifact is actionable from the traceback
    alone instead of a bare ``JSONDecodeError``/``KeyError``."""

# collective ops the tuner prices; 'bcast' keeps the legacy table-key format
OPS = ("bcast", "reduce", "allreduce", "allgather", "reduce_scatter",
       "allgatherv", "alltoallv")

# ragged ops: decisions additionally depend on the per-rank size vector
# (skew-bucketed into the empirical key; fed to the skew-aware cost forms)
RAGGED_OPS = ("allgatherv", "alltoallv")


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


@dataclasses.dataclass(frozen=True)
class Decision:
    """A tuning decision for one (op, M, n) point.

    ``overlap_depth`` is the tuned in-flight bucket window for bucket-
    streamed execution (``repro.comm.overlap``); ``None`` means the table
    carries no depth for this point and the overlap planner should fall
    back to the analytic :func:`cost_model.optimal_overlap_depth` sweep.

    ``fused_path`` is the compiled-executor flag: ``True`` pins this point
    to the fori_loop compiled replay (``comm.executors.execute_compiled``),
    ``False`` to the exact unrolled replay, ``None`` (default) defers to
    ``comm.api.apply_plan``'s round-count/zero-waste policy. Calibration can
    record it per point the way it records ``num_chunks``.

    ``exec_path`` generalizes ``fused_path`` to the three-executor routing
    tier: 'inkernel' | 'compiled' | 'unrolled' pins the point to that
    executor (``comm.api._resolve_exec_path``'s middle tier — an explicit
    ``inkernel=`` call-site flag still outranks it); ``None`` defers to
    ``fused_path``/policy. The auto policy never selects inkernel on its
    own: it enters via this tuned field or the explicit flag.

    ``wire_format`` is what the chunks look like on the wire: 'bf16'
    (bit-identical passthrough) | 'fp8' | 'int8' (per-block quantized —
    see :mod:`repro.comm.compress`); ``None`` means passthrough. Like
    ``exec_path`` it can come from the table (an :class:`OnlineTuner`
    exploring formats records it) or be pinned at the call site.
    """

    algo: str
    num_chunks: int
    chunk_bytes: int
    predicted_s: float
    source: str  # 'analytic' | 'empirical' | 'explore'
    overlap_depth: int | None = None
    fused_path: bool | None = None
    exec_path: str | None = None
    wire_format: str | None = None


# algorithms the executor can run, with practical applicability predicates
_CANDIDATES: dict[str, Callable[[int, int], bool]] = {
    "direct": lambda M, n: n <= 4,
    "chain": lambda M, n: True,
    "binomial": lambda M, n: True,
    "knomial": lambda M, n: n >= 8,
    "scatter_allgather": lambda M, n: _is_pow2(n) and n >= 4 and M >= 4 * n,
    "pipelined_chain": lambda M, n: n >= 3 and M >= 4 * n,
    # beyond-paper bidirectional chain (full-duplex ICI)
    "bidir_chain": lambda M, n: n >= 4 and M >= 8 * n,
}

# per-op candidate sets for the non-bcast collectives (repro.comm)
_OP_CANDIDATES: dict[str, dict[str, Callable[[int, int], bool]]] = {
    "reduce": {
        "binomial_reduce": lambda M, n: True,
        "pipelined_reduce_chain": lambda M, n: n >= 3 and M >= 4 * n,
    },
    "allreduce": {
        "reduce_then_bcast": lambda M, n: True,
        "fused_rsb": lambda M, n: n >= 2 and M >= 4 * n,
        "ring_allreduce": lambda M, n: n >= 3 and M >= 4 * n,
    },
    "allgather": {
        "ring_allgather": lambda M, n: True,
        "doubling_allgather": lambda M, n: _is_pow2(n),
    },
    "reduce_scatter": {
        "ring_reduce_scatter": lambda M, n: True,
    },
    "allgatherv": {
        "ring_allgatherv": lambda M, n: True,
        "doubling_allgatherv": lambda M, n: _is_pow2(n),
    },
    "alltoallv": {
        "pairwise_alltoallv": lambda M, n: True,
        "ring_alltoallv": lambda M, n: True,
    },
}


WIRE_FORMATS = ("bf16", "fp8", "int8")
_EXEC_PATHS = ("inkernel", "compiled", "unrolled")


def _dim_overlap_depth(v):
    return max(1, int(v))


def _dim_fused_path(v):
    return bool(v)


def _dim_exec_path(v):
    if v not in _EXEC_PATHS:
        raise ValueError(
            f"exec_path must be 'inkernel'|'compiled'|'unrolled', got {v!r}"
        )
    return str(v)


def _dim_wire_format(v):
    if v not in WIRE_FORMATS:
        raise ValueError(
            f"wire_format must be one of {WIRE_FORMATS}, got {v!r}"
        )
    return str(v)


# the optional per-point decision dimensions Tuner.record accepts via its
# `extras` dict: name -> validator/normalizer. Adding a dimension is ONE
# entry here (plus select()/load() surfacing) — not a signature edit at
# every record call site.
RECORD_DIMENSIONS: dict[str, Callable] = {
    "overlap_depth": _dim_overlap_depth,
    "fused_path": _dim_fused_path,
    "exec_path": _dim_exec_path,
    "wire_format": _dim_wire_format,
}


class Tuner:
    def __init__(
        self,
        hw: Hardware = TPU_V5E,
        *,
        max_chunks: int = 64,
        knomial_k: int = 4,
        allow: Sequence[str] | None = None,
        table: dict | None = None,
    ):
        self.hw = hw
        self.max_chunks = max_chunks
        self.knomial_k = knomial_k
        self.allow = tuple(allow) if allow is not None else tuple(_CANDIDATES)
        # empirical table: {f"{n}:{bucket}": {"algo":..., "num_chunks":...}}
        self.table = dict(table or {})
        # mutation counter backing the memoized fingerprint (record /
        # record_overlap bump it; calibrate mutates through record)
        self._version = 0
        self._fingerprint: tuple[int, str] | None = None

    # -- analytic path ------------------------------------------------------

    def _analytic(self, M: int, n: int, inter_pod: bool) -> Decision:
        B = self.hw.path_bw(inter_pod)
        best: tuple[float, str, int] | None = None
        for algo in self.allow:
            if algo not in _CANDIDATES or not _CANDIDATES[algo](M, n):
                continue
            if algo == "pipelined_chain":
                c_star = cost_model.optimal_chunk_bytes(M, n, self.hw, B)
                num_chunks = max(1, min(self.max_chunks, math.ceil(M / c_star)))
                c_eff = math.ceil(M / num_chunks)
                t = cost_model.t_pipelined_chain(M, n, self.hw, B, C=c_eff)
            elif algo == "bidir_chain":
                hops = (n - 1 + 1) // 2
                c_star = cost_model.optimal_chunk_bytes(M, hops + 1, self.hw, B)
                num_chunks = max(1, min(self.max_chunks, math.ceil(M / c_star)))
                t = cost_model.t_bidir_chain(M, n, self.hw, B, C=math.ceil(M / num_chunks))
            elif algo == "knomial":
                t = cost_model.t_knomial(M, n, self.hw, B, k=self.knomial_k)
                num_chunks = 1
            elif algo == "scatter_allgather":
                t = cost_model.t_scatter_allgather(M, n, self.hw, B)
                num_chunks = n
            else:
                t = cost_model.cost(algo, M, n, self.hw, inter_pod=inter_pod)
                num_chunks = 1
            if best is None or t < best[0]:
                best = (t, algo, num_chunks)
        assert best is not None, "no applicable algorithm (allow list too strict?)"
        t, algo, num_chunks = best
        return Decision(algo, num_chunks, math.ceil(M / num_chunks), t, "analytic")

    def _analytic_op(self, op: str, M: int, n: int, inter_pod: bool) -> Decision:
        """Analytic selection for the non-bcast collectives (repro.comm)."""
        B = self.hw.path_bw(inter_pod)
        best: tuple[float, str, int] | None = None
        for algo, ok in _OP_CANDIDATES[op].items():
            if not ok(M, n):
                continue
            if algo == "pipelined_reduce_chain":
                c_star = cost_model.optimal_chunk_bytes(M, n, self.hw, B)
                num_chunks = max(1, min(self.max_chunks, math.ceil(M / c_star)))
                t = cost_model.t_pipelined_chain(M, n, self.hw, B, C=math.ceil(M / num_chunks))
            elif algo == "reduce_then_bcast":
                # barrier composite: reversed-binomial reduce + the tuned
                # bcast. Priced via select() — NOT _analytic — so empirical
                # bcast entries shape the price exactly as plan_collective
                # builds the inner schedule.
                bcast = self.select(M, n, op="bcast", inter_pod=inter_pod)
                t = cost_model.t_knomial(M, n, self.hw, B, k=2) + bcast.predicted_s
                num_chunks = bcast.num_chunks
            elif algo == "fused_rsb":
                c_star = cost_model.optimal_chunk_bytes_fused(M, n, self.hw, B)
                num_chunks = max(1, min(self.max_chunks, math.ceil(M / c_star)))
                t = cost_model.t_fused_rsb(M, n, self.hw, B, C=math.ceil(M / num_chunks))
            elif algo in ("ring_allreduce", "ring_allgather", "doubling_allgather", "ring_reduce_scatter"):
                t = cost_model.cost(algo, M, n, self.hw, inter_pod=inter_pod)
                num_chunks = n
            else:  # binomial_reduce and any whole-message mirror
                t = cost_model.cost(algo, M, n, self.hw, inter_pod=inter_pod)
                num_chunks = 1
            if best is None or t < best[0]:
                best = (t, algo, num_chunks)
        assert best is not None, f"no applicable {op} algorithm for (M={M}, n={n})"
        t, algo, num_chunks = best
        return Decision(algo, num_chunks, math.ceil(M / num_chunks), t, "analytic")

    def _analytic_ragged(self, op: str, M: int, n: int, inter_pod: bool,
                         sizes: Sequence[int] | None) -> Decision:
        """Analytic selection for the ragged ops. ``sizes`` is the row-count
        vector (per rank for allgatherv; per destination or per (src, dst)
        block for alltoallv); the cost forms are fed byte sizes so the
        max(sizes)-vs-sum(sizes) skew term prices each candidate."""
        B = self.hw.path_bw(inter_pod)
        total = sum(sizes) if sizes else 0
        if total <= 0:
            sizes, total = None, 0
        row_bytes = M / total if total else float(M)
        sizes_bytes = [s * row_bytes for s in sizes] if sizes is not None else None
        best: tuple[float, str] | None = None
        for algo, ok in _OP_CANDIDATES[op].items():
            if not ok(M, n):
                continue
            t = cost_model.cost(algo, M, n, self.hw, inter_pod=inter_pod,
                                sizes=sizes_bytes)
            if best is None or t < best[0]:
                best = (t, algo)
        assert best is not None, f"no applicable {op} algorithm for (M={M}, n={n})"
        t, algo = best
        # the schedule's chunk axis is the ragged row axis: num_chunks is
        # pinned by the size vector (sum of rows), never swept
        num_chunks = max(total, 1)
        return Decision(algo, num_chunks, math.ceil(M / num_chunks), t, "analytic")

    # -- empirical table ----------------------------------------------------

    @staticmethod
    def _bucket(M: int) -> int:
        return max(0, int(math.log2(max(M, 1))))

    @staticmethod
    def _flat_sizes(sizes):
        """Canonical flat tuple: alltoallv callers may hand the n x n nested
        block matrix straight to select/record."""
        if sizes is None:
            return None
        sizes = tuple(sizes)
        if sizes and isinstance(sizes[0], (list, tuple)):
            return tuple(int(v) for row in sizes for v in row)
        return tuple(int(s) for s in sizes)

    @staticmethod
    def _skew_bucket(sizes: Sequence[int] | None) -> int:
        """log2 bucket of max/mean — 0 for uniform (or unknown) sizes, up to
        log2(len) for a single hot rank. Ragged empirical keys carry it so a
        measurement under skew never overrides the uniform bucket."""
        if not sizes or sum(sizes) <= 0:
            return 0
        return max(0, int(round(math.log2(cost_model.skew_ratio(sizes)))))

    def _key(self, M: int, n: int, inter_pod: bool, op: str = "bcast",
             sizes: Sequence[int] | None = None) -> str:
        # bcast keeps the legacy key format so existing saved tables load
        base = f"{n}:{self._bucket(M)}:{int(inter_pod)}"
        if op == "bcast":
            return base
        if op in RAGGED_OPS:
            return f"{op}:{base}:s{self._skew_bucket(sizes)}"
        return f"{op}:{base}"

    def fingerprint(self) -> str:
        """Content hash of everything a tuned decision can depend on: the
        empirical table plus the tuner's configuration. ``record`` /
        ``record_overlap`` / ``calibrate`` change it, so host-side plan
        caches (``repro.comm.plan.plan_cached``) keyed on it can never
        replay a plan built against stale calibration data.

        Memoized on the mutation counter — plan_cached calls this per
        collective per trace, and re-hashing a calibrated table every call
        would reintroduce the O(table) host cost the cache removes. Mutate
        the table through ``record``/``record_overlap`` (not by poking
        ``self.table`` directly) or the memo goes stale."""
        if self._fingerprint is not None and self._fingerprint[0] == self._version:
            return self._fingerprint[1]
        payload = json.dumps(
            {
                "hw": self.hw.name,
                "max_chunks": self.max_chunks,
                "knomial_k": self.knomial_k,
                "allow": list(self.allow),
                "table": self.table,
            },
            sort_keys=True,
            default=repr,
        )
        fp = hashlib.sha1(payload.encode()).hexdigest()
        self._fingerprint = (self._version, fp)
        return fp

    def record(self, M: int, n: int, algo: str, num_chunks: int, measured_s: float, *, inter_pod: bool = False, op: str = "bcast", sizes: Sequence[int] | None = None, extras: dict | None = None) -> None:
        """Record one measured point. Optional decision dimensions ride in
        ``extras`` — one validated dict (:data:`RECORD_DIMENSIONS`:
        ``overlap_depth``/``fused_path``/``exec_path``/``wire_format``)
        instead of one keyword per dimension, so the NEXT dimension is a
        registry entry, not a signature edit at every call site. Unknown
        dimension keys raise :class:`ValueError` eagerly (even when the
        improvement guard would discard the measurement).

        Improvement-only: a slower measurement never displaces a faster
        one at the same key. Each dimension left unset carries over from
        the previous entry ONLY when that entry was for the SAME
        algorithm — a depth/routing/format tuned against another
        algorithm's round profile must not float onto this one.
        """
        extras = dict(extras or {})
        unknown = set(extras) - set(RECORD_DIMENSIONS)
        if unknown:
            raise ValueError(
                f"unknown record dimension(s) {sorted(unknown)}; known "
                f"dimensions are {sorted(RECORD_DIMENSIONS)}"
            )
        extras = {
            k: RECORD_DIMENSIONS[k](v) for k, v in extras.items() if v is not None
        }
        key = self._key(M, n, inter_pod, op, self._flat_sizes(sizes))
        prev = self.table.get(key)
        # depth-only entries (record_overlap before any measurement) carry no
        # measured_s and never block a real measurement from landing
        if prev is None or "measured_s" not in prev or measured_s < prev["measured_s"]:
            entry = {
                "algo": algo,
                "num_chunks": num_chunks,
                "measured_s": measured_s,
            }
            for dim in RECORD_DIMENSIONS:
                val = extras.get(dim)
                if (
                    val is None
                    and prev is not None
                    and dim in prev
                    and prev.get("algo") == algo
                ):
                    # same-algorithm-only carryover (see docstring); a
                    # depth-only entry (no algo key) also drops: it was
                    # tuned against whatever 'auto' picked, which this
                    # measurement may have just displaced
                    val = prev[dim]
                if val is not None:
                    entry[dim] = val
            self.table[key] = entry
            self._version += 1

    def record_overlap(self, M: int, n: int, depth: int, *, inter_pod: bool = False, op: str = "allreduce") -> None:
        """Attach a tuned in-flight bucket window to the (op, M, n) table
        entry alongside ``num_chunks``. With no measured entry at that point
        yet, a DEPTH-ONLY entry is stored — it never masquerades as an
        empirical algorithm decision (``select`` still prices analytically
        and only annotates the Decision with the depth)."""
        key = self._key(M, n, inter_pod, op)
        entry = self.table.setdefault(key, {})
        entry["overlap_depth"] = max(1, int(depth))
        self._version += 1

    def record_stream(self, name: str, *, overlap_depth: int | None = None,
                      priority: int | None = None) -> None:
        """Record a per-stream scheduling decision under a ``stream:<name>``
        key: the in-flight window and/or arbitration priority the
        multi-stream planner (:func:`repro.comm.streams.plan_streams`)
        falls back to when the :class:`StreamSpec` leaves them None. Like
        depth-only entries these are schedule-STRUCTURE choices, not
        timings — they survive ``allow_dryrun`` loads. Idempotent:
        re-recording an unchanged decision does NOT bump the content
        fingerprint (so factory-time recording never churns the plan
        cache step over step)."""
        key = f"stream:{name}"
        entry = dict(self.table.get(key, {}))
        if overlap_depth is not None:
            entry["overlap_depth"] = max(1, int(overlap_depth))
        if priority is not None:
            entry["priority"] = int(priority)
        if not entry or entry == self.table.get(key):
            return
        self.table[key] = entry
        self._version += 1

    def stream_decision(self, name: str) -> dict:
        """The recorded ``stream:<name>`` entry (possibly-empty dict copy
        with ``overlap_depth``/``priority`` keys)."""
        return dict(self.table.get(f"stream:{name}", {}))

    def calibrate(
        self,
        measure: Callable[[str, int, int, int], float],
        sizes: Iterable[int],
        n: int,
        *,
        inter_pod: bool = False,
        op: str = "bcast",
    ) -> None:
        """Populate the table: ``measure(algo, M, n, num_chunks) -> seconds``."""
        if op == "bcast":
            candidates = {a: _CANDIDATES[a] for a in self.allow if a in _CANDIDATES}
        else:
            candidates = _OP_CANDIDATES[op]
        for M in sizes:
            for algo, applicable in candidates.items():
                if not applicable(M, n):
                    continue
                if algo in ("pipelined_chain", "pipelined_reduce_chain", "fused_rsb"):
                    chunk_opts = sorted(
                        {
                            max(1, min(self.max_chunks, math.ceil(M / c)))
                            for c in (M, M // 4, M // 16, M // 64)
                            if c and c > 0
                        }
                    )
                elif algo in ("scatter_allgather", "ring_allreduce", "ring_allgather",
                              "doubling_allgather", "ring_reduce_scatter"):
                    chunk_opts = [n]
                elif algo == "reduce_then_bcast":
                    chunk_opts = [self.select(M, n, inter_pod=inter_pod).num_chunks]
                else:
                    chunk_opts = [1]
                for k in chunk_opts:
                    t = measure(algo, M, n, k)
                    self.record(M, n, algo, k, t, inter_pod=inter_pod, op=op)

    # -- public -------------------------------------------------------------

    def select(self, M: int, n: int, *, op: str = "bcast", inter_pod: bool = False,
               sizes: Sequence[int] | None = None) -> Decision:
        """Tuned decision for one collective: op in :data:`OPS` (default
        'bcast' — the legacy single-op signature is unchanged). Empirical
        table entries are keyed per-op and override the analytic choice.

        Ragged ops (``allgatherv``/``alltoallv``) take the row-count vector
        via ``sizes``: the analytic path prices candidates with the
        skew-aware cost forms and the empirical key carries a skew bucket,
        so a table entry measured under one skew regime never decides for
        another."""
        if op not in OPS:
            raise ValueError(f"unknown collective op {op!r}; have {OPS}")
        if sizes is not None and op not in RAGGED_OPS:
            raise ValueError(f"sizes= is only meaningful for {RAGGED_OPS}, not {op!r}")
        sizes = self._flat_sizes(sizes)
        if n <= 1:
            return Decision("noop", 1, max(M, 1), 0.0, "analytic")
        hit = self.table.get(self._key(M, n, inter_pod, op, sizes))
        depth = hit.get("overlap_depth") if hit is not None else None
        depth = max(1, int(depth)) if depth is not None else None
        if hit is not None and "algo" in hit:
            if op in RAGGED_OPS:
                # the size vector pins the chunk axis: only the algorithm
                # choice (and executor routing) comes from the table
                k = max(sum(sizes), 1) if sizes else 1
            else:
                # Empirical entries are data, not code: a table recorded
                # with a larger max_chunks (or a corrupted num_chunks < 1)
                # must not flow into a Decision the executors can't honor —
                # clamp at hit time, exactly as Tuner.load clamps at read
                # time.
                k = min(max(int(hit["num_chunks"]), 1), self.max_chunks)
            return Decision(
                hit["algo"],
                k,
                math.ceil(M / k),
                float(hit["measured_s"]),
                "empirical",
                overlap_depth=depth,
                fused_path=hit.get("fused_path"),
                exec_path=hit.get("exec_path"),
                wire_format=hit.get("wire_format"),
            )
        # depth-only entries (record_overlap with no measurement yet) keep
        # the analytic pricing and only annotate the decision with the depth
        if op == "bcast":
            dec = self._analytic(M, n, inter_pod)
        elif op in RAGGED_OPS:
            dec = self._analytic_ragged(op, M, n, inter_pod, sizes)
        else:
            dec = self._analytic_op(op, M, n, inter_pod)
        return dataclasses.replace(dec, overlap_depth=depth) if depth is not None else dec

    # -- persistence ---------------------------------------------------------

    def save(self, path: str, *, dryrun: bool = False) -> None:
        """Persist the table. ``dryrun=True`` brands the artifact as
        simulator-derived: :meth:`load` refuses to seed empirical decisions
        from such a table (stand-ins must never read as measurements)."""
        payload = {
            "hw": self.hw.name,
            "max_chunks": self.max_chunks,
            "knomial_k": self.knomial_k,
            "table": self.table,
        }
        if dryrun:
            payload["dryrun"] = True
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)

    @classmethod
    def load(cls, path: str, hw: Hardware = TPU_V5E, *, allow_dryrun: bool = False) -> "Tuner":
        """Load a saved table. Tables branded ``dryrun`` (simulator clocks,
        not device measurements) raise unless ``allow_dryrun=True`` — and
        even then their MEASURED entries are DROPPED after schema
        validation, so a dry-run artifact can be format-checked but a
        simulator clock can never masquerade as empirical tuning data.
        Depth-only entries (``record_overlap``) and per-stream decisions
        (``record_stream``, ``stream:<name>`` keys) survive the drop: an
        overlap window or an arbitration priority is a schedule-structure
        choice, not a timing measurement, so ``plan_overlap`` /
        ``plan_streams`` may consume them from a dryrun artifact
        (``experiments/overlap_depths.json``)."""
        try:
            with open(path) as f:
                payload = json.load(f)
        except json.JSONDecodeError as e:
            raise TunerTableError(
                f"{path}: corrupt or truncated JSON (line {e.lineno} col {e.colno}: "
                f"{e.msg}) — regenerate the table with benchmarks/bench_tuner.py"
            ) from e
        except OSError as e:
            raise TunerTableError(f"{path}: unreadable tuner table: {e}") from e
        if not isinstance(payload, dict):
            raise TunerTableError(
                f"{path}: expected a JSON object with a 'table' field, got "
                f"{type(payload).__name__}"
            )
        table = payload.get("table", {})
        if not isinstance(table, dict):
            raise TunerTableError(f"{path}: 'table' must be an object")
        max_chunks = payload.get("max_chunks", 64)
        # schema gate: a rotten empirical table must fail here, not at trace
        # time deep inside a train step (see repro.comm.tables for the
        # experiments/ artifact loaders with the same policy)
        known = set(cost_model.ALGO_COSTS) | {"noop", "xla_psum", "xla_allgather"}
        for key, entry in table.items():
            if not isinstance(entry, dict):
                raise TunerTableError(f"{path}: entry {key!r} must be an object, got {entry!r}")
            if "overlap_depth" in entry and (
                not isinstance(entry["overlap_depth"], int) or entry["overlap_depth"] < 1
            ):
                raise TunerTableError(f"{path}: entry {key!r} overlap_depth must be a positive int")
            if "fused_path" in entry and not isinstance(entry["fused_path"], bool):
                raise TunerTableError(f"{path}: entry {key!r} fused_path must be a bool")
            if "exec_path" in entry and entry["exec_path"] not in (
                "inkernel", "compiled", "unrolled"
            ):
                raise TunerTableError(
                    f"{path}: entry {key!r} exec_path must be "
                    f"'inkernel'|'compiled'|'unrolled', got {entry['exec_path']!r}"
                )
            if "wire_format" in entry and entry["wire_format"] not in WIRE_FORMATS:
                raise TunerTableError(
                    f"{path}: entry {key!r} wire_format must be one of "
                    f"{WIRE_FORMATS}, got {entry['wire_format']!r}"
                )
            if key.startswith("stream:"):
                # per-stream scheduling decisions (record_stream): structure
                # choices only — never algo/num_chunks/measured_s
                if not set(entry) <= {"overlap_depth", "priority"}:
                    raise TunerTableError(
                        f"{path}: stream entry {key!r} may only carry "
                        f"overlap_depth/priority, got {sorted(entry)}"
                    )
                if "priority" in entry and not isinstance(entry["priority"], int):
                    raise TunerTableError(
                        f"{path}: stream entry {key!r} priority must be an int"
                    )
                continue
            if set(entry) == {"overlap_depth"}:
                continue  # depth-only entry (record_overlap, no measurement)
            if not {"algo", "num_chunks", "measured_s"} <= set(entry):
                raise TunerTableError(
                    f"{path}: entry {key!r} must have algo/num_chunks/measured_s, got {entry!r}"
                )
            if entry["algo"] not in known:
                raise TunerTableError(f"{path}: entry {key!r} has unknown algo {entry['algo']!r}")
            if not isinstance(entry["num_chunks"], int) or entry["num_chunks"] < 1:
                raise TunerTableError(f"{path}: entry {key!r} num_chunks must be a positive int")
            if not isinstance(entry["measured_s"], (int, float)) or not math.isfinite(
                entry["measured_s"]
            ):
                raise TunerTableError(f"{path}: entry {key!r} measured_s must be finite")
            # clamp num_chunks to the table's own max_chunks at read time —
            # the executors honor at most that many chunks (see select())
            entry["num_chunks"] = min(entry["num_chunks"], max_chunks)
        if payload.get("dryrun"):
            if not allow_dryrun:
                raise TunerTableError(
                    f"{path}: table is branded dryrun (simulator stand-ins, not device "
                    "measurements) and cannot seed empirical tuner decisions; pass "
                    "allow_dryrun=True to schema-check it (measured entries are "
                    "dropped, depth-only entries kept)"
                )
            table = {
                k: e for k, e in table.items()
                if set(e) == {"overlap_depth"} or k.startswith("stream:")
            }
        return cls(
            hw,
            max_chunks=max_chunks,
            knomial_k=payload.get("knomial_k", 4),
            table=table,
        )


class OnlineTuner:
    """Epsilon-greedy bandit exploration over (algo x num_chunks x
    wire_format) arms for ONE (op, M, n, inter_pod) point.

    The offline table is a snapshot; a production fleet drifts. This loop
    closes it: :meth:`propose` usually returns the planned decision
    (:meth:`Tuner.select` — the table's best), but with probability
    ``epsilon`` (and always while an arm is untried) it swaps in an
    exploration arm; :meth:`observe` feeds the measured time back through
    :meth:`Tuner.record`, so an exploration that beats the incumbent lands
    in the table, bumps the content fingerprint, and invalidates every
    cached plan for the point (``plan_cached`` keys on the fingerprint —
    observable via ``comm.cache_stats()``). Because ``record`` is
    improvement-only, a bad exploration costs one step and changes
    nothing.

    Untried arms are visited first in deterministic order, so the planted
    best arm of a rigged landscape is found within ``len(arms)`` steps —
    the bounded-convergence property the tests pin.
    """

    def __init__(
        self,
        tuner: Tuner,
        op: str,
        M: int,
        n: int,
        *,
        inter_pod: bool = False,
        arms: Sequence[tuple] | None = None,
        wire_formats: Sequence[str] = WIRE_FORMATS,
        epsilon: float = 0.25,
        seed: int = 0,
    ):
        if op not in OPS:
            raise ValueError(f"unknown collective op {op!r}; have {OPS}")
        if op in RAGGED_OPS:
            raise ValueError(
                f"online exploration over wire formats is scoped to the dense "
                f"ops, not {op!r} (compressed formats reject ragged chunking)"
            )
        self.tuner = tuner
        self.op, self.M, self.n, self.inter_pod = op, int(M), int(n), bool(inter_pod)
        self.epsilon = float(epsilon)
        self._rng = random.Random(seed)
        for fmt in wire_formats:
            _dim_wire_format(fmt)
        self.arms: list[tuple[str, int, str]] = (
            [self._norm_arm(a) for a in arms]
            if arms is not None
            else self._default_arms(tuple(wire_formats))
        )
        if not self.arms:
            raise ValueError(f"no applicable arms for {op!r} at (M={M}, n={n})")
        # per-arm statistics live HERE, not in the table: the table only
        # ever holds the best decision, the bandit needs every observation
        self._pulls = {arm: 0 for arm in self.arms}
        self._total_s = {arm: 0.0 for arm in self.arms}

    def _norm_arm(self, arm) -> tuple[str, int, str]:
        algo, num_chunks, fmt = arm
        return (str(algo), self._arm_chunks(algo) if num_chunks is None
                else int(num_chunks), _dim_wire_format(fmt))

    def _arm_chunks(self, algo: str) -> int:
        """Analytic chunk count for an arm (same per-algo logic as
        :meth:`Tuner.calibrate`'s sweep, collapsed to the model optimum)."""
        M, n, t = self.M, self.n, self.tuner
        B = t.hw.path_bw(self.inter_pod)
        if algo in ("pipelined_chain", "pipelined_reduce_chain"):
            c = cost_model.optimal_chunk_bytes(M, n, t.hw, B)
        elif algo == "bidir_chain":
            c = cost_model.optimal_chunk_bytes(M, (n - 1 + 1) // 2 + 1, t.hw, B)
        elif algo == "fused_rsb":
            c = cost_model.optimal_chunk_bytes_fused(M, n, t.hw, B)
        elif algo in ("scatter_allgather", "ring_allreduce", "ring_allgather",
                      "doubling_allgather", "ring_reduce_scatter"):
            return n
        else:
            return 1
        return max(1, min(t.max_chunks, math.ceil(M / c)))

    def _default_arms(self, wire_formats: tuple[str, ...]) -> list:
        if self.op == "bcast":
            cands = {a: _CANDIDATES[a] for a in self.tuner.allow if a in _CANDIDATES}
        else:
            cands = _OP_CANDIDATES[self.op]
        return [
            (algo, self._arm_chunks(algo), fmt)
            for algo in sorted(cands)
            if cands[algo](self.M, self.n)
            for fmt in wire_formats
        ]

    def _decision(self, arm: tuple[str, int, str]) -> Decision:
        algo, k, fmt = arm
        predicted = cost_model.cost_wire(
            algo, self.M, self.n, self.tuner.hw,
            wire_format=fmt, inter_pod=self.inter_pod,
            **({"C": float(math.ceil(self.M / k))} if algo in (
                "pipelined_chain", "bidir_chain", "pipelined_reduce_chain",
                "fused_rsb") else {}),
        ) if algo in cost_model.ALGO_COSTS else float("nan")
        return Decision(algo, k, math.ceil(self.M / max(1, k)), predicted,
                        "explore", wire_format=fmt)

    def propose(self) -> Decision:
        """The decision to run THIS step: an untried arm first (deterministic
        order), then an epsilon-random arm, else the planned decision."""
        for arm in self.arms:
            if self._pulls[arm] == 0:
                return self._decision(arm)
        if self._rng.random() < self.epsilon:
            return self._decision(self._rng.choice(self.arms))
        return self.tuner.select(self.M, self.n, op=self.op,
                                 inter_pod=self.inter_pod)

    def observe(self, decision: Decision, measured_s: float) -> None:
        """Feed one measured step back: bandit statistics here, the
        improvement-only table update (fingerprint bump on improvement)
        through :meth:`Tuner.record`."""
        arm = (decision.algo, int(decision.num_chunks),
               decision.wire_format or "bf16")
        if arm in self._pulls:
            self._pulls[arm] += 1
            self._total_s[arm] += float(measured_s)
        self.tuner.record(
            self.M, self.n, decision.algo, decision.num_chunks,
            float(measured_s), inter_pod=self.inter_pod, op=self.op,
            extras={"wire_format": decision.wire_format},
        )

    def step(self, measure: Callable[[Decision], float]) -> tuple[Decision, float]:
        """One explore-measure-record cycle; returns (decision, seconds)."""
        dec = self.propose()
        t = float(measure(dec))
        self.observe(dec, t)
        return dec, t

    def best_arm(self) -> tuple[str, int, str] | None:
        """Lowest mean measured time among tried arms (None before any)."""
        tried = [a for a in self.arms if self._pulls[a] > 0]
        if not tried:
            return None
        return min(tried, key=lambda a: self._total_s[a] / self._pulls[a])


_DEFAULT: Tuner | None = None


def default_tuner() -> Tuner:
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Tuner(TPU_V5E)
    return _DEFAULT
