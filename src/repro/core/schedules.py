"""Static broadcast/reduce schedule generation.

A *schedule* is the algorithm-level description of a collective: an ordered
tuple of rounds, where every transfer inside one round happens concurrently
(disjoint destinations -> one ``lax.ppermute`` per round) and rounds are
serialized by data dependency.

The same schedule objects drive three consumers:

  * ``core.algorithms``   — the shard_map/ppermute executor (JAX, on device)
  * ``core.simulator``    — a pure-numpy step simulator used for property tests
  * ``core.cost_model``   — round-count / bytes-on-wire accounting

This mirrors the paper's framing (Sec. III/IV): the algorithm is a schedule of
point-to-point sends; the runtime then maps it onto the fabric.

Chunks: the message is viewed as ``num_chunks`` equal chunks. Whole-message
algorithms use ``num_chunks == 1``. A transfer moves the contiguous chunk
range ``[chunk_start, chunk_start + chunk_count)``.

Ragged collectives (allgatherv/alltoallv) reuse the same chunk axis as a
*row* axis: ``Schedule.sizes`` records the per-rank (or per-block) row
counts, ``num_chunks == sum(sizes)``, and transfers move variable-height
contiguous row ranges. Nothing else in the IR changes — the lowering's
per-rank ``[lo, hi)`` windows already express a ragged tail as a narrower
row window of a fixed-height block.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable, Tuple

import numpy as np

__all__ = [
    "Transfer",
    "Round",
    "Schedule",
    "LaneClass",
    "LoweredSchedule",
    "KernelTables",
    "lane_partition",
    "lower_schedule",
    "pack_tables",
    "direct",
    "chain",
    "pipelined_chain",
    "binomial",
    "knomial",
    "scatter_allgather",
    "binomial_reduce",
    "ALGORITHMS",
    "build",
]


@dataclasses.dataclass(frozen=True)
class Transfer:
    """One point-to-point send of a contiguous chunk range.

    ``combine=True`` marks a reducing transfer: the receiver accumulates the
    payload into its buffer (sum) instead of overwriting. This is the only
    IR difference between broadcast-family and reduce-family collectives —
    everything else (rounds, chunking, lanes) is shared.
    """

    src: int
    dst: int
    chunk_start: int = 0
    chunk_count: int = 1
    combine: bool = False

    def chunks(self) -> range:
        return range(self.chunk_start, self.chunk_start + self.chunk_count)


@dataclasses.dataclass(frozen=True)
class Round:
    """Transfers that are issued concurrently (one ppermute per lane).

    A destination may appear more than once in a round only if the incoming
    chunk ranges are disjoint (e.g. the fused allreduce chain, where an
    interior rank receives a reduce chunk and a bcast chunk concurrently on
    its two full-duplex links).

    Transfers in one round may move ranges of different heights (ragged
    collectives do); :func:`lane_partition` keeps each ppermute lane
    uniform-height so the executors' static block slices stay valid."""

    transfers: Tuple[Transfer, ...]

    def __post_init__(self):
        by_dst: dict[int, list[Transfer]] = {}
        for t in self.transfers:
            by_dst.setdefault(t.dst, []).append(t)
        for dst, ts in by_dst.items():
            if len(ts) > 1:
                seen: set[int] = set()
                for t in ts:
                    rng = set(t.chunks())
                    if seen & rng:
                        raise ValueError(
                            f"overlapping chunk ranges for destination {dst}: {ts}"
                        )
                    seen |= rng
        if any(t.chunk_count <= 0 for t in self.transfers):
            raise ValueError("transfers must move a non-empty chunk range")

    @property
    def chunk_count(self) -> int:
        return self.transfers[0].chunk_count if self.transfers else 0


@dataclasses.dataclass(frozen=True)
class Schedule:
    """A full collective schedule."""

    name: str
    n: int
    root: int
    num_chunks: int
    rounds: Tuple[Round, ...]
    # collective op this schedule implements: 'bcast' | 'reduce' |
    # 'allreduce' | 'allgather' | 'reduce_scatter' | 'allgatherv' |
    # 'alltoallv'. Reduce-family transfers carry combine=True (accumulate at
    # dst); see repro.comm.schedules for the non-bcast builders.
    kind: str = "bcast"
    # Ragged collectives: per-rank (allgatherv, len n) or per-(src, dst)
    # block (alltoallv, len n*n row-major) row counts. When set,
    # ``num_chunks == sum(sizes)`` and the chunk axis is the row axis of the
    # ragged payload. ``None`` for uniform collectives.
    sizes: Tuple[int, ...] | None = None

    @property
    def num_rounds(self) -> int:
        return len(self.rounds)

    def wire_chunks(self) -> int:
        """Total chunk-transfers on the wire (bandwidth accounting)."""
        return sum(t.chunk_count for r in self.rounds for t in r.transfers)

    def validate_ranks(self) -> None:
        if self.sizes is not None:
            if len(self.sizes) not in (self.n, self.n * self.n):
                raise ValueError(
                    f"sizes must have n or n*n entries, got {len(self.sizes)}"
                )
            if any(s < 0 for s in self.sizes):
                raise ValueError(f"sizes must be non-negative: {self.sizes}")
            if sum(self.sizes) != self.num_chunks:
                raise ValueError(
                    f"sum(sizes)={sum(self.sizes)} != num_chunks={self.num_chunks}"
                )
        for r in self.rounds:
            for t in r.transfers:
                if not (0 <= t.src < self.n and 0 <= t.dst < self.n):
                    raise ValueError(f"rank out of range in {t} (n={self.n})")
                if t.src == t.dst:
                    raise ValueError(f"self-send in {t}")
                if not (0 <= t.chunk_start and t.chunk_start + t.chunk_count <= self.num_chunks):
                    raise ValueError(f"chunk range out of bounds in {t}")


def _rot(rank: int, root: int, n: int) -> int:
    """Relabel logical rank (root-relative) to physical rank."""
    return (rank + root) % n


# ---------------------------------------------------------------------------
# Host-side lowering: schedule -> dense per-round index tables
#
# The trace-level executor (comm.executors.execute_collective) unrolls every
# round into HLO, so program size grows with the round count. Lowering turns
# a schedule into a handful of *lane classes* — each a static ppermute
# permutation plus dense (num_rounds, n) numpy index tables — which the
# compiled executor (comm.executors.execute_compiled) replays with ONE
# lax.fori_loop over rounds: HLO size is O(num_classes), independent of
# num_chunks and round count. All of this runs once per schedule on the host
# (cached), never at trace time.
# ---------------------------------------------------------------------------


def lane_partition(transfers) -> list[list[Transfer]]:
    """Partition a round's transfers into ppermute lanes: within one lane
    each rank is a source at most once AND a destination at most once, and
    all transfers share the combine flag and block height (so the executor's
    static-shape slice per lane stays valid for ragged rounds). Multi-lane
    rounds (bidir chain, fused_rsb) run on disjoint full-duplex links
    concurrently on TPU.

    Greedy first-fit is O(T^2) in the round's transfer count — which is why
    it lives in the host-side lowering (computed once per schedule via
    :func:`lower_schedule`), not at trace time."""
    lanes: list[list[Transfer]] = []
    for t in transfers:
        for lane in lanes:
            if (
                lane[0].combine == t.combine
                and lane[0].chunk_count == t.chunk_count
                and all(t.src != u.src and t.dst != u.dst for u in lane)
            ):
                lane.append(t)
                break
        else:
            lanes.append([t])
    return lanes


@dataclasses.dataclass(frozen=True, eq=False)
class LaneClass:
    """One static ppermute 'wire' of the compiled executor.

    ``perm`` is the union of every (src, dst) pair the class ever carries —
    a valid permutation fragment (each rank a source at most once, a
    destination at most once) held CONSTANT across rounds; rounds where a
    pair is inactive send a clipped garbage block that the destination's
    ``lo == hi`` window masks away (exactly the fill/drain discipline of the
    old hand-written fori_loop executors). ``combine`` is PER ROUND (a class
    carries one lane per round, and that lane's combine flag may differ
    between rounds) — this is what lets ring_allreduce's reduce-scatter and
    allgather phases share one fully-active class instead of two
    half-idle ones. The dense tables are indexed ``[round, rank]``:

      * ``send_start`` — first buffer row the rank slices into its outgoing
        block (clipped to ``num_chunks - block``);
      * ``recv_start`` — first buffer row the incoming block lands on
        (same transfer's ``chunk_start``, identically clipped, so the row
        alignment inside the block is shared by both ends);
      * ``lo`` / ``hi`` — the half-open row window of the block that is
        actually valid at the destination this round (``lo == hi`` when the
        rank is not a destination).
    """

    perm: Tuple[Tuple[int, int], ...]
    combine: np.ndarray             # (num_rounds,) int32: 1 = accumulate
    block: int                      # block height (max chunk_count it carries)
    send_start: np.ndarray          # (num_rounds, n) int32
    recv_start: np.ndarray          # (num_rounds, n) int32
    lo: np.ndarray                  # (num_rounds, n) int32
    hi: np.ndarray                  # (num_rounds, n) int32


@dataclasses.dataclass(frozen=True, eq=False)
class LoweredSchedule:
    """Dense round tables + hoisted lane partition for one schedule."""

    name: str
    kind: str
    n: int
    num_chunks: int
    classes: Tuple[LaneClass, ...]
    # lane partition per (non-empty) round, in schedule order — the unrolled
    # executor replays these; computed once here, never at trace time
    round_lanes: Tuple[Tuple[Tuple[Transfer, ...], ...], ...]

    @property
    def num_rounds(self) -> int:
        return len(self.round_lanes)

    @property
    def num_classes(self) -> int:
        return len(self.classes)

    def lane_counts(self) -> Tuple[int, ...]:
        """Lanes per round (pinned by the lane-partition unit tests)."""
        return tuple(len(lanes) for lanes in self.round_lanes)

    def wire_chunks_exact(self) -> int:
        """Chunk-transfers the exact (unrolled) replay puts on the wire."""
        return sum(
            t.chunk_count for lanes in self.round_lanes for lane in lanes for t in lane
        )

    def wire_chunks_compiled(self) -> int:
        """Chunk-transfers the compiled replay puts on the wire: every class
        sends its full block over its full permutation every round (inactive
        pairs carry masked garbage — the compiled executor trades fill/drain
        wire for O(1) HLO)."""
        return self.num_rounds * sum(len(c.perm) * c.block for c in self.classes)

    @property
    def zero_waste(self) -> bool:
        """True when the compiled replay sends exactly the schedule's bytes
        (fully-active rounds, e.g. the ring family) — compiled then
        dominates the unrolled executor outright."""
        return self.wire_chunks_compiled() == self.wire_chunks_exact()


@functools.lru_cache(maxsize=256)
def lower_schedule(schedule: Schedule) -> LoweredSchedule:
    """Lower a schedule to dense per-round index tables (host-side, cached).

    Greedy class assignment: walk rounds in order; each lane joins the
    first class whose permutation it can extend without conflict (a rank
    already sending must keep its destination; a new pair must not reuse an
    occupied destination), one lane per class per round. The combine flag is
    recorded per round, not per class, so a class may carry combining rounds
    and overwriting rounds (ring_allreduce: one class for both phases).
    Chain/ring schedules collapse to 1-2 classes regardless of chunk count;
    tree schedules get O(log n) classes (one per doubling level).
    """
    K = max(schedule.num_chunks, 1)
    n = schedule.n
    rounds = [r for r in schedule.rounds if r.transfers]
    round_lanes = tuple(
        tuple(tuple(lane) for lane in lane_partition(r.transfers)) for r in rounds
    )
    T = len(rounds)

    classes: list[dict] = []
    for ri, lanes in enumerate(round_lanes):
        used: set[int] = set()
        for lane in lanes:
            placed = None
            for ci, cl in enumerate(classes):
                if ci in used:
                    continue
                ok = True
                for t in lane:
                    d = cl["perm"].get(t.src)
                    if (d is not None and d != t.dst) or (d is None and t.dst in cl["dsts"]):
                        ok = False
                        break
                if ok:
                    placed = ci
                    break
            if placed is None:
                classes.append({"perm": {}, "dsts": set(), "entries": []})
                placed = len(classes) - 1
            cl = classes[placed]
            for t in lane:
                if t.src not in cl["perm"]:
                    cl["perm"][t.src] = t.dst
                    cl["dsts"].add(t.dst)
            cl["entries"].append((ri, lane))
            used.add(placed)

    out: list[LaneClass] = []
    for cl in classes:
        block = max(t.chunk_count for _ri, lane in cl["entries"] for t in lane)
        combine = np.zeros((T,), np.int32)
        send = np.zeros((T, n), np.int32)
        recv = np.zeros((T, n), np.int32)
        lo = np.zeros((T, n), np.int32)
        hi = np.zeros((T, n), np.int32)
        clip = max(K - block, 0)
        for ri, lane in cl["entries"]:
            combine[ri] = int(lane[0].combine)
            for t in lane:
                s = min(t.chunk_start, clip)
                send[ri, t.src] = s
                recv[ri, t.dst] = s
                off = t.chunk_start - s
                lo[ri, t.dst] = off
                hi[ri, t.dst] = off + t.chunk_count
        perm = tuple(sorted(cl["perm"].items()))
        out.append(LaneClass(perm, combine, block, send, recv, lo, hi))

    return LoweredSchedule(
        schedule.name, schedule.kind, n, K, tuple(out), round_lanes
    )


@dataclasses.dataclass(frozen=True, eq=False)
class KernelTables:
    """Kernel-ready stacked layout of a lowering's per-class round tables.

    The in-kernel executor (``repro.kernels.inkernel_collective``) replays a
    whole schedule inside ONE Pallas launch, so it needs every class's tables
    as dense operands it can index absolutely from the kernel body:

      * ``send_start``/``recv_start``/``lo``/``hi`` — int32
        ``(num_classes, num_rounds, n)``, the per-class ``LaneClass`` tables
        stacked on a leading class axis (scalar-prefetch operands on TPU);
      * ``combine`` — int32 ``(num_classes, num_rounds)``;
      * ``perms``/``blocks`` — the static per-class permutation fragments
        and block heights, which become kernel *structure* (python loops),
        not data.

    Classes with ``block == 0`` never occur (lowering drops empty rounds and
    every transfer moves >= 1 chunk), but a ragged schedule may address
    zero-height windows through ``lo == hi`` — the kernel's row mask handles
    those identically to the numpy simulator's skip.
    """

    n: int
    num_chunks: int
    perms: Tuple[Tuple[Tuple[int, int], ...], ...]
    blocks: Tuple[int, ...]
    send_start: np.ndarray          # (num_classes, num_rounds, n) int32
    recv_start: np.ndarray          # (num_classes, num_rounds, n) int32
    lo: np.ndarray                  # (num_classes, num_rounds, n) int32
    hi: np.ndarray                  # (num_classes, num_rounds, n) int32
    combine: np.ndarray             # (num_classes, num_rounds) int32

    @property
    def num_classes(self) -> int:
        return len(self.blocks)

    @property
    def num_rounds(self) -> int:
        return self.send_start.shape[1]


@functools.lru_cache(maxsize=256)
def pack_tables(lowered: LoweredSchedule) -> KernelTables:
    """Stack a lowering's per-class tables into the kernel-resident layout.

    Cached on the ``LoweredSchedule`` identity (``lower_schedule`` is itself
    cached, so repeated plans share one packing)."""
    n, T = lowered.n, lowered.num_rounds
    cs = lowered.classes
    if not cs:
        z3 = np.zeros((0, T, n), np.int32)
        return KernelTables(
            n, lowered.num_chunks, (), (), z3, z3, z3, z3,
            np.zeros((0, T), np.int32),
        )
    return KernelTables(
        n,
        lowered.num_chunks,
        tuple(c.perm for c in cs),
        tuple(c.block for c in cs),
        np.ascontiguousarray(np.stack([c.send_start for c in cs]), np.int32),
        np.ascontiguousarray(np.stack([c.recv_start for c in cs]), np.int32),
        np.ascontiguousarray(np.stack([c.lo for c in cs]), np.int32),
        np.ascontiguousarray(np.stack([c.hi for c in cs]), np.int32),
        np.ascontiguousarray(np.stack([c.combine for c in cs]), np.int32),
    )


# ---------------------------------------------------------------------------
# Fundamental algorithms (paper Sec. III-A)
# ---------------------------------------------------------------------------


def direct(n: int, root: int = 0) -> Schedule:
    """Eq. 1 — serialized loop of root -> i sends of the whole message."""
    rounds = tuple(
        Round((Transfer(root, _rot(i, root, n)),)) for i in range(1, n)
    )
    return Schedule("direct", n, root, 1, rounds)


def chain(n: int, root: int = 0) -> Schedule:
    """Eq. 2 — chain without wrap-around; whole message per hop."""
    rounds = tuple(
        Round((Transfer(_rot(i - 1, root, n), _rot(i, root, n)),))
        for i in range(1, n)
    )
    return Schedule("chain", n, root, 1, rounds)


def pipelined_chain(n: int, root: int = 0, num_chunks: int = 8) -> Schedule:
    """Eq. 5 — THE paper's proposed design.

    The root pushes chunks down the chain; every interior process forwards
    chunk ``c`` one round after receiving it. Round ``s`` carries chunk
    ``s - j`` over edge ``j -> j+1`` (logical ranks). Total rounds:
    ``num_chunks + n - 2``.
    """
    if n == 1:
        return Schedule("pipelined_chain", n, root, num_chunks, ())
    rounds = []
    for s in range(num_chunks + n - 2):
        transfers = []
        for j in range(n - 1):  # edge j -> j+1
            c = s - j
            if 0 <= c < num_chunks:
                transfers.append(
                    Transfer(_rot(j, root, n), _rot(j + 1, root, n), c, 1)
                )
        if transfers:
            rounds.append(Round(tuple(transfers)))
    return Schedule("pipelined_chain", n, root, num_chunks, tuple(rounds))


def knomial(n: int, root: int = 0, k: int = 2) -> Schedule:
    """Eq. 3 — k-nomial tree. ``k == 2`` is the binomial tree.

    Logical round ``t`` (t = 0.. ceil(log_k n)-1): every rank that already has
    the data (logical rank < k**t) sends to ranks ``r + j * k**t`` for
    j = 1..k-1. The j-loop is serialized into sub-rounds (a parent has one
    egress port), matching MPI implementations; for k == 2 this coincides
    with the paper's Eq. 3 round count exactly.
    """
    if k < 2:
        raise ValueError("k must be >= 2")
    rounds = []
    span = 1  # k**t
    while span < n:
        for j in range(1, k):
            transfers = []
            for r in range(span):
                dst = r + j * span
                if dst < n:
                    transfers.append(Transfer(_rot(r, root, n), _rot(dst, root, n)))
            if transfers:
                rounds.append(Round(tuple(transfers)))
        span *= k
    return Schedule(f"knomial{k}", n, root, 1, tuple(rounds))


def binomial(n: int, root: int = 0) -> Schedule:
    sched = knomial(n, root, k=2)
    return dataclasses.replace(sched, name="binomial")


def _is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def scatter_allgather(n: int, root: int = 0) -> Schedule:
    """Eq. 4 — binomial-tree scatter + ring allgather (bandwidth-optimal).

    Requires power-of-two ``n`` (as the paper's model assumes). The message is
    split into ``n`` chunks; after the scatter, logical rank ``r`` owns chunk
    ``r``; the ring then circulates chunks for ``n - 1`` rounds.
    """
    if not _is_pow2(n):
        raise ValueError(f"scatter_allgather requires power-of-two n, got {n}")
    if n == 1:
        return Schedule("scatter_allgather", n, root, 1, ())
    rounds = []
    # Phase 1: recursive-halving scatter. Owner of block [lo, lo+size) sends
    # the upper half to rank lo + size/2.
    size = n
    while size > 1:
        half = size // 2
        transfers = []
        for lo in range(0, n, size):
            transfers.append(
                Transfer(_rot(lo, root, n), _rot(lo + half, root, n), lo + half, half)
            )
        rounds.append(Round(tuple(transfers)))
        size = half
    # Phase 2: ring allgather. Round s: logical rank r sends chunk (r - s) mod n
    # to (r + 1) mod n.
    for s in range(n - 1):
        transfers = []
        for r in range(n):
            c = (r - s) % n
            transfers.append(Transfer(_rot(r, root, n), _rot((r + 1) % n, root, n), c, 1))
        rounds.append(Round(tuple(transfers)))
    return Schedule("scatter_allgather", n, root, n, tuple(rounds))


def bidirectional_chain(n: int, root: int = 0, num_chunks: int = 8) -> Schedule:
    """BEYOND-PAPER: bidirectional pipelined chain.

    TPU ICI links are full-duplex: the root streams ALL chunks down a
    rightward chain serving logical ranks 1..ceil((n-1)/2) and, concurrently,
    down a leftward chain serving the rest. Each chunk reaches the farthest
    rank in ~(n-1)/2 hops instead of n-1, so the round count drops from
    (M/C + n - 2) to (M/C + ceil((n-1)/2) - 1) — it halves the latency term
    of Eq. 5 while keeping the bandwidth term (both directions carry the
    full message, on disjoint links). The executor issues the two directions
    as separate ppermute lanes within one round.
    """
    if n <= 2:
        return dataclasses.replace(
            pipelined_chain(n, root, num_chunks), name="bidir_chain"
        )
    n_right = (n - 1 + 1) // 2          # logical ranks 1..n_right
    n_left = n - 1 - n_right            # logical ranks n-1 .. n_right+1
    rounds = []
    s = 0
    while True:
        transfers = []
        for j in range(n_right):        # right edge j -> j+1, chunk s-j
            c = s - j
            if 0 <= c < num_chunks:
                transfers.append(Transfer(_rot(j, root, n), _rot(j + 1, root, n), c, 1))
        for j in range(n_left):         # left edge -j -> -(j+1), chunk s-j
            c = s - j
            if 0 <= c < num_chunks:
                transfers.append(
                    Transfer(_rot(-j, root, n), _rot(-(j + 1), root, n), c, 1)
                )
        if not transfers:
            break
        rounds.append(Round(tuple(transfers)))
        s += 1
    return Schedule("bidir_chain", n, root, num_chunks, tuple(rounds))


# ---------------------------------------------------------------------------
# Reduce (paper Sec. VII future work — we provide it for the bcast-sync
# trainer: reduce-to-root is the mirror image of the binomial bcast)
# ---------------------------------------------------------------------------


def binomial_reduce(n: int, root: int = 0) -> Schedule:
    """Reduce-to-root: the binomial bcast schedule reversed, with src/dst
    swapped. Transfers in a round are combined (summed) into the destination."""
    fwd = binomial(n, root)
    rounds = tuple(
        Round(tuple(
            Transfer(t.dst, t.src, t.chunk_start, t.chunk_count, combine=True)
            for t in r.transfers
        ))
        for r in reversed(fwd.rounds)
    )
    return Schedule("binomial_reduce", n, root, 1, rounds, kind="reduce")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ALGORITHMS: dict[str, Callable[..., Schedule]] = {
    "direct": direct,
    "chain": chain,
    "pipelined_chain": pipelined_chain,
    "bidir_chain": bidirectional_chain,
    "binomial": binomial,
    "knomial": knomial,
    "scatter_allgather": scatter_allgather,
}


def build(name: str, n: int, root: int = 0, **kw) -> Schedule:
    if name not in ALGORITHMS:
        raise KeyError(f"unknown algorithm {name!r}; have {sorted(ALGORITHMS)}")
    sched = ALGORITHMS[name](n, root, **kw)
    sched.validate_ranks()
    return sched
