"""Pure-numpy schedule simulator.

Executes a :class:`core.schedules.Schedule` on host arrays, enforcing the
causality invariant the real fabric enforces: a rank may only send chunks it
already owns at the *start* of the round. Used by the hypothesis property
tests and by the cost model's round-accurate timing estimate.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from .schedules import Schedule


class CausalityError(AssertionError):
    pass


def simulate_bcast(schedule: Schedule, data: Sequence[np.ndarray]) -> list[np.ndarray]:
    """Run a bcast schedule over per-rank buffers.

    ``data[r]`` is rank r's initial buffer with shape (num_chunks, chunk).
    Returns final per-rank buffers. Raises :class:`CausalityError` if any
    rank sends a chunk before owning it.
    """
    n, root = schedule.n, schedule.root
    bufs = [np.array(d, copy=True) for d in data]
    owned = [set() for _ in range(n)]
    owned[root] = set(range(schedule.num_chunks))
    for ridx, rnd in enumerate(schedule.rounds):
        # snapshot ownership: all transfers in a round are concurrent.
        pre = [set(o) for o in owned]
        staged = []
        for t in rnd.transfers:
            for c in t.chunks():
                if c not in pre[t.src]:
                    raise CausalityError(
                        f"{schedule.name}: round {ridx}: rank {t.src} sends chunk "
                        f"{c} before owning it ({t})"
                    )
            staged.append((t, bufs[t.src][t.chunk_start : t.chunk_start + t.chunk_count].copy()))
        for t, payload in staged:
            bufs[t.dst][t.chunk_start : t.chunk_start + t.chunk_count] = payload
            owned[t.dst].update(t.chunks())
    return bufs


def simulate_reduce(schedule: Schedule, data: Sequence[np.ndarray]) -> list[np.ndarray]:
    """Run a reduce-to-root schedule (sum combiner).

    Every rank starts owning its own contribution; a transfer accumulates the
    sender's current partial sum into the receiver. At the end, ``root``
    holds sum(data).
    """
    if schedule.kind != "reduce":
        raise ValueError("schedule is not a reduce schedule")
    bufs = [np.array(d, copy=True) for d in data]
    alive = [True] * schedule.n  # a rank's partial may be consumed only once
    for ridx, rnd in enumerate(schedule.rounds):
        staged = []
        for t in rnd.transfers:
            if not alive[t.src]:
                raise CausalityError(
                    f"{schedule.name}: round {ridx}: rank {t.src} already merged ({t})"
                )
            staged.append((t, bufs[t.src].copy()))
        for t, payload in staged:
            bufs[t.dst] = bufs[t.dst] + payload
            alive[t.src] = False
    return bufs


def simulate_collective(
    schedule: Schedule,
    data: Sequence[np.ndarray],
    faults=None,
    report: dict | None = None,
) -> list[np.ndarray]:
    """Value-level replay of ANY schedule (bcast/reduce/allreduce/allgather/
    reduce_scatter): every transfer reads the sender's buffer as it was at
    the *start* of the round (concurrent semantics), and either overwrites
    the destination chunk range or — for ``combine=True`` transfers —
    accumulates into it.

    ``faults`` (a :class:`comm.faults.FaultSpec`, duck-typed) replays the
    same schedule under injected faults. Dead ranks raise
    ``DeadRankError`` before any round runs; transient drops are link-layer
    retransmits of the round-start payload, so the final values are
    bit-identical to the fault-free replay unless the retry budget is
    exceeded (``TransientDropError``). Slow links and stalls are clock-only
    faults — :func:`timed_rounds` accounts for them; values never change.
    ``report`` (optional dict) is filled with retry/stall counters.

    Correctness (including causality and double-counting) is checked by the
    property tests comparing the result against numpy references on random
    data; garbage sent too early or a contribution summed twice cannot
    produce the reference value.
    """
    if faults is not None:
        faults.check_alive(schedule)
    bufs = [np.array(d, copy=True) for d in data]
    retries = 0
    for ridx, rnd in enumerate(schedule.rounds):
        staged = [
            (t, bufs[t.src][t.chunk_start : t.chunk_start + t.chunk_count].copy())
            for t in rnd.transfers
        ]
        if faults is not None and faults.drop_prob > 0.0:
            for t, _payload in staged:
                # retransmits of the round-start snapshot: value-identical,
                # but a streak over budget is a typed failure.
                retries += faults.retries(ridx, t.src, t.dst)
        for t, payload in staged:
            sl = slice(t.chunk_start, t.chunk_start + t.chunk_count)
            if t.combine:
                bufs[t.dst][sl] = bufs[t.dst][sl] + payload
            else:
                bufs[t.dst][sl] = payload
    if report is not None:
        report["retries"] = retries
        report["stalled_rounds"] = (
            len([r for r in faults.stalled_rounds if r < len(schedule.rounds)])
            if faults is not None
            else 0
        )
    return bufs


def simulate_lowered(
    lowered, data: Sequence[np.ndarray], faults=None, report: dict | None = None
) -> list[np.ndarray]:
    """Value-level numpy replay of a :class:`core.schedules.LoweredSchedule`
    — the EXACT algorithm the compiled device executor runs: for every round,
    every lane class slices each source's block (clipped start), 'permutes'
    it, and applies only the ``[lo, hi)`` row window at each destination
    (overwrite or accumulate). Classes apply sequentially within a round,
    with sends snapshotted per class, mirroring
    ``comm.executors.execute_compiled`` operation for operation.

    ``faults``/``report`` follow :func:`simulate_collective`: the round
    structure is compiled into dense lane tables, so the dead-rank check
    runs over every lane's (src, dst) pairs and drop streaks are keyed by
    (round, src, dst, lane-class index) — deterministic but independent of
    the schedule-IR keying.

    The lowering parity tests assert this replay is bit-identical to
    :func:`simulate_collective` on the original schedule.
    """
    if faults is not None:
        faults.check_alive_pairs(
            {(src, dst) for cls in lowered.classes for src, dst in cls.perm},
            context=lowered.name,
        )
    bufs = [np.array(d, copy=True) for d in data]
    retries = 0
    for s in range(lowered.num_rounds):
        for ci, cls in enumerate(lowered.classes):
            blocks = {
                dst: bufs[src][cls.send_start[s, src]: cls.send_start[s, src] + cls.block].copy()
                for src, dst in cls.perm
            }
            if faults is not None and faults.drop_prob > 0.0:
                for src, dst in cls.perm:
                    if int(cls.hi[s, dst]) > int(cls.lo[s, dst]):
                        retries += faults.retries(s, src, dst, tag=ci)
            for _src, dst in cls.perm:
                lo, hi = int(cls.lo[s, dst]), int(cls.hi[s, dst])
                if hi <= lo:
                    continue
                r0 = int(cls.recv_start[s, dst])
                if cls.combine[s]:
                    bufs[dst][r0 + lo: r0 + hi] += blocks[dst][lo:hi]
                else:
                    bufs[dst][r0 + lo: r0 + hi] = blocks[dst][lo:hi]
    if report is not None:
        report["retries"] = retries
        report["stalled_rounds"] = (
            len([r for r in faults.stalled_rounds if r < lowered.num_rounds])
            if faults is not None
            else 0
        )
    return bufs


def check_complete(schedule: Schedule) -> None:
    """Assert every rank ends up owning every chunk (bcast completeness)."""
    n = schedule.n
    chunk = 1
    data = [np.full((schedule.num_chunks, chunk), -1.0) for _ in range(n)]
    data[schedule.root] = np.arange(schedule.num_chunks, dtype=np.float64).reshape(
        schedule.num_chunks, chunk
    )
    out = simulate_bcast(schedule, data)
    want = data[schedule.root]
    for r in range(n):
        if not np.array_equal(out[r], want):
            missing = [c for c in range(schedule.num_chunks) if out[r][c, 0] != want[c, 0]]
            raise AssertionError(
                f"{schedule.name}: rank {r} incomplete after schedule; missing chunks {missing}"
            )


def timed_rounds(
    schedule: Schedule, chunk_bytes: int, ts: float, bw: float, faults=None
) -> float:
    """Round-accurate time estimate: each round costs ts + (bytes of the
    largest transfer in the round)/bw; rounds serialize.

    With ``faults``, the clock degrades the way the fabric would: a round's
    bandwidth term is gated by its slowest active link (per-link slowdown
    factors divide bw), transient drops inflate wire traffic by the expected
    retransmit factor 1/(1-p), and stalled rounds add ``stall_s`` each. Dead
    ranks raise ``DeadRankError`` — a dead mesh has no finish time.

    This is the 'simulator clock' the closed-form models in cost_model.py
    approximate; property tests assert they agree on the canonical cases.
    """
    if faults is not None:
        faults.check_alive(schedule)
    retry = faults.retry_factor if faults is not None else 1.0
    stalled = set(faults.stalled_rounds) if faults is not None else ()
    total = 0.0
    for ridx, rnd in enumerate(schedule.rounds):
        if not rnd.transfers:
            continue
        if faults is None:
            biggest = max(t.chunk_count for t in rnd.transfers) * chunk_bytes
        else:
            biggest = max(
                t.chunk_count * chunk_bytes * faults.slowdown(t.src, t.dst)
                for t in rnd.transfers
            )
        total += ts + biggest * retry / bw
        if ridx in stalled:
            total += faults.stall_s
    return total
