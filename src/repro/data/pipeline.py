"""Deterministic, shardable data pipeline.

Sources:
  * SyntheticZipf — endless deterministic token stream (hash-of-step), the
    default for benchmarks/smoke (no files needed, reproducible anywhere);
  * MemmapTokens  — packed token file (one long int32 array), the "real
    corpus" path used by examples (examples/make_corpus.py writes one).

Both produce global ``{"tokens", "labels"}`` batches (labels = next token);
the trainer device_puts them with the mesh's batch sharding. Multimodal
archs get stub frontend embeddings appended (deterministic per step).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

import jax.numpy as jnp

__all__ = ["SyntheticZipf", "MemmapTokens", "batches", "make_source"]


class SyntheticZipf:
    """Zipf-distributed tokens, deterministic in (seed, step)."""

    def __init__(self, vocab: int, seed: int = 0, alpha: float = 1.1):
        self.vocab = vocab
        self.seed = seed
        ranks = np.arange(1, vocab + 1, dtype=np.float64)
        w = ranks ** (-alpha)
        self.cdf = np.cumsum(w / w.sum())

    def batch(self, step: int, batch: int, seq: int) -> np.ndarray:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % (2**31))
        u = rng.rand(batch, seq + 1)
        return np.searchsorted(self.cdf, u).astype(np.int32)


class MemmapTokens:
    """Packed int32 token file; windows are deterministic in step."""

    def __init__(self, path: str, seed: int = 0):
        self.tokens = np.load(path, mmap_mode="r")
        assert self.tokens.ndim == 1
        self.seed = seed

    @property
    def vocab(self) -> int:
        return int(self.tokens.max()) + 1

    def batch(self, step: int, batch: int, seq: int) -> np.ndarray:
        n = len(self.tokens) - (seq + 1)
        rng = np.random.RandomState((self.seed * 9_176_923 + step) % (2**31))
        starts = rng.randint(0, max(n, 1), size=batch)
        return np.stack(
            [np.asarray(self.tokens[s : s + seq + 1], np.int32) for s in starts]
        )


def make_source(cfg, *, path: Optional[str] = None, seed: int = 0):
    if path:
        return MemmapTokens(path, seed)
    return SyntheticZipf(min(cfg.vocab_size, 32768), seed)


def batches(source, cfg, *, batch: int, seq: int, start_step: int = 0) -> Iterator[dict]:
    """Yield global batches. ``seq`` counts text tokens (the frontend prefix
    for VLM archs is supplied separately as stub embeddings)."""
    step = start_step
    while True:
        toks = source.batch(step, batch, seq)
        out = {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }
        if cfg.frontend == "vision":
            rng = np.random.RandomState(step % (2**31))
            out["embeds"] = jnp.asarray(
                rng.randn(batch, cfg.prefix_len, cfg.d_model).astype(np.float32),
                jnp.dtype(cfg.dtype),
            )
        elif cfg.arch_type == "encdec":
            rng = np.random.RandomState(step % (2**31))
            out["embeds"] = jnp.asarray(
                rng.randn(batch, cfg.frontend_len, cfg.d_model).astype(np.float32),
                jnp.dtype(cfg.dtype),
            )
        yield out
        step += 1
