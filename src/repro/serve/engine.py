"""Serving engine: batched prefill + step-synchronous greedy decode.

``serve_step`` (one new token against the KV cache) is the function the
decode-shape dry-runs lower. Weight distribution at engine start uses the
paper's tuned broadcast (weights enter on the root and are pbcast to the
data axis) when a multi-device mesh is present.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import comm
from ..configs.base import ModelConfig
from ..dist import topology
from ..dist.sharding import cache_specs, param_specs
from ..models import Model

__all__ = [
    "Engine",
    "GenerationResult",
    "distribute_weights",
    "distribution_stream_graph",
    "plan_distribution",
]


def _placements(mesh, specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, steps)
    logprobs: np.ndarray        # (B, steps)
    prefill_len: int


class Engine:
    """On a multi-device mesh the engine consumes ``repro.dist`` layouts:
    weights land on ``param_specs(fsdp=False, attn_fallback='head_dim')``
    (TP-only serving layout, head_dim split for non-divisible heads) and
    prefill-built KV caches are placed per ``cache_specs``."""

    def __init__(self, cfg: ModelConfig, params, *, mesh=None, max_len: int = 0,
                 distribute: bool = False, double_buffer: bool = False,
                 drain_dir: Optional[str] = None):
        self.cfg = cfg
        self.model = Model(cfg)
        self.mesh = mesh
        self.max_len = max_len
        self._sharded = mesh is not None and mesh.devices.size > 1
        if self._sharded:
            pspecs = param_specs(
                self.model.param_shapes(), mesh, fsdp=False, attn_fallback="head_dim"
            )
            if distribute:
                # the engine owns the freshly-loaded weights here — donate
                # them so distribution never doubles the resident footprint
                params = distribute_weights(
                    params, mesh, specs=pspecs, double_buffer=double_buffer,
                    donate=True, drain_dir=drain_dir,
                )
            else:
                params = jax.device_put(params, _placements(mesh, pspecs))
        self.params = params
        self._prefill = jax.jit(
            lambda p, b, ml: self.model.prefill(p, b, max_len=ml),
            static_argnums=(2,),
        )
        self._step = jax.jit(self.model.decode_step)

    def _place_caches(self, caches):
        if not self._sharded:
            return caches
        specs = cache_specs(caches, self.mesh, self.cfg)
        return jax.device_put(caches, _placements(self.mesh, specs))

    def generate(
        self,
        batch: dict,
        *,
        steps: int,
        greedy: bool = True,
        temperature: float = 1.0,
        seed: int = 0,
    ) -> GenerationResult:
        cfg = self.cfg
        T = batch["tokens"].shape[1]
        max_len = self.max_len or (T + steps)
        logits, caches = self._prefill(self.params, batch, max_len)
        caches = self._place_caches(caches)
        offset = cfg.prefix_len if cfg.frontend == "vision" else 0
        cur = logits[:, -1]
        toks, lps = [], []
        key = jax.random.PRNGKey(seed)
        for i in range(steps):
            if greedy:
                nxt = jnp.argmax(cur, axis=-1)
            else:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, cur / temperature, axis=-1)
            lp = jax.nn.log_softmax(cur, axis=-1)
            lps.append(np.asarray(jnp.take_along_axis(lp, nxt[:, None], axis=-1)[:, 0]))
            toks.append(np.asarray(nxt))
            logits, caches = self._step(
                self.params,
                nxt[:, None].astype(jnp.int32),
                caches,
                jnp.asarray(T + offset + i, jnp.int32),
            )
            cur = logits[:, 0]
        return GenerationResult(
            tokens=np.stack(toks, axis=1), logprobs=np.stack(lps, axis=1), prefill_len=T
        )


def plan_distribution(params, mesh, *, algo: str = "auto", tuner=None,
                      bucket_bytes: int = 4 << 20, stream: str | None = None):
    """Host-side planning for weight distribution: pack the parameter tree
    into same-dtype buckets and resolve one :class:`~repro.comm.
    CollectivePlan` per (bucket, mesh level) — inter-pod level first, priced
    with the tuner's ``inter_pod`` constants. Returns ``(bucket_spec,
    {axis_name: [plan per bucket]})``; the plans are inspectable (algorithm,
    chunking, predicted time, bytes on wire) before anything is traced.
    ``stream`` keys the plan cache on a stream-graph fingerprint (see
    :func:`distribution_stream_graph`)."""
    from ..core import bucketing

    spec = bucketing.plan_buckets(params, bucket_bytes)
    sizes = topology.axis_sizes(mesh)
    plans = {}
    for ax in topology.bcast_axes(mesh):
        n = sizes[ax]
        # plan_cached: identical (bucket size, axis) points — across buckets
        # AND across engine restarts in one process — share one resolved
        # plan and its pre-lowered round tables
        plans[ax] = [
            comm.plan_cached(
                "bcast", M, n, algo=algo, tuner=tuner,
                inter_pod=topology.is_inter_pod(ax), stream=stream,
            )
            for M in spec.bucket_bytes()
        ]
    return spec, plans


def distribution_stream_graph(params, mesh, *, algo: str = "auto", tuner=None,
                              bucket_bytes: int = 4 << 20,
                              double_buffer: bool = False,
                              overlap_depth: int = 2, drain: bool = False):
    """Weight distribution as a :class:`~repro.comm.StreamGraph`.

    Two prioritized entries on distinct links:

    * ``ckpt_drain`` (present when ``drain``) — the host-side snapshot of
      the pre-distribution weights, priority 2 on the ``host`` link. It
      carries the same bucket mix but no collective plans (one round per
      bucket over the host link in the simulator's accounting).
    * ``distribute`` — the tuned hierarchical broadcast over
      ``topology.bcast_axes(mesh)``, DAG-ordered ``after`` the drain
      (snapshot-before-donate: the drain must hold a valid copy before
      donation can invalidate the buffers), ``overlap_depth`` staging
      buffers deep when ``double_buffer``.

    The graph fingerprint is computed from the raw request BEFORE any plan
    resolves and keys ``plan_cached`` (``stream=``), so distribution plans
    never collide with another graph shape's at the same (op, M, n) point.
    Returns ``(graph, bucket_spec, plans)``."""
    from ..comm import streams as comm_streams
    from ..core import bucketing

    spec = bucketing.plan_buckets(params, bucket_bytes)
    sizes = topology.axis_sizes(mesh)
    axes = list(topology.bcast_axes(mesh))
    depth = max(1, int(overlap_depth)) if double_buffer else 1
    gkey = comm_streams.graph_key({
        "consumer": "serve.distribute_weights",
        "op": "bcast",
        "algo": algo,
        "axes": [[ax, int(sizes[ax])] for ax in axes],
        "buckets": list(spec.bucket_bytes()),
        "depth": depth,
        "drain": bool(drain),
    })
    bucket_spec, plans = plan_distribution(
        params, mesh, algo=algo, tuner=tuner, bucket_bytes=bucket_bytes,
        stream=gkey,
    )
    order = tuple(range(bucket_spec.num_buckets))  # load order, not reversed
    entries = []
    after: tuple[str, ...] = ()
    if drain:
        entries.append(comm_streams.StreamEntry(
            name="ckpt_drain", op="drain", spec=bucket_spec, axes=(),
            plans={}, order=order, overlap_depth=1, compute_s=0.0,
            depth_source="manual", priority=2, after=(), link="host",
        ))
        after = ("ckpt_drain",)
    entries.append(comm_streams.StreamEntry(
        name="distribute", op="bcast", spec=bucket_spec, axes=tuple(plans),
        plans={ax: tuple(ax_plans) for ax, ax_plans in plans.items()},
        order=order, overlap_depth=depth, compute_s=0.0,
        depth_source="manual", priority=1, after=after, link="ici",
    ))
    graph = comm_streams.StreamGraph(tuple(entries), key=gkey)
    return graph, bucket_spec, plans


def distribute_weights(params, mesh, *, algo: str = "auto", tuner=None, specs=None,
                       bucket_bytes: int = 4 << 20, return_plans: bool = False,
                       double_buffer: bool = False, overlap_depth: int = 2,
                       stage_chunk: int = 64 * 1024, donate: bool = False,
                       compiled: bool | None = None,
                       drain_dir: Optional[str] = None):
    """Broadcast freshly-loaded weights across the data axes with the tuned
    library (the paper's 'training parameters exchange' applied at load).

    The collective sequence is fully planned host-side
    (:func:`plan_distribution`) and the shard_map program replays those
    plans verbatim via ``comm.apply_plan`` — hierarchically per
    ``dist.topology.bcast_axes(mesh)``, inter-pod level first when a pod
    axis exists. When ``specs`` (a ``param_specs`` tree) is given, the
    replicated result is then laid out per those specs, so the weights land
    exactly where the serving/training layout declares. ``return_plans=True``
    additionally returns the executed plan table.

    Execution rides the multi-stream layer: distribution is the
    ``distribute`` entry of :func:`distribution_stream_graph` (with a
    ``ckpt_drain`` entry DAG-ordered before it when ``drain_dir`` is set —
    program order realizes the edge: the snapshot is fetched before the
    broadcast program runs). ``double_buffer=True`` widens the entry's
    staging window: bucket k+1 is staged through the ``chunked_copy``
    Pallas pipeline (Sec. IV-C) while bucket k's broadcast is in flight —
    ``overlap_depth`` staging buffers deep, buckets in load order.
    Per-bucket collectives are the SAME plans either way, so the
    distributed weights are identical.

    ``donate=True`` donates the incoming weight buffers to the broadcast
    program (``jax.jit(..., donate_argnums)``): combined with the compiled
    executor's in-place loop carry, distribution then never holds two full
    copies of a bucket in device memory. The caller's ``params`` are
    invalidated — pass it when the engine owns the freshly-loaded weights
    (the ``Engine(distribute=True)`` path does). ``compiled`` routes the
    per-bucket replay (None = tuned policy, see ``comm.api.apply_plan``).

    ``drain_dir``: graceful degradation on unrecoverable failure. If the
    distribution program itself raises (mesh lost a device mid-broadcast,
    compile failure, OOM), the pre-distribution weights are drained to an
    atomic checkpoint under ``drain_dir`` and a typed
    :class:`~repro.comm.WeightSyncError` is raised chaining the cause —
    never a silent partial distribution. The drain fetches the host copy
    before donation hands the buffers to the program, so the snapshot is
    valid even when ``donate=True`` invalidated the device buffers."""
    graph, bucket_spec, plans = distribution_stream_graph(
        params, mesh, algo=algo, tuner=tuner, bucket_bytes=bucket_bytes,
        double_buffer=double_buffer, overlap_depth=overlap_depth,
        drain=drain_dir is not None,
    )
    dist_entry = graph.entry("distribute")

    def run(p):
        return comm.execute_stream_entry(
            dist_entry, p, stage=double_buffer, stage_chunk=stage_chunk,
            compiled=compiled,
        )

    f = jax.shard_map(
        run,
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), params),),
        out_specs=jax.tree.map(lambda _: P(), params),
        check_vma=False,
    )
    snapshot = None
    if drain_dir is not None:
        # host copy taken before donation can invalidate the device buffers;
        # host RAM is the cheap side of the serving node, device HBM is not
        snapshot = jax.tree.map(lambda a: np.asarray(jax.device_get(a)), params)
    try:
        out = jax.jit(f, donate_argnums=(0,) if donate else ())(params)
        if specs is not None:
            out = jax.device_put(out, _placements(mesh, specs))
    except Exception as e:  # noqa: BLE001 — rewrapped as a typed, actionable error
        if snapshot is None:
            raise
        from ..comm.faults import WeightSyncError
        from ..train import checkpoint as ckpt_lib

        try:
            fname = ckpt_lib.save_checkpoint(drain_dir, 0, snapshot)
        except Exception as drain_err:  # pragma: no cover - disk-full etc.
            raise WeightSyncError(
                f"weight distribution failed ({type(e).__name__}: {e}) AND the "
                f"drain to {drain_dir!r} also failed "
                f"({type(drain_err).__name__}: {drain_err}); weights may be lost"
            ) from e
        raise WeightSyncError(
            f"weight distribution failed ({type(e).__name__}: {e}); "
            f"pre-distribution weights drained to {fname} — restore from the "
            f"checkpoint and replan on a healthy mesh"
        ) from e
    return (out, plans) if return_plans else out
