"""Serving engine: batched prefill + step-synchronous greedy decode.

``serve_step`` (one new token against the KV cache) is the function the
decode-shape dry-runs lower. Weight distribution at engine start uses the
paper's tuned broadcast (weights enter on the root and are pbcast to the
data axis) when a multi-device mesh is present.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..models import Model

__all__ = ["Engine", "GenerationResult"]


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, steps)
    logprobs: np.ndarray        # (B, steps)
    prefill_len: int


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, mesh=None, max_len: int = 0):
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = params
        self.mesh = mesh
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b, ml: self.model.prefill(p, b, max_len=ml),
            static_argnums=(2,),
        )
        self._step = jax.jit(self.model.decode_step)

    def generate(
        self,
        batch: dict,
        *,
        steps: int,
        greedy: bool = True,
        temperature: float = 1.0,
        seed: int = 0,
    ) -> GenerationResult:
        cfg = self.cfg
        T = batch["tokens"].shape[1]
        max_len = self.max_len or (T + steps)
        logits, caches = self._prefill(self.params, batch, max_len)
        offset = cfg.prefix_len if cfg.frontend == "vision" else 0
        cur = logits[:, -1]
        toks, lps = [], []
        key = jax.random.PRNGKey(seed)
        for i in range(steps):
            if greedy:
                nxt = jnp.argmax(cur, axis=-1)
            else:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, cur / temperature, axis=-1)
            lp = jax.nn.log_softmax(cur, axis=-1)
            lps.append(np.asarray(jnp.take_along_axis(lp, nxt[:, None], axis=-1)[:, 0]))
            toks.append(np.asarray(nxt))
            logits, caches = self._step(
                self.params,
                nxt[:, None].astype(jnp.int32),
                caches,
                jnp.asarray(T + offset + i, jnp.int32),
            )
            cur = logits[:, 0]
        return GenerationResult(
            tokens=np.stack(toks, axis=1), logprobs=np.stack(lps, axis=1), prefill_len=T
        )


def distribute_weights(params, mesh, *, algo: str = "auto"):
    """Broadcast freshly-loaded weights across the data axis with the tuned
    library (the paper's 'training parameters exchange' applied at load)."""
    from ..core.bcast import pbcast_tree

    def run(p):
        return pbcast_tree(p, "data", algo=algo)

    f = jax.shard_map(
        run,
        mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), params),),
        out_specs=jax.tree.map(lambda _: P(), params),
        check_vma=False,
    )
    return jax.jit(f)(params)
