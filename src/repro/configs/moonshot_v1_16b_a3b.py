"""Moonlight-16B-A3B (moonshot) — DeepSeek-style MoE: 64 experts top-6 + 2
shared experts [hf:moonshotai/Moonlight-16B-A3B]. Listed as [dense] in the
assignment header but its config line specifies MoE 64e top-6; built as MoE
(DESIGN.md Sec. 6)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=163840,
    num_experts=64,
    experts_per_token=6,
    num_shared_experts=2,
    block_pattern=("moe",),
    source="hf:moonshotai/Moonlight-16B-A3B; 64e top-6 + 2 shared",
)
