"""Hymba-1.5B — parallel attention + mamba heads per layer
[arXiv:2411.13676]. Adaptation (DESIGN.md Sec. 6): all attention heads use
SWA-1024 (the paper's few global layers + meta tokens are dropped), keeping
every layer sub-quadratic so long_500k decode runs."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    block_pattern=("hybrid",),
    attn_pattern=(1024,),
    source="arXiv:2411.13676 (Hymba); parallel attn+SSM heads, ssm_state=16",
)
