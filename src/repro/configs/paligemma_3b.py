"""PaliGemma-3B — SigLIP vision encoder (STUB) + Gemma-2B decoder
[arXiv:2407.07726]. The vision tower is a stub: input_specs() supplies 256
patch embeddings; the decoder uses a bidirectional prefix-LM mask over them.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16384,
    vocab_size=257216,
    head_dim=256,
    frontend="vision",
    frontend_len=256,
    prefix_len=256,
    act="geglu",
    source="arXiv:2407.07726 (PaliGemma); gemma-2B decoder, MQA, 256 patches",
)
