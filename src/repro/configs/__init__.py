"""Config registry: --arch <id> resolution for every assigned architecture."""
from .base import INPUT_SHAPES, ModelConfig, RunConfig, ShapeSpec

from . import (
    gemma3_27b,
    hymba_1p5b,
    minitron_8b,
    mixtral_8x7b,
    moonshot_v1_16b_a3b,
    paligemma_3b,
    qwen15_32b,
    qwen3_moe_30b_a3b,
    whisper_large_v3,
    xlstm_350m,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        xlstm_350m,
        qwen3_moe_30b_a3b,
        minitron_8b,
        paligemma_3b,
        mixtral_8x7b,
        gemma3_27b,
        hymba_1p5b,
        whisper_large_v3,
        qwen15_32b,
        moonshot_v1_16b_a3b,
    )
}


def get_config(name: str) -> ModelConfig:
    """Resolve '--arch <id>'; '<id>-smoke' gives the reduced CPU variant."""
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ARCHS",
    "get_config",
    "INPUT_SHAPES",
    "ModelConfig",
    "RunConfig",
    "ShapeSpec",
]
