"""Gemma-3-27B — 5:1 local(1024):global attention, 128k context
[hf:google/gemma-3-1b-pt family card]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    head_dim=128,
    attn_pattern=(1024, 1024, 1024, 1024, 1024, None),  # 5 local : 1 global
    act="geglu",
    rope_theta=1e6,
    source="hf:google/gemma-3 family; 5:1 local:global, window 1024",
)
