"""Whisper-large-v3 — encoder-decoder ASR backbone [arXiv:2212.04356].
The mel-spectrogram + conv frontend is a STUB: input_specs() supplies 1500
frame embeddings directly to the encoder."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,            # decoder layers
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    arch_type="encdec",
    encoder_layers=32,
    frontend="audio",
    frontend_len=1500,
    qkv_bias=True,
    act="gelu",
    source="arXiv:2212.04356 (Whisper); enc-dec, conv frontend stubbed",
)
