"""xLSTM-350M — sLSTM + mLSTM blocks in a 7:1 ratio [arXiv:2405.04517]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    head_dim=256,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    ssm_expand=2,
    ssm_chunk=128,
    source="arXiv:2405.04517 (xLSTM); 7:1 mLSTM:sLSTM block ratio",
)
