"""Config system: model architecture + input shapes + run settings."""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeSpec", "INPUT_SHAPES", "RunConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description. One instance per assigned architecture
    (see src/repro/configs/<id>.py); ``reduced()`` derives the CPU smoke
    variant of the same family."""

    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 512       # GShard dispatch group length
    router_aux_coef: float = 0.01
    # expert-dispatch transport: 'einsum' (dense one-hot; GSPMD infers the
    # all-to-all) or 'alltoallv' (explicit repro.comm.palltoallv expert
    # parallelism — needs an axis_name threaded to moe_ffn)
    moe_dispatch: str = "einsum"

    # --- attention ---
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    # per-layer window pattern, repeated over depth; None entry = global attn.
    # e.g. gemma3: (1024, 1024, 1024, 1024, 1024, None)  -> 5 local : 1 global
    attn_pattern: Tuple[Optional[int], ...] = (None,)

    # --- block pattern (ssm / hybrid); entries: 'attn'|'moe'|'mlstm'|'slstm'|'hybrid'
    block_pattern: Optional[Tuple[str, ...]] = None
    ssm_state: int = 0              # mamba state dim N
    ssm_expand: int = 2             # mamba/mlstm inner expansion
    ssm_conv: int = 4               # mamba short-conv width
    ssm_chunk: int = 128            # chunkwise-scan chunk length

    # --- structure ---
    arch_type: str = "decoder"      # decoder | encdec
    encoder_layers: int = 0
    frontend: Optional[str] = None  # 'audio' | 'vision' (STUB embeddings)
    frontend_len: int = 0           # frames / patches supplied by the stub
    prefix_len: int = 0             # bidirectional prefix (VLM prefix-LM)
    tie_embeddings: bool = True
    act: str = "silu"               # mlp nonlinearity: silu (swiglu) | gelu
    norm_eps: float = 1e-6
    vocab_pad_to: int = 256
    dtype: str = "bfloat16"
    # decode KV-cache dtype. Production override for archs whose MHA cache
    # would exceed HBM at the assigned decode shapes (qwen1.5-32b @ 32k x 128
    # needs float8_e5m2 to fit a single v5e pod — see EXPERIMENTS.md Dry-run).
    kv_cache_dtype: str = "bfloat16"

    # --- citation ---
    source: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.block_pattern is None:
            kind = "moe" if self.num_experts else "attn"
            object.__setattr__(self, "block_pattern", (kind,))
        if self.num_heads % self.num_kv_heads:
            raise ValueError("num_heads must be divisible by num_kv_heads")

    # ---- derived ----

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return ((self.vocab_size + p - 1) // p) * p

    @property
    def pattern_period(self) -> int:
        return max(len(self.block_pattern), len(self.attn_pattern))

    def layer_kinds(self) -> list[str]:
        bp = self.block_pattern
        return [bp[i % len(bp)] for i in range(self.num_layers)]

    def layer_windows(self) -> list[Optional[int]]:
        ap = self.attn_pattern
        return [ap[i % len(ap)] for i in range(self.num_layers)]

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode: layers are SSM / windowed
        attention, allowing a MINORITY of global layers (gemma3's 5:1
        local:global long-context design — decode against a global cache is
        linear per token; the windowed majority bounds the cache growth)."""
        kinds = self.layer_kinds()
        wins = self.layer_windows()
        n_global = 0
        n_attn = 0
        for k, w in zip(kinds, wins):
            if k in ("mlstm", "slstm"):
                continue
            n_attn += 1
            if w is None:
                n_global += 1
        if n_attn == 0:
            return True
        if n_global == 0:
            return True
        return n_global / n_attn <= 0.34 and len(self.attn_pattern) > 1

    # ---- parameter counting (for 6*N*D model-FLOPs accounting) ----

    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.head_dim
        H, KV = self.num_heads, self.num_kv_heads
        total = self.padded_vocab * d  # embed
        if not self.tie_embeddings:
            total += self.padded_vocab * d
        kinds = self.layer_kinds()

        def attn_params():
            p = d * H * hd + 2 * d * KV * hd + H * hd * d
            if self.qkv_bias:
                p += H * hd + 2 * KV * hd
            return p

        def mlp_params(f):
            return 3 * d * f if self.act in ("silu", "geglu") else 2 * d * f

        def ssm_params():
            di = self.ssm_expand * d
            if self.ssm_state:  # mamba
                return d * di * 2 + di * self.ssm_conv + di * (2 * self.ssm_state + 2) + di * d
            # mlstm: q,k,v,o over inner dim + gates
            return d * di * 4 + 2 * d * H + di * d

        for i, kind in enumerate(kinds):
            if kind == "attn":
                total += attn_params() + mlp_params(self.d_ff)
            elif kind == "moe":
                e = self.experts_per_token if active_only else self.num_experts
                total += attn_params() + (e + self.num_shared_experts) * mlp_params(self.d_ff)
                total += d * self.num_experts  # router
            elif kind == "mlstm":
                total += ssm_params()
            elif kind == "slstm":
                total += 4 * d * d + 4 * d * H  # i,f,z,o projections + gates
            elif kind == "hybrid":
                total += attn_params() + ssm_params() + mlp_params(self.d_ff)
            total += 2 * d  # norms
        if self.arch_type == "encdec":
            # encoder layers + decoder cross-attention
            enc = self.encoder_layers * (attn_params() + mlp_params(self.d_ff) + 2 * d)
            cross = self.num_layers * (attn_params() + d)
            total += enc + cross
        return int(total)

    def reduced(self) -> "ModelConfig":
        """CPU smoke variant of the same family: 2 pattern periods of layers,
        d_model <= 512, <= 4 experts."""
        period = self.pattern_period
        n_layers = min(self.num_layers, 2 * period)
        d = min(self.d_model, 256)
        hd = 32
        kv = min(self.num_kv_heads, 2)
        heads = max(kv, min(self.num_heads, 4))
        heads = (heads // kv) * kv
        enc = min(self.encoder_layers, 2)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=n_layers,
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else self.d_ff,
            vocab_size=min(self.vocab_size, 1024),
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            num_shared_experts=min(self.num_shared_experts, 1),
            moe_group_size=64,
            encoder_layers=enc,
            frontend_len=min(self.frontend_len, 16) if self.frontend_len else 0,
            prefix_len=min(self.prefix_len, 16) if self.prefix_len else 0,
            attn_pattern=tuple(
                (min(w, 64) if w is not None else None) for w in self.attn_pattern
            ),
            ssm_chunk=16,
            vocab_pad_to=64,
        )


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    mode: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Training/serving run settings."""

    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    optimizer: str = "adamw"
    # data-parallel sync mode: 'grad_allreduce' (modern baseline, GSPMD
    # inserts the collective), 'param_bcast' (the paper's CA-CNTK pattern:
    # reduce-to-root + tuned bcast through core.bcast), 'tuned_allreduce'
    # (the repro.comm plan layer: bucketed, hierarchical, per-op tuned
    # allreduce — reduce_then_bcast/fused_rsb/ring windows), or
    # 'overlap_allreduce' (same plans, bucket-streamed through the overlap
    # engine: backward-order dispatch inside a tuned in-flight window —
    # identical params up to float summation order)
    sync_mode: str = "grad_allreduce"
    bcast_algo: str = "auto"
    # allreduce algorithm for sync_mode='tuned_allreduce'/'overlap_allreduce':
    # 'auto' consults the per-op tuner; or pin 'reduce_then_bcast' |
    # 'fused_rsb' | 'ring_allreduce' | 'xla_psum'
    allreduce_algo: str = "auto"
    # path to a calibrated empirical table (Tuner.save format; a REAL-device
    # run of benchmarks/bench_allreduce.py writes a loadable
    # experiments/allreduce_table.json). None = analytic decisions. Applies
    # to all explicit sync modes. NOTE: the committed copy of that artifact
    # is regenerated by CI in --dryrun mode and branded as such — Tuner.load
    # refuses dryrun tables, so point this at a table from a device run.
    tuner_table: Optional[str] = None
    # collective executor for the repro.comm sync modes: True pins the
    # compiled fori_loop replay (O(1)-HLO schedule executor), False the
    # exact unrolled replay, None (default) the tuned round-count policy
    # (Decision.fused_path / comm.api.apply_plan)
    compiled_collectives: Optional[bool] = None
    # in-flight bucket window for sync_mode='overlap_allreduce': None tunes
    # it (tuner table overlap_depth, else cost_model.optimal_overlap_depth)
    overlap_depth: Optional[int] = None
    # backward-pass seconds the overlap engine may hide collectives behind
    # (0.0 = depth tuning assumes staging-bound, still streams buckets)
    overlap_compute_s: float = 0.0
    # second comm stream for sync_mode='overlap_allreduce': broadcast the
    # UPDATED params right after optimizer.update as a lower-priority
    # 'weight_prefetch' stream entry DAG-ordered after 'grad_sync'
    # (comm.streams link scheduler). Params are replicated, so the bcast
    # is value-identical — it pre-stages next step's weights on the wire
    # schedule without changing any result bit.
    prefetch_stream: bool = False
    # wire format for sync_mode='compressed_allreduce' ('bf16'|'fp8'|'int8'):
    # gradients cross every hop quantized to 1 byte/element + per-256-block
    # f32 scales; the error-feedback residual (carried in opt_state['ef'])
    # re-injects each step's quantization error into the next step's
    # gradient, so the compressed run tracks the full-precision trajectory.
    # 'bf16' is the full-precision passthrough (bit-identical to
    # tuned_allreduce).
    wire_format: str = "bf16"
    bcast_bucket_bytes: int = 4 << 20
    num_microbatches: int = 1
    remat: bool = True
    seed: int = 0
