"""Mixtral-8x7B — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    num_experts=8,
    experts_per_token=2,
    block_pattern=("moe",),
    attn_pattern=(4096,),  # sliding window (Mistral-style)
    rope_theta=1e6,
    source="arXiv:2401.04088 (Mixtral); 8 experts top-2, SWA 4096",
)
