"""Qwen3-30B-A3B — 128-expert top-8 MoE [hf:Qwen/Qwen3-30B-A3B]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=768,
    vocab_size=151936,
    head_dim=128,  # explicit head_dim per model card (not d_model/H)
    num_experts=128,
    experts_per_token=8,
    block_pattern=("moe",),
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B; 128 experts, top-8, moe_ff=768, head_dim=128",
)
