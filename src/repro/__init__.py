"""repro — optimized-broadcast reproduction grown into a jax serving/training
system. Importing any subpackage activates the jax API compatibility gate."""
from . import _jax_compat  # noqa: F401  (side effects: newer-jax names on 0.4.x)
