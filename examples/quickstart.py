"""Quickstart: train a reduced assigned architecture with the paper's
bcast-based data-parallel sync, then greedy-decode from it.

    PYTHONPATH=src python examples/quickstart.py [--devices 4]

`--devices` simulates N host devices (set before jax import) so the paper's
collectives actually run; 1 also works (collectives no-op).
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=4)
ap.add_argument("--arch", default="minitron-8b-smoke")
ap.add_argument("--steps", type=int, default=20)
args = ap.parse_args()
os.environ.setdefault("XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.launch.mesh import make_local_mesh
from repro.serve.engine import Engine
from repro.train.trainer import Trainer

cfg = get_config(args.arch)
run = RunConfig(
    total_steps=args.steps,
    warmup_steps=max(args.steps // 10, 1),
    sync_mode="param_bcast",      # the paper's reduce-to-root + tuned bcast
    bcast_algo="auto",            # tuning framework picks per bucket size
    learning_rate=1e-3,
)
trainer = Trainer(cfg, run, mesh=make_local_mesh(1))
params, _, hist = trainer.train(batch=8, seq=64, steps=args.steps, log_every=5)
print(f"\nloss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

engine = Engine(cfg, params)
prompt = jnp.asarray(np.random.RandomState(0).randint(0, 500, (2, 8)))
result = engine.generate({"tokens": prompt}, steps=8)
print("generated tokens:\n", result.tokens)
