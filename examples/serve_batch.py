"""Batched serving: prefill a batch of prompts, stream greedy tokens.

    PYTHONPATH=src python examples/serve_batch.py --arch xlstm-350m-smoke
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="xlstm-350m-smoke")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=16)
ap.add_argument("--steps", type=int, default=16)
args = ap.parse_args()
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serve.engine import Engine

cfg = get_config(args.arch)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
engine = Engine(cfg, params, max_len=args.prompt_len + args.steps)

rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size - 1, (args.batch, args.prompt_len)))}
if cfg.frontend == "vision":
    batch["embeds"] = jnp.asarray(rng.randn(args.batch, cfg.prefix_len, cfg.d_model), jnp.bfloat16)
if cfg.arch_type == "encdec":
    batch["embeds"] = jnp.asarray(rng.randn(args.batch, cfg.frontend_len, cfg.d_model), jnp.bfloat16)

res = engine.generate(batch, steps=args.steps)
print(f"arch={cfg.name}  batch={args.batch}  prefill={args.prompt_len}  decode={args.steps}")
for b in range(args.batch):
    print(f"req{b}: tokens {res.tokens[b].tolist()}  mean-lp {res.logprobs[b].mean():.3f}")
