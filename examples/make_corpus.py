"""Write a packed int32 token corpus for MemmapTokens (examples/train_100m)."""
import argparse
import os
import sys

import numpy as np

ap = argparse.ArgumentParser()
ap.add_argument("--out", default="experiments/corpus.npy")
ap.add_argument("--tokens", type=int, default=2_000_000)
ap.add_argument("--vocab", type=int, default=32768)
ap.add_argument("--seed", type=int, default=0)
args = ap.parse_args()

# Zipf unigram + a simple first-order structure so the loss has signal
rng = np.random.RandomState(args.seed)
ranks = np.arange(1, args.vocab + 1)
p = ranks ** -1.1
p /= p.sum()
base = rng.choice(args.vocab, size=args.tokens, p=p).astype(np.int32)
# bigram structure: with prob .5 next token = f(prev)
mix = rng.rand(args.tokens) < 0.5
shifted = (np.roll(base, 1) * 31 + 17) % args.vocab
toks = np.where(mix, shifted, base).astype(np.int32)
os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
np.save(args.out, toks)
print(f"wrote {args.tokens} tokens (vocab {args.vocab}) to {args.out}")
