"""End-to-end driver: train a ~100M-parameter dense model for a few hundred
steps on a real (synthetic-corpus) data pipeline with the paper's
param-bcast sync, checkpointing every 50 steps.

    PYTHONPATH=src python examples/make_corpus.py
    PYTHONPATH=src python examples/train_100m.py --steps 300 [--devices 4]

On the CPU container this takes a while (use --steps 30 for a quick look);
the same script drives a real cluster by replacing the mesh.
"""
import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=2)
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--data", default="experiments/corpus.npy")
ap.add_argument("--ckpt", default="experiments/ckpt_100m")
args = ap.parse_args()
os.environ.setdefault("XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.configs.base import ModelConfig, RunConfig
from repro.launch.mesh import make_local_mesh
from repro.train.trainer import Trainer

# ~100M params: 12 layers x d768 (GPT-2-small class), swiglu, GQA 12/4
CFG_100M = ModelConfig(
    name="repro-100m",
    family="dense",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=4,
    d_ff=2048,
    vocab_size=32768,
    source="in-repo 100M driver config",
)
print(f"params ~{CFG_100M.param_count()/1e6:.1f}M")

run = RunConfig(
    learning_rate=6e-4,
    warmup_steps=30,
    total_steps=args.steps,
    sync_mode="param_bcast",
    bcast_algo="auto",
    num_microbatches=1,
)
data = args.data if os.path.exists(args.data) else None
if data is None:
    print("corpus not found; falling back to the synthetic zipf stream")
trainer = Trainer(CFG_100M, run, mesh=make_local_mesh(1), data_path=data, ckpt_dir=args.ckpt)
trainer.train(batch=args.batch, seq=args.seq, steps=args.steps, log_every=10, ckpt_every=50)
