"""Broadcast algorithm playground: run every algorithm of the library over
N simulated devices, verify they agree, and print measured vs modelled cost.

    PYTHONPATH=src python examples/bcast_microbench.py --devices 8 --mb 4
"""
import argparse
import os
import sys
import time

ap = argparse.ArgumentParser()
ap.add_argument("--devices", type=int, default=8)
ap.add_argument("--mb", type=float, default=4.0, help="message size in MiB")
args = ap.parse_args()
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={args.devices}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Tuner, bcast_stacked, cost_model

n = args.devices
M = int(args.mb * 2**20)
mesh = jax.make_mesh((n,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
xs = jnp.asarray(np.random.RandomState(0).randn(n, M // 4).astype(np.float32))
tuner = Tuner()
dec = tuner.select(M, n)
print(f"message {M/2**20:.1f} MiB over {n} ranks; tuner picks: {dec.algo} "
      f"(chunks={dec.num_chunks}, predicted {dec.predicted_s*1e6:.1f} us on TPU v5e)\n")

ref = None
for algo in ["direct", "chain", "binomial", "knomial", "scatter_allgather",
             "pipelined_chain", "xla_psum", "xla_allgather"]:
    if algo == "scatter_allgather" and (n & (n - 1)):
        continue
    out = bcast_stacked(xs, mesh, "data", root=0, algo=algo)
    out.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        bcast_stacked(xs, mesh, "data", root=0, algo=algo).block_until_ready()
    dt = (time.perf_counter() - t0) / 3
    arr = np.asarray(out)
    if ref is None:
        ref = arr
    assert np.array_equal(arr, ref), algo
    model_us = (cost_model.cost(algo, M, n) * 1e6 if algo in cost_model.ALGO_COSTS else float("nan"))
    print(f"{algo:18s} measured {dt*1e3:9.2f} ms   TPU-model {model_us:9.1f} us")
print("\nall algorithms produced identical results")
